//! End-to-end validation driver (DESIGN.md §8, EXPERIMENTS.md §E2E):
//! load the real AOT-compiled two-stage img-to-text proxy model
//! (VGG-ish feature extractor → LSTM caption head, ~19M parameters of
//! real matmul/scan compute per query batch), serve a Poisson stream of
//! batched requests through the Camelot coordinator with Python nowhere
//! on the path, and report throughput + latency percentiles.
//!
//! Run with: `cargo run --release --example serve_pipeline [rate_qps]`
//! (requires `make artifacts`)

use std::sync::Arc;
use std::time::{Duration, Instant};

use camelot::coordinator::{Coordinator, CoordinatorConfig, PjrtBackend};
use camelot::suite::workload::PoissonArrivals;

const STAGES: [&str; 2] = ["vgg_features", "lstm_caption"];
const D_IN: usize = 512;
const BATCH: usize = 8;
const QUERIES: usize = 400;

fn main() -> anyhow::Result<()> {
    let rate: f64 = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(60.0);
    let stages: Vec<String> = STAGES.iter().map(|s| s.to_string()).collect();

    eprintln!("compiling artifacts (PJRT CPU)...");
    let t0 = Instant::now();
    let backend = Arc::new(PjrtBackend::new("artifacts", &stages, BATCH)?);
    eprintln!("  compile+load took {:.2} s", t0.elapsed().as_secs_f64());

    let coordinator = Coordinator::launch(
        CoordinatorConfig {
            stages,
            instances: vec![2, 2], // two workers per stage
            batch: BATCH,
            max_wait: Duration::from_millis(15),
        },
        backend,
    );

    eprintln!("serving {QUERIES} queries at {rate} qps (open-loop Poisson)...");
    let mut arrivals =
        PoissonArrivals::new(rate, 42).times_until(QUERIES as f64 / rate * 4.0 + 5.0);
    arrivals.truncate(QUERIES);
    let t0 = Instant::now();
    let (mut sent, mut received) = (0usize, 0usize);
    while received < arrivals.len() {
        while sent < arrivals.len() && t0.elapsed().as_secs_f64() >= arrivals[sent] {
            // a "query": one 512-feature activation row (the image
            // embedding the upstream frontend would upload)
            let payload: Vec<f32> = (0..D_IN).map(|i| ((i * 37) % 101) as f32 * 0.01).collect();
            coordinator.submit(payload);
            sent += 1;
        }
        while let Some(comp) = coordinator.recv_timeout(Duration::from_millis(1)) {
            assert_eq!(comp.output.len(), 512, "caption head output width");
            assert!(comp.output.iter().all(|x| x.is_finite()));
            received += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let hist = coordinator.histogram();

    println!("== serve_pipeline report ==");
    println!("  pipeline   : img-to-text proxy (vgg_features -> lstm_caption)");
    println!("  batch      : {BATCH}, instances per stage: 2");
    println!("  offered    : {rate:.0} qps, {QUERIES} queries");
    println!("  wall time  : {wall:.2} s");
    println!("  completed  : {}", hist.count());
    println!("  throughput : {:.1} qps", hist.count() as f64 / wall);
    println!("  p50 latency: {:.1} ms", hist.p50() * 1e3);
    println!("  p95 latency: {:.1} ms", hist.p95() * 1e3);
    println!("  p99 latency: {:.1} ms", hist.p99() * 1e3);
    println!("  max latency: {:.1} ms", hist.max() * 1e3);
    assert_eq!(hist.count() as usize, QUERIES, "all queries must complete");
    coordinator.shutdown();
    println!("serve_pipeline OK");
    Ok(())
}
