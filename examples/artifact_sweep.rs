//! Build a custom artifact pipeline (compute + memory + PCIe intensity
//! levels from the command line), plan it with Camelot and the
//! baselines, and measure the supported peak load of each on the
//! simulator — the §VIII-E "generalizing to complex microservices"
//! workflow as a user-facing tool.
//!
//! Run with: `cargo run --release --example artifact_sweep [p c m]`
//! where p/c/m are intensity levels 1..=3 (default 2 2 2).

use camelot::baselines::Planner;
use camelot::config::ClusterSpec;
use camelot::figures::common::{planner_peak, sweep_opts, train_predictors};
use camelot::suite::artifact;
use camelot::util::{fnum, Table};

fn main() {
    let args: Vec<u32> = std::env::args()
        .skip(1)
        .filter_map(|a| a.parse().ok())
        .collect();
    let (p, c, m) = match args.as_slice() {
        [a, b, z] => (*a, *b, *z),
        _ => (2, 2, 2),
    };
    assert!(
        (1..=3).contains(&p) && (1..=3).contains(&c) && (1..=3).contains(&m),
        "levels must be 1..=3"
    );
    let pipeline = artifact::pipeline(p, c, m);
    let cluster = ClusterSpec::two_2080ti();
    eprintln!("benchmark {}: training predictors...", pipeline.name);
    let predictors = train_predictors(&pipeline, &cluster);

    let mut table = Table::new(
        &format!("Peak load of {} on 2x {}", pipeline.name, cluster.gpu.name),
        &["planner", "peak_qps", "p99_ms", "instances", "gpus_used"],
    );
    let opts = sweep_opts();
    for planner in [Planner::EvenAllocation, Planner::Laius, Planner::Camelot] {
        match planner_peak(planner, &pipeline, &cluster, &predictors, 32, &opts) {
            Some((d, peak, report)) => table.push(&[
                planner.name().to_string(),
                fnum(peak),
                format!("{:.1}", report.p99() * 1e3),
                format!("{:?}", d.instances_per_stage(pipeline.n_stages())),
                d.gpus_used().to_string(),
            ]),
            None => table.push(&[
                planner.name().to_string(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]),
        }
    }
    println!("{}", table.render());
}
