//! Capacity planning for all four real benchmarks: run both Camelot
//! policies (Case 1 max-peak-load, Case 2 min-resource at 30% load) and
//! print the plans a datacenter operator would deploy.
//!
//! Run with: `cargo run --release --example capacity_planning`

use std::time::Instant;

use camelot::allocator::{max_load, min_resource, AllocContext, SaParams};
use camelot::config::ClusterSpec;
use camelot::figures::common::train_predictors;
use camelot::suite::real;
use camelot::util::Table;

fn main() {
    let cluster = ClusterSpec::two_2080ti();
    let batch = 32;
    let mut table = Table::new(
        &format!("Capacity plans on 2x {} (batch {batch})", cluster.gpu.name),
        &[
            "benchmark", "peak_qps", "peak_instances", "peak_quotas",
            "low_load_qps", "low_gpus", "low_usage", "solve_ms",
        ],
    );

    for pipeline in real::all() {
        eprintln!("planning {}...", pipeline.name);
        let predictors = train_predictors(&pipeline, &cluster);
        let ctx = AllocContext::new(&pipeline, &cluster, &predictors, batch);

        let t0 = Instant::now();
        let peak = max_load::solve(&ctx, SaParams::default()).expect("case-1 feasible");
        let low_target = peak.best_objective * 0.3;
        let (low, gpus) =
            min_resource::solve(&ctx, low_target, SaParams::default()).expect("case-2 feasible");
        let solve_ms = t0.elapsed().as_secs_f64() * 1e3;

        table.push(&[
            pipeline.name.clone(),
            format!("{:.0}", peak.best_objective),
            format!("{:?}", peak.best.instances),
            format!(
                "{:?}",
                peak.best
                    .quotas
                    .iter()
                    .map(|q| format!("{:.0}%", q * 100.0))
                    .collect::<Vec<_>>()
            ),
            format!("{low_target:.0}"),
            gpus.to_string(),
            format!("{:.2}", low.best.total_quota()),
            format!("{solve_ms:.0}"),
        ]);
    }
    println!("{}", table.render());
    println!(
        "peak_* from Case 1 (Eq. 1); low_* from Case 2 (Eq. 2/3) at 30% of peak\n\
         low_usage is Σ N·p in GPU-equivalents — compare against {} GPUs deployed",
        cluster.num_gpus
    );
}
