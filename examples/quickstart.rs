//! Quickstart: the smallest end-to-end tour of the Camelot public API.
//!
//! 1. Load one AOT artifact through the PJRT runtime and run a batch
//!    (the L1/L2 compute path, Python-free).
//! 2. Train a performance predictor and plan an allocation through the
//!    unified planner API (Case-1 max-load objective).
//! 3. Validate the plan on the simulator.
//!
//! Run with: `cargo run --release --example quickstart`
//! (requires `make artifacts` for step 1; skipped gracefully otherwise)

use camelot::config::ClusterSpec;
use camelot::figures::common::train_predictors;
use camelot::planner::{CamelotPlanner, ClusterState, Objective, PlanRequest, Planner as _};
use camelot::runtime::Engine;
use camelot::sim::{SimOptions, Simulator};
use camelot::suite::real;

fn main() -> anyhow::Result<()> {
    // --- 1. real compute through PJRT ---------------------------------
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let mut engine = Engine::open("artifacts")?;
        println!("PJRT platform: {}", engine.platform());
        let exe = engine.load_stage("vgg_features", 8)?;
        let n_in: usize = exe.meta.input_shape.iter().product();
        let out = exe.run(&vec![0.05f32; n_in])?;
        println!(
            "ran vgg_features_b8: {} inputs -> {} outputs (first = {:.4})",
            n_in,
            out.len(),
            out[0]
        );
    } else {
        println!("(artifacts/ missing — run `make artifacts` for the PJRT demo)");
    }

    // --- 2. plan an allocation through the unified planner -------------
    let pipeline = real::img_to_text();
    let cluster = ClusterSpec::two_2080ti();
    println!("\nplanning {} on 2x {}...", pipeline.name, cluster.gpu.name);
    let predictors = train_predictors(&pipeline, &cluster);
    let request = PlanRequest::new(
        Objective::MaxLoad,
        ClusterState::exclusive(&cluster),
        &pipeline,
        &predictors,
    )
    .batch(16);
    let plan = CamelotPlanner.plan(&request).expect("feasible plan");
    println!("  instances : {:?}", plan.allocation.instances);
    println!(
        "  SM quotas : {:?}",
        plan.allocation
            .quotas
            .iter()
            .map(|q| format!("{:.0}%", q * 100.0))
            .collect::<Vec<_>>()
    );
    println!("  predicted peak: {:.0} qps", plan.objective_value);
    println!("  predicted p99 : {:.1} ms", plan.predicted_p99_s * 1e3);

    // --- 3. validate on the simulator ----------------------------------
    // the solution already carries the bandwidth-aware placement
    let report = Simulator::new(
        &pipeline,
        &cluster,
        &plan.deployment,
        SimOptions { queries: 3_000, ..Default::default() },
    )
    .run(plan.objective_value * 0.8)
    .expect("sim runs");
    println!(
        "  simulated at 80% of predicted peak: p99 = {:.1} ms (QoS {:.0} ms)",
        report.p99() * 1e3,
        pipeline.qos_target_s * 1e3
    );
    assert!(report.p99() <= pipeline.qos_target_s, "plan must meet QoS");
    println!("\nquickstart OK");
    Ok(())
}
