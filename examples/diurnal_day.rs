//! §VIII-C as a runnable scenario: serve one simulated day of diurnal
//! load (Google's pattern — 30% trough, midday peak) with the Camelot
//! autoscaler re-provisioning as load drifts, and report per-tick
//! resource usage + p99 so the usage-follows-load curve is visible.
//!
//! Run with: `cargo run --release --example diurnal_day [peak_qps]`

use camelot::config::ClusterSpec;
use camelot::coordinator::{AutoscaleConfig, Autoscaler};
use camelot::figures::common::train_predictors;
use camelot::sim::{SimOptions, Simulator};
use camelot::suite::{real, workload::DiurnalPattern};
use camelot::util::{fnum, Table};

fn main() {
    let peak: f64 = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(400.0);
    let pipeline = real::img_to_text();
    let cluster = ClusterSpec::two_2080ti();
    eprintln!("training predictors for {}...", pipeline.name);
    let predictors = train_predictors(&pipeline, &cluster);
    let mut scaler = Autoscaler::new(&pipeline, &cluster, &predictors, AutoscaleConfig::default());
    let day = DiurnalPattern::new(peak);

    let mut table = Table::new(
        &format!("One diurnal day of {} (peak {peak:.0} qps)", pipeline.name),
        &["hour", "load_qps", "replanned", "usage_gpu_equiv", "p99_ms", "qos_met"],
    );
    let opts = SimOptions { queries: 1_500, ..Default::default() };
    for hour in (0..24).step_by(2) {
        let load = day.rate_at(hour as f64 * 3_600.0);
        let replanned = scaler.observe(load).is_some();
        let plan = scaler.current().expect("provisioned");
        let report = Simulator::new(&pipeline, &cluster, &plan.deployment, opts.clone())
            .run(load.max(1.0))
            .expect("simulates");
        table.push(&[
            format!("{hour:02}:00"),
            fnum(load),
            if replanned { "yes" } else { "" }.to_string(),
            format!("{:.2}", plan.usage),
            format!("{:.1}", report.p99() * 1e3),
            (report.p99() <= pipeline.qos_target_s).to_string(),
        ]);
    }
    println!("{}", table.render());
    println!(
        "replans over the day: {} (hysteresis threshold ±{:.0}%)",
        scaler.replans(),
        AutoscaleConfig::default().replan_threshold * 100.0
    );
}
