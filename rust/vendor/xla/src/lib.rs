//! Type-level shim for the PJRT `xla` bindings (see vendor/README.md).
//!
//! The build environment has no XLA/PJRT libraries, so every
//! constructor returns [`XlaError`] at runtime. The API surface matches
//! the call sites in `camelot::runtime` exactly; replacing this shim
//! with the real `xla` crate re-enables hardware serving without source
//! changes. The PJRT-dependent tests and benches already gate on
//! `artifacts/manifest.json` existing, so they skip under the shim.

use std::fmt;

/// Error type matching the real bindings' role in `?` conversions.
#[derive(Debug, Clone)]
pub struct XlaError(String);

impl XlaError {
    fn unavailable(what: &str) -> XlaError {
        XlaError(format!(
            "{what}: PJRT is unavailable in this build (offline xla shim; \
             vendor the real xla crate to serve artifacts)"
        ))
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

type Result<T> = std::result::Result<T, XlaError>;

/// Host-side tensor literal.
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(XlaError::unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(XlaError::unavailable("Literal::to_vec"))
    }
}

/// Device-resident buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(XlaError::unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation ready for compilation.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled, device-loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client (one per platform).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(XlaError::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "offline-shim".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
        let lit = Literal::vec1(&[1.0]).reshape(&[1]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("offline xla shim"), "{msg}");
    }
}
