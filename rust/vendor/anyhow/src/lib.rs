//! Minimal offline shim for the `anyhow` crate (see vendor/README.md).
//!
//! Implements exactly the subset this repository uses: a
//! message-carrying [`Error`], the [`anyhow!`] macro, the [`Context`]
//! extension trait, and a blanket `From<E: std::error::Error>` so `?`
//! conversions from concrete error types work.

use std::fmt;

/// A boxed-message error. Unlike the real crate it keeps only the
/// rendered message chain, which is all the call sites here need.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Prepend a context line (the `Context` trait calls this).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `?` conversions from any std error. `Error` itself deliberately does
// NOT implement `std::error::Error`, mirroring the real crate — that is
// what keeps this blanket impl coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` alias with the shim error as default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and to `None`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Format an [`Error`] from format-string arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Early-return an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "disk"))?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().contains("disk"));
    }

    #[test]
    fn context_chains_messages() {
        let r: Result<()> = io_fail().with_context(|| "opening manifest");
        let msg = r.unwrap_err().to_string();
        assert!(msg.starts_with("opening manifest:"), "{msg}");
        assert!(msg.contains("disk"));
    }

    #[test]
    fn option_context() {
        let r: Result<i32> = None.context("missing");
        assert_eq!(r.unwrap_err().to_string(), "missing");
    }

    #[test]
    fn macro_formats_inline_args() {
        let stage = 3;
        let e = anyhow!("stage {stage} out of range");
        assert_eq!(e.to_string(), "stage 3 out of range");
    }
}
