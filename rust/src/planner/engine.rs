//! The planning engine: the paper's two contention-aware solve bodies
//! (§VII Case 1 / Case 2), shared by [`super::CamelotPlanner`] and the
//! legacy `allocator::{max_load, min_resource}::solve` shims.
//!
//! Both solvers evaluate candidates against an [`AllocContext`], whose
//! [`ClusterState`](super::ClusterState) carries the merged co-tenant
//! holds — reservation awareness is structural, not threaded by hand.

use crate::allocator::constraints::AllocContext;
use crate::allocator::sa::{anneal, SaParams, SaResult};
use crate::deploy::Allocation;

/// Case 1 (§VII-B): maximize the supported peak load with limited GPUs.
///
/// Objective: MAX min_i N_i·f(p_i) — the end-to-end peak load is set by
/// the slowest stage, so the optimizer raises the floor — under the
/// Eq. 1 constraint set (checked by [`AllocContext`]).
pub(crate) fn solve_case1(ctx: &AllocContext<'_>, params: SaParams) -> Option<SaResult> {
    let n = ctx.pipeline.n_stages();
    let max_inst = ctx.cluster().total_contexts().min(48);
    let c = ctx.cluster().num_gpus as f64;
    // throughput-balanced per-GPU quotas (the Laius shape) — a strong
    // starting corner the optimizer should dominate, never lose to
    let balanced: Vec<f64> = crate::baselines::balanced_quotas(ctx.predictors, ctx.batch)
        .into_iter()
        .map(|q| ((q / 0.05).round() * 0.05).clamp(0.05, 0.95))
        .collect();
    // several starting corners: the annealer keeps the best feasible
    // result across them (the landscape has disconnected feasible
    // islands when the QoS budget is tight)
    let inits = [
        // conservative: one instance per stage, even share of one GPU
        Allocation { instances: vec![1; n], quotas: vec![((1.0 / n as f64).min(0.9) / 0.05).round() * 0.05; n] },
        // fat: one instance per stage at (near-)full quota — the only
        // feasible corner when per-stage durations are QoS-tight
        Allocation {
            instances: vec![1; n],
            quotas: vec![((c / n as f64).min(0.95) / 0.05).round() * 0.05; n],
        },
        // replicated: one instance per stage per GPU, even shares
        Allocation {
            instances: vec![ctx.cluster().num_gpus as u32; n],
            quotas: vec![((1.0 / n as f64).min(0.9) / 0.05).round() * 0.05; n],
        },
        // replicated balanced (the Laius corner)
        Allocation {
            instances: vec![ctx.cluster().num_gpus as u32; n],
            quotas: balanced,
        },
    ];
    let params = SaParams { max_instances: max_inst, ..params };
    let mut inits: Vec<Allocation> = inits.to_vec();
    // If none of the corners is feasible (tight QoS + bandwidth budgets
    // leave a needle-shaped feasible region, e.g. the m3-heavy artifact
    // pipelines), seed from a coarse quota grid search.
    if !inits.iter().any(|a| ctx.check(a).is_ok()) {
        const GRID: [f64; 6] = [0.1, 0.25, 0.4, 0.6, 0.8, 0.95];
        let mut combo = vec![0usize; n];
        'grid: loop {
            let cand = Allocation {
                instances: vec![1; n],
                quotas: combo.iter().map(|&i| GRID[i]).collect(),
            };
            if ctx.check(&cand).is_ok() {
                inits.push(cand);
                break;
            }
            // odometer increment
            for digit in combo.iter_mut() {
                *digit += 1;
                if *digit < GRID.len() {
                    continue 'grid;
                }
                *digit = 0;
            }
            break;
        }
    }
    let mut best: Option<SaResult> = None;
    for (i, init) in inits.into_iter().enumerate() {
        let p = SaParams { seed: params.seed ^ ((i as u64) << 32), ..params };
        if let Some(r) = anneal(
            init,
            p,
            |a| ctx.check(a).is_ok(),
            |a| ctx.predicted_peak(a),
        ) {
            if best.as_ref().map_or(true, |b| r.best_objective > b.best_objective) {
                best = Some(r);
            }
        }
    }
    best
}

/// Case 2 (§VII-C): minimize GPU resource usage at a given (low) load
/// while ensuring QoS. Two phases, as in the paper:
///
///  1. Eq. 2 — lower-bound the number of GPUs `y` from aggregate
///     compute and memory ([`crate::allocator::min_resource::min_gpus`]),
///     then
///  2. Eq. 3 — minimize Σ N_i·p_i on those `y` GPUs subject to the same
///     constraint families plus a throughput floor at the target load.
///
/// The returned allocation is feasible on a cluster restricted to the
/// returned GPU count and supports the load.
///
/// With co-tenant holds in the context's [`ClusterState`]
/// (`is_shared()`), the Eq. 2 GPU-count restriction still applies as
/// long as the holds do not overlap the candidate GPUs (the first `y`
/// devices): unheld trailing GPUs are simply dropped, and the
/// restricted sub-problem carries the truncated holds
/// ([`ClusterState::restrict`](super::ClusterState::restrict)). Only
/// when a hold sits inside the candidate set is the Eq. 2 bound invalid
/// (it assumes empty devices) — then the solve starts from the full
/// cluster with the holds applied and the usage objective alone keeps
/// the plan small.
pub(crate) fn solve_case2(
    ctx: &AllocContext<'_>,
    load_qps: f64,
    params: SaParams,
) -> Option<(SaResult, usize)> {
    let mut y = {
        let bound = crate::allocator::min_resource::min_gpus(ctx, load_qps);
        if ctx.state().has_holds_within(bound) {
            ctx.cluster().num_gpus
        } else {
            bound
        }
    };
    // Eq. 2 is a lower bound; grow y if the restricted problem is
    // infeasible (e.g. bandwidth or QoS-bound rather than capacity-bound)
    while y <= ctx.cluster().num_gpus {
        // the restricted cluster keeps GPUs 0..y, so it keeps exactly
        // their holds (growth past the initial bound can pull held
        // devices into scope — their truncated entries come with them).
        // The predictor grid depends only on (predictors, batch), so
        // every restriction shares the parent context's memo instead of
        // re-querying the trees.
        let mut sub = AllocContext::shared_with_grids(
            ctx.pipeline,
            ctx.state().restrict(y),
            ctx.predictors,
            ctx.batch,
            ctx.grids(),
        );
        sub.comm = ctx.comm;
        sub.enforce_bw = ctx.enforce_bw;
        sub.qos_headroom = ctx.qos_headroom;
        sub.compute_scale = ctx.compute_scale;
        let n = ctx.pipeline.n_stages();
        let init = Allocation {
            instances: vec![1; n],
            quotas: vec![(1.0 / n as f64).min(0.9); n],
        };
        let result = anneal(
            init,
            params,
            // feasible = all constraints + the load's predicted p99
            // stays inside QoS (tail-aware, not just capacity)
            |a| {
                // 35% tail margin: Case 2 sits at the feasibility
                // boundary by construction, so the predicted p99 needs
                // real headroom over the tail-model error
                sub.check(a).is_ok()
                    && sub.predicted_p99(a, load_qps) <= ctx.pipeline.qos_target_s * 0.65
            },
            // maximize the negated usage ⇒ minimize Σ N_i·p_i
            |a| -a.total_quota(),
        );
        if let Some(r) = result {
            return Some((r, y));
        }
        y += 1;
    }
    None
}
