//! The unified planning surface — **the** public API for every
//! spatial-partitioning decision Camelot makes.
//!
//! Before this module, the repo had four divergent call shapes for the
//! same underlying question ("what (N_i, p_i) vector and placement
//! serve this pipeline on this cluster?"): `max_load::solve`,
//! `min_resource::solve`, `Autoscaler::observe*`, and
//! `AdmissionController::try_admit`, each hand-threading
//! `&[GpuReservation]` through the constraint checker and the
//! placement pass. MISO and ParvaGPU both frame spatial-partition
//! decisions as one plan-request/plan-outcome interface over cluster
//! state; this module adopts that shape:
//!
//! * [`ClusterState`] — the cluster spec plus the *merged* per-GPU
//!   holds of co-located tenants, owned in one value.
//! * [`PlanRequest`] — a typed request: an [`Objective`] (Case-1
//!   max-load, Case-2 min-resource, a placement-only re-pack, or a
//!   resident shrink), the cluster state, the pipeline and its trained
//!   predictors, and the knobs that used to live on `AllocContext`.
//! * [`Planner::plan`] — `&PlanRequest -> PlanOutcome`. The outcome is
//!   a typed `Result`: a [`Solution`] carrying the solved allocation,
//!   the concrete placement, the predicted p99 (total and per stage),
//!   GPU count and usage — or an [`Infeasible`] diagnostic instead of
//!   a bare `None`.
//! * [`CamelotPlanner`] — the paper's policies behind the trait; the
//!   legacy `allocator::{max_load, min_resource}::solve` entry points
//!   are thin shims over the same engine (`engine`), golden-tested to
//!   agree bit-for-bit.
//! * [`ScenarioSpec`] — a declarative JSON description of cluster +
//!   tenants + objectives (`camelot plan/admit/colocate --spec`),
//!   replacing hand-rolled scenario construction.
//! * [`SolveCache`] — bounded-LRU memoization of `Planner::plan` keyed
//!   on a canonical request fingerprint; the online control loop
//!   (admission, re-pack, shrink, autoscale) plans through it and gets
//!   bit-identical `Solution`s back without re-running the SA solver
//!   for configurations it has already priced.
//! * [`HeteroPlanner`] — the heterogeneity-aware strategy: per-GPU-class
//!   sub-pool planning over mixed fleets (A100/H100/…) and MIG-style
//!   discrete slice catalogs, delegating verbatim to [`CamelotPlanner`]
//!   on homogeneous continuous pools (bit-identical, golden-gated).

#![warn(missing_docs)]

pub mod cache;
pub mod cluster;
pub(crate) mod engine;
pub mod hetero;
pub mod scenario;

pub use cache::{CacheStats, SolveCache};
pub use cluster::ClusterState;
pub use hetero::HeteroPlanner;
pub use scenario::{ScenarioBurst, ScenarioGpuFailure, ScenarioSpec, ScenarioTenant};

use crate::allocator::{AllocContext, SaParams};
use crate::comm::CommMode;
use crate::deploy::{self, Allocation, BwBudget};
use crate::predictor::StagePredictor;
use crate::sim::Deployment;
use crate::suite::Pipeline;

/// What the planner optimizes.
#[derive(Debug, Clone, PartialEq)]
pub enum Objective {
    /// Case 1 (§VII-B): maximize the supported peak load.
    MaxLoad,
    /// Case 2 (§VII-C): minimize Σ N_i·p_i while serving `load_qps`
    /// within QoS.
    MinResource { load_qps: f64 },
    /// Re-place an existing allocation into the current cluster state
    /// without re-solving — the cheapest migration (instance counts and
    /// quotas unchanged, instances just move). The departure re-packing
    /// pass runs this before falling back to a full re-solve.
    Repack { allocation: Allocation },
    /// Resident shrink (online re-admission): re-solve an existing plan
    /// for a lower `target_qps` and succeed only if the new plan
    /// actually uses less than `current` — the path that lets the
    /// controller reclaim capacity from a resident whose offered load
    /// fell, instead of holding its provisioned peak until departure.
    Shrink { target_qps: f64, current: Allocation },
}

impl Objective {
    /// Short label for tables and logs.
    pub fn name(&self) -> &'static str {
        match self {
            Objective::MaxLoad => "max-load",
            Objective::MinResource { .. } => "min-resource",
            Objective::Repack { .. } => "repack",
            Objective::Shrink { .. } => "shrink",
        }
    }
}

/// A typed planning request: everything [`Planner::plan`] needs, in one
/// value. Construct with [`PlanRequest::new`] and override the knobs
/// with the builder methods.
#[derive(Debug, Clone)]
pub struct PlanRequest<'a> {
    /// What to optimize (Case-1, Case-2, re-pack, or shrink).
    pub objective: Objective,
    /// The cluster plus merged co-tenant reservations.
    pub cluster: ClusterState,
    /// The tenant's pipeline (stages + QoS target).
    pub pipeline: &'a Pipeline,
    /// One trained predictor per stage (profiled on the base GPU spec).
    pub predictors: &'a [StagePredictor],
    /// Serving batch size the plan is evaluated at.
    pub batch: u32,
    /// Inter-stage communication mode (global IPC or main memory).
    pub comm: CommMode,
    /// Enforce the C3 bandwidth constraint (false = Camelot-NC).
    pub enforce_bw: bool,
    /// Fraction of the QoS budget available to stage processing +
    /// communication (C5 headroom).
    pub qos_headroom: f64,
    /// Relative service-time multiplier of the GPU class being planned
    /// for (1.0 = the class the predictors were profiled on; see
    /// [`crate::config::GpuClass::compute_scale`]). The heterogeneous
    /// planner sets this per sub-pool; callers planning a homogeneous
    /// cluster leave the default.
    pub compute_scale: f64,
    /// Simulated-annealing search budget and seed.
    pub sa: SaParams,
}

impl<'a> PlanRequest<'a> {
    /// A request with the repo-wide defaults (batch 32, global-IPC
    /// communication, bandwidth constraint on, 80% C5 headroom,
    /// default SA budget).
    pub fn new(
        objective: Objective,
        cluster: ClusterState,
        pipeline: &'a Pipeline,
        predictors: &'a [StagePredictor],
    ) -> Self {
        PlanRequest {
            objective,
            cluster,
            pipeline,
            predictors,
            batch: 32,
            comm: CommMode::GlobalIpc,
            enforce_bw: true,
            qos_headroom: 0.80,
            compute_scale: 1.0,
            sa: SaParams::default(),
        }
    }

    /// Override the serving batch size.
    pub fn batch(mut self, batch: u32) -> Self {
        self.batch = batch;
        self
    }

    /// Override the SA search budget/seed.
    pub fn sa(mut self, sa: SaParams) -> Self {
        self.sa = sa;
        self
    }

    /// Override the inter-stage communication mode.
    pub fn comm(mut self, comm: CommMode) -> Self {
        self.comm = comm;
        self
    }

    /// Toggle the C3 bandwidth constraint (false = Camelot-NC).
    pub fn enforce_bw(mut self, enforce: bool) -> Self {
        self.enforce_bw = enforce;
        self
    }

    /// Override the C5 headroom fraction.
    pub fn qos_headroom(mut self, qos_headroom: f64) -> Self {
        self.qos_headroom = qos_headroom;
        self
    }

    /// Override the GPU-class service-time multiplier (see the
    /// [`compute_scale`](Self::compute_scale) field).
    pub fn compute_scale(mut self, scale: f64) -> Self {
        self.compute_scale = scale;
        self
    }

    /// Same request, different objective (the Case-2 → Case-1 fallback
    /// ladder the coordinator climbs).
    pub fn objective(mut self, objective: Objective) -> Self {
        self.objective = objective;
        self
    }

    /// The [`AllocContext`] this request evaluates candidates against.
    fn alloc_context(&self) -> AllocContext<'a> {
        let mut ctx = AllocContext::shared(
            self.pipeline,
            self.cluster.clone(),
            self.predictors,
            self.batch,
        );
        ctx.comm = self.comm;
        ctx.enforce_bw = self.enforce_bw;
        ctx.qos_headroom = self.qos_headroom;
        ctx.compute_scale = self.compute_scale;
        ctx
    }
}

/// A solved plan: the paper's `(n_i, p_i)` vector plus everything the
/// coordinator needs to run and reason about it.
#[derive(Debug, Clone)]
pub struct Solution {
    /// N_i / p_i per stage.
    pub allocation: Allocation,
    /// Concrete bandwidth-aware placement on the cluster state.
    pub deployment: Deployment,
    /// Load (queries/s) the predictions below are evaluated at: the
    /// solved peak for `MaxLoad`, the requested load for
    /// `MinResource`/`Shrink`, and 0 for `Repack` (unloaded latencies —
    /// the re-pack pass consumes only the placement).
    pub plan_qps: f64,
    /// Predicted end-to-end 99%-ile latency at `plan_qps`.
    pub predicted_p99_s: f64,
    /// Per-stage decomposition of the p99 prediction (service +
    /// queueing tail per stage; communication is the remainder).
    pub stage_p99_s: Vec<f64>,
    /// Σ N_i·p_i — GPU-equivalents of SM share.
    pub usage: f64,
    /// Distinct devices the placement actually occupies. (The Case-2
    /// Eq. 2 sub-cluster size proves feasibility on a prefix, but the
    /// full-cluster bandwidth-aware placement may deliberately spread
    /// wider — this field counts what is really held, so operators can
    /// tally devices from it.)
    pub gpus: usize,
    /// Raw solver objective: predicted peak qps (`MaxLoad`), negated
    /// usage (`MinResource`/`Shrink`), 0 for `Repack` (nothing is
    /// optimized — the allocation is given).
    pub objective_value: f64,
    /// SA search statistics (0 for `Repack`, which does not search):
    /// candidates evaluated.
    pub evaluated: usize,
    /// SA search statistics: feasible candidates found.
    pub feasible_found: usize,
}

/// Why a request has no plan — typed diagnostics instead of `None`.
#[derive(Debug, Clone, PartialEq)]
pub enum Infeasible {
    /// The request itself is malformed (non-positive load, shape
    /// mismatch between `current` and the pipeline, …).
    BadRequest { detail: String },
    /// No feasible allocation exists in the capacity the co-tenant
    /// holds leave free (C1/C2/C5 over the remainder).
    NoAllocation { detail: String },
    /// An allocation exists but no placement satisfies every per-GPU
    /// budget (C2/C3/C4 structurally).
    NoPlacement { stage: usize, detail: String },
    /// `Shrink` only: a plan exists at the target load but would not
    /// use less than the current plan — shrinking would churn instances
    /// for nothing.
    NoImprovement { current_usage: f64, planned_usage: f64 },
    /// The pipeline's GPU-memory demand (weights + activations + KV
    /// cache per query) can never fit the cluster's free memory — no
    /// SM-share allocation can fix a capacity shortfall, so the request
    /// is rejected before the solver runs. Only pipelines with a
    /// nonzero per-stage `mem_bytes_per_query` are pre-checked.
    NoMemory {
        /// Bytes the hungriest check that failed demands.
        needed_bytes: f64,
        /// Free bytes the same check has available.
        available_bytes: f64,
    },
}

impl std::fmt::Display for Infeasible {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            // NoAllocation renders its detail verbatim: the legacy
            // callers' error strings (and the admission trace golden
            // fingerprints) depend on it
            Infeasible::NoAllocation { detail } => write!(f, "{detail}"),
            Infeasible::NoPlacement { stage, detail } => {
                write!(f, "cannot place stage {stage}: {detail}")
            }
            Infeasible::BadRequest { detail } => write!(f, "bad plan request: {detail}"),
            Infeasible::NoImprovement { current_usage, planned_usage } => write!(
                f,
                "no improvement: planned usage {planned_usage:.3} >= current {current_usage:.3}"
            ),
            Infeasible::NoMemory { needed_bytes, available_bytes } => write!(
                f,
                "NoMemory: insufficient GPU memory (need {needed_bytes:.3e} B, have \
                 {available_bytes:.3e} B free)"
            ),
        }
    }
}

/// The outcome of [`Planner::plan`].
pub type PlanOutcome = Result<Solution, Infeasible>;

/// A planning strategy: anything that can answer a [`PlanRequest`].
/// The paper's policies live behind [`CamelotPlanner`]; alternative
/// strategies (baselines, heterogeneous-cluster planners) implement the
/// same trait and become drop-in interchangeable.
pub trait Planner {
    /// Answer the request with a [`Solution`] or a typed [`Infeasible`].
    fn plan(&self, req: &PlanRequest<'_>) -> PlanOutcome;
}

/// The paper's contention-aware planner: Case-1/Case-2 simulated
/// annealing over the Eq. 1/3 constraint set, bandwidth-aware
/// placement, reservation-aware throughout via [`ClusterState`].
#[derive(Debug, Clone, Copy, Default)]
pub struct CamelotPlanner;

impl Planner for CamelotPlanner {
    fn plan(&self, req: &PlanRequest<'_>) -> PlanOutcome {
        validate(req)?;
        let ctx = req.alloc_context();
        match &req.objective {
            Objective::MaxLoad => {
                let r = engine::solve_case1(&ctx, req.sa).ok_or_else(|| {
                    Infeasible::NoAllocation { detail: "no feasible allocation".to_string() }
                })?;
                let peak = r.best_objective;
                finish(req, &ctx, r.best, peak, peak, (r.evaluated, r.feasible_found))
            }
            Objective::MinResource { load_qps } => {
                let (r, _y) = engine::solve_case2(&ctx, *load_qps, req.sa).ok_or_else(|| {
                    Infeasible::NoAllocation {
                        detail: format!("no allocation supports {load_qps:.1} qps"),
                    }
                })?;
                let stats = (r.evaluated, r.feasible_found);
                finish(req, &ctx, r.best, *load_qps, r.best_objective, stats)
            }
            Objective::Repack { allocation } => {
                // placement-only: no solve, and no peak search either —
                // the re-pack pass consumes only the placement, so the
                // prediction block is evaluated at zero load (unloaded
                // latencies) instead of paying a bisection per survivor
                finish(req, &ctx, allocation.clone(), 0.0, 0.0, (0, 0))
            }
            Objective::Shrink { target_qps, current } => {
                let (r, _y) = engine::solve_case2(&ctx, *target_qps, req.sa).ok_or_else(|| {
                    Infeasible::NoAllocation {
                        detail: format!("no allocation supports {target_qps:.1} qps"),
                    }
                })?;
                let planned_usage = r.best.total_quota();
                let current_usage = current.total_quota();
                if planned_usage >= current_usage - 1e-9 {
                    return Err(Infeasible::NoImprovement { current_usage, planned_usage });
                }
                let stats = (r.evaluated, r.feasible_found);
                finish(req, &ctx, r.best, *target_qps, r.best_objective, stats)
            }
        }
    }
}

/// Request sanity checks shared by every objective.
fn validate(req: &PlanRequest<'_>) -> Result<(), Infeasible> {
    let bad = |detail: String| Err(Infeasible::BadRequest { detail });
    if req.predictors.len() != req.pipeline.n_stages() {
        return bad(format!(
            "{} predictors for a {}-stage pipeline",
            req.predictors.len(),
            req.pipeline.n_stages()
        ));
    }
    if req.batch == 0 {
        return bad("batch must be at least 1".to_string());
    }
    // KV-cache pre-flight (gated: classic pipelines with no
    // `mem_bytes_per_query` never reach it, so their error types and
    // golden fingerprints are untouched). A capacity shortfall is
    // structural — no SM-share vector can fix it — so reject before
    // spending the SA budget: every stage instance must fit the free
    // memory of *some* single GPU, and the pipeline's total demand must
    // fit the cluster's total free memory.
    if req.pipeline.stages.iter().any(|s| s.mem_bytes_per_query > 0.0) {
        let spec = req.cluster.spec();
        let holds = req.cluster.reservations();
        let free_at = |g: usize| spec.gpu_at(g).mem_bytes as f64 - holds[g].mem_bytes;
        let max_free =
            (0..req.cluster.num_gpus()).map(free_at).fold(f64::NEG_INFINITY, f64::max);
        let total_free: f64 = (0..req.cluster.num_gpus()).map(free_at).sum();
        let batch = req.batch as f64;
        let mut total_need = 0.0;
        let mut worst_need = 0.0f64;
        for st in &req.pipeline.stages {
            let need =
                st.model_bytes + (st.act_bytes_per_query + st.mem_bytes_per_query) * batch;
            total_need += need;
            worst_need = worst_need.max(need);
        }
        if worst_need > max_free {
            return Err(Infeasible::NoMemory {
                needed_bytes: worst_need,
                available_bytes: max_free.max(0.0),
            });
        }
        if total_need > total_free {
            return Err(Infeasible::NoMemory {
                needed_bytes: total_need,
                available_bytes: total_free.max(0.0),
            });
        }
    }
    match &req.objective {
        Objective::MinResource { load_qps } if load_qps.is_nan() || *load_qps <= 0.0 => {
            bad(format!("load must be positive, got {load_qps}"))
        }
        Objective::Shrink { target_qps, current } => {
            if target_qps.is_nan() || *target_qps <= 0.0 {
                return bad(format!("shrink target must be positive, got {target_qps}"));
            }
            if !shaped_like(current, req.pipeline) {
                return bad("shrink `current` does not match the pipeline".to_string());
            }
            Ok(())
        }
        Objective::Repack { allocation } if !shaped_like(allocation, req.pipeline) => {
            bad("repack allocation does not match the pipeline".to_string())
        }
        _ => Ok(()),
    }
}

/// Both per-stage vectors of an allocation match the pipeline's shape.
fn shaped_like(alloc: &Allocation, pipeline: &Pipeline) -> bool {
    alloc.instances.len() == pipeline.n_stages() && alloc.quotas.len() == pipeline.n_stages()
}

/// Shared tail of every successful plan: bandwidth-aware placement on
/// the cluster state, then the prediction block of the [`Solution`].
fn finish(
    req: &PlanRequest<'_>,
    ctx: &AllocContext<'_>,
    allocation: Allocation,
    plan_qps: f64,
    objective_value: f64,
    (evaluated, feasible_found): (usize, usize),
) -> PlanOutcome {
    let demands = ctx.bw_budget_storage(&allocation);
    let deployment = deploy::deploy(
        req.pipeline,
        &req.cluster,
        &allocation,
        req.batch,
        req.comm,
        demands.as_deref().map(|d| BwBudget {
            demands: d,
            cap: 0.75 * req.cluster.spec().gpu.mem_bw,
        }),
    )
    .map_err(|e| Infeasible::NoPlacement { stage: e.stage, detail: e.detail })?;
    let gpus = deploy::gpus_in_use([&deployment]);
    let usage = allocation.total_quota();
    Ok(Solution {
        predicted_p99_s: ctx.predicted_p99(&allocation, plan_qps),
        stage_p99_s: ctx.predicted_stage_p99(&allocation, plan_qps),
        allocation,
        deployment,
        plan_qps,
        usage,
        gpus,
        objective_value,
        evaluated,
        feasible_found,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::deploy::GpuReservation;
    use crate::predictor::train_pipeline;
    use crate::suite::real;

    fn fixture() -> (ClusterSpec, crate::suite::Pipeline, Vec<StagePredictor>) {
        let c = ClusterSpec::two_2080ti();
        let p = real::img_to_text();
        let preds = train_pipeline(&p, &c.gpu);
        (c, p, preds)
    }

    #[test]
    fn max_load_plan_carries_full_solution() {
        let (c, p, preds) = fixture();
        let req = PlanRequest::new(
            Objective::MaxLoad,
            ClusterState::exclusive(&c),
            &p,
            &preds,
        )
        .batch(16);
        let s = CamelotPlanner.plan(&req).expect("feasible");
        assert_eq!(s.allocation.instances.len(), p.n_stages());
        assert_eq!(s.stage_p99_s.len(), p.n_stages());
        assert!(s.objective_value > 0.0 && s.plan_qps == s.objective_value);
        assert!(s.predicted_p99_s <= p.qos_target_s);
        assert!(s.gpus >= 1 && s.gpus <= c.num_gpus);
        assert!((s.usage - s.allocation.total_quota()).abs() < 1e-12);
        assert!(!s.deployment.placements.is_empty());
        assert!(s.evaluated > 0 && s.feasible_found > 0);
    }

    #[test]
    fn min_resource_plan_respects_reservations() {
        let (c, p, preds) = fixture();
        let free = PlanRequest::new(
            Objective::MinResource { load_qps: 30.0 },
            ClusterState::exclusive(&c),
            &p,
            &preds,
        )
        .batch(16);
        let sf = CamelotPlanner.plan(&free).expect("exclusive solves");
        // a co-tenant holding half of each GPU squeezes the plan, and
        // placements must avoid the held share
        let held = vec![
            GpuReservation { sm_frac: 0.5, contexts: 8, ..Default::default() };
            c.num_gpus
        ];
        let shared = PlanRequest::new(
            Objective::MinResource { load_qps: 30.0 },
            ClusterState::with_reservations(&c, &held),
            &p,
            &preds,
        )
        .batch(16);
        let ss = CamelotPlanner.plan(&shared).expect("remainder solves");
        // per GPU, the tenant's own share fits inside the remainder
        let mut per_gpu = vec![0.0f64; c.num_gpus];
        for pl in &ss.deployment.placements {
            per_gpu[pl.gpu] += pl.sm_frac;
        }
        for share in per_gpu {
            assert!(share <= 0.5 + 1e-9, "placement overlaps the hold: {share}");
        }
        assert!(sf.usage > 0.0 && ss.usage > 0.0);
    }

    #[test]
    fn infeasible_is_typed_not_silent() {
        let (c, p, preds) = fixture();
        let req = PlanRequest::new(
            Objective::MinResource { load_qps: 1.0e9 },
            ClusterState::exclusive(&c),
            &p,
            &preds,
        )
        .batch(16);
        match CamelotPlanner.plan(&req) {
            Err(Infeasible::NoAllocation { detail }) => {
                assert!(detail.contains("1000000000.0 qps"), "{detail}")
            }
            other => panic!("expected NoAllocation, got {other:?}"),
        }
        // malformed request: zero batch
        let bad = PlanRequest::new(
            Objective::MaxLoad,
            ClusterState::exclusive(&c),
            &p,
            &preds,
        )
        .batch(0);
        assert!(matches!(
            CamelotPlanner.plan(&bad),
            Err(Infeasible::BadRequest { .. })
        ));
        // negative shrink target
        let neg = PlanRequest::new(
            Objective::Shrink {
                target_qps: -5.0,
                current: Allocation { instances: vec![1, 1], quotas: vec![0.5, 0.5] },
            },
            ClusterState::exclusive(&c),
            &p,
            &preds,
        );
        assert!(matches!(
            CamelotPlanner.plan(&neg),
            Err(Infeasible::BadRequest { .. })
        ));
    }

    #[test]
    fn kv_hungry_pipeline_is_rejected_with_no_memory() {
        let c = ClusterSpec::two_2080ti();
        // 2 MB of KV per token on a 512-token prompt: one batch-16
        // prefill instance wants ~18 GB against an 11 GB card
        let p = crate::llm::pipeline(&crate::llm::LlmParams {
            prompt_tokens: 512,
            output_tokens: 128,
            kv_bytes_per_token: 2_000_000,
        });
        let preds = train_pipeline(&p, &c.gpu);
        let req = PlanRequest::new(
            Objective::MinResource { load_qps: 5.0 },
            ClusterState::exclusive(&c),
            &p,
            &preds,
        )
        .batch(16);
        match CamelotPlanner.plan(&req) {
            Err(Infeasible::NoMemory { needed_bytes, available_bytes }) => {
                assert!(needed_bytes > available_bytes);
                let msg = Infeasible::NoMemory { needed_bytes, available_bytes }.to_string();
                assert!(msg.contains("NoMemory"), "{msg}");
            }
            other => panic!("expected NoMemory, got {other:?}"),
        }
        // a sane KV budget plans normally end to end
        let ok_p = crate::llm::pipeline(&crate::llm::LlmParams::default());
        let ok_preds = train_pipeline(&ok_p, &c.gpu);
        let ok = PlanRequest::new(
            Objective::MinResource { load_qps: 5.0 },
            ClusterState::exclusive(&c),
            &ok_p,
            &ok_preds,
        )
        .batch(16);
        CamelotPlanner.plan(&ok).expect("default LLM params fit an 11 GB card");
    }

    #[test]
    fn repack_keeps_allocation_and_places() {
        let (c, p, preds) = fixture();
        let alloc = Allocation { instances: vec![1, 2], quotas: vec![0.5, 0.4] };
        let req = PlanRequest::new(
            Objective::Repack { allocation: alloc.clone() },
            ClusterState::exclusive(&c),
            &p,
            &preds,
        )
        .batch(16);
        let s = CamelotPlanner.plan(&req).expect("placeable");
        assert_eq!(s.allocation, alloc, "repack must not re-solve");
        assert_eq!(s.deployment.placements.len(), 3);
        assert_eq!(s.evaluated, 0);
    }

    #[test]
    fn shrink_requires_a_real_improvement() {
        let (c, p, preds) = fixture();
        // provision generously at a high load...
        let big = CamelotPlanner
            .plan(
                &PlanRequest::new(
                    Objective::MinResource { load_qps: 200.0 },
                    ClusterState::exclusive(&c),
                    &p,
                    &preds,
                )
                .batch(16),
            )
            .expect("high load solves");
        // ...then shrink to a much lower target: must use less
        let shrunk = CamelotPlanner
            .plan(
                &PlanRequest::new(
                    Objective::Shrink {
                        target_qps: 25.0,
                        current: big.allocation.clone(),
                    },
                    ClusterState::exclusive(&c),
                    &p,
                    &preds,
                )
                .batch(16),
            )
            .expect("shrink finds a smaller plan");
        assert!(
            shrunk.usage < big.usage,
            "shrunk {} must undercut {}",
            shrunk.usage,
            big.usage
        );
        // shrinking an already-minimal plan to its own load is refused
        let noop = CamelotPlanner.plan(
            &PlanRequest::new(
                Objective::Shrink {
                    target_qps: 25.0,
                    current: shrunk.allocation.clone(),
                },
                ClusterState::exclusive(&c),
                &p,
                &preds,
            )
            .batch(16),
        );
        assert!(
            matches!(noop, Err(Infeasible::NoImprovement { .. })),
            "{noop:?}"
        );
    }
}
