//! [`HeteroPlanner`] — heterogeneity-aware planning over mixed GPU
//! pools (A100/H100/…) and MIG-style discrete slice catalogs.
//!
//! The paper's policies ([`CamelotPlanner`]) assume one GPU spec and
//! continuous MPS quotas. Datacenter fleets are neither: MISO (arXiv
//! 2207.11428) plans over discrete MIG slices, and ParvaGPU (arXiv
//! 2409.14447) mixes MIG and MPS at scale. This planner closes both
//! gaps behind the same [`Planner`] trait:
//!
//! * **Mixed pools.** A [`ClusterSpec`] whose `classes` are non-empty is
//!   planned *per class*: each contiguous homogeneous run of GPUs
//!   becomes a sub-pool (the class's own [`GpuSpec`], its co-tenant
//!   holds sliced out of the parent state), solved independently by
//!   [`CamelotPlanner`] with the class's
//!   [`compute_scale`](GpuClass::compute_scale) applied to every
//!   predictor read. The best class wins — highest predicted peak for
//!   `MaxLoad`, lowest Σ N·p usage otherwise, earliest class on ties —
//!   and its placement is remapped onto the class's global GPU ids.
//!   One tenant never spans classes (MISO makes the same choice: a
//!   deployment's instances live on one device type so one predictor
//!   scaling is exact for all of them).
//! * **Discrete slices.** A class (or the whole pool) in
//!   [`PartitionMode::Discrete`] solves in continuous quotas first,
//!   then *snaps every quota up* to the slice catalog — more SMs per
//!   instance, never fewer, so the snapped plan is never slower — and
//!   re-validates + re-places the snapped allocation. `Shrink` prices
//!   the slice moves via [`SliceCatalog::amortized_cost`] before
//!   accepting: a shrink that saves less usage than its repartition
//!   disruption is refused as `NoImprovement`.
//!
//! **Bit-identity contract** (golden-gated): on an effectively
//! homogeneous continuous pool ([`ClusterSpec::effectively_homogeneous`]
//! — no classes, or only identity classes) every request is delegated
//! verbatim to [`CamelotPlanner`], so plans, placements, predicted
//! p99s, and trace fingerprints are bit-for-bit those of the paper's
//! planner.

use crate::config::{ClusterSpec, GpuClass, GpuSpec, PartitionMode, SliceCatalog};
use crate::deploy::Allocation;

use super::{
    CamelotPlanner, ClusterState, Infeasible, Objective, PlanOutcome, PlanRequest, Planner,
    Solution,
};

/// Heterogeneity-aware planner: per-class sub-pool planning over mixed
/// fleets, discrete-slice snapping, verbatim [`CamelotPlanner`]
/// delegation on homogeneous continuous pools. See the module docs.
#[derive(Debug, Clone, Copy, Default)]
pub struct HeteroPlanner;

impl Planner for HeteroPlanner {
    fn plan(&self, req: &PlanRequest<'_>) -> PlanOutcome {
        let spec = req.cluster.spec();
        if spec.effectively_homogeneous() {
            // the golden-gated fast path: nothing heterogeneous about
            // the pool, so the paper's planner answers bit-identically
            return CamelotPlanner.plan(req);
        }
        super::validate(req)?;
        if let Err(detail) = spec.validate_classes() {
            return Err(Infeasible::BadRequest { detail });
        }
        let classes = pool_classes(spec);
        let mut best: Option<Solution> = None;
        let mut no_improvement: Option<Infeasible> = None;
        let mut failures: Vec<String> = Vec::new();
        let mut start = 0usize;
        for (idx, class) in classes.iter().enumerate() {
            match plan_class(req, class, start) {
                Ok(mut s) => {
                    for p in &mut s.deployment.placements {
                        p.gpu += start;
                    }
                    if best.as_ref().map_or(true, |b| beats(&req.objective, &s, b)) {
                        best = Some(s);
                    }
                }
                Err(e) => {
                    if matches!(e, Infeasible::NoImprovement { .. }) && no_improvement.is_none() {
                        no_improvement = Some(e.clone());
                    }
                    failures.push(format!("class {idx} ({}x {}): {e}", class.count, class.gpu.name));
                }
            }
            start += class.count;
        }
        if let Some(s) = best {
            return Ok(s);
        }
        // every class refused: a pure no-improvement outcome keeps its
        // type (the shrink caller backs off instead of logging an error)
        if let (Some(e), true) = (no_improvement, failures.len() == 1) {
            return Err(e);
        }
        Err(Infeasible::NoAllocation { detail: format!("no class admits the plan: {}", failures.join("; ")) })
    }
}

/// `a` strictly beats `b` under the objective (ties keep the earlier
/// class, so iteration order is the deterministic tie-break).
fn beats(objective: &Objective, a: &Solution, b: &Solution) -> bool {
    match objective {
        Objective::MaxLoad => a.objective_value > b.objective_value,
        Objective::MinResource { .. } | Objective::Shrink { .. } | Objective::Repack { .. } => {
            a.usage < b.usage
        }
    }
}

/// The pool as a list of homogeneous classes: the declared classes, or
/// one synthetic whole-pool class when `classes` is empty but the
/// pool-level partition mode is discrete.
fn pool_classes(spec: &ClusterSpec) -> Vec<GpuClass> {
    if spec.classes.is_empty() {
        vec![GpuClass {
            gpu: spec.gpu.clone(),
            count: spec.num_gpus,
            compute_scale: 1.0,
            partition: spec.partition.clone(),
        }]
    } else {
        spec.classes.clone()
    }
}

/// Plan the request into one class's sub-pool (GPUs
/// `start..start+count`), with the class's compute scale applied and
/// its quotas snapped to the slice catalog when discrete. Placements in
/// the returned solution are sub-pool-relative (the caller remaps).
fn plan_class(req: &PlanRequest<'_>, class: &GpuClass, start: usize) -> PlanOutcome {
    let parent = req.cluster.spec();
    let sub_spec = ClusterSpec {
        gpu: class.gpu.clone(),
        num_gpus: class.count,
        classes: Vec::new(),
        partition: PartitionMode::Continuous,
        degrade: Vec::new(),
        ..parent.clone()
    };
    let holds = &req.cluster.reservations()[start..start + class.count];
    let sub_state = ClusterState::with_reservations(&sub_spec, holds);
    let sub_req = PlanRequest { cluster: sub_state, ..req.clone() }
        .compute_scale(class.compute_scale);
    let sol = CamelotPlanner.plan(&sub_req)?;
    match class.partition.catalog() {
        None => Ok(sol),
        Some(cat) => snap_to_catalog(&sub_req, sol, cat),
    }
}

/// Round every quota of a continuous solution *up* to the slice
/// catalog, re-validate, and re-place. `Shrink` additionally prices the
/// slice reconfiguration against the usage saving.
fn snap_to_catalog(sub_req: &PlanRequest<'_>, sol: Solution, cat: &SliceCatalog) -> PlanOutcome {
    let snapped = Allocation {
        instances: sol.allocation.instances.clone(),
        quotas: sol.allocation.quotas.iter().map(|&q| cat.snap_up(q)).collect(),
    };
    if snapped.quotas == sol.allocation.quotas {
        return Ok(sol); // already on the catalog (e.g. a resident re-pack)
    }
    let ctx = sub_req.alloc_context();
    if let Err(detail) = ctx.check(&snapped) {
        return Err(Infeasible::NoAllocation {
            detail: format!(
                "discrete catalog ({} slices): snapped allocation infeasible: {detail}",
                cat.units
            ),
        });
    }
    let (plan_qps, objective_value) = match &sub_req.objective {
        // snapping up only adds SMs, so the peak can only move up —
        // recompute it for an honest objective
        Objective::MaxLoad => {
            let peak = ctx.predicted_peak(&snapped);
            (peak, peak)
        }
        Objective::MinResource { load_qps } => (*load_qps, -snapped.total_quota()),
        Objective::Repack { .. } => (0.0, 0.0),
        Objective::Shrink { target_qps, current } => {
            let planned = snapped.total_quota();
            let cur = current.total_quota();
            let moved = slices_changed(cat, current, &snapped);
            if planned + cat.amortized_cost(moved) >= cur - 1e-9 {
                return Err(Infeasible::NoImprovement {
                    current_usage: cur,
                    planned_usage: planned,
                });
            }
            (*target_qps, -planned)
        }
    };
    super::finish(
        sub_req,
        &ctx,
        snapped,
        plan_qps,
        objective_value,
        (sol.evaluated, sol.feasible_found),
    )
}

/// Slice boundaries that move when `old` is replaced by `new`: the
/// per-stage change in total occupied slice units, summed. The input to
/// the repartition-cost model.
fn slices_changed(cat: &SliceCatalog, old: &Allocation, new: &Allocation) -> u32 {
    old.instances
        .iter()
        .zip(&old.quotas)
        .zip(new.instances.iter().zip(&new.quotas))
        .map(|((&no, &qo), (&nn, &qn))| {
            (no * cat.units_for(qo)).abs_diff(nn * cat.units_for(qn))
        })
        .sum()
}

/// The GPU spec of the class a deployment occupies (all placements sit
/// in one class by construction); the base spec for a classless pool.
pub fn deployment_class<'a>(spec: &'a ClusterSpec, deployment: &crate::sim::Deployment) -> &'a GpuSpec {
    deployment
        .placements
        .first()
        .map_or(&spec.gpu, |p| spec.gpu_at(p.gpu))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::predictor::train_pipeline;
    use crate::suite::real;

    fn fixture() -> (ClusterSpec, crate::suite::Pipeline, Vec<crate::predictor::StagePredictor>) {
        let c = ClusterSpec::two_2080ti();
        let p = real::img_to_text();
        let preds = train_pipeline(&p, &c.gpu);
        (c, p, preds)
    }

    /// Identity classes (same spec, scale 1.0, continuous) delegate to
    /// CamelotPlanner and reproduce its solution bit for bit.
    #[test]
    fn homogeneous_delegation_is_bit_identical() {
        let (c, p, preds) = fixture();
        let mut classy = c.clone();
        classy.classes = vec![GpuClass::scaled(c.gpu.clone(), 2, 1.0)];
        for objective in [
            Objective::MaxLoad,
            Objective::MinResource { load_qps: 30.0 },
        ] {
            let flat = CamelotPlanner
                .plan(
                    &PlanRequest::new(
                        objective.clone(),
                        ClusterState::exclusive(&c),
                        &p,
                        &preds,
                    )
                    .batch(16),
                )
                .expect("flat solves");
            let hetero = HeteroPlanner
                .plan(
                    &PlanRequest::new(
                        objective,
                        ClusterState::exclusive(&classy),
                        &p,
                        &preds,
                    )
                    .batch(16),
                )
                .expect("identity classes solve");
            assert_eq!(flat.allocation, hetero.allocation);
            assert_eq!(flat.deployment.placements, hetero.deployment.placements);
            assert_eq!(flat.predicted_p99_s.to_bits(), hetero.predicted_p99_s.to_bits());
            assert_eq!(flat.objective_value.to_bits(), hetero.objective_value.to_bits());
            assert_eq!(flat.plan_qps.to_bits(), hetero.plan_qps.to_bits());
        }
    }

    /// A faster second class (lower compute_scale) wins MaxLoad, and the
    /// winning placement lands on that class's global GPU ids.
    #[test]
    fn max_load_prefers_the_faster_class() {
        let (c, p, preds) = fixture();
        let mut mixed = ClusterSpec { num_gpus: 4, ..c.clone() };
        mixed.classes = vec![
            GpuClass::scaled(c.gpu.clone(), 2, 1.0),
            GpuClass::scaled(c.gpu.clone(), 2, 0.5),
        ];
        mixed.validate_classes().unwrap();
        let s = HeteroPlanner
            .plan(
                &PlanRequest::new(
                    Objective::MaxLoad,
                    ClusterState::exclusive(&mixed),
                    &p,
                    &preds,
                )
                .batch(16),
            )
            .expect("mixed pool solves");
        assert!(
            s.deployment.placements.iter().all(|pl| pl.gpu >= 2),
            "peak plan should land on the 2x-faster class: {:?}",
            s.deployment.placements
        );
        // and it should beat the homogeneous 2-GPU peak
        let flat = CamelotPlanner
            .plan(
                &PlanRequest::new(
                    Objective::MaxLoad,
                    ClusterState::exclusive(&c),
                    &p,
                    &preds,
                )
                .batch(16),
            )
            .unwrap();
        assert!(s.objective_value > flat.objective_value);
    }

    /// Discrete mode: every quota is a whole multiple of 1/units, at
    /// least the continuous quota, and no GPU exceeds its slice budget.
    #[test]
    fn discrete_snap_lands_on_catalog_without_overcommit() {
        let (c, p, preds) = fixture();
        let mut mig = c.clone();
        mig.partition = PartitionMode::Discrete(SliceCatalog::mig7());
        let cont = HeteroPlanner
            .plan(
                &PlanRequest::new(
                    Objective::MinResource { load_qps: 30.0 },
                    ClusterState::exclusive(&c),
                    &p,
                    &preds,
                )
                .batch(16),
            )
            .unwrap();
        let disc = HeteroPlanner
            .plan(
                &PlanRequest::new(
                    Objective::MinResource { load_qps: 30.0 },
                    ClusterState::exclusive(&mig),
                    &p,
                    &preds,
                )
                .batch(16),
            )
            .expect("discrete pool solves");
        let cat = SliceCatalog::mig7();
        for (qd, qc) in disc.allocation.quotas.iter().zip(&cont.allocation.quotas) {
            let units = qd * cat.units as f64;
            assert!(
                (units - units.round()).abs() < 1e-9,
                "quota {qd} is not on the 1/{} grid",
                cat.units
            );
            assert!(*qd >= *qc - 1e-12, "snap must round up: {qd} < {qc}");
        }
        assert!(disc.usage >= cont.usage - 1e-12);
        // per-GPU slice budget: Σ units ≤ catalog.units on every device
        let mut per_gpu = vec![0u32; mig.num_gpus];
        for pl in &disc.deployment.placements {
            per_gpu[pl.gpu] += cat.units_for(pl.sm_frac);
        }
        for (g, &u) in per_gpu.iter().enumerate() {
            assert!(u <= cat.units, "gpu {g} holds {u}/{} slices", cat.units);
        }
    }

    /// Shrink in discrete mode refuses when the repartition cost eats
    /// the saving (same target ⇒ same snapped plan ⇒ NoImprovement).
    #[test]
    fn discrete_shrink_prices_repartition() {
        let (c, p, preds) = fixture();
        let mut mig = c.clone();
        mig.partition = PartitionMode::Discrete(SliceCatalog::mig7());
        let plan = HeteroPlanner
            .plan(
                &PlanRequest::new(
                    Objective::MinResource { load_qps: 30.0 },
                    ClusterState::exclusive(&mig),
                    &p,
                    &preds,
                )
                .batch(16),
            )
            .unwrap();
        let noop = HeteroPlanner.plan(
            &PlanRequest::new(
                Objective::Shrink { target_qps: 30.0, current: plan.allocation.clone() },
                ClusterState::exclusive(&mig),
                &p,
                &preds,
            )
            .batch(16),
        );
        assert!(
            matches!(noop, Err(Infeasible::NoImprovement { .. })),
            "{noop:?}"
        );
    }

    #[test]
    fn slices_changed_counts_unit_moves() {
        let cat = SliceCatalog::mig7();
        let old = Allocation { instances: vec![2, 1], quotas: vec![3.0 / 7.0, 2.0 / 7.0] };
        let new = Allocation { instances: vec![1, 1], quotas: vec![3.0 / 7.0, 1.0 / 7.0] };
        // stage 0: 6 -> 3 units (3 moved); stage 1: 2 -> 1 (1 moved)
        assert_eq!(slices_changed(&cat, &old, &new), 4);
    }

    /// Mis-declared classes surface as a typed BadRequest, not a panic.
    #[test]
    fn invalid_classes_are_bad_requests() {
        let (c, p, preds) = fixture();
        let mut broken = c.clone();
        // non-identity scale so the homogeneous fast path does not
        // apply, and a count that does not cover the pool
        broken.classes = vec![GpuClass::scaled(c.gpu.clone(), 1, 0.5)];
        let out = HeteroPlanner.plan(&PlanRequest::new(
            Objective::MaxLoad,
            ClusterState::exclusive(&broken),
            &p,
            &preds,
        ));
        assert!(matches!(out, Err(Infeasible::BadRequest { .. })), "{out:?}");
    }
}
