//! [`ClusterState`] — cluster capacity as one value: the static
//! [`ClusterSpec`] plus the *merged* per-GPU holds of every co-located
//! tenant.
//!
//! Before the planner existed, every layer threaded a bare
//! `&[GpuReservation]` by hand (the allocator's constraint checker, the
//! Case-1/Case-2 solvers, the placement pass, the autoscaler, the
//! admission controller), with "empty slice means exclusive cluster" as
//! an implicit convention. `ClusterState` owns that vector, normalizes
//! the empty case away (the reservation vector always has one entry per
//! GPU; an all-default entry is an unheld device), and provides the
//! capacity arithmetic every consumer was re-deriving.

use crate::config::ClusterSpec;
use crate::deploy::{merge_reservations, reservations_for, GpuReservation};
use crate::sim::Deployment;
use crate::suite::Pipeline;

/// A cluster plus the capacity co-located tenants already hold on it.
///
/// Invariant: `reserved.len() == spec.num_gpus` — always. Constructors
/// normalize the legacy "empty = exclusive" convention into a vector of
/// default (zero-hold) entries, which every downstream consumer treats
/// identically.
#[derive(Debug, Clone)]
pub struct ClusterState {
    spec: ClusterSpec,
    reserved: Vec<GpuReservation>,
}

impl ClusterState {
    /// An exclusive (unshared) cluster: every GPU fully free.
    pub fn exclusive(spec: &ClusterSpec) -> ClusterState {
        ClusterState {
            reserved: vec![GpuReservation::default(); spec.num_gpus],
            spec: spec.clone(),
        }
    }

    /// A cluster with co-tenant holds. `reserved` is either empty
    /// (exclusive — the legacy convention) or one entry per GPU.
    pub fn with_reservations(spec: &ClusterSpec, reserved: &[GpuReservation]) -> ClusterState {
        assert!(
            reserved.is_empty() || reserved.len() == spec.num_gpus,
            "reservations must cover every GPU"
        );
        let mut state = ClusterState::exclusive(spec);
        if !reserved.is_empty() {
            state.reserved.copy_from_slice(reserved);
        }
        state
    }

    /// The static cluster description.
    pub fn spec(&self) -> &ClusterSpec {
        &self.spec
    }

    /// Number of GPUs in the pool.
    pub fn num_gpus(&self) -> usize {
        self.spec.num_gpus
    }

    /// The merged per-GPU holds (always one entry per GPU).
    pub fn reservations(&self) -> &[GpuReservation] {
        &self.reserved
    }

    /// Whether any GPU carries a hold (false ⇒ behaves exactly like an
    /// exclusive cluster).
    pub fn is_shared(&self) -> bool {
        self.reserved.iter().any(holds_capacity)
    }

    /// Whether any of the first `bound` GPUs carries a hold — the Eq. 2
    /// GPU-count restriction in the Case-2 solver is only valid when the
    /// candidate prefix is unheld (the bound assumes empty devices).
    pub fn has_holds_within(&self, bound: usize) -> bool {
        self.reserved.iter().take(bound).any(holds_capacity)
    }

    /// Merge another tenant's per-GPU holds into this state.
    pub fn reserve(&mut self, extra: &[GpuReservation]) {
        merge_reservations(&mut self.reserved, extra);
    }

    /// Merge the footprint of a deployed tenant (via
    /// [`reservations_for`]) into this state.
    pub fn reserve_tenant(&mut self, pipeline: &Pipeline, deployment: &Deployment) {
        let holds = reservations_for(pipeline, &self.spec, deployment);
        self.reserve(&holds);
    }

    /// Cluster SM-quota capacity left after the holds (the C1
    /// right-hand side).
    pub fn available_compute(&self) -> f64 {
        let held: f64 = self.reserved.iter().map(|r| r.sm_frac).sum();
        (self.spec.total_compute() - held).max(0.0)
    }

    /// MPS context capacity left after the holds (the C2 right-hand
    /// side).
    pub fn available_contexts(&self) -> u32 {
        let cap = self.spec.total_contexts();
        let held: u32 = self.reserved.iter().map(|r| r.contexts).sum();
        cap.saturating_sub(held)
    }

    /// The sub-cluster of the first `y` GPUs, carrying their (possibly
    /// truncated) holds — the restricted problem the Case-2 solver
    /// grows from its Eq. 2 lower bound.
    pub fn restrict(&self, y: usize) -> ClusterState {
        assert!(y >= 1 && y <= self.spec.num_gpus, "restriction out of range");
        ClusterState {
            spec: self.spec.prefix(y),
            reserved: self.reserved[..y].to_vec(),
        }
    }
}

/// Whether a reservation actually holds anything on its GPU (an
/// all-default entry is indistinguishable from an unheld device).
fn holds_capacity(r: &GpuReservation) -> bool {
    r.sm_frac > 0.0 || r.mem_bytes > 0.0 || r.contexts > 0 || r.bw_demand > 0.0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn held(sm: f64, ctxs: u32) -> GpuReservation {
        GpuReservation { sm_frac: sm, contexts: ctxs, ..Default::default() }
    }

    #[test]
    fn exclusive_has_full_capacity() {
        let c = ClusterSpec::two_2080ti();
        let s = ClusterState::exclusive(&c);
        assert_eq!(s.reservations().len(), 2);
        assert!(!s.is_shared());
        assert!((s.available_compute() - 2.0).abs() < 1e-12);
        assert_eq!(s.available_contexts(), 2 * 48);
    }

    #[test]
    fn empty_slice_normalizes_to_exclusive() {
        let c = ClusterSpec::two_2080ti();
        let s = ClusterState::with_reservations(&c, &[]);
        assert_eq!(s.reservations().len(), 2);
        assert!(!s.is_shared());
        // all-default entries are also exclusive
        let t = ClusterState::with_reservations(&c, &[GpuReservation::default(); 2]);
        assert!(!t.is_shared());
    }

    #[test]
    fn holds_shrink_capacity_and_merge() {
        let c = ClusterSpec::two_2080ti();
        let mut s = ClusterState::with_reservations(&c, &[held(0.5, 8), held(0.0, 0)]);
        assert!(s.is_shared());
        assert!((s.available_compute() - 1.5).abs() < 1e-12);
        assert_eq!(s.available_contexts(), 96 - 8);
        s.reserve(&[held(0.2, 2), held(0.3, 4)]);
        assert!((s.available_compute() - 1.0).abs() < 1e-12);
        assert_eq!(s.available_contexts(), 96 - 14);
    }

    #[test]
    fn restrict_truncates_holds() {
        let c = ClusterSpec::two_2080ti();
        let s = ClusterState::with_reservations(&c, &[held(0.0, 0), held(0.7, 4)]);
        assert!(!s.has_holds_within(1));
        assert!(s.has_holds_within(2));
        let sub = s.restrict(1);
        assert_eq!(sub.num_gpus(), 1);
        assert!(!sub.is_shared());
        assert!((sub.available_compute() - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "reservations must cover every GPU")]
    fn rejects_partial_reservation_vectors() {
        let c = ClusterSpec::two_2080ti();
        let _ = ClusterState::with_reservations(&c, &[held(0.1, 1)]);
    }
}
