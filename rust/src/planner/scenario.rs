//! Declarative scenarios: a JSON [`ScenarioSpec`] describing cluster +
//! tenants + objectives, parsed with the in-tree [`crate::util::json`]
//! parser (the environment has no serde) and runnable through the
//! unified planner.
//!
//! One spec file drives three CLI entry points:
//!
//! * `camelot plan --spec f.json` — [`ScenarioSpec::plan_tables`]:
//!   sequential shared-cluster planning (each tenant plans into the
//!   remainder the previous tenants leave), per-tenant objectives
//!   (Case-1 `max-load` / Case-2 `min-resource`), then a resident-shrink
//!   pass ([`Objective::Shrink`]) for tenants with `shrink_to`.
//! * `camelot admit --spec f.json` — [`ScenarioSpec::trace`]: the
//!   tenants become a [`TenantTrace`] (arrive/depart/shrink events) the
//!   N-tenant admission controller replays with `ClusterSim`
//!   validation.
//! * `camelot colocate --spec f.json` — the first two tenants feed the
//!   co-location + diurnal-autoscaling experiment.
//!
//! Schema (all fields with defaults optional — see EXPERIMENTS.md
//! §ScenarioSpec for the full reference, `examples/*.json` for
//! runnable instances):
//!
//! ```json
//! {
//!   "name": "case1-case2-shrink",
//!   "cluster": {"preset": "2080ti", "gpus": 2},
//!   "//": "mixed pools: cluster.gpu_classes + cluster.partition_mode",
//!   "batch": 16,
//!   "seed": 42,
//!   "queries": 600,
//!   "cells": 1,
//!   "tenants": [
//!     {"name": "captioner", "pipeline": "img-to-text",
//!      "objective": "max-load", "plan_qps": 150.0},
//!     {"name": "translator", "pipeline": "text-to-text",
//!      "objective": "min-resource", "plan_qps": 80.0,
//!      "arrivals": "diurnal", "period_s": 30.0, "trough_frac": 0.3,
//!      "arrive_s": 60.0, "depart_s": 900.0,
//!      "shrink_to": 30.0, "shrink_at_s": 300.0}
//!   ]
//! }
//! ```

use std::path::Path;

use crate::config::{ClusterSpec, GpuClass, GpuSpec, PartitionMode, SliceCatalog};
use crate::predictor::{train_pipeline, StagePredictor};
use crate::suite::workload::{
    ArrivalProcess, DiurnalPattern, Priority, TenantTrace, TenantTraceEvent, TraceEventKind,
};
use crate::suite::Pipeline;
use crate::util::json::Json;
use crate::util::{fnum, Table};

use super::{ClusterState, HeteroPlanner, Objective, Planner, Solution};

/// One tenant of a declarative scenario.
#[derive(Debug, Clone)]
pub struct ScenarioTenant {
    /// Display name (defaults to `<pipeline>#<index>`).
    pub name: String,
    /// Benchmark name, resolvable by [`crate::suite::pipeline_by_name`].
    /// Either given verbatim via `"pipeline"` or synthesized from
    /// `"workload": "llm"` plus `prompt_tokens` / `output_tokens` /
    /// `kv_bytes_per_token` into the canonical
    /// `llm:p{P}:o{O}:kv{K}` grammar (see [`crate::llm`]).
    pub pipeline: String,
    /// `"max-load"` (Case 1) or `"min-resource"` (Case 2, the default).
    pub objective: ScenarioObjective,
    /// Planning load in queries/s (also the arrival process's peak).
    pub plan_qps: f64,
    /// Offered-load model while resident.
    pub arrivals: ArrivalProcess,
    /// Trace timing (used by `admit --spec`): arrival instant.
    pub arrive_s: f64,
    /// Trace timing: departure instant (resident forever when absent).
    pub depart_s: Option<f64>,
    /// Resident shrink: re-admit at this lower load after planning.
    pub shrink_to: Option<f64>,
    /// When the shrink fires in the trace (default: 1 s after arrival).
    pub shrink_at_s: Option<f64>,
    /// Service tier (`"latency-critical"`, the default, or
    /// `"best-effort"`): best-effort residents are preemptible when a
    /// latency-critical arrival would otherwise be rejected.
    pub priority: Priority,
    /// Flash-crowd windows while resident (trace replay only).
    pub bursts: Vec<ScenarioBurst>,
}

/// One flash-crowd window of a scenario tenant: offered load scales to
/// `rate_mult ×` the current peak at `at_s` and restores `duration_s`
/// later.
#[derive(Debug, Clone, Copy)]
pub struct ScenarioBurst {
    /// When the flash crowd opens.
    pub at_s: f64,
    /// Offered-load multiplier while the window is open.
    pub rate_mult: f64,
    /// Window length in seconds.
    pub duration_s: f64,
}

/// One GPU-failure window of a scenario: the listed GPUs fail at
/// `at_s` and (optionally) return at `recover_s`.
#[derive(Debug, Clone)]
pub struct ScenarioGpuFailure {
    /// When the failure strikes.
    pub at_s: f64,
    /// The failed GPU ids.
    pub gpus: Vec<usize>,
    /// When the GPUs return (never when absent).
    pub recover_s: Option<f64>,
}

/// One GPU-degrade window of a scenario: the listed GPUs slow down by
/// `scale` (ECC/thermal throttling) at `at_s` and (optionally) return
/// to full speed at `restore_s`.
#[derive(Debug, Clone)]
pub struct ScenarioGpuDegrade {
    /// When the slowdown begins.
    pub at_s: f64,
    /// The affected GPU ids.
    pub gpus: Vec<usize>,
    /// Compute-time multiplier while degraded (> 1.0: slower).
    pub scale: f64,
    /// When the GPUs return to full speed (never when absent).
    pub restore_s: Option<f64>,
}

/// The per-tenant objective kinds a spec may name.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScenarioObjective {
    /// Case 1 — maximize the supported peak load.
    MaxLoad,
    /// Case 2 — minimize usage at the planning load (the default).
    MinResource,
}

/// A parsed declarative scenario.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    /// Scenario display name.
    pub name: String,
    /// The pool (preset + size, plus `gpu_classes`/`partition_mode` for
    /// mixed or MIG-sliced fleets).
    pub cluster: ClusterSpec,
    /// Serving batch size every tenant plans at.
    pub batch: u32,
    /// Root seed for validation simulations.
    pub seed: u64,
    /// Queries per tenant in validation simulations (`admit --spec`).
    pub queries: usize,
    /// Cells for the cluster-of-cells router (`admit --spec`): 1 runs
    /// the flat admission controller, N > 1 shards the cluster.
    pub cells: usize,
    /// The tenants, in planning/arrival order.
    pub tenants: Vec<ScenarioTenant>,
    /// Chaos: GPU-failure windows injected into the trace replay.
    pub gpu_failures: Vec<ScenarioGpuFailure>,
    /// Chaos: GPU-degrade (slowdown) windows injected into the trace
    /// replay.
    pub gpu_degrades: Vec<ScenarioGpuDegrade>,
}

impl ScenarioSpec {
    /// Parse a spec from JSON text.
    pub fn parse(text: &str) -> Result<ScenarioSpec, String> {
        let doc = Json::parse(text).map_err(|e| format!("scenario spec: {e}"))?;
        Self::from_json(&doc)
    }

    /// Read and parse a spec file.
    pub fn load(path: &Path) -> Result<ScenarioSpec, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    fn from_json(doc: &Json) -> Result<ScenarioSpec, String> {
        let obj = doc.as_obj().ok_or("scenario spec must be a JSON object")?;
        for key in obj.keys() {
            const KNOWN: [&str; 9] = [
                "name", "cluster", "batch", "seed", "queries", "cells", "tenants",
                "gpu_failures", "gpu_degrades",
            ];
            if !KNOWN.contains(&key.as_str()) {
                return Err(format!("unknown scenario field '{key}'"));
            }
        }
        let name = doc.get_str("name").unwrap_or("scenario").to_string();
        let cluster = parse_cluster(doc.get("cluster"))?;
        let batch = parse_count(doc, "batch", 32)?;
        if batch == 0 || batch > u32::MAX as u64 {
            return Err(format!("'batch' must be in 1..={}, got {batch}", u32::MAX));
        }
        let batch = batch as u32;
        let seed = parse_count(doc, "seed", 42)?;
        let queries = parse_count(doc, "queries", 800)? as usize;
        let cells = parse_count(doc, "cells", 1)? as usize;
        if cells == 0 || cells > cluster.num_gpus {
            return Err(format!(
                "'cells' must be in 1..={} (one GPU per cell minimum), got {cells}",
                cluster.num_gpus
            ));
        }
        let tenants_json = doc
            .get("tenants")
            .and_then(Json::as_arr)
            .ok_or("scenario spec needs a 'tenants' array")?;
        if tenants_json.is_empty() {
            return Err("scenario spec needs at least one tenant".to_string());
        }
        let mut tenants: Vec<ScenarioTenant> = Vec::with_capacity(tenants_json.len());
        for (i, t) in tenants_json.iter().enumerate() {
            let tenant = parse_tenant(t, i)?;
            if tenants.iter().any(|u| u.name == tenant.name) {
                return Err(format!("duplicate tenant name '{}'", tenant.name));
            }
            tenants.push(tenant);
        }
        let gpu_failures = parse_gpu_failures(doc.get("gpu_failures"), cluster.num_gpus)?;
        let gpu_degrades = parse_gpu_degrades(doc.get("gpu_degrades"), cluster.num_gpus)?;
        Ok(ScenarioSpec {
            name,
            cluster,
            batch,
            seed,
            queries,
            cells,
            tenants,
            gpu_failures,
            gpu_degrades,
        })
    }

    /// The tenants as a time-ordered arrival/departure/shrink trace for
    /// the admission controller, chaos events (flash-crowd bursts and
    /// GPU-failure windows) included. Burst *end* events are not
    /// emitted here — the replay synthesizes them from each burst's
    /// `duration_s`.
    pub fn trace(&self) -> TenantTrace {
        let mut events = Vec::new();
        for (i, t) in self.tenants.iter().enumerate() {
            let tenant = i as u64;
            events.push(TenantTraceEvent {
                t_s: t.arrive_s,
                tenant,
                kind: TraceEventKind::Arrive {
                    pipeline: t.pipeline.clone(),
                    name: Some(t.name.clone()),
                    arrivals: t.arrivals.clone(),
                    plan_qps: t.plan_qps,
                    priority: t.priority,
                },
            });
            if let Some(target) = t.shrink_to {
                events.push(TenantTraceEvent {
                    t_s: t.shrink_at_s.unwrap_or(t.arrive_s + 1.0),
                    tenant,
                    kind: TraceEventKind::Shrink { target_qps: target },
                });
            }
            for b in &t.bursts {
                events.push(TenantTraceEvent {
                    t_s: b.at_s,
                    tenant,
                    kind: TraceEventKind::Burst {
                        rate_mult: b.rate_mult,
                        duration_s: b.duration_s,
                    },
                });
            }
            if let Some(at) = t.depart_s {
                events.push(TenantTraceEvent { t_s: at, tenant, kind: TraceEventKind::Depart });
            }
        }
        for f in &self.gpu_failures {
            // tenant id 0 by convention: GPU events are fleet-scoped
            events.push(TenantTraceEvent {
                t_s: f.at_s,
                tenant: 0,
                kind: TraceEventKind::GpuFail { gpu_ids: f.gpus.clone() },
            });
            if let Some(r) = f.recover_s {
                events.push(TenantTraceEvent {
                    t_s: r,
                    tenant: 0,
                    kind: TraceEventKind::GpuRecover { gpu_ids: f.gpus.clone() },
                });
            }
        }
        for d in &self.gpu_degrades {
            events.push(TenantTraceEvent {
                t_s: d.at_s,
                tenant: 0,
                kind: TraceEventKind::GpuDegrade { gpu_ids: d.gpus.clone(), scale: d.scale },
            });
            if let Some(r) = d.restore_s {
                events.push(TenantTraceEvent {
                    t_s: r,
                    tenant: 0,
                    kind: TraceEventKind::GpuRestore { gpu_ids: d.gpus.clone() },
                });
            }
        }
        TenantTrace::sort_events(&mut events);
        TenantTrace { events }
    }

    /// Run the spec through the unified planner: sequential
    /// shared-cluster planning in tenant order (each tenant's plan
    /// becomes a reservation the next tenant plans around), then the
    /// resident-shrink pass. Returns the plan table and — when any
    /// tenant declares `shrink_to` — the shrink table.
    pub fn plan_tables(&self) -> Result<Vec<Table>, String> {
        struct Planned {
            pipeline: Pipeline,
            predictors: Vec<StagePredictor>,
            solution: Solution,
        }
        let mut plan_t = Table::new(
            &format!("Scenario '{}': sequential shared-cluster planning", self.name),
            &[
                "tenant", "pipeline", "objective", "instances", "sm_pct", "usage", "gpus",
                "pred_p99_ms", "qos_ms",
            ],
        );
        let mut planned: Vec<Planned> = Vec::with_capacity(self.tenants.len());
        let mut state = ClusterState::exclusive(&self.cluster);
        // training is deterministic, so the per-pipeline memo is purely
        // a speedup for specs that repeat pipelines (same pattern as
        // AdmissionController::predictors_for)
        let mut predictor_cache: Vec<(String, Vec<StagePredictor>)> = Vec::new();
        for t in &self.tenants {
            let pipeline = crate::suite::pipeline_by_name(&t.pipeline)
                .ok_or_else(|| format!("tenant '{}': unknown pipeline '{}'", t.name, t.pipeline))?;
            let predictors = match predictor_cache.iter().find(|(n, _)| *n == pipeline.name) {
                Some((_, preds)) => preds.clone(),
                None => {
                    let preds = train_pipeline(&pipeline, &self.cluster.gpu);
                    predictor_cache.push((pipeline.name.clone(), preds.clone()));
                    preds
                }
            };
            let objective = match t.objective {
                ScenarioObjective::MaxLoad => Objective::MaxLoad,
                ScenarioObjective::MinResource => {
                    Objective::MinResource { load_qps: t.plan_qps }
                }
            };
            let req = super::PlanRequest::new(objective, state.clone(), &pipeline, &predictors)
                .batch(self.batch);
            let solution = HeteroPlanner
                .plan(&req)
                .map_err(|e| format!("tenant '{}': {e}", t.name))?;
            state.reserve_tenant(&pipeline, &solution.deployment);
            plan_t.push(&[
                t.name.clone(),
                pipeline.name.clone(),
                req.objective.name().to_string(),
                format!("{:?}", solution.allocation.instances),
                quota_pcts(&solution.allocation.quotas),
                format!("{:.2}", solution.usage),
                solution.gpus.to_string(),
                format!("{:.1}", solution.predicted_p99_s * 1e3),
                format!("{:.1}", pipeline.qos_target_s * 1e3),
            ]);
            planned.push(Planned { pipeline, predictors, solution });
        }

        let mut tables = vec![plan_t];
        if self.tenants.iter().any(|t| t.shrink_to.is_some()) {
            let mut shrink_t = Table::new(
                &format!("Scenario '{}': resident shrink (Objective::Shrink)", self.name),
                &["tenant", "target_qps", "usage_before", "usage_after", "gpus", "outcome"],
            );
            for (i, t) in self.tenants.iter().enumerate() {
                let Some(target) = t.shrink_to else { continue };
                // the remainder this tenant re-plans into: every OTHER
                // tenant's current footprint
                let mut others = ClusterState::exclusive(&self.cluster);
                for (j, pl) in planned.iter().enumerate() {
                    if j != i {
                        others.reserve_tenant(&pl.pipeline, &pl.solution.deployment);
                    }
                }
                let outcome = {
                    let pl = &planned[i];
                    let req = super::PlanRequest::new(
                        Objective::Shrink {
                            target_qps: target,
                            current: pl.solution.allocation.clone(),
                        },
                        others,
                        &pl.pipeline,
                        &pl.predictors,
                    )
                    .batch(self.batch);
                    HeteroPlanner.plan(&req)
                };
                let before = planned[i].solution.usage;
                match outcome {
                    Ok(s) => {
                        shrink_t.push(&[
                            t.name.clone(),
                            fnum(target),
                            format!("{before:.2}"),
                            format!("{:.2}", s.usage),
                            s.gpus.to_string(),
                            "shrunk".to_string(),
                        ]);
                        planned[i].solution = s;
                    }
                    Err(e) => shrink_t.push(&[
                        t.name.clone(),
                        fnum(target),
                        format!("{before:.2}"),
                        format!("{before:.2}"),
                        planned[i].solution.gpus.to_string(),
                        format!("held: {e}"),
                    ]),
                }
            }
            tables.push(shrink_t);
        }
        Ok(tables)
    }
}

fn quota_pcts(quotas: &[f64]) -> String {
    format!(
        "{:?}",
        quotas.iter().map(|q| (q * 100.0).round() as u32).collect::<Vec<_>>()
    )
}

fn parse_cluster(node: Option<&Json>) -> Result<ClusterSpec, String> {
    let Some(node) = node else {
        return Ok(ClusterSpec::two_2080ti());
    };
    let obj = node.as_obj().ok_or("'cluster' must be a JSON object")?;
    for key in obj.keys() {
        const KNOWN: [&str; 4] = ["preset", "gpus", "partition_mode", "gpu_classes"];
        if !KNOWN.contains(&key.as_str()) {
            return Err(format!("cluster: unknown field '{key}'"));
        }
    }
    let preset = node.get_str("preset").unwrap_or("2080ti");
    let mut cluster = match preset {
        "2080ti" => ClusterSpec::two_2080ti(),
        "dgx2" => ClusterSpec::dgx2(),
        other => return Err(format!("unknown cluster preset '{other}' (2080ti | dgx2)")),
    };
    if let Some(g) = node.get_f64("gpus") {
        let gpus = g as usize;
        if g.fract() != 0.0 || !(1..=32).contains(&gpus) {
            return Err(format!("cluster gpus must be an integer in 1..=32, got {g}"));
        }
        cluster.num_gpus = gpus;
    }
    cluster.partition = parse_partition_mode(node.get("partition_mode"), "cluster")?;
    if let Some(classes_json) = node.get("gpu_classes") {
        let arr = classes_json
            .as_arr()
            .ok_or("cluster: 'gpu_classes' must be an array")?;
        if arr.is_empty() {
            return Err("cluster: 'gpu_classes' must not be empty".to_string());
        }
        let mut classes = Vec::with_capacity(arr.len());
        for (i, c) in arr.iter().enumerate() {
            classes.push(parse_gpu_class(c, i, &cluster)?);
        }
        // 'gpus' may be omitted when the classes describe the pool fully
        if node.get("gpus").is_none() {
            cluster.num_gpus = classes.iter().map(|c: &GpuClass| c.count).sum();
        }
        cluster.classes = classes;
        cluster
            .validate_classes()
            .map_err(|e| format!("cluster: {e}"))?;
    }
    Ok(cluster)
}

fn parse_partition_mode(node: Option<&Json>, what: &str) -> Result<PartitionMode, String> {
    match node {
        None => Ok(PartitionMode::Continuous),
        Some(v) => match v.as_str() {
            Some("continuous") => Ok(PartitionMode::Continuous),
            Some("discrete") => Ok(PartitionMode::Discrete(SliceCatalog::mig7())),
            Some(other) => Err(format!(
                "{what}: unknown partition_mode '{other}' (continuous | discrete)"
            )),
            None => Err(format!("{what}: 'partition_mode' must be a string")),
        },
    }
}

/// One entry of a cluster's `gpu_classes` array:
/// `{"gpu": "a100", "count": 2, "compute_scale": 0.7, "partition_mode": "discrete"}`.
///
/// `compute_scale` defaults to the GFLOPS ratio of the pool's base GPU
/// to the class GPU (an H100 class in a 2080 Ti pool defaults to a
/// scale < 1, i.e. faster stages); `partition_mode` defaults to the
/// pool-wide mode.
fn parse_gpu_class(node: &Json, index: usize, pool: &ClusterSpec) -> Result<GpuClass, String> {
    let obj = node
        .as_obj()
        .ok_or_else(|| format!("gpu_classes[{index}] must be a JSON object"))?;
    for key in obj.keys() {
        const KNOWN: [&str; 4] = ["gpu", "count", "compute_scale", "partition_mode"];
        if !KNOWN.contains(&key.as_str()) {
            return Err(format!("gpu_classes[{index}]: unknown field '{key}'"));
        }
    }
    let name = node
        .get_str("gpu")
        .ok_or_else(|| format!("gpu_classes[{index}] needs a 'gpu' preset name"))?;
    let gpu = GpuSpec::by_name(name).ok_or_else(|| {
        format!("gpu_classes[{index}]: unknown gpu '{name}' (2080ti | v100 | a100 | h100)")
    })?;
    let count = match node.get_f64("count") {
        Some(c) if c.fract() == 0.0 && (1.0..=32.0).contains(&c) => c as usize,
        Some(c) => {
            return Err(format!(
                "gpu_classes[{index}]: count must be an integer in 1..=32, got {c}"
            ))
        }
        None => return Err(format!("gpu_classes[{index}] needs a 'count'")),
    };
    let compute_scale = match node.get_f64("compute_scale") {
        Some(s) if s.is_finite() && s > 0.0 => s,
        Some(s) => {
            return Err(format!(
                "gpu_classes[{index}]: compute_scale must be finite and > 0, got {s}"
            ))
        }
        None => pool.gpu.gflops / gpu.gflops,
    };
    let partition = match node.get("partition_mode") {
        None => pool.partition.clone(),
        some => parse_partition_mode(some, &format!("gpu_classes[{index}]"))?,
    };
    Ok(GpuClass { gpu, count, compute_scale, partition })
}

/// Read a non-negative integer field with a default.
fn parse_count(doc: &Json, key: &str, default: u64) -> Result<u64, String> {
    match doc.get(key) {
        None => Ok(default),
        Some(v) => {
            let x = v.as_f64().ok_or_else(|| format!("'{key}' must be a number"))?;
            if x.fract() != 0.0 || x < 0.0 || x > u64::MAX as f64 {
                return Err(format!("'{key}' must be a non-negative integer, got {x}"));
            }
            Ok(x as u64)
        }
    }
}

fn parse_tenant(node: &Json, index: usize) -> Result<ScenarioTenant, String> {
    let obj = node
        .as_obj()
        .ok_or_else(|| format!("tenant #{index} must be a JSON object"))?;
    for key in obj.keys() {
        const KNOWN: [&str; 17] = [
            "name", "pipeline", "objective", "plan_qps", "arrivals", "period_s",
            "trough_frac", "arrive_s", "depart_s", "shrink_to", "shrink_at_s",
            "priority", "bursts", "workload", "prompt_tokens", "output_tokens",
            "kv_bytes_per_token",
        ];
        if !KNOWN.contains(&key.as_str()) {
            return Err(format!("tenant #{index}: unknown field '{key}'"));
        }
    }
    let pipeline = match (node.get_str("pipeline"), node.get_str("workload")) {
        (Some(_), Some(_)) => {
            return Err(format!(
                "tenant #{index}: 'pipeline' and 'workload' are mutually exclusive"
            ))
        }
        (Some(p), None) => {
            for key in ["prompt_tokens", "output_tokens", "kv_bytes_per_token"] {
                if node.get(key).is_some() {
                    return Err(format!(
                        "tenant #{index}: '{key}' requires \"workload\": \"llm\""
                    ));
                }
            }
            p.to_string()
        }
        (None, Some("llm")) => {
            // synthesize the canonical llm:p{P}:o{O}:kv{K} pipeline name
            // so the tenant resolves through pipeline_by_name like any
            // benchmark — the grammar is the declarative contract
            let prompt = parse_count(node, "prompt_tokens", 512)?;
            let output = parse_count(node, "output_tokens", 128)?;
            let kv = parse_count(node, "kv_bytes_per_token", 65_536)?;
            if prompt == 0 || output == 0 || kv == 0 {
                return Err(format!(
                    "tenant #{index}: llm workload parameters must be positive"
                ));
            }
            if prompt > u32::MAX as u64 || output > u32::MAX as u64 {
                return Err(format!(
                    "tenant #{index}: llm token counts must fit in 32 bits"
                ));
            }
            let params = crate::llm::LlmParams {
                prompt_tokens: prompt as u32,
                output_tokens: output as u32,
                kv_bytes_per_token: kv,
            };
            params.pipeline_name()
        }
        (None, Some(other)) => {
            return Err(format!(
                "tenant #{index}: unknown workload '{other}' (llm)"
            ))
        }
        (None, None) => {
            return Err(format!("tenant #{index} needs a 'pipeline' or a 'workload'"))
        }
    };
    if crate::suite::pipeline_by_name(&pipeline).is_none() {
        return Err(format!("tenant #{index}: unknown pipeline '{pipeline}'"));
    }
    let name = node
        .get_str("name")
        .map(str::to_string)
        .unwrap_or_else(|| format!("{pipeline}#{index}"));
    let objective = match node.get_str("objective").unwrap_or("min-resource") {
        "max-load" => ScenarioObjective::MaxLoad,
        "min-resource" => ScenarioObjective::MinResource,
        other => {
            return Err(format!(
                "tenant '{name}': unknown objective '{other}' (max-load | min-resource)"
            ))
        }
    };
    let plan_qps = node
        .get_f64("plan_qps")
        .ok_or_else(|| format!("tenant '{name}' needs a positive 'plan_qps'"))?;
    if plan_qps.is_nan() || plan_qps <= 0.0 {
        return Err(format!("tenant '{name}': plan_qps must be positive, got {plan_qps}"));
    }
    let period_s = node.get_f64("period_s").unwrap_or(30.0);
    let trough_frac = node.get_f64("trough_frac").unwrap_or(0.3);
    if !(0.0..=1.0).contains(&trough_frac) {
        return Err(format!("tenant '{name}': trough_frac must be in [0, 1]"));
    }
    let arrivals = match node.get_str("arrivals").unwrap_or("constant") {
        "constant" => ArrivalProcess::constant(plan_qps),
        "diurnal" => ArrivalProcess::diurnal(DiurnalPattern {
            peak_qps: plan_qps,
            trough_frac,
            period_s,
        }),
        other => {
            return Err(format!(
                "tenant '{name}': unknown arrivals '{other}' (constant | diurnal)"
            ))
        }
    };
    let arrive_s = node.get_f64("arrive_s").unwrap_or(index as f64);
    let depart_s = node.get_f64("depart_s");
    if let Some(d) = depart_s {
        if d <= arrive_s {
            return Err(format!("tenant '{name}': depart_s {d} must follow arrive_s {arrive_s}"));
        }
    }
    let shrink_to = node.get_f64("shrink_to");
    if let Some(s) = shrink_to {
        if s.is_nan() || s <= 0.0 {
            return Err(format!("tenant '{name}': shrink_to must be positive, got {s}"));
        }
    }
    let priority = match node.get_str("priority").unwrap_or("latency-critical") {
        "latency-critical" => Priority::LatencyCritical,
        "best-effort" => Priority::BestEffort,
        other => {
            return Err(format!(
                "tenant '{name}': unknown priority '{other}' (latency-critical | best-effort)"
            ))
        }
    };
    let mut bursts = Vec::new();
    if let Some(arr) = node.get("bursts") {
        let arr = arr
            .as_arr()
            .ok_or_else(|| format!("tenant '{name}': 'bursts' must be an array"))?;
        for (j, b) in arr.iter().enumerate() {
            let obj = b
                .as_obj()
                .ok_or_else(|| format!("tenant '{name}': burst #{j} must be a JSON object"))?;
            for key in obj.keys() {
                const KNOWN: [&str; 3] = ["at_s", "rate_mult", "duration_s"];
                if !KNOWN.contains(&key.as_str()) {
                    return Err(format!("tenant '{name}': burst #{j}: unknown field '{key}'"));
                }
            }
            let at_s = b
                .get_f64("at_s")
                .ok_or_else(|| format!("tenant '{name}': burst #{j} needs an 'at_s'"))?;
            let rate_mult = b
                .get_f64("rate_mult")
                .ok_or_else(|| format!("tenant '{name}': burst #{j} needs a 'rate_mult'"))?;
            if !rate_mult.is_finite() || rate_mult <= 0.0 {
                return Err(format!(
                    "tenant '{name}': burst #{j}: rate_mult must be positive, got {rate_mult}"
                ));
            }
            let duration_s = b
                .get_f64("duration_s")
                .ok_or_else(|| format!("tenant '{name}': burst #{j} needs a 'duration_s'"))?;
            if !duration_s.is_finite() || duration_s <= 0.0 {
                return Err(format!(
                    "tenant '{name}': burst #{j}: duration_s must be positive, got {duration_s}"
                ));
            }
            // a burst opening outside the residency window would
            // silently no-op in the replay — reject it here instead
            if at_s < arrive_s {
                return Err(format!(
                    "tenant '{name}': burst #{j}: at_s {at_s} must not precede arrive_s {arrive_s}"
                ));
            }
            if let Some(d) = depart_s {
                if at_s >= d {
                    return Err(format!(
                        "tenant '{name}': burst #{j}: at_s {at_s} must precede depart_s {d}"
                    ));
                }
            }
            bursts.push(ScenarioBurst { at_s, rate_mult, duration_s });
        }
    }
    let shrink_at_s = node.get_f64("shrink_at_s");
    if shrink_to.is_some() {
        // a shrink outside the tenant's residency window would sort
        // before the arrival (or after the departure) and silently
        // no-op in the trace replay — reject it here instead
        let at = shrink_at_s.unwrap_or(arrive_s + 1.0);
        if at <= arrive_s {
            return Err(format!(
                "tenant '{name}': shrink_at_s {at} must follow arrive_s {arrive_s}"
            ));
        }
        if let Some(d) = depart_s {
            if at >= d {
                return Err(format!(
                    "tenant '{name}': shrink_at_s {at} must precede depart_s {d} \
                     (set shrink_at_s explicitly for short residencies)"
                ));
            }
        }
    } else if shrink_at_s.is_some() {
        return Err(format!("tenant '{name}': shrink_at_s without shrink_to"));
    }
    Ok(ScenarioTenant {
        name,
        pipeline,
        objective,
        plan_qps,
        arrivals,
        arrive_s,
        depart_s,
        shrink_to,
        shrink_at_s,
        priority,
        bursts,
    })
}

/// Parse and validate the scenario-level `gpu_failures` array against
/// the resolved cluster size.
fn parse_gpu_failures(
    node: Option<&Json>,
    num_gpus: usize,
) -> Result<Vec<ScenarioGpuFailure>, String> {
    let Some(node) = node else {
        return Ok(Vec::new());
    };
    let arr = node.as_arr().ok_or("'gpu_failures' must be an array")?;
    let mut out = Vec::with_capacity(arr.len());
    for (j, f) in arr.iter().enumerate() {
        let obj = f
            .as_obj()
            .ok_or_else(|| format!("gpu failure #{j} must be a JSON object"))?;
        for key in obj.keys() {
            const KNOWN: [&str; 3] = ["at_s", "gpus", "recover_s"];
            if !KNOWN.contains(&key.as_str()) {
                return Err(format!("gpu failure #{j}: unknown field '{key}'"));
            }
        }
        let at_s = f
            .get_f64("at_s")
            .ok_or_else(|| format!("gpu failure #{j} needs an 'at_s'"))?;
        let gpus_json = f
            .get("gpus")
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("gpu failure #{j} needs a 'gpus' array"))?;
        if gpus_json.is_empty() {
            return Err(format!("gpu failure #{j}: 'gpus' must not be empty"));
        }
        let mut gpus = Vec::with_capacity(gpus_json.len());
        for g in gpus_json {
            let x = g
                .as_f64()
                .ok_or_else(|| format!("gpu failure #{j}: gpu ids must be numbers"))?;
            if x.fract() != 0.0 || x < 0.0 || x as usize >= num_gpus {
                return Err(format!(
                    "gpu failure #{j}: gpu id {x} out of range (cluster has {num_gpus} GPUs)"
                ));
            }
            gpus.push(x as usize);
        }
        let recover_s = f.get_f64("recover_s");
        if let Some(r) = recover_s {
            if r <= at_s {
                return Err(format!(
                    "gpu failure #{j}: recover_s {r} must follow at_s {at_s}"
                ));
            }
        }
        out.push(ScenarioGpuFailure { at_s, gpus, recover_s });
    }
    Ok(out)
}

/// Parse and validate the scenario-level `gpu_degrades` array against
/// the resolved cluster size.
fn parse_gpu_degrades(
    node: Option<&Json>,
    num_gpus: usize,
) -> Result<Vec<ScenarioGpuDegrade>, String> {
    let Some(node) = node else {
        return Ok(Vec::new());
    };
    let arr = node.as_arr().ok_or("'gpu_degrades' must be an array")?;
    let mut out = Vec::with_capacity(arr.len());
    for (j, d) in arr.iter().enumerate() {
        let obj = d
            .as_obj()
            .ok_or_else(|| format!("gpu degrade #{j} must be a JSON object"))?;
        for key in obj.keys() {
            const KNOWN: [&str; 4] = ["at_s", "gpus", "scale", "restore_s"];
            if !KNOWN.contains(&key.as_str()) {
                return Err(format!("gpu degrade #{j}: unknown field '{key}'"));
            }
        }
        let at_s = d
            .get_f64("at_s")
            .ok_or_else(|| format!("gpu degrade #{j} needs an 'at_s'"))?;
        let gpus_json = d
            .get("gpus")
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("gpu degrade #{j} needs a 'gpus' array"))?;
        if gpus_json.is_empty() {
            return Err(format!("gpu degrade #{j}: 'gpus' must not be empty"));
        }
        let mut gpus = Vec::with_capacity(gpus_json.len());
        for g in gpus_json {
            let x = g
                .as_f64()
                .ok_or_else(|| format!("gpu degrade #{j}: gpu ids must be numbers"))?;
            if x.fract() != 0.0 || x < 0.0 || x as usize >= num_gpus {
                return Err(format!(
                    "gpu degrade #{j}: gpu id {x} out of range (cluster has {num_gpus} GPUs)"
                ));
            }
            gpus.push(x as usize);
        }
        let scale = d
            .get_f64("scale")
            .ok_or_else(|| format!("gpu degrade #{j} needs a 'scale'"))?;
        // 1.0 is a no-op and < 1.0 would be a speed-UP; a degrade is
        // strictly a slowdown
        if !scale.is_finite() || scale <= 1.0 {
            return Err(format!(
                "gpu degrade #{j}: scale must be finite and > 1.0 (slower), got {scale}"
            ));
        }
        let restore_s = d.get_f64("restore_s");
        if let Some(r) = restore_s {
            if r <= at_s {
                return Err(format!(
                    "gpu degrade #{j}: restore_s {r} must follow at_s {at_s}"
                ));
            }
        }
        out.push(ScenarioGpuDegrade { at_s, gpus, scale, restore_s });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    // min-resource tenant plans first; the max-load tenant fills the
    // remainder and is later shrunk back to its off-peak load — the
    // same shape as examples/scenario_plan_shrink.json
    const SPEC: &str = r#"{
        "name": "test",
        "cluster": {"preset": "2080ti"},
        "batch": 16,
        "queries": 200,
        "tenants": [
            {"name": "b", "pipeline": "text-to-text", "objective": "min-resource",
             "plan_qps": 80.0},
            {"name": "a", "pipeline": "img-to-text", "objective": "max-load",
             "plan_qps": 150.0, "arrivals": "diurnal", "arrive_s": 10.0,
             "depart_s": 500.0, "shrink_to": 40.0, "shrink_at_s": 200.0}
        ]
    }"#;

    #[test]
    fn parses_the_reference_spec() {
        let spec = ScenarioSpec::parse(SPEC).unwrap();
        assert_eq!(spec.name, "test");
        assert_eq!(spec.batch, 16);
        assert_eq!(spec.queries, 200);
        assert_eq!(spec.seed, 42, "default seed");
        assert_eq!(spec.cells, 1, "default cells");
        assert_eq!(spec.tenants.len(), 2);
        assert_eq!(spec.tenants[0].objective, ScenarioObjective::MinResource);
        assert_eq!(spec.tenants[1].objective, ScenarioObjective::MaxLoad);
        assert_eq!(spec.tenants[1].shrink_to, Some(40.0));
        assert!(matches!(
            spec.tenants[1].arrivals,
            ArrivalProcess::Diurnal { .. }
        ));
    }

    #[test]
    fn trace_orders_arrive_shrink_depart() {
        let spec = ScenarioSpec::parse(SPEC).unwrap();
        let trace = spec.trace();
        assert_eq!(trace.events.len(), 4);
        assert!(trace.events.windows(2).all(|w| w[0].t_s <= w[1].t_s));
        let kinds: Vec<&'static str> = trace
            .events
            .iter()
            .map(|e| match e.kind {
                TraceEventKind::Arrive { .. } => "arrive",
                TraceEventKind::Shrink { .. } => "shrink",
                TraceEventKind::Depart => "depart",
                TraceEventKind::Burst { .. } => "burst",
                TraceEventKind::BurstEnd => "burst-end",
                TraceEventKind::GpuFail { .. } => "gpufail",
                TraceEventKind::GpuRecover { .. } => "gpurecover",
                TraceEventKind::GpuDegrade { .. } => "gpudegrade",
                TraceEventKind::GpuRestore { .. } => "gpurestore",
            })
            .collect();
        assert_eq!(kinds, ["arrive", "arrive", "shrink", "depart"]);
    }

    #[test]
    fn parses_chaos_fields() {
        let spec = ScenarioSpec::parse(
            r#"{
            "gpu_failures": [{"at_s": 100.0, "gpus": [0], "recover_s": 200.0}],
            "gpu_degrades": [{"at_s": 300.0, "gpus": [1], "scale": 1.5,
                              "restore_s": 400.0}],
            "tenants": [
                {"name": "lc", "pipeline": "img-to-text", "plan_qps": 90,
                 "bursts": [{"at_s": 30.0, "rate_mult": 2.0, "duration_s": 15.0}]},
                {"name": "be", "pipeline": "text-to-text", "plan_qps": 40,
                 "priority": "best-effort", "arrive_s": 5.0}
            ]
        }"#,
        )
        .unwrap();
        assert_eq!(spec.tenants[0].priority, Priority::LatencyCritical, "default tier");
        assert_eq!(spec.tenants[1].priority, Priority::BestEffort);
        assert_eq!(spec.tenants[0].bursts.len(), 1);
        assert_eq!(spec.gpu_failures.len(), 1);
        assert_eq!(spec.gpu_failures[0].gpus, vec![0]);
        assert_eq!(spec.gpu_degrades.len(), 1);
        assert_eq!(spec.gpu_degrades[0].gpus, vec![1]);
        assert_eq!(spec.gpu_degrades[0].scale, 1.5);
        // trace emits arrive(0), be-arrive(5), burst(30), gpufail(100),
        // gpurecover(200), gpudegrade(300), gpurestore(400) — burst ends
        // are the replay's to synthesize
        let trace = spec.trace();
        let kinds: Vec<&'static str> = trace
            .events
            .iter()
            .map(|e| match e.kind {
                TraceEventKind::Arrive { .. } => "arrive",
                TraceEventKind::Shrink { .. } => "shrink",
                TraceEventKind::Depart => "depart",
                TraceEventKind::Burst { .. } => "burst",
                TraceEventKind::BurstEnd => "burst-end",
                TraceEventKind::GpuFail { .. } => "gpufail",
                TraceEventKind::GpuRecover { .. } => "gpurecover",
                TraceEventKind::GpuDegrade { .. } => "gpudegrade",
                TraceEventKind::GpuRestore { .. } => "gpurestore",
            })
            .collect();
        assert_eq!(
            kinds,
            ["arrive", "arrive", "burst", "gpufail", "gpurecover", "gpudegrade", "gpurestore"]
        );
        let priorities: Vec<Priority> = trace
            .events
            .iter()
            .filter_map(|e| match &e.kind {
                TraceEventKind::Arrive { priority, .. } => Some(*priority),
                _ => None,
            })
            .collect();
        assert_eq!(priorities, [Priority::LatencyCritical, Priority::BestEffort]);
    }

    #[test]
    fn rejects_malformed_chaos_fields() {
        // (fragment, expected error substring) — the strings are part
        // of the spec surface (fuzz failure dumps lean on them), so
        // they are pinned here
        for (frag, want) in [
            (
                r#"{"tenants": [{"pipeline": "img-to-text", "plan_qps": 10,
                    "priority": "whenever"}]}"#,
                "unknown priority 'whenever'",
            ),
            (
                r#"{"tenants": [{"pipeline": "img-to-text", "plan_qps": 10,
                    "bursts": [{"at_s": 5, "rate_mult": 2.0, "duration_s": 10, "typo": 1}]}]}"#,
                "burst #0: unknown field 'typo'",
            ),
            (
                r#"{"tenants": [{"pipeline": "img-to-text", "plan_qps": 10,
                    "bursts": [{"at_s": 5, "rate_mult": -2.0, "duration_s": 10}]}]}"#,
                "rate_mult must be positive",
            ),
            (
                r#"{"tenants": [{"pipeline": "img-to-text", "plan_qps": 10,
                    "bursts": [{"at_s": 5, "rate_mult": 2.0, "duration_s": 0}]}]}"#,
                "duration_s must be positive",
            ),
            (
                r#"{"tenants": [{"pipeline": "img-to-text", "plan_qps": 10, "arrive_s": 50,
                    "bursts": [{"at_s": 5, "rate_mult": 2.0, "duration_s": 10}]}]}"#,
                "must not precede arrive_s",
            ),
            (
                r#"{"tenants": [{"pipeline": "img-to-text", "plan_qps": 10, "depart_s": 100,
                    "bursts": [{"at_s": 150, "rate_mult": 2.0, "duration_s": 10}]}]}"#,
                "must precede depart_s",
            ),
            (
                r#"{"gpu_failures": [{"at_s": 5, "gpus": [7]}],
                    "tenants": [{"pipeline": "img-to-text", "plan_qps": 10}]}"#,
                "gpu id 7 out of range",
            ),
            (
                r#"{"gpu_failures": [{"at_s": 5, "gpus": []}],
                    "tenants": [{"pipeline": "img-to-text", "plan_qps": 10}]}"#,
                "'gpus' must not be empty",
            ),
            (
                r#"{"gpu_failures": [{"at_s": 50, "gpus": [0], "recover_s": 50}],
                    "tenants": [{"pipeline": "img-to-text", "plan_qps": 10}]}"#,
                "recover_s 50 must follow at_s 50",
            ),
            (
                r#"{"gpu_failures": [{"at_s": 5, "gpus": [0], "undo_s": 9}],
                    "tenants": [{"pipeline": "img-to-text", "plan_qps": 10}]}"#,
                "gpu failure #0: unknown field 'undo_s'",
            ),
            (
                r#"{"gpu_degrades": [{"at_s": 5, "gpus": [0]}],
                    "tenants": [{"pipeline": "img-to-text", "plan_qps": 10}]}"#,
                "gpu degrade #0 needs a 'scale'",
            ),
            (
                r#"{"gpu_degrades": [{"at_s": 5, "gpus": [0], "scale": 1.0}],
                    "tenants": [{"pipeline": "img-to-text", "plan_qps": 10}]}"#,
                "scale must be finite and > 1.0",
            ),
            (
                r#"{"gpu_degrades": [{"at_s": 5, "gpus": [7], "scale": 1.5}],
                    "tenants": [{"pipeline": "img-to-text", "plan_qps": 10}]}"#,
                "gpu degrade #0: gpu id 7 out of range",
            ),
            (
                r#"{"gpu_degrades": [{"at_s": 50, "gpus": [0], "scale": 1.5, "restore_s": 50}],
                    "tenants": [{"pipeline": "img-to-text", "plan_qps": 10}]}"#,
                "restore_s 50 must follow at_s 50",
            ),
            (
                r#"{"gpu_degrades": [{"at_s": 5, "gpus": [0], "scale": 1.5, "undo_s": 9}],
                    "tenants": [{"pipeline": "img-to-text", "plan_qps": 10}]}"#,
                "gpu degrade #0: unknown field 'undo_s'",
            ),
        ] {
            let err = ScenarioSpec::parse(frag).expect_err(want);
            assert!(err.contains(want), "expected '{want}' in '{err}'");
        }
    }

    #[test]
    fn rejects_malformed_specs() {
        for (frag, what) in [
            (r#"{"tenants": []}"#, "empty tenants"),
            (r#"{"tenants": [{"pipeline": "nope", "plan_qps": 10}]}"#, "bad pipeline"),
            (
                r#"{"tenants": [{"pipeline": "img-to-text", "plan_qps": -1}]}"#,
                "negative load",
            ),
            (
                r#"{"tenants": [{"pipeline": "img-to-text", "plan_qps": 10, "objective": "x"}]}"#,
                "bad objective",
            ),
            (
                r#"{"tenants": [{"pipeline": "img-to-text", "plan_qps": 10, "arrive_s": 5, "depart_s": 2}]}"#,
                "departure before arrival",
            ),
            (
                r#"{"cluster": {"preset": "tpu"}, "tenants": [{"pipeline": "img-to-text", "plan_qps": 10}]}"#,
                "bad preset",
            ),
            (
                r#"{"cluster": {"preset": "dgx2", "gpu": 8}, "tenants": [{"pipeline": "img-to-text", "plan_qps": 10}]}"#,
                "unknown cluster field (typo for gpus)",
            ),
            (
                r#"{"typo": 1, "tenants": [{"pipeline": "img-to-text", "plan_qps": 10}]}"#,
                "unknown field",
            ),
            (
                r#"{"tenants": [{"pipeline": "img-to-text", "plan_qps": 10, "arrive_s": 60, "shrink_to": 5, "shrink_at_s": 10}]}"#,
                "shrink before arrival",
            ),
            (
                r#"{"tenants": [{"pipeline": "img-to-text", "plan_qps": 10, "depart_s": 100, "shrink_to": 5, "shrink_at_s": 200}]}"#,
                "shrink after departure",
            ),
            (
                r#"{"tenants": [{"pipeline": "img-to-text", "plan_qps": 10, "shrink_at_s": 5}]}"#,
                "shrink_at_s without shrink_to",
            ),
            (
                r#"{"cells": 0, "tenants": [{"pipeline": "img-to-text", "plan_qps": 10}]}"#,
                "zero cells",
            ),
            (
                r#"{"cells": 3, "tenants": [{"pipeline": "img-to-text", "plan_qps": 10}]}"#,
                "more cells than the 2-GPU default cluster holds",
            ),
        ] {
            assert!(ScenarioSpec::parse(frag).is_err(), "{what} must be rejected");
        }
    }

    #[test]
    fn defaults_fill_in() {
        let spec = ScenarioSpec::parse(
            r#"{"tenants": [{"pipeline": "img-to-text", "plan_qps": 50}]}"#,
        )
        .unwrap();
        assert_eq!(spec.batch, 32);
        assert_eq!(spec.cluster.num_gpus, 2);
        assert_eq!(spec.cells, 1);
        let t = &spec.tenants[0];
        assert_eq!(t.name, "img-to-text#0");
        assert_eq!(t.objective, ScenarioObjective::MinResource);
        assert!(matches!(t.arrivals, ArrivalProcess::Constant { .. }));
        assert_eq!(t.arrive_s, 0.0);
    }

    #[test]
    fn all_example_specs_parse() {
        // examples/ lives at the repo root, one level above the crate
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples");
        let mut found = 0usize;
        for entry in std::fs::read_dir(&dir).expect("examples dir exists") {
            let path = entry.expect("dir entry").path();
            if path.extension().and_then(|e| e.to_str()) != Some("json") {
                continue;
            }
            ScenarioSpec::load(&path)
                .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
            found += 1;
        }
        assert!(found >= 4, "expected >= 4 example specs, found {found}");
        // the LLM co-location example ships with the repo
        assert!(
            dir.join("scenario_llm_colocate.json").exists(),
            "examples/scenario_llm_colocate.json missing"
        );
    }

    #[test]
    fn parses_llm_workload_tenants() {
        let spec = ScenarioSpec::parse(
            r#"{
            "tenants": [
                {"workload": "llm", "plan_qps": 20},
                {"workload": "llm", "plan_qps": 10, "prompt_tokens": 1024,
                 "output_tokens": 256, "kv_bytes_per_token": 131072}
            ]
        }"#,
        )
        .unwrap();
        // defaults fill in; the synthesized name is the canonical grammar
        assert_eq!(spec.tenants[0].pipeline, "llm:p512:o128:kv65536");
        assert_eq!(spec.tenants[1].pipeline, "llm:p1024:o256:kv131072");
        // and it resolves to a real pipeline with a KV-bearing stage
        let p = crate::suite::pipeline_by_name(&spec.tenants[1].pipeline).unwrap();
        assert!(p.stages.iter().any(|s| s.mem_bytes_per_query > 0.0));
    }

    #[test]
    fn rejects_malformed_llm_tenants() {
        for (tenant, want) in [
            (
                r#"{"workload": "llm", "pipeline": "img-to-text", "plan_qps": 5}"#,
                "'pipeline' and 'workload' are mutually exclusive",
            ),
            (
                r#"{"workload": "vision", "plan_qps": 5}"#,
                "unknown workload 'vision' (llm)",
            ),
            (
                r#"{"pipeline": "img-to-text", "plan_qps": 5, "prompt_tokens": 64}"#,
                "'prompt_tokens' requires \"workload\": \"llm\"",
            ),
            (
                r#"{"workload": "llm", "plan_qps": 5, "output_tokens": 0}"#,
                "llm workload parameters must be positive",
            ),
            (r#"{"plan_qps": 5}"#, "needs a 'pipeline' or a 'workload'"),
        ] {
            let text = format!(r#"{{"tenants": [{tenant}]}}"#);
            let err = ScenarioSpec::parse(&text).unwrap_err();
            assert!(err.contains(want), "want '{want}' in '{err}'");
        }
    }

    #[test]
    fn parses_hetero_cluster_fields() {
        let spec = ScenarioSpec::parse(
            r#"{
            "cluster": {
                "preset": "2080ti",
                "partition_mode": "discrete",
                "gpu_classes": [
                    {"gpu": "a100", "count": 2},
                    {"gpu": "h100", "count": 1, "compute_scale": 0.25,
                     "partition_mode": "continuous"}
                ]
            },
            "tenants": [{"pipeline": "img-to-text", "plan_qps": 50}]
        }"#,
        )
        .unwrap();
        let c = &spec.cluster;
        // 'gpus' omitted: class counts define the pool size
        assert_eq!(c.num_gpus, 3);
        assert_eq!(c.classes.len(), 2);
        assert_eq!(c.classes[0].gpu.name, "A100-SXM4-80GB");
        assert_eq!(c.classes[0].count, 2);
        // default compute_scale = base gflops / class gflops (< 1: faster)
        let derived = c.gpu.gflops / c.classes[0].gpu.gflops;
        assert_eq!(c.classes[0].compute_scale.to_bits(), derived.to_bits());
        assert!(derived < 1.0);
        // class partition defaults to the pool-wide mode...
        assert!(matches!(c.classes[0].partition, PartitionMode::Discrete(_)));
        // ...unless overridden per class
        assert_eq!(c.classes[1].compute_scale, 0.25);
        assert_eq!(c.classes[1].partition, PartitionMode::Continuous);
        assert!(!c.effectively_homogeneous());
    }

    #[test]
    fn rejects_malformed_hetero_fields() {
        const TENANTS: &str = r#""tenants": [{"pipeline": "img-to-text", "plan_qps": 10}]"#;
        for (cluster, want) in [
            (
                r#"{"preset": "2080ti", "partition_mode": "mig"}"#,
                "cluster: unknown partition_mode 'mig' (continuous | discrete)",
            ),
            (
                r#"{"gpu_classes": []}"#,
                "cluster: 'gpu_classes' must not be empty",
            ),
            (
                r#"{"gpu_classes": [{"gpu": "tpu", "count": 1}]}"#,
                "gpu_classes[0]: unknown gpu 'tpu' (2080ti | v100 | a100 | h100)",
            ),
            (
                r#"{"gpu_classes": [{"gpu": "a100"}]}"#,
                "gpu_classes[0] needs a 'count'",
            ),
            (
                r#"{"gpu_classes": [{"gpu": "a100", "count": 1.5}]}"#,
                "gpu_classes[0]: count must be an integer in 1..=32, got 1.5",
            ),
            (
                r#"{"gpu_classes": [{"gpu": "a100", "count": 1, "compute_scale": -2}]}"#,
                "gpu_classes[0]: compute_scale must be finite and > 0, got -2",
            ),
            (
                r#"{"gpu_classes": [{"gpu": "a100", "count": 1, "slices": 7}]}"#,
                "gpu_classes[0]: unknown field 'slices'",
            ),
            (
                r#"{"gpus": 4, "gpu_classes": [{"gpu": "a100", "count": 3}]}"#,
                "counts sum to 3 but num_gpus is 4",
            ),
        ] {
            let frag = format!("{{\"cluster\": {cluster}, {TENANTS}}}");
            let err = ScenarioSpec::parse(&frag).expect_err(want);
            assert!(err.contains(want), "expected '{want}' in '{err}'");
        }
    }

    #[test]
    fn plan_tables_handles_a_mixed_pool() {
        let spec = ScenarioSpec::parse(
            r#"{
            "cluster": {"preset": "2080ti", "gpus": 4,
                        "gpu_classes": [{"gpu": "2080ti", "count": 2},
                                        {"gpu": "a100", "count": 2}]},
            "tenants": [{"pipeline": "text-to-text", "plan_qps": 60}]
        }"#,
        )
        .unwrap();
        let tables = spec.plan_tables().expect("mixed pool plans");
        assert_eq!(tables.len(), 1);
        assert_eq!(tables[0].rows.len(), 1);
    }

    #[test]
    fn plan_tables_runs_case1_case2_and_shrink() {
        let spec = ScenarioSpec::parse(SPEC).unwrap();
        let tables = spec.plan_tables().expect("scenario plans");
        assert_eq!(tables.len(), 2, "plan table + shrink table");
        assert_eq!(tables[0].rows.len(), 2);
        assert_eq!(tables[1].rows.len(), 1);
        let shrink_row = &tables[1].rows[0];
        assert_eq!(shrink_row[0], "a");
        let before: f64 = shrink_row[2].parse().unwrap();
        let after: f64 = shrink_row[3].parse().unwrap();
        assert_eq!(shrink_row[5], "shrunk", "{shrink_row:?}");
        assert!(after < before, "shrink must reduce usage: {shrink_row:?}");
    }
}
