//! Planner memoization — the [`SolveCache`] that makes the *online*
//! control loop fast.
//!
//! Camelot is a runtime system: admission, shrink, and re-packing
//! decisions happen while queries are in flight, so planner latency is
//! a budget of its own (§VIII-G prices one solve at ~5 ms — an
//! admission attempt runs several, and a departure re-pack runs one per
//! survivor). MISO and ParvaGPU both observe that reallocation-decision
//! latency bounds how fine-grained GPU sharing can get; the control
//! loop therefore must not re-derive a plan it has already computed.
//!
//! The cache is exact, not approximate: entries are keyed on a
//! **canonical fingerprint** of everything [`Planner::plan`] reads —
//! the objective (including embedded allocations and load targets, as
//! f64 bit patterns), the full [`ClusterState`] (spec constants and the
//! merged per-GPU co-tenant holds), the pipeline (per-stage resource
//! signature and QoS target), the *predictor identity* (each stage
//! predictor evaluated over the entire 5% planning grid — the values
//! the solver consults — so differently trained predictor sets never
//! collide even under the same stage names; see
//! [`request_fingerprint`] for the exact scope of this guarantee), and
//! every knob (`batch`, `comm`, `enforce_bw`, `qos_headroom`, the full
//! `SaParams` including the seed). Planning is a pure function of
//! exactly these inputs (seeded SA, no wall clock), so a hit returns a
//! [`Solution`](super::Solution) **bit-identical** to a fresh solve —
//! `tests/control_loop_cache.rs` pins this, and the keys are exact
//! strings, never lossy hashes.
//!
//! Capacity is bounded: a least-recently-used entry is evicted when the
//! cache is full, so week-long admission traces cannot grow memory
//! unboundedly. Statistics (`hits`/`misses`/`evictions`) are surfaced
//! through `camelot admit` / `camelot colocate` so cache behavior is
//! observable.

use std::cell::RefCell;
use std::collections::HashMap;
use std::fmt::Write as _;

use crate::comm::CommMode;
use crate::deploy::Allocation;
use crate::sim::{Deployment, InstancePlacement};
use crate::suite::workload::{ArrivalProcess, DiurnalPattern, Priority};
use crate::suite::Pipeline;
use crate::util::json::Json;

use super::{HeteroPlanner, Infeasible, Objective, PlanOutcome, PlanRequest, Planner, Solution};

/// Snapshot of a [`SolveCache`]'s counters.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    /// Requests answered from the memo.
    pub hits: u64,
    /// Requests that required a fresh solve.
    pub misses: u64,
    /// Entries discarded to make room (LRU order).
    pub evictions: u64,
    /// Entries currently resident (≤ capacity).
    pub entries: usize,
}

impl CacheStats {
    /// hits / (hits + misses); 0 when the cache saw no traffic.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Entry {
    outcome: PlanOutcome,
    last_used: u64,
}

#[derive(Default)]
struct Inner {
    map: HashMap<String, Entry>,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

/// Bounded-capacity LRU memo over [`Planner::plan`]. Interior-mutable
/// (`&self` methods) so callers holding shared borrows of their own
/// state can still consult it; single-threaded by design — each
/// controller owns its cache, and the parallel phases of the replay
/// harnesses never plan.
pub struct SolveCache {
    capacity: usize,
    inner: RefCell<Inner>,
}

impl SolveCache {
    /// A cache holding at most `capacity` solved requests. `capacity`
    /// 0 disables memoization entirely (every call plans fresh and
    /// counts as a miss) — the "cold" configuration the benches and
    /// golden tests compare against.
    pub fn new(capacity: usize) -> SolveCache {
        SolveCache { capacity, inner: RefCell::new(Inner::default()) }
    }

    /// The configured entry bound.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Plan `req` through the repo's default strategy — the
    /// heterogeneity-aware [`HeteroPlanner`], which delegates verbatim
    /// to [`CamelotPlanner`](super::CamelotPlanner) on homogeneous
    /// continuous pools (bit-identical, golden-gated) — memoized. Every
    /// online-control-loop caller (admission, autoscale, cells, replay)
    /// plans through here, so mixed pools light up across the
    /// coordinator with zero call-site changes.
    pub fn plan(&self, req: &PlanRequest<'_>) -> PlanOutcome {
        self.plan_with(&HeteroPlanner, req)
    }

    /// Plan `req` through an arbitrary strategy, memoized. The planner
    /// must be a pure function of the request (every [`Planner`] in
    /// this crate is); with caching disabled this is exactly
    /// `planner.plan(req)`.
    pub fn plan_with<P: Planner>(&self, planner: &P, req: &PlanRequest<'_>) -> PlanOutcome {
        if self.capacity == 0 {
            self.inner.borrow_mut().misses += 1;
            return planner.plan(req);
        }
        let key = request_fingerprint(req);
        {
            let mut inner = self.inner.borrow_mut();
            inner.tick += 1;
            let tick = inner.tick;
            let cached = inner.map.get_mut(&key).map(|e| {
                e.last_used = tick;
                e.outcome.clone()
            });
            if let Some(outcome) = cached {
                inner.hits += 1;
                return outcome;
            }
            inner.misses += 1;
        }
        // solve outside the borrow: a strategy is free to consult the
        // cache itself without tripping the RefCell
        let outcome = planner.plan(req);
        let mut inner = self.inner.borrow_mut();
        if inner.map.len() >= self.capacity {
            let oldest = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            if let Some(k) = oldest {
                inner.map.remove(&k);
                inner.evictions += 1;
            }
        }
        let tick = inner.tick;
        inner.map.insert(key, Entry { outcome: outcome.clone(), last_used: tick });
        outcome
    }

    /// Snapshot the hit/miss/eviction counters.
    pub fn stats(&self) -> CacheStats {
        let inner = self.inner.borrow();
        CacheStats {
            hits: inner.hits,
            misses: inner.misses,
            evictions: inner.evictions,
            entries: inner.map.len(),
        }
    }

    /// Serialize the cache contents (capacity + every resident entry,
    /// least-recently-used first) to JSON. Keys are the exact-string
    /// request fingerprints, so a reload warm-starts lookups verbatim;
    /// every f64 travels as its raw bit pattern (hex string), making the
    /// round-trip bit-exact. Counters are *not* serialized — a reloaded
    /// cache starts its hit/miss statistics fresh, so the "warm
    /// hit-rate" `camelot admit --cache-load` reports measures only the
    /// current run.
    pub fn to_json(&self) -> String {
        let inner = self.inner.borrow();
        // LRU order: oldest first, so load_json replays inserts in age
        // order and capacity truncation drops the stalest entries
        let mut entries: Vec<(&String, &Entry)> = inner.map.iter().collect();
        entries.sort_by_key(|(_, e)| e.last_used);
        let mut out = String::with_capacity(256 + entries.len() * 512);
        let _ = write!(out, "{{\"capacity\": {}, \"entries\": [", self.capacity);
        for (i, (key, e)) in entries.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str("{\"key\": ");
            json_str(&mut out, key);
            out.push_str(", \"outcome\": ");
            json_outcome(&mut out, &e.outcome);
            out.push('}');
        }
        out.push_str("]}");
        out
    }

    /// Rebuild a cache from [`to_json`](Self::to_json) output. The new
    /// cache has the serialized capacity, the entries in their
    /// serialized recency order (ticks reassigned densely), and zeroed
    /// counters.
    pub fn from_json(text: &str) -> Result<SolveCache, String> {
        let v = Json::parse(text).map_err(|e| format!("solve-cache json: {e}"))?;
        let capacity = v
            .get_f64("capacity")
            .ok_or("solve-cache json: missing capacity")? as usize;
        let cache = SolveCache::new(capacity);
        cache.load_json_value(&v)?;
        Ok(cache)
    }

    /// Warm-start this cache from [`to_json`](Self::to_json) output,
    /// keeping this cache's own capacity: entries are inserted in their
    /// serialized recency order, and when the payload holds more than
    /// fit, only the most recent `capacity` land (no eviction counter
    /// noise). Returns the number of entries loaded. A capacity-0 cache
    /// loads nothing.
    pub fn load_json(&self, text: &str) -> Result<usize, String> {
        let v = Json::parse(text).map_err(|e| format!("solve-cache json: {e}"))?;
        self.load_json_value(&v)
    }

    /// [`load_json`](Self::load_json) over an already-parsed value (the
    /// controller snapshot embeds the cache object directly).
    pub fn load_json_value(&self, v: &Json) -> Result<usize, String> {
        let entries = v
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or("solve-cache json: missing entries array")?;
        if self.capacity == 0 {
            return Ok(0);
        }
        // keep only the most recent `capacity` entries
        let skip = entries.len().saturating_sub(self.capacity);
        let mut inner = self.inner.borrow_mut();
        let mut loaded = 0usize;
        for e in &entries[skip..] {
            let key = e
                .get_str("key")
                .ok_or("solve-cache json: entry missing key")?;
            let outcome = parse_outcome(
                e.get("outcome").ok_or("solve-cache json: entry missing outcome")?,
            )?;
            inner.tick += 1;
            let tick = inner.tick;
            inner.map.insert(key.to_string(), Entry { outcome, last_used: tick });
            loaded += 1;
        }
        Ok(loaded)
    }
}

// ---------------------------------------------------------------------
// Bit-exact JSON round-trip of cached outcomes (cross-session
// warm-start; the controller snapshots reuse these emitters)
// ---------------------------------------------------------------------

/// Append `s` as a JSON string literal.
pub(crate) fn json_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Append an f64 as its raw bit pattern — a hex *string*, because the
/// parser narrows every JSON number through f64 and must not touch the
/// bits.
pub(crate) fn json_bits(out: &mut String, x: f64) {
    let _ = write!(out, "\"{:x}\"", x.to_bits());
}

/// Parse a [`json_bits`] hex string back to the exact f64.
pub(crate) fn parse_bits(v: &Json) -> Result<f64, String> {
    let s = v.as_str().ok_or("expected f64 bit string")?;
    u64::from_str_radix(s, 16)
        .map(f64::from_bits)
        .map_err(|e| format!("bad f64 bit string '{s}': {e}"))
}

/// Parse a `[json_bits, ...]` array back to exact f64s.
pub(crate) fn parse_bits_arr(v: &Json) -> Result<Vec<f64>, String> {
    v.as_arr()
        .ok_or("expected array of f64 bit strings")?
        .iter()
        .map(parse_bits)
        .collect()
}

/// Append a `[json_bits, ...]` array.
pub(crate) fn json_bits_arr(out: &mut String, xs: &[f64]) {
    out.push('[');
    for (i, &x) in xs.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        json_bits(out, x);
    }
    out.push(']');
}

fn get_usize(v: &Json, key: &str) -> Result<usize, String> {
    v.get_f64(key).map(|x| x as usize).ok_or_else(|| format!("missing {key}"))
}

/// Append an [`Allocation`].
pub(crate) fn json_alloc(out: &mut String, a: &Allocation) {
    let _ = write!(out, "{{\"instances\": {:?}, \"quotas\": ", a.instances);
    json_bits_arr(out, &a.quotas);
    out.push('}');
}

/// Parse an [`Allocation`].
pub(crate) fn parse_alloc(v: &Json) -> Result<Allocation, String> {
    let instances = v
        .get("instances")
        .and_then(Json::as_arr)
        .ok_or("allocation missing instances")?
        .iter()
        .map(|x| x.as_f64().map(|f| f as u32).ok_or("bad instance count"))
        .collect::<Result<Vec<_>, _>>()?;
    let quotas = parse_bits_arr(v.get("quotas").ok_or("allocation missing quotas")?)?;
    Ok(Allocation { instances, quotas })
}

/// Append a [`Deployment`] (placements in order, batch, comm mode).
pub(crate) fn json_deployment(out: &mut String, d: &Deployment) {
    let comm = match d.comm {
        CommMode::MainMemory => "main_memory",
        CommMode::GlobalIpc => "global_ipc",
    };
    let _ = write!(out, "{{\"batch\": {}, \"comm\": \"{comm}\", \"placements\": [", d.batch);
    for (i, p) in d.placements.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        let _ = write!(out, "{{\"stage\": {}, \"gpu\": {}, \"sm_frac\": ", p.stage, p.gpu);
        json_bits(out, p.sm_frac);
        out.push('}');
    }
    out.push_str("]}");
}

/// Parse a [`Deployment`].
pub(crate) fn parse_deployment(v: &Json) -> Result<Deployment, String> {
    let batch = get_usize(v, "batch")? as u32;
    let comm = match v.get_str("comm").ok_or("deployment missing comm")? {
        "main_memory" => CommMode::MainMemory,
        "global_ipc" => CommMode::GlobalIpc,
        other => return Err(format!("unknown comm mode '{other}'")),
    };
    let placements = v
        .get("placements")
        .and_then(Json::as_arr)
        .ok_or("deployment missing placements")?
        .iter()
        .map(|p| {
            Ok(InstancePlacement {
                stage: get_usize(p, "stage")?,
                gpu: get_usize(p, "gpu")?,
                sm_frac: parse_bits(p.get("sm_frac").ok_or("placement missing sm_frac")?)?,
            })
        })
        .collect::<Result<Vec<_>, String>>()?;
    Ok(Deployment { placements, batch, comm })
}

/// Emit an [`ArrivalProcess`] (rates as bit-exact hex, like every float
/// in the durability layer).
pub(crate) fn json_arrivals(out: &mut String, a: &ArrivalProcess) {
    match a {
        ArrivalProcess::Constant { rate_qps } => {
            out.push_str("{\"constant\": {\"rate_qps\": ");
            json_bits(out, *rate_qps);
            out.push_str("}}");
        }
        ArrivalProcess::Diurnal { pattern } => {
            out.push_str("{\"diurnal\": {\"peak_qps\": ");
            json_bits(out, pattern.peak_qps);
            out.push_str(", \"trough_frac\": ");
            json_bits(out, pattern.trough_frac);
            out.push_str(", \"period_s\": ");
            json_bits(out, pattern.period_s);
            out.push_str("}}");
        }
    }
}

/// Parse an [`ArrivalProcess`].
pub(crate) fn parse_arrivals(v: &Json) -> Result<ArrivalProcess, String> {
    if let Some(c) = v.get("constant") {
        let rate_qps = parse_bits(c.get("rate_qps").ok_or("constant missing rate_qps")?)?;
        return Ok(ArrivalProcess::Constant { rate_qps });
    }
    if let Some(d) = v.get("diurnal") {
        return Ok(ArrivalProcess::Diurnal {
            pattern: DiurnalPattern {
                peak_qps: parse_bits(d.get("peak_qps").ok_or("diurnal missing peak_qps")?)?,
                trough_frac: parse_bits(
                    d.get("trough_frac").ok_or("diurnal missing trough_frac")?,
                )?,
                period_s: parse_bits(d.get("period_s").ok_or("diurnal missing period_s")?)?,
            },
        });
    }
    Err("arrival process must be 'constant' or 'diurnal'".to_string())
}

/// Emit a [`Priority`] tag.
pub(crate) fn json_priority(out: &mut String, p: Priority) {
    out.push_str(match p {
        Priority::LatencyCritical => "\"latency_critical\"",
        Priority::BestEffort => "\"best_effort\"",
    });
}

/// Parse a [`Priority`] tag.
pub(crate) fn parse_priority(v: &Json) -> Result<Priority, String> {
    match v.as_str().ok_or("priority must be a string")? {
        "latency_critical" => Ok(Priority::LatencyCritical),
        "best_effort" => Ok(Priority::BestEffort),
        other => Err(format!("unknown priority '{other}'")),
    }
}

fn json_solution(out: &mut String, s: &Solution) {
    out.push_str("{\"allocation\": ");
    json_alloc(out, &s.allocation);
    out.push_str(", \"deployment\": ");
    json_deployment(out, &s.deployment);
    out.push_str(", \"plan_qps\": ");
    json_bits(out, s.plan_qps);
    out.push_str(", \"predicted_p99_s\": ");
    json_bits(out, s.predicted_p99_s);
    out.push_str(", \"stage_p99_s\": ");
    json_bits_arr(out, &s.stage_p99_s);
    out.push_str(", \"usage\": ");
    json_bits(out, s.usage);
    let _ = write!(out, ", \"gpus\": {}", s.gpus);
    out.push_str(", \"objective_value\": ");
    json_bits(out, s.objective_value);
    let _ = write!(
        out,
        ", \"evaluated\": {}, \"feasible_found\": {}}}",
        s.evaluated, s.feasible_found
    );
}

fn parse_solution(v: &Json) -> Result<Solution, String> {
    Ok(Solution {
        allocation: parse_alloc(v.get("allocation").ok_or("solution missing allocation")?)?,
        deployment: parse_deployment(
            v.get("deployment").ok_or("solution missing deployment")?,
        )?,
        plan_qps: parse_bits(v.get("plan_qps").ok_or("solution missing plan_qps")?)?,
        predicted_p99_s: parse_bits(
            v.get("predicted_p99_s").ok_or("solution missing predicted_p99_s")?,
        )?,
        stage_p99_s: parse_bits_arr(
            v.get("stage_p99_s").ok_or("solution missing stage_p99_s")?,
        )?,
        usage: parse_bits(v.get("usage").ok_or("solution missing usage")?)?,
        gpus: get_usize(v, "gpus")?,
        objective_value: parse_bits(
            v.get("objective_value").ok_or("solution missing objective_value")?,
        )?,
        evaluated: get_usize(v, "evaluated")?,
        feasible_found: get_usize(v, "feasible_found")?,
    })
}

fn json_outcome(out: &mut String, o: &PlanOutcome) {
    match o {
        Ok(s) => {
            out.push_str("{\"ok\": ");
            json_solution(out, s);
            out.push('}');
        }
        Err(e) => {
            out.push_str("{\"err\": ");
            match e {
                Infeasible::BadRequest { detail } => {
                    out.push_str("{\"kind\": \"bad_request\", \"detail\": ");
                    json_str(out, detail);
                    out.push('}');
                }
                Infeasible::NoAllocation { detail } => {
                    out.push_str("{\"kind\": \"no_allocation\", \"detail\": ");
                    json_str(out, detail);
                    out.push('}');
                }
                Infeasible::NoPlacement { stage, detail } => {
                    let _ = write!(out, "{{\"kind\": \"no_placement\", \"stage\": {stage}, \"detail\": ");
                    json_str(out, detail);
                    out.push('}');
                }
                Infeasible::NoImprovement { current_usage, planned_usage } => {
                    out.push_str("{\"kind\": \"no_improvement\", \"current_usage\": ");
                    json_bits(out, *current_usage);
                    out.push_str(", \"planned_usage\": ");
                    json_bits(out, *planned_usage);
                    out.push('}');
                }
                Infeasible::NoMemory { needed_bytes, available_bytes } => {
                    out.push_str("{\"kind\": \"no_memory\", \"needed_bytes\": ");
                    json_bits(out, *needed_bytes);
                    out.push_str(", \"available_bytes\": ");
                    json_bits(out, *available_bytes);
                    out.push('}');
                }
            }
            out.push('}');
        }
    }
}

fn parse_outcome(v: &Json) -> Result<PlanOutcome, String> {
    if let Some(s) = v.get("ok") {
        return Ok(Ok(parse_solution(s)?));
    }
    let e = v.get("err").ok_or("outcome missing both ok and err")?;
    let detail = || -> Result<String, String> {
        e.get_str("detail").map(str::to_string).ok_or_else(|| "infeasible missing detail".into())
    };
    let err = match e.get_str("kind").ok_or("infeasible missing kind")? {
        "bad_request" => Infeasible::BadRequest { detail: detail()? },
        "no_allocation" => Infeasible::NoAllocation { detail: detail()? },
        "no_placement" => Infeasible::NoPlacement { stage: get_usize(e, "stage")?, detail: detail()? },
        "no_improvement" => Infeasible::NoImprovement {
            current_usage: parse_bits(e.get("current_usage").ok_or("missing current_usage")?)?,
            planned_usage: parse_bits(e.get("planned_usage").ok_or("missing planned_usage")?)?,
        },
        "no_memory" => Infeasible::NoMemory {
            needed_bytes: parse_bits(e.get("needed_bytes").ok_or("missing needed_bytes")?)?,
            available_bytes: parse_bits(
                e.get("available_bytes").ok_or("missing available_bytes")?,
            )?,
        },
        other => return Err(format!("unknown infeasible kind '{other}'")),
    };
    Ok(Err(err))
}

// ---------------------------------------------------------------------
// Canonical fingerprints
// ---------------------------------------------------------------------
//
// f64s are rendered as their raw bit patterns (hex), so two inputs
// fingerprint equal iff they are bit-identical — the same standard the
// golden suites hold outputs to.

fn fp_f64(out: &mut String, x: f64) {
    let _ = write!(out, "{:x},", x.to_bits());
}

pub(crate) fn fp_alloc(out: &mut String, a: &Allocation) {
    let _ = write!(out, "n{:?}p", a.instances);
    for &q in &a.quotas {
        fp_f64(out, q);
    }
}

/// Pipeline identity: name, QoS target, and the full per-stage resource
/// signature (every field the cost model and placement pass read).
pub(crate) fn fp_pipeline(out: &mut String, p: &Pipeline) {
    let _ = write!(out, "pipe={};", p.name);
    fp_f64(out, p.qos_target_s);
    for st in &p.stages {
        let _ = write!(out, "st={},{:?};", st.name, st.kind);
        for x in [
            st.flops_per_query,
            st.hbm_bytes_per_query,
            st.model_bytes,
            st.act_bytes_per_query,
            st.in_bytes_per_query,
            st.out_bytes_per_query,
            st.serial_frac,
            st.batch_half,
        ] {
            fp_f64(out, x);
        }
        // appended only when nonzero so every legacy (KV-free) pipeline
        // fingerprints byte-identically to before the field existed —
        // cached plans can't be reused across memory-distinct requests
        if st.mem_bytes_per_query != 0.0 {
            out.push_str("kv=");
            fp_f64(out, st.mem_bytes_per_query);
        }
    }
}

/// Deployment identity: placements in order, batch, comm mode.
pub(crate) fn fp_deployment(out: &mut String, d: &Deployment) {
    let _ = write!(out, "dep=b{},{:?};", d.batch, d.comm);
    for pl in &d.placements {
        let _ = write!(out, "s{}g{}q", pl.stage, pl.gpu);
        fp_f64(out, pl.sm_frac);
    }
}

/// Arrival-process identity (the offered-load model, not a drawn
/// stream — streams are derived from seeds the caller fingerprints
/// separately).
pub(crate) fn fp_arrivals(out: &mut String, a: &ArrivalProcess) {
    match a {
        ArrivalProcess::Constant { rate_qps } => {
            out.push_str("arr=c");
            fp_f64(out, *rate_qps);
        }
        ArrivalProcess::Diurnal { pattern } => {
            out.push_str("arr=d");
            fp_f64(out, pattern.peak_qps);
            fp_f64(out, pattern.trough_frac);
            fp_f64(out, pattern.period_s);
        }
    }
}

/// Partition-mode identity: continuous, or the discrete slice catalog.
fn fp_partition(out: &mut String, p: &crate::config::PartitionMode) {
    match p {
        crate::config::PartitionMode::Continuous => out.push_str("pc;"),
        crate::config::PartitionMode::Discrete(cat) => {
            let _ = write!(out, "pd{},", cat.units);
            fp_f64(out, cat.repartition_s_per_slice);
        }
    }
}

/// The canonical cache key: everything [`Planner::plan`] reads.
pub fn request_fingerprint(req: &PlanRequest<'_>) -> String {
    let mut s = String::with_capacity(512);
    match &req.objective {
        Objective::MaxLoad => s.push_str("obj=ml"),
        Objective::MinResource { load_qps } => {
            s.push_str("obj=mr");
            fp_f64(&mut s, *load_qps);
        }
        Objective::Repack { allocation } => {
            s.push_str("obj=rp");
            fp_alloc(&mut s, allocation);
        }
        Objective::Shrink { target_qps, current } => {
            s.push_str("obj=sh");
            fp_f64(&mut s, *target_qps);
            fp_alloc(&mut s, current);
        }
    }
    // cluster spec: every constant the cost model / constraint checker
    // reads (presets differ in all of these)
    let spec = req.cluster.spec();
    let _ = write!(
        s,
        "|cl={},{},{},{};",
        spec.gpu.name, spec.num_gpus, spec.gpu.sms, spec.gpu.mps_contexts
    );
    for x in [
        spec.gpu.gflops,
        spec.gpu.mem_bytes as f64,
        spec.gpu.mem_bw,
        spec.gpu.launch_overhead_s,
        spec.pcie.effective_bw,
        spec.pcie.per_stream_bw,
        spec.pcie.setup_s,
        spec.ipc.setup_s,
        spec.ipc.per_msg_s,
        spec.ipc.handle_bytes as f64,
    ] {
        fp_f64(&mut s, x);
    }
    // heterogeneity block — appended only when the request is actually
    // heterogeneous (classes, a discrete pool partition, or a non-unit
    // compute scale), so every legacy homogeneous fingerprint stays
    // byte-identical to its pre-heterogeneity form
    if req.compute_scale != 1.0 {
        s.push_str("|cs=");
        fp_f64(&mut s, req.compute_scale);
    }
    if !spec.classes.is_empty() || spec.partition != crate::config::PartitionMode::Continuous {
        s.push_str("|hw=");
        fp_partition(&mut s, &spec.partition);
        for c in &spec.classes {
            let _ = write!(s, "cls={},{},{},{};", c.gpu.name, c.count, c.gpu.sms, c.gpu.mps_contexts);
            for x in [
                c.gpu.gflops,
                c.gpu.mem_bytes as f64,
                c.gpu.mem_bw,
                c.gpu.launch_overhead_s,
                c.compute_scale,
            ] {
                fp_f64(&mut s, x);
            }
            fp_partition(&mut s, &c.partition);
        }
    }
    // merged co-tenant holds, per GPU
    s.push_str("|res=");
    for r in req.cluster.reservations() {
        let _ = write!(s, "c{},", r.contexts);
        fp_f64(&mut s, r.sm_frac);
        fp_f64(&mut s, r.mem_bytes);
        fp_f64(&mut s, r.bw_demand);
    }
    s.push('|');
    fp_pipeline(&mut s, req.pipeline);
    // predictor identity: all three predictor families evaluated over
    // the full 5% quota grid at the request's batch — exactly the
    // surface the solver consults (`StageGrids` memoizes the same
    // values), so two predictor sets alias only if they agree at every
    // on-grid point the solve can read. (Off-grid probes — possible for
    // a hand-rolled Planner — are not fingerprinted; in this repo
    // predictors are pure functions of the pipeline, the GPU spec, and
    // the default profiling config, all of which this key covers.)
    //
    // Cost note, deliberate: this re-runs ~60 tree evaluations per
    // stage per lookup (hits included), a few µs — against the ≥ms SA
    // solve a hit avoids. Exactness is worth that ratio; sharing the
    // already-built StageGrids here would couple the key builder to
    // allocator internals for a <1% saving.
    s.push_str("|pred=");
    for p in req.predictors {
        let _ = write!(s, "{}:", p.stage_name);
        for k in 0..20u32 {
            let q = (k + 1) as f64 * 0.05;
            fp_f64(&mut s, p.duration(req.batch, q));
            fp_f64(&mut s, p.bandwidth(req.batch, q));
            fp_f64(&mut s, p.throughput(req.batch, q));
        }
    }
    // knobs
    let _ = write!(s, "|k=b{},{:?},bw{};", req.batch, req.comm, req.enforce_bw);
    fp_f64(&mut s, req.qos_headroom);
    let sa = req.sa;
    let _ = write!(
        s,
        "|sa=i{},n{},m{},s{};",
        sa.iterations, sa.inst_step, sa.max_instances, sa.seed
    );
    for x in [sa.t_start, sa.t_end, sa.quota_step, sa.min_quota] {
        fp_f64(&mut s, x);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::deploy::GpuReservation;
    use crate::planner::{CamelotPlanner, ClusterState};
    use crate::predictor::train_pipeline;
    use crate::suite::real;

    fn fixture() -> (ClusterSpec, Pipeline, Vec<crate::predictor::StagePredictor>) {
        let c = ClusterSpec::two_2080ti();
        let p = real::img_to_text();
        let preds = train_pipeline(&p, &c.gpu);
        (c, p, preds)
    }

    #[test]
    fn fingerprint_separates_every_knob() {
        let (c, p, preds) = fixture();
        let base = PlanRequest::new(
            Objective::MinResource { load_qps: 50.0 },
            ClusterState::exclusive(&c),
            &p,
            &preds,
        )
        .batch(16);
        let fp = request_fingerprint(&base);
        // identical request -> identical key
        assert_eq!(fp, request_fingerprint(&base.clone()));
        // every knob perturbation must change the key
        assert_ne!(fp, request_fingerprint(&base.clone().batch(32)));
        assert_ne!(fp, request_fingerprint(&base.clone().enforce_bw(false)));
        assert_ne!(
            fp,
            request_fingerprint(&base.clone().objective(Objective::MaxLoad))
        );
        assert_ne!(
            fp,
            request_fingerprint(
                &base
                    .clone()
                    .objective(Objective::MinResource { load_qps: 50.0 + 1e-9 })
            )
        );
        let mut sa = base.sa;
        sa.seed ^= 1;
        assert_ne!(fp, request_fingerprint(&base.clone().sa(sa)));
        // co-tenant holds change the key
        let held = vec![
            GpuReservation { sm_frac: 0.25, contexts: 2, ..Default::default() };
            c.num_gpus
        ];
        let shared = PlanRequest::new(
            Objective::MinResource { load_qps: 50.0 },
            ClusterState::with_reservations(&c, &held),
            &p,
            &preds,
        )
        .batch(16);
        assert_ne!(fp, request_fingerprint(&shared));
        // and so does the cluster preset
        let dgx = ClusterSpec::dgx2();
        let preds_dgx = train_pipeline(&p, &dgx.gpu);
        let other = PlanRequest::new(
            Objective::MinResource { load_qps: 50.0 },
            ClusterState::exclusive(&dgx),
            &p,
            &preds_dgx,
        )
        .batch(16);
        assert_ne!(fp, request_fingerprint(&other));
    }

    #[test]
    fn fingerprint_hetero_block_only_when_nondefault() {
        use crate::config::{GpuClass, PartitionMode, SliceCatalog};
        let (c, p, preds) = fixture();
        let base = PlanRequest::new(
            Objective::MaxLoad,
            ClusterState::exclusive(&c),
            &p,
            &preds,
        )
        .batch(16);
        let fp = request_fingerprint(&base);
        // default (classless, continuous, scale 1.0): no hetero block,
        // so every pre-heterogeneity key is byte-identical
        assert!(!fp.contains("|hw=") && !fp.contains("|cs="), "{fp}");
        // each heterogeneity input changes the key
        assert_ne!(fp, request_fingerprint(&base.clone().compute_scale(0.5)));
        let mut mig = c.clone();
        mig.partition = PartitionMode::Discrete(SliceCatalog::mig7());
        let mig_req = PlanRequest::new(
            Objective::MaxLoad,
            ClusterState::exclusive(&mig),
            &p,
            &preds,
        )
        .batch(16);
        assert_ne!(fp, request_fingerprint(&mig_req));
        let mut classy = c.clone();
        classy.classes = vec![
            GpuClass::scaled(c.gpu.clone(), 1, 1.0),
            GpuClass::scaled(c.gpu.clone(), 1, 0.5),
        ];
        let classy_req = PlanRequest::new(
            Objective::MaxLoad,
            ClusterState::exclusive(&classy),
            &p,
            &preds,
        )
        .batch(16);
        let classy_fp = request_fingerprint(&classy_req);
        assert_ne!(fp, classy_fp);
        // and two different class scales never collide
        let mut classy2 = classy.clone();
        classy2.classes[1].compute_scale = 0.25;
        let classy2_req = PlanRequest::new(
            Objective::MaxLoad,
            ClusterState::exclusive(&classy2),
            &p,
            &preds,
        )
        .batch(16);
        assert_ne!(classy_fp, request_fingerprint(&classy2_req));
    }

    #[test]
    fn fingerprint_kv_memory_block_only_when_nonzero() {
        let (c, p, preds) = fixture();
        let base = PlanRequest::new(
            Objective::MaxLoad,
            ClusterState::exclusive(&c),
            &p,
            &preds,
        )
        .batch(16);
        let fp = request_fingerprint(&base);
        // KV-free pipelines carry no memory block: every pre-LLM key is
        // byte-identical to before the field existed
        assert!(!fp.contains("kv="), "{fp}");
        // a memory-distinct pipeline must never alias a cached plan
        let mut kv_p = p.clone();
        kv_p.stages[0].mem_bytes_per_query = 1.0e6;
        let kv_req = PlanRequest::new(
            Objective::MaxLoad,
            ClusterState::exclusive(&c),
            &kv_p,
            &preds,
        )
        .batch(16);
        let kv_fp = request_fingerprint(&kv_req);
        assert!(kv_fp.contains("kv="), "{kv_fp}");
        assert_ne!(fp, kv_fp);
        // and two different KV footprints never collide either
        let mut kv_p2 = kv_p.clone();
        kv_p2.stages[0].mem_bytes_per_query = 2.0e6;
        let kv_req2 = PlanRequest::new(
            Objective::MaxLoad,
            ClusterState::exclusive(&c),
            &kv_p2,
            &preds,
        )
        .batch(16);
        assert_ne!(kv_fp, request_fingerprint(&kv_req2));
    }

    #[test]
    fn hit_returns_bit_identical_solution() {
        let (c, p, preds) = fixture();
        let req = PlanRequest::new(
            Objective::MaxLoad,
            ClusterState::exclusive(&c),
            &p,
            &preds,
        )
        .batch(16);
        let direct = CamelotPlanner.plan(&req).expect("solves");
        let cache = SolveCache::new(8);
        let miss = cache.plan(&req).expect("solves");
        let hit = cache.plan(&req).expect("solves");
        for s in [&miss, &hit] {
            assert_eq!(s.allocation, direct.allocation);
            assert_eq!(s.deployment.placements, direct.deployment.placements);
            assert_eq!(s.objective_value.to_bits(), direct.objective_value.to_bits());
            assert_eq!(s.predicted_p99_s.to_bits(), direct.predicted_p99_s.to_bits());
            assert_eq!(
                (s.evaluated, s.feasible_found),
                (direct.evaluated, direct.feasible_found)
            );
        }
        let stats = cache.stats();
        assert_eq!((stats.hits, stats.misses, stats.entries), (1, 1, 1));
        assert!((stats.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn lru_eviction_bounds_entries() {
        let (c, p, preds) = fixture();
        let cache = SolveCache::new(2);
        for load in [30.0, 40.0, 50.0] {
            let req = PlanRequest::new(
                Objective::MinResource { load_qps: load },
                ClusterState::exclusive(&c),
                &p,
                &preds,
            )
            .batch(16);
            let _ = cache.plan(&req);
        }
        let stats = cache.stats();
        assert_eq!(stats.entries, 2, "capacity must bound the map");
        assert_eq!(stats.evictions, 1);
        assert_eq!(stats.misses, 3);
        // the least-recently-used entry (load 30) was evicted: planning
        // it again misses but still matches a fresh solve exactly
        let req = PlanRequest::new(
            Objective::MinResource { load_qps: 30.0 },
            ClusterState::exclusive(&c),
            &p,
            &preds,
        )
        .batch(16);
        let again = cache.plan(&req).expect("solves");
        assert_eq!(cache.stats().misses, 4);
        let direct = CamelotPlanner.plan(&req).expect("solves");
        assert_eq!(again.allocation, direct.allocation);
        // the most-recent entries survive: 30 (just re-inserted) and 50
        // are resident, so re-planning 50 hits without evicting
        let req50 = PlanRequest::new(
            Objective::MinResource { load_qps: 50.0 },
            ClusterState::exclusive(&c),
            &p,
            &preds,
        )
        .batch(16);
        let _ = cache.plan(&req50);
        let s = cache.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 4);
        assert_eq!(s.entries, 2);
        assert_eq!(s.evictions, 2);
    }

    #[test]
    fn zero_capacity_disables_memoization() {
        let (c, p, preds) = fixture();
        let cache = SolveCache::new(0);
        let req = PlanRequest::new(
            Objective::MaxLoad,
            ClusterState::exclusive(&c),
            &p,
            &preds,
        )
        .batch(16);
        let a = cache.plan(&req).expect("solves");
        let b = cache.plan(&req).expect("solves");
        assert_eq!(a.allocation, b.allocation);
        let stats = cache.stats();
        assert_eq!(stats.hits, 0);
        assert_eq!(stats.misses, 2);
        assert_eq!(stats.entries, 0);
    }

    #[test]
    fn json_round_trip_is_bit_identical_and_warm() {
        let (c, p, preds) = fixture();
        let cache = SolveCache::new(8);
        // populate with a feasible solve AND a typed infeasibility so
        // both outcome arms round-trip
        let ok_req = PlanRequest::new(
            Objective::MinResource { load_qps: 40.0 },
            ClusterState::exclusive(&c),
            &p,
            &preds,
        )
        .batch(16);
        let err_req = PlanRequest::new(
            Objective::MinResource { load_qps: 1.0e9 },
            ClusterState::exclusive(&c),
            &p,
            &preds,
        )
        .batch(16);
        let direct_ok = cache.plan(&ok_req).expect("solves");
        let direct_err = cache.plan(&err_req).expect_err("1e9 qps is infeasible");
        let text = cache.to_json();

        // from_json: full reconstruction, zeroed counters
        let warm = SolveCache::from_json(&text).expect("parses its own output");
        assert_eq!(warm.capacity(), 8);
        assert_eq!(warm.stats().entries, 2);
        assert_eq!((warm.stats().hits, warm.stats().misses), (0, 0));
        let hit = warm.plan(&ok_req).expect("solves");
        assert_eq!(hit.allocation, direct_ok.allocation);
        assert_eq!(hit.deployment.placements, direct_ok.deployment.placements);
        assert_eq!(hit.predicted_p99_s.to_bits(), direct_ok.predicted_p99_s.to_bits());
        assert_eq!(hit.objective_value.to_bits(), direct_ok.objective_value.to_bits());
        assert_eq!(
            (hit.evaluated, hit.feasible_found),
            (direct_ok.evaluated, direct_ok.feasible_found)
        );
        assert_eq!(warm.plan(&err_req).expect_err("still infeasible"), direct_err);
        // both lookups were served from the warm entries
        assert_eq!((warm.stats().hits, warm.stats().misses), (2, 0));
        // serialize -> load -> serialize is a fixpoint
        assert_eq!(warm.to_json(), text);

        // load_json keeps the receiving cache's capacity: a 1-entry
        // cache keeps only the most recent serialized entry
        let tiny = SolveCache::new(1);
        assert_eq!(tiny.load_json(&text).expect("loads"), 1);
        assert_eq!(tiny.stats().entries, 1);
        let _ = tiny.plan(&err_req);
        assert_eq!(tiny.stats().hits, 1, "most recent entry (err_req) survived");
        // and a capacity-0 cache loads nothing
        let off = SolveCache::new(0);
        assert_eq!(off.load_json(&text).expect("loads"), 0);
    }
}
