//! Multi-GPU deployment scheme (§VII-D, Fig 13).
//!
//! Given per-stage instance counts and SM quotas, place every instance
//! on a concrete GPU:
//!
//! 1. Stages are deployed in descending memory-footprint order (global
//!    memory is "the major resource bottleneck" — highest-priority
//!    resource dimension).
//! 2. For each instance, candidate GPUs are sorted by *fewest remaining
//!    resources first* (remaining global memory, then remaining SMs) so
//!    the pool does not fragment.
//! 3. GPUs already hosting an instance of the same stage are preferred:
//!    co-located same-stage instances share the model weights, reducing
//!    global-memory pressure.
//!
//! Placement is validated with the same admission rules the simulator
//! enforces (SM quota ≤ 100%, ≤48 MPS contexts, memory capacity with
//! model sharing).
//!
//! Every entry point takes a [`ClusterState`], which carries the
//! cluster spec *and* the merged per-GPU holds of co-located tenants —
//! there is exactly one placement path, reservation-aware by
//! construction (the former non-reserved/`*_reserved` variant pairs are
//! gone; an exclusive cluster is just a hold-free state).

use crate::config::ClusterSpec;
use crate::planner::ClusterState;
use crate::sim::{Deployment, InstancePlacement, SimGpu};
use crate::suite::Pipeline;

/// Per-stage allocation produced by the policies in [`crate::planner`].
#[derive(Debug, Clone, PartialEq)]
pub struct Allocation {
    /// N_i — instances per stage.
    pub instances: Vec<u32>,
    /// p_i — SM quota of each instance of stage i.
    pub quotas: Vec<f64>,
}

impl Allocation {
    /// Σ N_i·p_i — the resource-usage objective of Eq. 3.
    pub fn total_quota(&self) -> f64 {
        self.instances
            .iter()
            .zip(&self.quotas)
            .map(|(&n, &p)| n as f64 * p)
            .sum()
    }
}

/// Reason a deployment attempt failed.
#[derive(Debug, Clone, PartialEq)]
pub struct DeployError {
    pub stage: usize,
    pub detail: String,
}

impl std::fmt::Display for DeployError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "cannot place stage {}: {}", self.stage, self.detail)
    }
}

/// Per-instance global-memory-bandwidth demands, used as an additional
/// placement dimension (the paper's Fig 13 multi-dimensional resource
/// ordering): `demands[stage]` is the predicted b(p_stage) of one
/// instance; `cap` is the per-GPU budget (margin × peak bandwidth).
#[derive(Debug, Clone, Copy)]
pub struct BwBudget<'a> {
    pub demands: &'a [f64],
    pub cap: f64,
}

/// Capacity on one GPU already committed to a co-located tenant
/// (shared-cluster planning): the planner for a new pipeline sees only
/// the remaining SM quota, memory, MPS contexts, and bandwidth budget.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct GpuReservation {
    /// Σ SM fractions the resident tenant holds on this GPU.
    pub sm_frac: f64,
    /// Global-memory bytes the resident tenant charges (models counted
    /// once per stage, activations per instance).
    pub mem_bytes: f64,
    /// MPS client contexts the resident tenant occupies.
    pub contexts: u32,
    /// Σ predicted bandwidth demands of the resident instances — the
    /// worst case where all of them run concurrently (conservative
    /// input to the C3 budget).
    pub bw_demand: f64,
}

/// Derive per-GPU [`GpuReservation`]s from a tenant already deployed on
/// the cluster, so a second pipeline can be planned into the remaining
/// capacity. Same-stage model sharing *within* the resident tenant is
/// honored; sharing across tenants is not assumed (conservative).
pub fn reservations_for(
    pipeline: &Pipeline,
    cluster: &ClusterSpec,
    deployment: &Deployment,
) -> Vec<GpuReservation> {
    let cost = crate::sim::CostModel::new(cluster.gpu.clone());
    let batch = deployment.batch.max(1);
    let mut res = vec![GpuReservation::default(); cluster.num_gpus];
    // model charged once per (gpu, stage)
    let mut model_seen = vec![0u64; cluster.num_gpus];
    for p in &deployment.placements {
        let st = &pipeline.stages[p.stage];
        let r = &mut res[p.gpu];
        r.sm_frac += p.sm_frac;
        r.contexts += 1;
        r.mem_bytes += st.act_bytes_per_query * batch as f64;
        if model_seen[p.gpu] >> p.stage & 1 == 0 {
            model_seen[p.gpu] |= 1 << p.stage;
            r.mem_bytes += st.model_bytes;
        }
        let scale = cluster.scale_at(p.gpu);
        let spec = cluster.gpu_at(p.gpu);
        r.bw_demand += if scale == 1.0 && *spec == cluster.gpu {
            cost.bw_demand(st, batch, p.sm_frac)
        } else {
            crate::sim::CostModel::new(spec.clone())
                .instance_cost_scaled(st, batch, p.sm_frac, scale)
                .bw_demand
        };
    }
    res
}

/// Accumulate `extra`'s per-GPU holds into `into` (same cluster, one
/// entry per GPU): the N-tenant form of [`reservations_for`], where the
/// remainder a newcomer plans into is the sum of every resident
/// tenant's footprint. [`ClusterState::reserve`] is the owned form.
pub fn merge_reservations(into: &mut [GpuReservation], extra: &[GpuReservation]) {
    assert_eq!(
        into.len(),
        extra.len(),
        "reservation vectors must cover the same GPUs"
    );
    for (a, b) in into.iter_mut().zip(extra) {
        a.sm_frac += b.sm_frac;
        a.mem_bytes += b.mem_bytes;
        a.contexts += b.contexts;
        a.bw_demand += b.bw_demand;
    }
}

/// Number of distinct GPUs hosting at least one instance across a set
/// of deployments — the footprint the departure re-packing pass tries
/// to shrink.
pub fn gpus_in_use<'a, I>(deployments: I) -> usize
where
    I: IntoIterator<Item = &'a Deployment>,
{
    // growable bitmask: datacenter-scale clusters (the cells bench runs
    // thousands of GPUs) overflow a fixed u64 word
    let mut words: Vec<u64> = Vec::new();
    for d in deployments {
        for p in &d.placements {
            let (word, bit) = (p.gpu / 64, p.gpu % 64);
            if word >= words.len() {
                words.resize(word + 1, 0);
            }
            words[word] |= 1u64 << bit;
        }
    }
    words.iter().map(|w| w.count_ones() as usize).sum()
}

/// Place an allocation on the cluster state (spec + co-tenant holds).
/// Returns the placements and the final per-GPU states (for constraint
/// inspection, e.g. Σ b(p) per GPU).
///
/// With a [`BwBudget`], a GPU whose accumulated bandwidth demand would
/// exceed the cap is skipped — bandwidth-hungry instances spread across
/// devices exactly like memory-hungry ones.
pub fn place(
    pipeline: &Pipeline,
    state: &ClusterState,
    alloc: &Allocation,
    batch: u32,
    bw: Option<BwBudget<'_>>,
) -> Result<(Vec<InstancePlacement>, Vec<SimGpu>), DeployError> {
    let cluster = state.spec();
    assert_eq!(alloc.instances.len(), pipeline.n_stages());
    assert_eq!(alloc.quotas.len(), pipeline.n_stages());
    let mut gpus: Vec<SimGpu> = (0..cluster.num_gpus)
        .map(|g| SimGpu::new(cluster.gpu_at(g).clone()))
        .collect();
    let mut gpu_bw = vec![0.0f64; cluster.num_gpus];
    for (g, r) in state.reservations().iter().enumerate() {
        gpus[g].reserve(r.sm_frac, r.mem_bytes, r.contexts);
        gpu_bw[g] += r.bw_demand;
    }
    let mut placements = Vec::new();
    // which stages already occupy each GPU (for model-sharing preference)
    let mut hosts: Vec<Vec<usize>> = vec![Vec::new(); cluster.num_gpus];

    // deploy memory-hungriest stages first
    let mut order: Vec<usize> = (0..pipeline.n_stages()).collect();
    order.sort_by(|&a, &b| {
        let ma = pipeline.stages[a].mem_footprint(batch);
        let mb = pipeline.stages[b].mem_footprint(batch);
        mb.partial_cmp(&ma).unwrap()
    });

    for &stage_idx in &order {
        let st = &pipeline.stages[stage_idx];
        let quota = alloc.quotas[stage_idx];
        for _ in 0..alloc.instances[stage_idx] {
            // candidate order: same-stage hosts first (model sharing),
            // then scarcest remaining memory, then scarcest SMs.
            let mut cand: Vec<usize> = (0..gpus.len()).collect();
            cand.sort_by(|&a, &b| {
                let share_a = hosts[a].contains(&stage_idx);
                let share_b = hosts[b].contains(&stage_idx);
                share_b
                    .cmp(&share_a)
                    .then(gpus[a].mem_free().partial_cmp(&gpus[b].mem_free()).unwrap())
                    .then(gpus[a].sm_free().partial_cmp(&gpus[b].sm_free()).unwrap())
            });
            let mut placed = false;
            let mut last_err = String::new();
            for &g in &cand {
                if let Some(b) = bw {
                    let demand = b.demands[stage_idx];
                    // the budget's cap is quoted for the base GPU spec;
                    // a class with more (less) peak bandwidth gets a
                    // proportionally larger (smaller) budget
                    let cap = if cluster.classes.is_empty() {
                        b.cap
                    } else {
                        b.cap * cluster.gpu_at(g).mem_bw / cluster.gpu.mem_bw
                    };
                    if gpu_bw[g] + demand > cap {
                        last_err = format!(
                            "bandwidth budget: {:.3e} + {demand:.3e} > {:.3e}",
                            gpu_bw[g], cap
                        );
                        continue;
                    }
                }
                match gpus[g].admit(
                    &st.name,
                    quota,
                    st.model_bytes,
                    st.act_bytes_per_query * batch as f64,
                ) {
                    Ok(()) => {
                        if let Some(b) = bw {
                            gpu_bw[g] += b.demands[stage_idx];
                        }
                        placements.push(InstancePlacement { stage: stage_idx, gpu: g, sm_frac: quota });
                        if !hosts[g].contains(&stage_idx) {
                            hosts[g].push(stage_idx);
                        }
                        placed = true;
                        break;
                    }
                    Err(e) => last_err = e.to_string(),
                }
            }
            if !placed {
                return Err(DeployError { stage: stage_idx, detail: last_err });
            }
        }
    }
    Ok((placements, gpus))
}

/// Allocation-free feasibility check: answers "does a placement
/// exist?" with the same greedy algorithm as [`place`] but on plain
/// arrays (no `SimGpu`, no `HashMap`, no `Vec<InstancePlacement>`).
/// This is the allocator's hot path — simulated annealing calls it for
/// every candidate (§VIII-G budgets the whole solve at ~5 ms).
///
/// Invariant (property-tested): `feasible_placement(..) ==
/// place(..).is_ok()`.
pub fn feasible_placement(
    pipeline: &Pipeline,
    state: &ClusterState,
    alloc: &Allocation,
    batch: u32,
    bw: Option<BwBudget<'_>>,
) -> bool {
    const MAX_GPUS: usize = 32;
    const MAX_STAGES: usize = 8;
    let cluster = state.spec();
    let n_stages = pipeline.n_stages();
    let n_gpus = cluster.num_gpus;
    assert!(n_gpus <= MAX_GPUS && n_stages <= MAX_STAGES, "raise MAX_* consts");
    // per-GPU capacities: uniform for a classless pool, per-class in a
    // mixed fleet (mirrors the SimGpu construction in place())
    let mut cap_mem = [0.0f64; MAX_GPUS];
    let mut cap_ctx = [0u32; MAX_GPUS];
    let mut bw_cap = [0.0f64; MAX_GPUS];
    for g in 0..n_gpus {
        let spec = cluster.gpu_at(g);
        cap_mem[g] = spec.mem_bytes as f64;
        cap_ctx[g] = spec.mps_contexts;
        if let Some(b) = bw {
            bw_cap[g] = if cluster.classes.is_empty() {
                b.cap
            } else {
                b.cap * spec.mem_bw / cluster.gpu.mem_bw
            };
        }
    }
    // per-GPU state on the stack — this runs thousands of times per
    // allocator solve and must not allocate
    let mut sm = [0.0f64; MAX_GPUS];
    let mut mem = [0.0f64; MAX_GPUS];
    let mut ctx = [0u32; MAX_GPUS];
    let mut bw_used = [0.0f64; MAX_GPUS];
    // model charged once per (gpu, stage): bitmask per gpu
    let mut hosts = [0u64; MAX_GPUS];
    for (g, r) in state.reservations().iter().enumerate() {
        sm[g] = r.sm_frac;
        mem[g] = r.mem_bytes;
        ctx[g] = r.contexts;
        bw_used[g] = r.bw_demand;
    }

    // same order as place(): memory-hungriest stages first
    let mut order = [0usize; MAX_STAGES];
    for (i, o) in order[..n_stages].iter_mut().enumerate() {
        *o = i;
    }
    let order = &mut order[..n_stages];
    order.sort_by(|&a, &b| {
        pipeline.stages[b]
            .mem_footprint(batch)
            .partial_cmp(&pipeline.stages[a].mem_footprint(batch))
            .unwrap()
    });

    let mut cand = [0usize; MAX_GPUS];
    let cand = &mut cand[..n_gpus];
    for &stage_idx in order.iter() {
        let st = &pipeline.stages[stage_idx];
        let quota = alloc.quotas[stage_idx];
        let act = st.act_bytes_per_query * batch as f64;
        for _ in 0..alloc.instances[stage_idx] {
            // candidate order: same-stage hosts first, then scarcest
            // remaining memory, then scarcest SMs (mirrors place())
            for (i, c) in cand.iter_mut().enumerate() {
                *c = i;
            }
            cand.sort_by(|&a, &b| {
                let share_a = hosts[a] >> stage_idx & 1;
                let share_b = hosts[b] >> stage_idx & 1;
                share_b
                    .cmp(&share_a)
                    .then((cap_mem[a] - mem[a]).partial_cmp(&(cap_mem[b] - mem[b])).unwrap())
                    .then((1.0 - sm[a]).partial_cmp(&(1.0 - sm[b])).unwrap())
            });
            let mut placed = false;
            for &g in cand.iter() {
                if let Some(b) = bw {
                    if bw_used[g] + b.demands[stage_idx] > bw_cap[g] {
                        continue;
                    }
                }
                if sm[g] + quota > 1.0 + 1e-9 || ctx[g] >= cap_ctx[g] {
                    continue;
                }
                let new_model = if hosts[g] >> stage_idx & 1 == 1 { 0.0 } else { st.model_bytes };
                if mem[g] + new_model + act > cap_mem[g] {
                    continue;
                }
                sm[g] += quota;
                ctx[g] += 1;
                mem[g] += new_model + act;
                hosts[g] |= 1 << stage_idx;
                if let Some(b) = bw {
                    bw_used[g] += b.demands[stage_idx];
                }
                placed = true;
                break;
            }
            if !placed {
                return false;
            }
        }
    }
    true
}

/// Convenience: place and wrap into a runnable [`Deployment`].
pub fn deploy(
    pipeline: &Pipeline,
    state: &ClusterState,
    alloc: &Allocation,
    batch: u32,
    comm: crate::comm::CommMode,
    bw: Option<BwBudget<'_>>,
) -> Result<Deployment, DeployError> {
    let (placements, _) = place(pipeline, state, alloc, batch, bw)?;
    Ok(Deployment { placements, batch, comm })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommMode;
    use crate::config::ClusterSpec;
    use crate::suite::{artifact, real};
    use crate::util::testkit;

    fn free(c: &ClusterSpec) -> ClusterState {
        ClusterState::exclusive(c)
    }

    #[test]
    fn places_simple_allocation() {
        let p = real::img_to_text();
        let c = ClusterSpec::two_2080ti();
        let a = Allocation { instances: vec![2, 2], quotas: vec![0.4, 0.3] };
        let (pl, gpus) = place(&p, &free(&c), &a, 16, None).unwrap();
        assert_eq!(pl.len(), 4);
        // no GPU oversubscribed
        for g in &gpus {
            assert!(g.sm_allocated() <= 1.0 + 1e-9);
            assert!(g.mem_free() >= 0.0);
        }
    }

    #[test]
    fn same_stage_instances_share_gpu_when_possible() {
        let p = real::img_to_text();
        let c = ClusterSpec::two_2080ti();
        let a = Allocation { instances: vec![2, 1], quotas: vec![0.3, 0.2] };
        let (pl, _) = place(&p, &free(&c), &a, 16, None).unwrap();
        let s0: Vec<usize> = pl.iter().filter(|x| x.stage == 0).map(|x| x.gpu).collect();
        assert_eq!(s0[0], s0[1], "same-stage instances should co-locate");
    }

    #[test]
    fn rejects_infeasible_sm_demand() {
        let p = real::img_to_img();
        let c = ClusterSpec::two_2080ti();
        // 2 GPUs cannot host 3.0 GPUs worth of quota
        let a = Allocation { instances: vec![3, 3], quotas: vec![0.5, 0.5] };
        assert!(place(&p, &free(&c), &a, 16, None).is_err());
    }

    #[test]
    fn memory_first_ordering_avoids_fragmentation() {
        // artifact pipeline with one fat-memory stage: it must be placed
        // even when other stages could have crowded the GPUs first.
        let p = artifact::pipeline(1, 1, 3);
        let c = ClusterSpec::two_2080ti();
        let a = Allocation { instances: vec![4, 4, 4], quotas: vec![0.1, 0.1, 0.2] };
        let (pl, _) = place(&p, &free(&c), &a, 64, None).unwrap();
        assert_eq!(pl.len(), 12);
    }

    #[test]
    fn feasible_placement_agrees_with_place() {
        testkit::forall_res(
            31,
            300,
            |r| {
                let three_stage = r.below(2) == 0;
                let stages = if three_stage { 3 } else { 2 };
                let inst: Vec<u32> = (0..stages).map(|_| 1 + r.below(8) as u32).collect();
                let quotas: Vec<f64> =
                    (0..stages).map(|_| r.range_f64(0.05, 0.8)).collect();
                // sometimes plan into a partially occupied cluster
                let reserved = if r.below(2) == 0 {
                    Vec::new()
                } else {
                    (0..2)
                        .map(|_| GpuReservation {
                            sm_frac: r.range_f64(0.0, 0.6),
                            mem_bytes: r.range_f64(0.0, 6.0e9),
                            contexts: r.below(8) as u32,
                            bw_demand: r.range_f64(0.0, 0.4) * 616.0e9,
                        })
                        .collect()
                };
                (inst, quotas, three_stage, 8u32 << r.below(3), reserved)
            },
            |(inst, quotas, three_stage, batch, reserved)| {
                let p = if *three_stage {
                    artifact::pipeline(1, 2, 1)
                } else {
                    real::img_to_img()
                };
                let c = ClusterSpec::two_2080ti();
                let state = ClusterState::with_reservations(&c, reserved);
                let a = Allocation { instances: inst.clone(), quotas: quotas.clone() };
                let demands: Vec<f64> =
                    p.stages.iter().map(|s| s.hbm_bytes(*batch) / 0.02).collect();
                for bw in [
                    None,
                    Some(BwBudget { demands: &demands, cap: 0.75 * c.gpu.mem_bw }),
                ] {
                    let fast = feasible_placement(&p, &state, &a, *batch, bw);
                    let slow = place(&p, &state, &a, *batch, bw).is_ok();
                    if fast != slow {
                        return Err(format!("disagree: fast={fast} slow={slow}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn mixed_pool_placement_respects_per_gpu_caps() {
        use crate::config::GpuClass;
        let mut c = ClusterSpec::two_2080ti();
        let mut small = c.gpu.clone();
        small.mem_bytes /= 4;
        small.mps_contexts = 1;
        c.classes = vec![
            GpuClass::scaled(c.gpu.clone(), 1, 1.0),
            GpuClass::scaled(small, 1, 1.0),
        ];
        c.validate_classes().unwrap();
        let p = real::img_to_text();
        let a = Allocation { instances: vec![2, 2], quotas: vec![0.1, 0.1] };
        if let Ok((pl, gpus)) = place(&p, &free(&c), &a, 16, None) {
            // the small GPU allows a single MPS context
            let on_small = pl.iter().filter(|x| x.gpu == 1).count();
            assert!(on_small <= 1, "small GPU over-committed: {on_small} contexts");
            for (g, s) in gpus.iter().enumerate() {
                assert!(s.sm_allocated() <= 1.0 + 1e-9);
                assert!(s.mem_free() >= 0.0, "gpu {g} memory over-committed");
            }
        }
        assert_eq!(
            feasible_placement(&p, &free(&c), &a, 16, None),
            place(&p, &free(&c), &a, 16, None).is_ok()
        );
    }

    #[test]
    fn feasible_placement_agrees_with_place_on_mixed_pool() {
        use crate::config::GpuClass;
        let mut c = ClusterSpec::two_2080ti();
        let mut small = c.gpu.clone();
        small.mem_bytes /= 2;
        small.mps_contexts = 4;
        small.mem_bw *= 0.5;
        c.classes = vec![
            GpuClass::scaled(c.gpu.clone(), 1, 1.0),
            GpuClass::scaled(small, 1, 0.5),
        ];
        c.validate_classes().unwrap();
        testkit::forall_res(
            77,
            200,
            |r| {
                let inst: Vec<u32> = (0..2).map(|_| 1 + r.below(6) as u32).collect();
                let quotas: Vec<f64> = (0..2).map(|_| r.range_f64(0.05, 0.8)).collect();
                (inst, quotas, 8u32 << r.below(3))
            },
            |(inst, quotas, batch)| {
                let p = real::img_to_img();
                let state = ClusterState::exclusive(&c);
                let a = Allocation { instances: inst.clone(), quotas: quotas.clone() };
                let demands: Vec<f64> =
                    p.stages.iter().map(|s| s.hbm_bytes(*batch) / 0.02).collect();
                for bw in [
                    None,
                    Some(BwBudget { demands: &demands, cap: 0.75 * c.gpu.mem_bw }),
                ] {
                    let fast = feasible_placement(&p, &state, &a, *batch, bw);
                    let slow = place(&p, &state, &a, *batch, bw).is_ok();
                    if fast != slow {
                        return Err(format!("disagree: fast={fast} slow={slow}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn reservations_shrink_capacity() {
        let p = real::img_to_img();
        let c = ClusterSpec::two_2080ti();
        let a = Allocation { instances: vec![2, 2], quotas: vec![0.45, 0.45] };
        // fits an empty cluster (Σ quota 1.8 on 2 GPUs)
        assert!(feasible_placement(&p, &free(&c), &a, 16, None));
        // a tenant holding 60% of each GPU leaves too little
        let held = vec![
            GpuReservation { sm_frac: 0.6, ..Default::default() };
            c.num_gpus
        ];
        let shared = ClusterState::with_reservations(&c, &held);
        assert!(!feasible_placement(&p, &shared, &a, 16, None));
        // but a smaller allocation still fits around the tenant
        let small = Allocation { instances: vec![1, 1], quotas: vec![0.3, 0.3] };
        assert!(feasible_placement(&p, &shared, &small, 16, None));
    }

    #[test]
    fn reservations_for_accounts_sharing_and_counts() {
        let p = real::img_to_text();
        let c = ClusterSpec::two_2080ti();
        let d = Deployment {
            placements: vec![
                InstancePlacement { stage: 0, gpu: 0, sm_frac: 0.3 },
                InstancePlacement { stage: 0, gpu: 0, sm_frac: 0.3 },
                InstancePlacement { stage: 1, gpu: 1, sm_frac: 0.5 },
            ],
            batch: 16,
            comm: CommMode::GlobalIpc,
        };
        let res = reservations_for(&p, &c, &d);
        assert_eq!(res.len(), 2);
        assert!((res[0].sm_frac - 0.6).abs() < 1e-12);
        assert_eq!(res[0].contexts, 2);
        assert_eq!(res[1].contexts, 1);
        // same-stage model charged once, activations per instance
        let s0 = &p.stages[0];
        let expect0 = s0.model_bytes + 2.0 * s0.act_bytes_per_query * 16.0;
        assert!((res[0].mem_bytes - expect0).abs() < 1.0);
        assert!(res[0].bw_demand > 0.0 && res[1].bw_demand > 0.0);
        // derived reservations must be admissible around the original:
        // the cluster sim admits the deployment, so a second tenant
        // planned into the remainder co-exists by construction
        let (_, gpus) = place(
            &p,
            &ClusterState::with_reservations(&c, &res),
            &Allocation { instances: vec![1, 1], quotas: vec![0.2, 0.2] },
            16,
            None,
        )
        .expect("remainder fits a small tenant");
        for g in &gpus {
            assert!(g.sm_allocated() <= 1.0 + 1e-9);
            assert!(g.mem_free() >= 0.0);
        }
    }

    #[test]
    fn merge_reservations_sums_per_gpu() {
        let mut a = vec![
            GpuReservation { sm_frac: 0.3, mem_bytes: 1.0e9, contexts: 2, bw_demand: 5.0e9 },
            GpuReservation::default(),
        ];
        let b = vec![
            GpuReservation { sm_frac: 0.2, mem_bytes: 2.0e9, contexts: 1, bw_demand: 1.0e9 },
            GpuReservation { sm_frac: 0.4, mem_bytes: 0.5e9, contexts: 3, bw_demand: 2.0e9 },
        ];
        merge_reservations(&mut a, &b);
        assert!((a[0].sm_frac - 0.5).abs() < 1e-12);
        assert!((a[0].mem_bytes - 3.0e9).abs() < 1.0);
        assert_eq!(a[0].contexts, 3);
        assert!((a[0].bw_demand - 6.0e9).abs() < 1.0);
        assert!((a[1].sm_frac - 0.4).abs() < 1e-12);
        assert_eq!(a[1].contexts, 3);
    }

    #[test]
    fn gpus_in_use_counts_distinct_devices() {
        let mk = |gpus: &[usize]| Deployment {
            placements: gpus
                .iter()
                .map(|&g| InstancePlacement { stage: 0, gpu: g, sm_frac: 0.1 })
                .collect(),
            batch: 8,
            comm: CommMode::GlobalIpc,
        };
        let a = mk(&[0, 0, 1]);
        let b = mk(&[1]);
        let c = mk(&[3]);
        assert_eq!(gpus_in_use([&a]), 2);
        assert_eq!(gpus_in_use([&a, &b]), 2);
        assert_eq!(gpus_in_use([&a, &b, &c]), 3);
        assert_eq!(gpus_in_use(std::iter::empty::<&Deployment>()), 0);
    }

    #[test]
    fn gpus_in_use_spans_word_boundaries_on_large_clusters() {
        let mk = |gpus: &[usize]| Deployment {
            placements: gpus
                .iter()
                .map(|&g| InstancePlacement { stage: 0, gpu: g, sm_frac: 0.1 })
                .collect(),
            batch: 8,
            comm: CommMode::GlobalIpc,
        };
        // the exact seam of the u64 bitmask words: 63 is the last bit
        // of word 0, 64 the first bit of word 1
        assert_eq!(gpus_in_use([&mk(&[63])]), 1);
        assert_eq!(gpus_in_use([&mk(&[64])]), 1);
        assert_eq!(gpus_in_use([&mk(&[63, 64, 65])]), 3);
        // same GPU across the seam, from different deployments, is
        // still one device
        assert_eq!(gpus_in_use([&mk(&[64, 64]), &mk(&[64])]), 1);
        // a datacenter-scale spread: every 64th GPU sets bit 0 of a new
        // word, plus stragglers that straddle words mid-way
        let spread: Vec<usize> = (0..=1024).step_by(64).chain([63, 127, 500]).collect();
        assert_eq!(gpus_in_use([&mk(&spread)]), 17 + 3);
        // ... and duplicates across the whole range collapse
        let doubled: Vec<usize> = spread.iter().chain(spread.iter()).copied().collect();
        assert_eq!(gpus_in_use([&mk(&doubled)]), 17 + 3);
    }

    #[test]
    fn deployment_admits_in_simulator() {
        // whatever deploy() accepts, the simulator must also admit
        testkit::forall_res(
            21,
            40,
            |r| {
                (
                    1 + r.below(3) as u32,
                    1 + r.below(3) as u32,
                    r.range_f64(0.05, 0.5),
                    r.range_f64(0.05, 0.5),
                    8 << r.below(3),
                )
            },
            |&(n0, n1, q0, q1, batch)| {
                let p = real::text_to_text();
                let c = ClusterSpec::two_2080ti();
                let a = Allocation { instances: vec![n0, n1], quotas: vec![q0, q1] };
                match deploy(&p, &free(&c), &a, batch as u32, CommMode::GlobalIpc, None) {
                    Ok(d) => {
                        let sim = crate::sim::Simulator::new(
                            &p,
                            &c,
                            &d,
                            crate::sim::SimOptions { queries: 1, ..Default::default() },
                        );
                        sim.admit().map(|_| ()).map_err(|e| format!("sim rejects: {e}"))
                    }
                    Err(_) => Ok(()), // infeasible is fine; inconsistency is not
                }
            },
        );
    }
}
