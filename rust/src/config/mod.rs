//! Cluster and GPU configuration.
//!
//! Encodes Table III of the paper (the two testbeds) as presets, plus
//! the PCIe constants of §VI-A. All resource-allocation constraints in
//! `allocator/` read their capacities (R, BW, F, I, G in Table II) from
//! a [`GpuSpec`].

/// Static description of one spatial-multitasking GPU model.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    /// Marketing name, e.g. "RTX 2080Ti".
    pub name: &'static str,
    /// Number of streaming multiprocessors (the paper allocates SMs as a
    /// percentage of this pool via Volta MPS).
    pub sms: u32,
    /// Peak fp32 throughput in GFLOPS (G in Table II).
    pub gflops: f64,
    /// Global memory capacity in bytes (F in Table II).
    pub mem_bytes: u64,
    /// Peak global memory bandwidth in bytes/s (BW in Table II).
    pub mem_bw: f64,
    /// Max concurrent MPS client contexts per device (I in Table II;
    /// Volta MPS allows 48).
    pub mps_contexts: u32,
    /// Fixed kernel launch/dispatch overhead per batch, seconds.
    pub launch_overhead_s: f64,
}

impl GpuSpec {
    /// NVIDIA GeForce RTX 2080Ti — the paper's two-GPU testbed.
    pub fn rtx2080ti() -> Self {
        GpuSpec {
            name: "RTX 2080Ti",
            sms: 68,
            gflops: 13_450.0,
            mem_bytes: 11 * (1 << 30),
            mem_bw: 616.0e9,
            mps_contexts: 48,
            launch_overhead_s: 30e-6,
        }
    }

    /// NVIDIA Tesla V100-SXM3 32GB — one of the 16 GPUs of the DGX-2.
    pub fn v100_sxm3() -> Self {
        GpuSpec {
            name: "V100-SXM3",
            sms: 80,
            gflops: 15_700.0,
            mem_bytes: 32 * (1 << 30),
            mem_bw: 897.0e9,
            mps_contexts: 48,
            launch_overhead_s: 30e-6,
        }
    }

    /// NVIDIA A100-SXM4 80GB — the MIG-capable datacenter part the
    /// heterogeneous-pool scenarios mix in (MISO's testbed).
    pub fn a100_sxm4_80g() -> Self {
        GpuSpec {
            name: "A100-SXM4-80GB",
            sms: 108,
            gflops: 19_500.0,
            mem_bytes: 80 * (1 << 30),
            mem_bw: 2_039.0e9,
            mps_contexts: 48,
            launch_overhead_s: 30e-6,
        }
    }

    /// NVIDIA H100-SXM5 80GB — the fastest class in the mixed-pool
    /// scenarios.
    pub fn h100_sxm5_80g() -> Self {
        GpuSpec {
            name: "H100-SXM5-80GB",
            sms: 132,
            gflops: 67_000.0,
            mem_bytes: 80 * (1 << 30),
            mem_bw: 3_350.0e9,
            mps_contexts: 48,
            launch_overhead_s: 30e-6,
        }
    }

    /// Peak fp32 FLOP/s as a plain f64.
    pub fn flops_per_sec(&self) -> f64 {
        self.gflops * 1e9
    }

    /// Look up a preset by the short names the scenario JSON uses.
    pub fn by_name(name: &str) -> Option<GpuSpec> {
        match name {
            "2080ti" => Some(GpuSpec::rtx2080ti()),
            "v100" => Some(GpuSpec::v100_sxm3()),
            "a100" => Some(GpuSpec::a100_sxm4_80g()),
            "h100" => Some(GpuSpec::h100_sxm5_80g()),
            _ => None,
        }
    }
}

/// MIG-style slice catalog: quotas on a discrete-partition GPU must
/// land on whole multiples of `1/units` of the device (an A100 exposes
/// 7 compute slices — the 1g/2g/3g/4g/7g profiles are all multiples of
/// 1/7). The planner solves in continuous quotas and then *snaps up*
/// to the catalog, so a discrete plan is never slower than the
/// continuous plan it rounds (more SMs per instance, never fewer).
#[derive(Debug, Clone, PartialEq)]
pub struct SliceCatalog {
    /// Equal compute slices per GPU (A100 MIG: 7).
    pub units: u32,
    /// Seconds of reconfiguration disruption charged per slice whose
    /// owner changes when a plan is replaced (MIG instances must be
    /// destroyed and re-created; cf. MISO §4). Amortized over
    /// [`SliceCatalog::AMORTIZE_HORIZON_S`] when the planner compares a
    /// shrink's resource gain against its repartition cost.
    pub repartition_s_per_slice: f64,
}

impl SliceCatalog {
    /// Horizon (seconds) over which a repartition's disruption is
    /// amortized when priced against a usage reduction: a shrink that
    /// frees `u` GPU-equivalents must save more than
    /// `cost_s / AMORTIZE_HORIZON_S` GPU-equivalents to be worth the
    /// churn.
    pub const AMORTIZE_HORIZON_S: f64 = 300.0;

    /// The A100's 7-slice MIG catalog.
    pub fn mig7() -> Self {
        SliceCatalog { units: 7, repartition_s_per_slice: 2.0 }
    }

    /// Smallest catalog quota ≥ `q` (clamped to one whole device).
    pub fn snap_up(&self, q: f64) -> f64 {
        let u = self.units as f64;
        ((q * u).ceil() / u).min(1.0)
    }

    /// Slice units a quota occupies. Quotas produced by
    /// [`snap_up`](Self::snap_up) are exact multiples of `1/units`, so
    /// the rounding here is only absorbing f64 noise.
    pub fn units_for(&self, q: f64) -> u32 {
        (q * self.units as f64).round() as u32
    }

    /// Disruption cost of moving `slices_changed` slice boundaries,
    /// amortized to GPU-equivalents over the planning horizon.
    pub fn amortized_cost(&self, slices_changed: u32) -> f64 {
        slices_changed as f64 * self.repartition_s_per_slice / Self::AMORTIZE_HORIZON_S
    }
}

/// How SM share is carved on a GPU (or a class of GPUs).
#[derive(Debug, Clone, PartialEq)]
pub enum PartitionMode {
    /// MPS-style fractional quotas (the paper's model): any share in
    /// `[0, 1]` is placeable.
    Continuous,
    /// MIG-style fixed slices: every quota must be a whole multiple of
    /// `1/catalog.units`.
    Discrete(SliceCatalog),
}

impl PartitionMode {
    /// The catalog when discrete, `None` when continuous.
    pub fn catalog(&self) -> Option<&SliceCatalog> {
        match self {
            PartitionMode::Continuous => None,
            PartitionMode::Discrete(c) => Some(c),
        }
    }
}

/// One homogeneous run of GPUs inside a mixed pool. Classes occupy
/// *contiguous* GPU-id ranges in declaration order (class 0 owns GPUs
/// `0..count₀`, class 1 the next `count₁`, …).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuClass {
    /// Hardware spec of every GPU in this class.
    pub gpu: GpuSpec,
    /// Number of GPUs in the class.
    pub count: usize,
    /// Relative per-stage service-time multiplier vs the profiled base
    /// [`ClusterSpec::gpu`] (< 1 means this class is faster). Applied to
    /// predictor reads and simulated kernel durations; 1.0 is an exact
    /// no-op (the bit-identity contract for homogeneous pools).
    pub compute_scale: f64,
    /// How SM share is carved on this class's devices.
    pub partition: PartitionMode,
}

impl GpuClass {
    /// A class with continuous partitioning and a given speed factor.
    pub fn scaled(gpu: GpuSpec, count: usize, compute_scale: f64) -> Self {
        GpuClass { gpu, count, compute_scale, partition: PartitionMode::Continuous }
    }
}

/// PCIe bus model constants (§VI-A of the paper).
#[derive(Debug, Clone, PartialEq)]
pub struct PcieSpec {
    /// Effective bus bandwidth, bytes/s (paper: 12,160 MB/s for ×16 3.0).
    pub effective_bw: f64,
    /// Bandwidth one pageable-memory memcpy stream can sustain, bytes/s
    /// (paper measurement: 3,150 MB/s).
    pub per_stream_bw: f64,
    /// Fixed DMA setup latency per transfer, seconds.
    pub setup_s: f64,
}

impl Default for PcieSpec {
    fn default() -> Self {
        PcieSpec {
            effective_bw: 12_160.0e6,
            per_stream_bw: 3_150.0e6,
            setup_s: 10e-6,
        }
    }
}

/// CUDA-IPC-style global-memory communication constants (§VI-B).
#[derive(Debug, Clone, PartialEq)]
pub struct IpcSpec {
    /// One-time channel setup (cudaIpcGetMemHandle + handshake): ~1 ms.
    pub setup_s: f64,
    /// Per-message overhead to probe/transfer/decode the 8-byte handle.
    /// This is what makes tiny (<0.02 MB) payloads favor the main-memory
    /// path in Fig 11.
    pub per_msg_s: f64,
    /// Handle size in bytes.
    pub handle_bytes: u64,
}

impl Default for IpcSpec {
    fn default() -> Self {
        IpcSpec {
            setup_s: 1e-3,
            per_msg_s: 25e-6,
            handle_bytes: 8,
        }
    }
}

/// A machine: GPUs behind one PCIe root complex per pair.
///
/// `gpu` is the *base* (profiling) spec: predictors are trained against
/// it and, when `classes` is empty, every one of the `num_gpus` devices
/// is an identical copy of it — the paper's homogeneous testbeds. A
/// non-empty `classes` describes a mixed pool (e.g. A100 + H100 + a
/// MIG-sliced class); class counts must sum to `num_gpus` and classes
/// occupy contiguous GPU-id ranges in declaration order.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    /// Base (profiling) GPU model; the hardware of every device when
    /// `classes` is empty.
    pub gpu: GpuSpec,
    /// Total devices in the pool.
    pub num_gpus: usize,
    /// PCIe bus constants shared by every device.
    pub pcie: PcieSpec,
    /// CUDA-IPC constants shared by every device.
    pub ipc: IpcSpec,
    /// Heterogeneous composition; empty = homogeneous pool of `gpu`.
    pub classes: Vec<GpuClass>,
    /// Pool-default partition mode, used for every GPU not covered by a
    /// class (and as the scenario-level `partition_mode` default for
    /// classes that don't override it).
    pub partition: PartitionMode,
    /// Per-GPU partial-degradation overlay (ECC retirement, thermal
    /// throttling): a service-time multiplier ≥ 1.0 per device,
    /// multiplied into [`scale_at`](Self::scale_at). Empty means every
    /// device is healthy — the canonical (and legacy) representation;
    /// [`set_degrade`](Self::set_degrade) normalizes an all-1.0 overlay
    /// back to empty so healthy clusters stay byte-identical to
    /// pre-overlay behavior. Non-empty overlays have exactly `num_gpus`
    /// entries.
    pub degrade: Vec<f64>,
}

impl ClusterSpec {
    /// The paper's primary testbed: 2× RTX 2080Ti.
    pub fn two_2080ti() -> Self {
        ClusterSpec {
            gpu: GpuSpec::rtx2080ti(),
            num_gpus: 2,
            pcie: PcieSpec::default(),
            ipc: IpcSpec::default(),
            classes: Vec::new(),
            partition: PartitionMode::Continuous,
            degrade: Vec::new(),
        }
    }

    /// The paper's large-scale testbed: DGX-2 with 16× V100.
    pub fn dgx2() -> Self {
        ClusterSpec {
            gpu: GpuSpec::v100_sxm3(),
            num_gpus: 16,
            pcie: PcieSpec::default(),
            ipc: IpcSpec::default(),
            classes: Vec::new(),
            partition: PartitionMode::Continuous,
            degrade: Vec::new(),
        }
    }

    /// Total SM-fraction capacity across the cluster (C × R with R = 1.0).
    pub fn total_compute(&self) -> f64 {
        self.num_gpus as f64
    }

    /// Check the class invariants: counts sum to `num_gpus`, no empty
    /// class, positive finite compute scales, sane slice catalogs.
    pub fn validate_classes(&self) -> Result<(), String> {
        if self.classes.is_empty() {
            return Ok(());
        }
        let total: usize = self.classes.iter().map(|c| c.count).sum();
        if total != self.num_gpus {
            return Err(format!(
                "gpu_classes: counts sum to {total} but num_gpus is {}",
                self.num_gpus
            ));
        }
        for (i, c) in self.classes.iter().enumerate() {
            if c.count == 0 {
                return Err(format!("gpu_classes[{i}]: count must be >= 1"));
            }
            if !(c.compute_scale > 0.0 && c.compute_scale.is_finite()) {
                return Err(format!(
                    "gpu_classes[{i}]: compute_scale must be positive and finite"
                ));
            }
            if let Some(cat) = c.partition.catalog() {
                if cat.units == 0 {
                    return Err(format!("gpu_classes[{i}]: slice catalog needs units >= 1"));
                }
            }
        }
        Ok(())
    }

    /// The class owning GPU `g` (`None` on a homogeneous pool).
    pub fn class_of(&self, g: usize) -> Option<&GpuClass> {
        let mut start = 0usize;
        for c in &self.classes {
            if g < start + c.count {
                return Some(c);
            }
            start += c.count;
        }
        None
    }

    /// `(first_gpu, count)` of each class, in class order.
    pub fn class_ranges(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.classes.len());
        let mut start = 0usize;
        for c in &self.classes {
            out.push((start, c.count));
            start += c.count;
        }
        out
    }

    /// Hardware spec of GPU `g` (the base spec on a homogeneous pool).
    pub fn gpu_at(&self, g: usize) -> &GpuSpec {
        self.class_of(g).map_or(&self.gpu, |c| &c.gpu)
    }

    /// Service-time multiplier of GPU `g` (1.0 on a homogeneous pool),
    /// including any partial-degradation overlay. Healthy clusters
    /// (empty overlay) multiply by exactly 1.0, so the legacy value is
    /// bit-identical.
    pub fn scale_at(&self, g: usize) -> f64 {
        self.class_of(g).map_or(1.0, |c| c.compute_scale) * self.degrade_at(g)
    }

    /// The degradation multiplier of GPU `g` alone (1.0 = healthy).
    pub fn degrade_at(&self, g: usize) -> f64 {
        if self.degrade.is_empty() {
            1.0
        } else {
            self.degrade[g]
        }
    }

    /// Set GPU `g`'s degradation multiplier (1.0 restores the device).
    /// The overlay is kept canonical: it stays empty until a non-unit
    /// multiplier is installed, and collapses back to empty when every
    /// device returns to 1.0.
    pub fn set_degrade(&mut self, g: usize, scale: f64) {
        if self.degrade.is_empty() {
            if scale == 1.0 {
                return;
            }
            self.degrade = vec![1.0; self.num_gpus];
        }
        self.degrade[g] = scale;
        if self.degrade.iter().all(|&s| s == 1.0) {
            self.degrade.clear();
        }
    }

    /// Partition mode of GPU `g` (class override, else the pool mode).
    pub fn partition_at(&self, g: usize) -> &PartitionMode {
        self.class_of(g).map_or(&self.partition, |c| &c.partition)
    }

    /// Whether the pool is indistinguishable from the homogeneous
    /// continuous-mode cluster the paper's planner assumes — the guard
    /// for the bit-identity contract (`planner::hetero` delegates to the
    /// unmodified `CamelotPlanner` exactly when this holds).
    pub fn effectively_homogeneous(&self) -> bool {
        self.partition == PartitionMode::Continuous
            && self.classes.iter().all(|c| {
                c.gpu == self.gpu
                    && c.compute_scale == 1.0
                    && c.partition == PartitionMode::Continuous
            })
    }

    /// Σ MPS context capacity across the pool.
    pub fn total_contexts(&self) -> u32 {
        if self.classes.is_empty() {
            self.num_gpus as u32 * self.gpu.mps_contexts
        } else {
            self.classes.iter().map(|c| c.count as u32 * c.gpu.mps_contexts).sum()
        }
    }

    /// The sub-cluster of the first `y` GPUs, with the class list
    /// truncated to match. This is what capacity-ladder searches use in
    /// place of `ClusterSpec { num_gpus: y, .. }` so a heterogeneous
    /// prefix keeps per-GPU specs aligned with GPU ids.
    pub fn prefix(&self, y: usize) -> ClusterSpec {
        let mut out = ClusterSpec { num_gpus: y, ..self.clone() };
        if !out.degrade.is_empty() {
            out.degrade.truncate(y);
            if out.degrade.iter().all(|&s| s == 1.0) {
                out.degrade.clear();
            }
        }
        if !self.classes.is_empty() {
            let mut remaining = y;
            let mut classes = Vec::new();
            for c in &self.classes {
                if remaining == 0 {
                    break;
                }
                let take = c.count.min(remaining);
                classes.push(GpuClass { count: take, ..c.clone() });
                remaining -= take;
            }
            out.classes = classes;
        }
        out
    }

    /// The sub-cluster of GPUs `start..start + len`, classes sliced to
    /// match — how the cluster-of-cells sharding splits a mixed pool.
    pub fn slice(&self, start: usize, len: usize) -> ClusterSpec {
        let mut out = ClusterSpec { num_gpus: len, ..self.clone() };
        if !out.degrade.is_empty() {
            out.degrade = self.degrade[start..start + len].to_vec();
            if out.degrade.iter().all(|&s| s == 1.0) {
                out.degrade.clear();
            }
        }
        if !self.classes.is_empty() {
            let mut classes = Vec::new();
            let mut base = 0usize;
            for c in &self.classes {
                let lo = start.max(base);
                let hi = (start + len).min(base + c.count);
                if hi > lo {
                    classes.push(GpuClass { count: hi - lo, ..c.clone() });
                }
                base += c.count;
            }
            out.classes = classes;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_table3() {
        let t = GpuSpec::rtx2080ti();
        assert_eq!(t.sms, 68);
        assert_eq!(t.mps_contexts, 48);
        assert!((t.mem_bw - 616.0e9).abs() < 1.0);
        let v = GpuSpec::v100_sxm3();
        assert!((v.mem_bw - 897.0e9).abs() < 1.0);
        assert_eq!(v.mem_bytes, 32 * (1 << 30));
    }

    #[test]
    fn pcie_contention_knee_at_three_streams() {
        // The paper's back-of-envelope: ⌊12160/3150⌋ = 3 concurrent
        // pageable streams fit before contention begins.
        let p = PcieSpec::default();
        assert_eq!((p.effective_bw / p.per_stream_bw) as u32, 3);
    }

    #[test]
    fn cluster_presets() {
        assert_eq!(ClusterSpec::two_2080ti().num_gpus, 2);
        assert_eq!(ClusterSpec::dgx2().num_gpus, 16);
        assert_eq!(ClusterSpec::dgx2().gpu.name, "V100-SXM3");
    }

    #[test]
    fn degrade_overlay_multiplies_scale_and_stays_canonical() {
        let mut c = ClusterSpec::two_2080ti();
        assert_eq!(c.scale_at(0), 1.0);
        // installing a unit multiplier is a no-op: overlay stays empty
        c.set_degrade(0, 1.0);
        assert!(c.degrade.is_empty());
        // a real degradation inflates only the affected device
        c.set_degrade(1, 1.5);
        assert_eq!(c.degrade.len(), c.num_gpus);
        assert_eq!(c.scale_at(0), 1.0);
        assert_eq!(c.scale_at(1), 1.5);
        assert_eq!(c.degrade_at(1), 1.5);
        // prefix/slice keep the overlay aligned with GPU ids
        assert!(c.prefix(1).degrade.is_empty(), "healthy prefix collapses");
        assert_eq!(c.slice(1, 1).degrade, vec![1.5]);
        // restoring the device collapses the overlay back to empty
        c.set_degrade(1, 1.0);
        assert!(c.degrade.is_empty());
        // degradation composes with class compute scales
        let mut m = mixed_pool();
        assert_eq!(m.scale_at(2), 0.35);
        m.set_degrade(2, 2.0);
        assert_eq!(m.scale_at(2), 0.35 * 2.0);
        // and never flips the homogeneity guard (planning stays naive;
        // the QoS gate and the sims see the slowdown)
        let mut flat = ClusterSpec::two_2080ti();
        flat.set_degrade(0, 4.0);
        assert!(flat.effectively_homogeneous());
    }

    fn mixed_pool() -> ClusterSpec {
        // 2× A100 + 1× H100 + 1× MIG-sliced A100 on a 2080Ti base
        ClusterSpec {
            num_gpus: 4,
            classes: vec![
                GpuClass::scaled(GpuSpec::a100_sxm4_80g(), 2, 0.6),
                GpuClass::scaled(GpuSpec::h100_sxm5_80g(), 1, 0.35),
                GpuClass {
                    gpu: GpuSpec::a100_sxm4_80g(),
                    count: 1,
                    compute_scale: 0.6,
                    partition: PartitionMode::Discrete(SliceCatalog::mig7()),
                },
            ],
            ..ClusterSpec::two_2080ti()
        }
    }

    #[test]
    fn class_lookup_follows_contiguous_ranges() {
        let c = mixed_pool();
        c.validate_classes().unwrap();
        assert_eq!(c.gpu_at(0).name, "A100-SXM4-80GB");
        assert_eq!(c.gpu_at(1).name, "A100-SXM4-80GB");
        assert_eq!(c.gpu_at(2).name, "H100-SXM5-80GB");
        assert_eq!(c.scale_at(2), 0.35);
        assert!(matches!(c.partition_at(3), PartitionMode::Discrete(_)));
        assert!(matches!(c.partition_at(0), PartitionMode::Continuous));
        assert_eq!(c.class_ranges(), vec![(0, 2), (2, 1), (3, 1)]);
        assert!(!c.effectively_homogeneous());
        assert_eq!(c.total_contexts(), 4 * 48);
    }

    #[test]
    fn homogeneous_accessors_are_identity() {
        let c = ClusterSpec::two_2080ti();
        assert!(c.effectively_homogeneous());
        assert_eq!(c.gpu_at(1), &c.gpu);
        assert_eq!(c.scale_at(0), 1.0);
        assert_eq!(c.total_contexts(), 2 * 48);
        // explicit single class identical to the base is still
        // effectively homogeneous (the bit-identity guard)
        let mut tagged = c.clone();
        tagged.classes = vec![GpuClass::scaled(tagged.gpu.clone(), 2, 1.0)];
        tagged.validate_classes().unwrap();
        assert!(tagged.effectively_homogeneous());
    }

    #[test]
    fn class_invariants_are_validated() {
        let mut c = mixed_pool();
        c.num_gpus = 5;
        assert!(c.validate_classes().unwrap_err().contains("counts sum to 4"));
        let mut c = mixed_pool();
        c.classes[0].compute_scale = 0.0;
        assert!(c.validate_classes().is_err());
        let mut c = mixed_pool();
        c.classes[1].count = 0;
        assert!(c.validate_classes().is_err());
    }

    #[test]
    fn prefix_and_slice_keep_classes_aligned() {
        let c = mixed_pool();
        let p = c.prefix(3);
        assert_eq!(p.num_gpus, 3);
        p.validate_classes().unwrap();
        assert_eq!(p.classes.len(), 2);
        assert_eq!(p.gpu_at(2).name, "H100-SXM5-80GB");
        let s = c.slice(1, 3);
        assert_eq!(s.num_gpus, 3);
        s.validate_classes().unwrap();
        assert_eq!(s.gpu_at(0).name, "A100-SXM4-80GB");
        assert_eq!(s.gpu_at(1).name, "H100-SXM5-80GB");
        assert!(matches!(s.partition_at(2), PartitionMode::Discrete(_)));
        // homogeneous prefix stays classless
        assert!(ClusterSpec::dgx2().prefix(4).classes.is_empty());
    }

    #[test]
    fn slice_catalog_snaps_up_and_counts_units() {
        let cat = SliceCatalog::mig7();
        assert_eq!(cat.units_for(cat.snap_up(0.10)), 1);
        assert_eq!(cat.units_for(cat.snap_up(1.0 / 7.0)), 1);
        assert_eq!(cat.units_for(cat.snap_up(0.15)), 2);
        assert_eq!(cat.units_for(cat.snap_up(0.99)), 7);
        assert_eq!(cat.snap_up(1.5), 1.0);
        for i in 1..=7u32 {
            let q = i as f64 / 7.0;
            // catalog points are fixed points of snap_up
            assert_eq!(cat.snap_up(q).to_bits(), q.to_bits());
        }
        assert!(cat.amortized_cost(3) > 0.0);
    }
}
