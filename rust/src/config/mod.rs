//! Cluster and GPU configuration.
//!
//! Encodes Table III of the paper (the two testbeds) as presets, plus
//! the PCIe constants of §VI-A. All resource-allocation constraints in
//! `allocator/` read their capacities (R, BW, F, I, G in Table II) from
//! a [`GpuSpec`].

/// Static description of one spatial-multitasking GPU model.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    /// Marketing name, e.g. "RTX 2080Ti".
    pub name: &'static str,
    /// Number of streaming multiprocessors (the paper allocates SMs as a
    /// percentage of this pool via Volta MPS).
    pub sms: u32,
    /// Peak fp32 throughput in GFLOPS (G in Table II).
    pub gflops: f64,
    /// Global memory capacity in bytes (F in Table II).
    pub mem_bytes: u64,
    /// Peak global memory bandwidth in bytes/s (BW in Table II).
    pub mem_bw: f64,
    /// Max concurrent MPS client contexts per device (I in Table II;
    /// Volta MPS allows 48).
    pub mps_contexts: u32,
    /// Fixed kernel launch/dispatch overhead per batch, seconds.
    pub launch_overhead_s: f64,
}

impl GpuSpec {
    /// NVIDIA GeForce RTX 2080Ti — the paper's two-GPU testbed.
    pub fn rtx2080ti() -> Self {
        GpuSpec {
            name: "RTX 2080Ti",
            sms: 68,
            gflops: 13_450.0,
            mem_bytes: 11 * (1 << 30),
            mem_bw: 616.0e9,
            mps_contexts: 48,
            launch_overhead_s: 30e-6,
        }
    }

    /// NVIDIA Tesla V100-SXM3 32GB — one of the 16 GPUs of the DGX-2.
    pub fn v100_sxm3() -> Self {
        GpuSpec {
            name: "V100-SXM3",
            sms: 80,
            gflops: 15_700.0,
            mem_bytes: 32 * (1 << 30),
            mem_bw: 897.0e9,
            mps_contexts: 48,
            launch_overhead_s: 30e-6,
        }
    }

    /// Peak fp32 FLOP/s as a plain f64.
    pub fn flops_per_sec(&self) -> f64 {
        self.gflops * 1e9
    }
}

/// PCIe bus model constants (§VI-A of the paper).
#[derive(Debug, Clone, PartialEq)]
pub struct PcieSpec {
    /// Effective bus bandwidth, bytes/s (paper: 12,160 MB/s for ×16 3.0).
    pub effective_bw: f64,
    /// Bandwidth one pageable-memory memcpy stream can sustain, bytes/s
    /// (paper measurement: 3,150 MB/s).
    pub per_stream_bw: f64,
    /// Fixed DMA setup latency per transfer, seconds.
    pub setup_s: f64,
}

impl Default for PcieSpec {
    fn default() -> Self {
        PcieSpec {
            effective_bw: 12_160.0e6,
            per_stream_bw: 3_150.0e6,
            setup_s: 10e-6,
        }
    }
}

/// CUDA-IPC-style global-memory communication constants (§VI-B).
#[derive(Debug, Clone, PartialEq)]
pub struct IpcSpec {
    /// One-time channel setup (cudaIpcGetMemHandle + handshake): ~1 ms.
    pub setup_s: f64,
    /// Per-message overhead to probe/transfer/decode the 8-byte handle.
    /// This is what makes tiny (<0.02 MB) payloads favor the main-memory
    /// path in Fig 11.
    pub per_msg_s: f64,
    /// Handle size in bytes.
    pub handle_bytes: u64,
}

impl Default for IpcSpec {
    fn default() -> Self {
        IpcSpec {
            setup_s: 1e-3,
            per_msg_s: 25e-6,
            handle_bytes: 8,
        }
    }
}

/// A machine: homogeneous GPUs behind one PCIe root complex per pair.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub gpu: GpuSpec,
    pub num_gpus: usize,
    pub pcie: PcieSpec,
    pub ipc: IpcSpec,
}

impl ClusterSpec {
    /// The paper's primary testbed: 2× RTX 2080Ti.
    pub fn two_2080ti() -> Self {
        ClusterSpec {
            gpu: GpuSpec::rtx2080ti(),
            num_gpus: 2,
            pcie: PcieSpec::default(),
            ipc: IpcSpec::default(),
        }
    }

    /// The paper's large-scale testbed: DGX-2 with 16× V100.
    pub fn dgx2() -> Self {
        ClusterSpec {
            gpu: GpuSpec::v100_sxm3(),
            num_gpus: 16,
            pcie: PcieSpec::default(),
            ipc: IpcSpec::default(),
        }
    }

    /// Total SM-fraction capacity across the cluster (C × R with R = 1.0).
    pub fn total_compute(&self) -> f64 {
        self.num_gpus as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_table3() {
        let t = GpuSpec::rtx2080ti();
        assert_eq!(t.sms, 68);
        assert_eq!(t.mps_contexts, 48);
        assert!((t.mem_bw - 616.0e9).abs() < 1.0);
        let v = GpuSpec::v100_sxm3();
        assert!((v.mem_bw - 897.0e9).abs() < 1.0);
        assert_eq!(v.mem_bytes, 32 * (1 << 30));
    }

    #[test]
    fn pcie_contention_knee_at_three_streams() {
        // The paper's back-of-envelope: ⌊12160/3150⌋ = 3 concurrent
        // pageable streams fit before contention begins.
        let p = PcieSpec::default();
        assert_eq!((p.effective_bw / p.per_stream_bw) as u32, 3);
    }

    #[test]
    fn cluster_presets() {
        assert_eq!(ClusterSpec::two_2080ti().num_gpus, 2);
        assert_eq!(ClusterSpec::dgx2().num_gpus, 16);
        assert_eq!(ClusterSpec::dgx2().gpu.name, "V100-SXM3");
    }
}
