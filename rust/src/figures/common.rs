//! Shared measurement protocol for the figure harnesses: predictor
//! training, peak-load ramp search on the simulator, and low-load
//! resource planning — the same procedure for every system compared.
//!
//! The peak search is coarse-to-fine (see EXPERIMENTS.md): an analytic
//! throughput bound brackets the ramp, quarter-precision simulations
//! locate the neighborhood, and full-precision runs confirm it with
//! speculative bisection probes fanned across threads.

use std::cell::RefCell;

use crate::allocator::SaParams;
use crate::baselines::{plan, Planner};
use crate::comm::CommMode;
use crate::config::ClusterSpec;
use crate::planner::{CamelotPlanner, ClusterState, Objective, PlanRequest, Planner as _};
use crate::predictor::StagePredictor;
use crate::sim::{CostModel, Deployment, InstancePlacement, SimOptions, SimReport, Simulator};
use crate::suite::{workload, Pipeline};
use crate::util::par;

/// Train the per-stage predictors for a pipeline (offline phase).
pub fn train_predictors(pipeline: &Pipeline, cluster: &ClusterSpec) -> Vec<StagePredictor> {
    crate::predictor::train_pipeline(pipeline, &cluster.gpu)
}

/// Simulation defaults for the sweeps: enough queries for a stable p99
/// at a tolerable cost.
pub fn sweep_opts() -> SimOptions {
    SimOptions { queries: 4_000, warmup_frac: 0.15, ..Default::default() }
}

/// Analytic (contention- and queueing-free) upper bound on a
/// deployment's supported load: the bottleneck stage's aggregate solo
/// throughput. The measured peak always sits below it, so it makes a
/// tight initial bracket for the ramp search.
pub fn analytic_peak_bound(
    pipeline: &Pipeline,
    cluster: &ClusterSpec,
    deployment: &Deployment,
) -> f64 {
    let cost = CostModel::new(cluster.gpu.clone());
    let batch = deployment.batch.max(1);
    let mut per_stage = vec![0.0f64; pipeline.n_stages()];
    for p in &deployment.placements {
        per_stage[p.stage] += cost.throughput_solo(&pipeline.stages[p.stage], batch, p.sm_frac);
    }
    per_stage
        .iter()
        .fold(f64::INFINITY, |a, &b| a.min(b))
        .max(1.0)
}

/// Measure the supported peak load of a fixed deployment: the highest
/// Poisson rate whose simulated p99 meets the pipeline QoS.
///
/// Coarse-to-fine protocol (EXPERIMENTS.md §Peak-load search):
/// 1. bracket with [`analytic_peak_bound`] — no simulated growth phase;
/// 2. locate the peak with quarter-precision (≥ 1k-query) simulations;
/// 3. confirm inside the coarse bracket at full precision — three
///    speculative probes per round fanned across threads when called
///    from a non-parallel context, plain bisection when already inside
///    a sweep worker (`util::par::in_worker`); every full-precision
///    report is cached so the final rate is never re-simulated.
///
/// Deterministic regardless of thread count: the probe set depends only
/// on bracket values and every simulation seeds from `opts.seed`.
pub fn peak_load(
    pipeline: &Pipeline,
    cluster: &ClusterSpec,
    deployment: &Deployment,
    opts: &SimOptions,
) -> (f64, SimReport) {
    let qos = pipeline.qos_target_s;
    let sim = Simulator::new(pipeline, cluster, deployment, opts.clone());
    let bound = analytic_peak_bound(pipeline, cluster, deployment);

    // phase 1+2: cheap sims, loose tolerance, analytic top bracket
    let coarse_queries = (opts.queries / 4).clamp(1_000.min(opts.queries.max(1)), opts.queries.max(1));
    let coarse_opts = SimOptions { queries: coarse_queries, ..opts.clone() };
    let coarse_sim = Simulator::new(pipeline, cluster, deployment, coarse_opts);
    let (coarse, _) = workload::peak_load_search(
        |rate| coarse_sim.run(rate).map(|r| r.p99()).unwrap_or(f64::INFINITY),
        qos,
        bound,
        0.10,
    );

    // phase 3: full-precision confirm with speculative parallel probes.
    // The cache is only touched from this thread (the par_map workers
    // return their reports), hence RefCell rather than a lock.
    let cache: RefCell<Vec<(u64, SimReport)>> = RefCell::new(Vec::new());
    let eval_many = |rates: &[f64]| -> Vec<f64> {
        let reports = par::par_map(rates, |_, &rate| match sim.run(rate) {
            Ok(r) => (r.p99(), Some(r)),
            Err(_) => (f64::INFINITY, None),
        });
        let mut cache = cache.borrow_mut();
        reports
            .into_iter()
            .zip(rates)
            .map(|((p99, rep), &rate)| {
                if let Some(rep) = rep {
                    cache.push((rate.to_bits(), rep));
                }
                p99
            })
            .collect()
    };
    // speculative 3-probe rounds only pay off when the probes actually
    // fan across threads; inside an already-parallel sweep cell they
    // would run serially, where plain bisection needs fewer sims
    let probes = if par::in_worker() { 1 } else { 3 };
    let (peak, _trials) = if coarse > 0.0 {
        workload::peak_load_search_bracketed(
            eval_many, qos, coarse * 0.7, coarse * 1.3, 0.03, probes,
        )
    } else {
        // even the cheap sims found nothing feasible below the analytic
        // bound — confirm (or overturn) at full precision from scratch
        workload::peak_load_search_bracketed(eval_many, qos, 0.0, bound, 0.03, probes)
    };

    let final_rate = peak.max(1.0);
    let report = {
        let mut cache = cache.borrow_mut();
        let key = final_rate.to_bits();
        match cache.iter().position(|(k, _)| *k == key) {
            Some(i) => cache.swap_remove(i).1,
            None => sim
                .run(final_rate)
                .unwrap_or_else(|e| panic!("sim at peak failed: {e}")),
        }
    };
    (peak, report)
}

/// Plan with `planner` and measure its peak load.
pub fn planner_peak(
    planner: Planner,
    pipeline: &Pipeline,
    cluster: &ClusterSpec,
    predictors: &[StagePredictor],
    batch: u32,
    opts: &SimOptions,
) -> Option<(Deployment, f64, SimReport)> {
    let d = plan(planner, pipeline, cluster, predictors, batch, SaParams::default()).ok()?;
    let (peak, report) = peak_load(pipeline, cluster, &d, opts);
    Some((d, peak, report))
}

/// Low-load planning: returns (deployment, Σ SM usage in GPU-equivalents).
///
/// * Camelot / Camelot-NC — Case 2 (min Σ N·p at the load).
/// * Laius — balanced quotas scaled down until its *predicted* pipeline
///   throughput just covers the load (its own adaptation policy), one
///   instance per stage, no contention management.
/// * EA — even quotas scaled the same way.
pub fn plan_low_load(
    planner: Planner,
    pipeline: &Pipeline,
    cluster: &ClusterSpec,
    predictors: &[StagePredictor],
    batch: u32,
    load_qps: f64,
) -> Option<Deployment> {
    match planner {
        Planner::Camelot | Planner::CamelotNC => {
            let req = PlanRequest::new(
                Objective::MinResource { load_qps },
                ClusterState::exclusive(cluster),
                pipeline,
                predictors,
            )
            .batch(batch)
            .enforce_bw(matches!(planner, Planner::Camelot));
            match CamelotPlanner.plan(&req) {
                Ok(s) => Some(s.deployment),
                // near the peak, Case 2 has no slack left: fall back to
                // the Case-1 (max-load) plan, as the online system does
                // when the load approaches capacity
                Err(_) => {
                    plan(planner, pipeline, cluster, predictors, batch, SaParams::default()).ok()
                }
            }
        }
        Planner::Laius | Planner::EvenAllocation => {
            let n = pipeline.n_stages();
            let base: Vec<f64> = match planner {
                Planner::Laius => crate::baselines::balanced_quotas(predictors, batch),
                _ => vec![1.0 / n as f64; n],
            };
            // Laius provisions from its own (contention-oblivious)
            // predictions: enough throughput to cover the load with a
            // 20% margin AND per-stage latencies within the stage's
            // share of the QoS budget. It does not model queueing tails
            // or interference — that gap is what Figs 16/17 measure.
            let qos_share = pipeline.qos_target_s * 0.45 / n as f64;
            let ok = |scale: f64| -> bool {
                let thr = (0..n)
                    .map(|i| predictors[i].throughput(batch, (base[i] * scale).clamp(0.05, 1.0)))
                    .fold(f64::INFINITY, f64::min);
                let lat_ok = (0..n).all(|i| {
                    predictors[i].duration(batch, (base[i] * scale).clamp(0.05, 1.0)) <= qos_share
                });
                thr >= load_qps * 1.2 && lat_ok
            };
            let mut scale = 1.0;
            for _ in 0..40 {
                if ok(scale) {
                    let shrunk = scale * 0.9;
                    if ok(shrunk) {
                        scale = shrunk;
                        continue;
                    }
                    break;
                }
                scale *= 1.15;
                if scale > 4.0 {
                    break;
                }
            }
            let placements: Vec<InstancePlacement> = (0..n)
                .map(|stage| InstancePlacement {
                    stage,
                    gpu: 0,
                    sm_frac: (base[stage] * scale).clamp(0.05, 1.0),
                })
                .collect();
            // single GPU if it fits; else spread round-robin
            let total: f64 = placements.iter().map(|p| p.sm_frac).sum();
            let placements = if total <= 1.0 {
                placements
            } else {
                placements
                    .into_iter()
                    .enumerate()
                    .map(|(i, mut p)| {
                        p.gpu = i % cluster.num_gpus;
                        p
                    })
                    .collect()
            };
            Some(Deployment { placements, batch, comm: CommMode::MainMemory })
        }
        _ => None,
    }
}

/// Resource usage normalized to "one whole GPU per stage" (the paper's
/// Fig 16 normalization).
pub fn normalized_usage(pipeline: &Pipeline, d: &Deployment) -> f64 {
    d.total_sm_usage() / pipeline.n_stages() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suite::real;

    #[test]
    fn peak_load_positive_for_simple_deployment() {
        let p = real::img_to_text();
        let c = ClusterSpec::two_2080ti();
        let d = Deployment {
            placements: vec![
                InstancePlacement { stage: 0, gpu: 0, sm_frac: 0.6 },
                InstancePlacement { stage: 1, gpu: 1, sm_frac: 0.6 },
            ],
            batch: 16,
            comm: CommMode::GlobalIpc,
        };
        let opts = SimOptions { queries: 1_500, ..sweep_opts() };
        let (peak, report) = peak_load(&p, &c, &d, &opts);
        assert!(peak > 10.0, "peak {peak}");
        assert!(report.p99() <= p.qos_target_s * 1.2);
    }

    #[test]
    fn analytic_bound_caps_measured_peak() {
        let p = real::img_to_text();
        let c = ClusterSpec::two_2080ti();
        let d = Deployment {
            placements: vec![
                InstancePlacement { stage: 0, gpu: 0, sm_frac: 0.6 },
                InstancePlacement { stage: 1, gpu: 1, sm_frac: 0.6 },
            ],
            batch: 16,
            comm: CommMode::GlobalIpc,
        };
        let bound = analytic_peak_bound(&p, &c, &d);
        assert!(bound > 1.0);
        let opts = SimOptions { queries: 1_200, ..sweep_opts() };
        let (peak, _) = peak_load(&p, &c, &d, &opts);
        assert!(
            peak <= bound * 1.05,
            "measured peak {peak} must sit below the analytic bound {bound}"
        );
    }

    #[test]
    fn camelot_low_load_uses_less_than_peak_plan() {
        let p = real::text_to_text();
        let c = ClusterSpec::two_2080ti();
        let preds = train_predictors(&p, &c);
        let low = plan_low_load(Planner::Camelot, &p, &c, &preds, 16, 30.0).expect("plan");
        assert!(normalized_usage(&p, &low) < 1.0);
    }
}
