//! Shared measurement protocol for the figure harnesses: predictor
//! training, peak-load ramp search on the simulator, and low-load
//! resource planning — the same procedure for every system compared.

use crate::allocator::{min_resource, AllocContext, SaParams};
use crate::baselines::{plan, Planner};
use crate::comm::CommMode;
use crate::config::ClusterSpec;
use crate::deploy;
use crate::predictor::{ProfileConfig, StagePredictor};
use crate::sim::{Deployment, InstancePlacement, SimOptions, SimReport, Simulator};
use crate::suite::{workload, Pipeline};

/// Train the per-stage predictors for a pipeline (offline phase).
pub fn train_predictors(pipeline: &Pipeline, cluster: &ClusterSpec) -> Vec<StagePredictor> {
    pipeline
        .stages
        .iter()
        .map(|s| StagePredictor::train(s, &cluster.gpu, &ProfileConfig::default()))
        .collect()
}

/// Simulation defaults for the sweeps: enough queries for a stable p99
/// at a tolerable cost.
pub fn sweep_opts() -> SimOptions {
    SimOptions { queries: 4_000, warmup_frac: 0.15, ..Default::default() }
}

/// Measure the supported peak load of a fixed deployment: the highest
/// Poisson rate whose simulated p99 meets the pipeline QoS.
pub fn peak_load(
    pipeline: &Pipeline,
    cluster: &ClusterSpec,
    deployment: &Deployment,
    opts: &SimOptions,
) -> (f64, SimReport) {
    let sim = Simulator::new(pipeline, cluster, deployment, opts.clone());
    let qos = pipeline.qos_target_s;
    let (peak, _trials) = workload::peak_load_search(
        |rate| sim.run(rate).map(|r| r.p99()).unwrap_or(f64::INFINITY),
        qos,
        50.0,
        0.03,
    );
    let report = sim
        .run(peak.max(1.0))
        .unwrap_or_else(|e| panic!("sim at peak failed: {e}"));
    (peak, report)
}

/// Plan with `planner` and measure its peak load.
pub fn planner_peak(
    planner: Planner,
    pipeline: &Pipeline,
    cluster: &ClusterSpec,
    predictors: &[StagePredictor],
    batch: u32,
    opts: &SimOptions,
) -> Option<(Deployment, f64, SimReport)> {
    let d = plan(planner, pipeline, cluster, predictors, batch, SaParams::default()).ok()?;
    let (peak, report) = peak_load(pipeline, cluster, &d, opts);
    Some((d, peak, report))
}

/// Low-load planning: returns (deployment, Σ SM usage in GPU-equivalents).
///
/// * Camelot / Camelot-NC — Case 2 (min Σ N·p at the load).
/// * Laius — balanced quotas scaled down until its *predicted* pipeline
///   throughput just covers the load (its own adaptation policy), one
///   instance per stage, no contention management.
/// * EA — even quotas scaled the same way.
pub fn plan_low_load(
    planner: Planner,
    pipeline: &Pipeline,
    cluster: &ClusterSpec,
    predictors: &[StagePredictor],
    batch: u32,
    load_qps: f64,
) -> Option<Deployment> {
    match planner {
        Planner::Camelot | Planner::CamelotNC => {
            let mut ctx = AllocContext::new(pipeline, cluster, predictors, batch);
            ctx.enforce_bw = matches!(planner, Planner::Camelot);
            match min_resource::solve(&ctx, load_qps, SaParams::default()) {
                Some((r, _gpus)) => {
                    let demands = ctx.bw_budget_storage(&r.best);
                    deploy::deploy(
                        pipeline, cluster, &r.best, batch, CommMode::GlobalIpc,
                        demands.as_deref().map(|d| deploy::BwBudget {
                            demands: d,
                            cap: 0.75 * cluster.gpu.mem_bw,
                        }),
                    )
                    .ok()
                }
                // near the peak, Case 2 has no slack left: fall back to
                // the Case-1 (max-load) plan, as the online system does
                // when the load approaches capacity
                None => plan(planner, pipeline, cluster, predictors, batch, SaParams::default())
                    .ok(),
            }
        }
        Planner::Laius | Planner::EvenAllocation => {
            let n = pipeline.n_stages();
            let base: Vec<f64> = match planner {
                Planner::Laius => crate::baselines::balanced_quotas(predictors, batch),
                _ => vec![1.0 / n as f64; n],
            };
            // Laius provisions from its own (contention-oblivious)
            // predictions: enough throughput to cover the load with a
            // 20% margin AND per-stage latencies within the stage's
            // share of the QoS budget. It does not model queueing tails
            // or interference — that gap is what Figs 16/17 measure.
            let qos_share = pipeline.qos_target_s * 0.45 / n as f64;
            let ok = |scale: f64| -> bool {
                let thr = (0..n)
                    .map(|i| predictors[i].throughput(batch, (base[i] * scale).clamp(0.05, 1.0)))
                    .fold(f64::INFINITY, f64::min);
                let lat_ok = (0..n).all(|i| {
                    predictors[i].duration(batch, (base[i] * scale).clamp(0.05, 1.0)) <= qos_share
                });
                thr >= load_qps * 1.2 && lat_ok
            };
            let mut scale = 1.0;
            for _ in 0..40 {
                if ok(scale) {
                    let shrunk = scale * 0.9;
                    if ok(shrunk) {
                        scale = shrunk;
                        continue;
                    }
                    break;
                }
                scale *= 1.15;
                if scale > 4.0 {
                    break;
                }
            }
            let placements: Vec<InstancePlacement> = (0..n)
                .map(|stage| InstancePlacement {
                    stage,
                    gpu: 0,
                    sm_frac: (base[stage] * scale).clamp(0.05, 1.0),
                })
                .collect();
            // single GPU if it fits; else spread round-robin
            let total: f64 = placements.iter().map(|p| p.sm_frac).sum();
            let placements = if total <= 1.0 {
                placements
            } else {
                placements
                    .into_iter()
                    .enumerate()
                    .map(|(i, mut p)| {
                        p.gpu = i % cluster.num_gpus;
                        p
                    })
                    .collect()
            };
            Some(Deployment { placements, batch, comm: CommMode::MainMemory })
        }
        _ => None,
    }
}

/// Resource usage normalized to "one whole GPU per stage" (the paper's
/// Fig 16 normalization).
pub fn normalized_usage(pipeline: &Pipeline, d: &Deployment) -> f64 {
    d.total_sm_usage() / pipeline.n_stages() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suite::real;

    #[test]
    fn peak_load_positive_for_simple_deployment() {
        let p = real::img_to_text();
        let c = ClusterSpec::two_2080ti();
        let d = Deployment {
            placements: vec![
                InstancePlacement { stage: 0, gpu: 0, sm_frac: 0.6 },
                InstancePlacement { stage: 1, gpu: 1, sm_frac: 0.6 },
            ],
            batch: 16,
            comm: CommMode::GlobalIpc,
        };
        let opts = SimOptions { queries: 1_500, ..sweep_opts() };
        let (peak, report) = peak_load(&p, &c, &d, &opts);
        assert!(peak > 10.0, "peak {peak}");
        assert!(report.p99() <= p.qos_target_s * 1.2);
    }

    #[test]
    fn camelot_low_load_uses_less_than_peak_plan() {
        let p = real::text_to_text();
        let c = ClusterSpec::two_2080ti();
        let preds = train_predictors(&p, &c);
        let low = plan_low_load(Planner::Camelot, &p, &c, &preds, 16, 30.0).expect("plan");
        assert!(normalized_usage(&p, &low) < 1.0);
    }
}
