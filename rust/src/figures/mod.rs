//! Figure harnesses: one entry per table/figure of the paper's
//! evaluation (see DESIGN.md §6 for the experiment index). Each harness
//! prints the paper-shaped rows and writes `results/<exp>.csv`.

pub mod common;
pub mod macro_evals;
pub mod micro;

use std::path::Path;

use crate::util::Table;

/// All experiment ids, in paper order (plus the cluster-level
/// scenarios, which have no single figure number: `colocate` reproduces
/// the §VIII-C savings protocol end-to-end, `admission` the N-tenant
/// online admission / re-packing loop vs static partitioning).
pub const ALL_EXPERIMENTS: &[&str] = &[
    "fig3", "fig4", "fig5", "fig6", "fig9", "fig11", "fig12", "fig14", "fig16", "fig17",
    "fig18", "fig19", "tab1", "colocate", "admission",
];

/// Run one experiment by id.
pub fn run(exp: &str) -> Result<Vec<Table>, String> {
    match exp {
        "fig3" => Ok(micro::fig3()),
        "fig4" => Ok(macro_evals::fig4()),
        "fig5" => Ok(micro::fig5()),
        "fig6" => Ok(micro::fig6()),
        "fig9" => Ok(micro::fig9()),
        "fig11" => Ok(micro::fig11()),
        "fig12" => Ok(micro::fig12()),
        "fig14" | "fig15" => Ok(macro_evals::fig14()),
        "fig16" => Ok(macro_evals::fig16()),
        "fig17" => Ok(macro_evals::fig17()),
        "fig18" | "fig20" | "fig21" => Ok(macro_evals::fig18()),
        "fig19" => Ok(macro_evals::fig19()),
        "tab1" => Ok(vec![crate::suite::real::table1()]),
        "colocate" => macro_evals::colocate(),
        "admission" => macro_evals::admission(),
        other => Err(format!(
            "unknown experiment '{other}'; available: {}",
            ALL_EXPERIMENTS.join(", ")
        )),
    }
}

/// Run an experiment, print its tables, and persist CSVs.
pub fn run_and_save(exp: &str, results_dir: &Path) -> Result<(), String> {
    let tables = run(exp)?;
    for (i, t) in tables.iter().enumerate() {
        println!("{}", t.render());
        let name = if tables.len() == 1 {
            exp.to_string()
        } else {
            format!("{exp}_{i}")
        };
        t.write_csv(results_dir, &name)
            .map_err(|e| format!("writing {name}.csv: {e}"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn unknown_experiment_rejected() {
        assert!(super::run("fig99").is_err());
    }

    #[test]
    fn tab1_runs() {
        let t = super::run("tab1").unwrap();
        assert_eq!(t.len(), 1);
    }
}
