//! Microbenchmark figures: Fig 3 (artifact-benchmark scalability),
//! Fig 5 (latency breakdown), Fig 6 (memory vs batch), Fig 9 (PCIe
//! contention), Fig 11 (communication mechanisms), Fig 12 (predictor
//! accuracy).

use std::time::Instant;

use crate::baselines::Planner;
use crate::comm;
use crate::config::{ClusterSpec, GpuSpec, IpcSpec, PcieSpec};
use crate::predictor::{
    mape, profile_stage, split, DecisionTree, ForestParams, LinReg, ProfileConfig, RandomForest,
    TreeParams,
};
use crate::sim::{CostModel, PcieBus, SimOptions};
use crate::suite::{artifact, real};
use crate::util::{fnum, par, Table};

use super::common;

/// Fig 3: processing time of c1..c3 and achieved bandwidth of m1..m3
/// versus the SM quota (solo runs).
pub fn fig3() -> Vec<Table> {
    let cost = CostModel::new(GpuSpec::rtx2080ti());
    let batch = 32;
    let mut a = Table::new(
        "Fig 3a: processing time (ms) of compute-intensive microservices vs SM%",
        &["sm_pct", "c1", "c2", "c3"],
    );
    let mut b = Table::new(
        "Fig 3b: memory bandwidth (GB/s) of memory-intensive microservices vs SM%",
        &["sm_pct", "m1", "m2", "m3"],
    );
    for pct in (10..=100).step_by(10) {
        let p = pct as f64 / 100.0;
        a.push(&[
            pct.to_string(),
            fnum(cost.duration_solo(&artifact::compute(1), batch, p) * 1e3),
            fnum(cost.duration_solo(&artifact::compute(2), batch, p) * 1e3),
            fnum(cost.duration_solo(&artifact::compute(3), batch, p) * 1e3),
        ]);
        b.push(&[
            pct.to_string(),
            fnum(cost.bw_demand(&artifact::memory(1), batch, p) / 1e9),
            fnum(cost.bw_demand(&artifact::memory(2), batch, p) / 1e9),
            fnum(cost.bw_demand(&artifact::memory(3), batch, p) / 1e9),
        ]);
    }
    vec![a, b]
}

/// Fig 5: end-to-end latency breakdown under the default (main-memory)
/// communication — the data-transfer share the paper reports as
/// 32.4–46.9%. One sweep cell per benchmark, fanned across cores.
pub fn fig5() -> Vec<Table> {
    let cluster = ClusterSpec::two_2080ti();
    let mut t = Table::new(
        "Fig 5: latency breakdown per query (main-memory comm, EA deployment)",
        &["benchmark", "exec_ms", "upload_ms", "hop_ms", "download_ms", "comm_pct"],
    );
    let pipelines = real::all();
    let rows: Vec<Option<Vec<String>>> = par::par_map(&pipelines, |_, p| {
        let preds = common::train_predictors(p, &cluster);
        let opts = SimOptions { queries: 3_000, ..common::sweep_opts() };
        let (_, peak, _) = common::planner_peak(
            Planner::EvenAllocation,
            p,
            &cluster,
            &preds,
            32,
            &opts,
        )?;
        // measure at 70% of peak: loaded but stable
        let d = crate::baselines::plan(
            Planner::EvenAllocation,
            p,
            &cluster,
            &preds,
            32,
            crate::allocator::SaParams::default(),
        )
        .unwrap();
        let r = crate::sim::Simulator::new(p, &cluster, &d, opts)
            .run((peak * 0.7).max(1.0))
            .unwrap();
        // completion unit is the request (= batch queries)
        let n = r.completed as f64 * 32.0;
        let bd = &r.breakdown;
        let comm = bd.comm_total();
        Some(vec![
            p.name.clone(),
            fnum(bd.exec_s / n * 1e3),
            fnum(bd.upload_s / n * 1e3),
            fnum(bd.hop_s / n * 1e3),
            fnum(bd.download_s / n * 1e3),
            format!("{:.1}", 100.0 * comm / (comm + bd.exec_s)),
        ])
    });
    for row in rows.into_iter().flatten() {
        t.row(&row);
    }
    vec![t]
}

/// Fig 6: global-memory usage of the img-to-img first microservice
/// (FR-API) vs batch size, against the 11 GB capacity of a 2080Ti.
pub fn fig6() -> Vec<Table> {
    let pipeline = real::img_to_img();
    let stage = pipeline.stages[0].clone();
    let gpu = GpuSpec::rtx2080ti();
    let cost = CostModel::new(gpu.clone());
    let mut t = Table::new(
        "Fig 6: global memory usage of FR-API vs batch size (2080Ti, 11 GB)",
        &["batch", "mem_gb", "fits", "min_sm_pct_for_qos"],
    );
    // The paper's companion curve: GPU *compute* utilization stays low
    // while memory fills. We report the smallest SM quota that still
    // meets the stage's share of the QoS budget — the compute the stage
    // actually needs; the rest of the GPU idles but cannot be lent out
    // because global memory is exhausted (SSIV-C).
    let budget = pipeline.qos_target_s * 0.6;
    for batch in [16u32, 32, 64, 128, 192, 256, 320, 512] {
        let mem = stage.mem_footprint(batch);
        let mut needed = None;
        for pct in 1..=100 {
            if cost.duration_solo(&stage, batch, pct as f64 / 100.0) <= budget {
                needed = Some(pct);
                break;
            }
        }
        t.push(&[
            batch.to_string(),
            fnum(mem / 1e9),
            (mem <= gpu.mem_bytes as f64).to_string(),
            needed.map_or("inf".to_string(), |p| p.to_string()),
        ]);
    }
    vec![t]
}

/// Fig 9: per-instance PCIe transfer time (5 GB copy) and kernel time
/// vs the number of co-located PCIe-intensive instances.
pub fn fig9() -> Vec<Table> {
    let mut t = Table::new(
        "Fig 9: PCIe transfer time for a 5 GB copy vs co-located instances",
        &["instances", "transfer_s", "kernel_s"],
    );
    let cost = CostModel::new(GpuSpec::rtx2080ti());
    let kernel = cost.duration_solo(&artifact::pcie(3), 32, 0.10);
    for k in 1..=8u32 {
        let mut bus = PcieBus::new(PcieSpec::default());
        // k concurrent instances each copying 5 GB
        let mut last = 0.0;
        for _ in 0..k {
            last = bus.begin_transfer(5.0e9);
        }
        t.push(&[k.to_string(), fnum(last), fnum(kernel)]);
    }
    vec![t]
}

/// Fig 11: communication time, main-memory vs global-memory IPC, across
/// payload sizes (uncontended bus).
pub fn fig11() -> Vec<Table> {
    let bus = PcieBus::new(PcieSpec::default());
    let ipc = IpcSpec::default();
    let mut t = Table::new(
        "Fig 11: communication time (ms) by payload size",
        &["payload_bytes", "main_memory_ms", "global_ipc_ms", "winner"],
    );
    let mut payload = 2.0f64;
    while payload <= 256.0e6 {
        let (mm, gi) = comm::fig11_point(payload, &bus, &ipc);
        t.push(&[
            fnum(payload),
            fnum(mm * 1e3),
            fnum(gi * 1e3),
            if mm < gi { "main-memory" } else { "global-ipc" }.to_string(),
        ]);
        payload *= 8.0;
    }
    vec![t]
}

/// Fig 12: prediction error (MAPE %) of LR / DT / RF for duration,
/// bandwidth, and throughput on every real microservice, plus predict
/// latency (the §VIII-G argument for choosing DT).
pub fn fig12() -> Vec<Table> {
    let gpu = GpuSpec::rtx2080ti();
    let mut t = Table::new(
        "Fig 12: prediction MAPE % (LR / DT / RF) per microservice",
        &[
            "microservice", "dur_lr", "dur_dt", "dur_rf", "bw_lr", "bw_dt", "bw_rf",
            "thr_lr", "thr_dt", "thr_rf",
        ],
    );
    let mut timing = Table::new(
        "Fig 12 (companion): prediction latency per 1000 queries",
        &["model", "time_ms_per_1k"],
    );
    let mut timed = false;
    for pipeline in real::all() {
        for stage in &pipeline.stages {
            let samples = profile_stage(stage, &gpu, &ProfileConfig::default());
            let (train, test) = split(&samples, 0.7, 77);
            let xs: Vec<Vec<f64>> = train.iter().map(|s| vec![s.batch, s.sm_frac]).collect();
            let targets: [(&str, Vec<f64>, fn(&crate::predictor::Sample) -> f64); 3] = [
                ("dur", train.iter().map(|s| s.duration_s).collect(), |s| s.duration_s),
                ("bw", train.iter().map(|s| s.bw_bytes_per_s).collect(), |s| s.bw_bytes_per_s),
                ("thr", train.iter().map(|s| s.throughput_qps).collect(), |s| s.throughput_qps),
            ];
            let mut row = vec![stage.name.clone()];
            for (_, ys, truth) in &targets {
                let lr = LinReg::fit(&xs, ys).unwrap();
                let dt = DecisionTree::fit(&xs, ys, TreeParams::default());
                let rf = RandomForest::fit(&xs, ys, ForestParams::default(), 5);
                row.push(format!("{:.1}", 100.0 * mape(&test, |s| (lr.predict(&[s.batch, s.sm_frac]), truth(s)))));
                row.push(format!("{:.1}", 100.0 * mape(&test, |s| (dt.predict(&[s.batch, s.sm_frac]), truth(s)))));
                row.push(format!("{:.1}", 100.0 * mape(&test, |s| (rf.predict(&[s.batch, s.sm_frac]), truth(s)))));
                if !timed {
                    // predict-latency comparison, once
                    let x = [32.0, 0.5];
                    let time_of = |f: &dyn Fn() -> f64| {
                        let t0 = Instant::now();
                        let mut acc = 0.0;
                        for _ in 0..1000 {
                            acc += f();
                        }
                        std::hint::black_box(acc);
                        t0.elapsed().as_secs_f64() * 1e3
                    };
                    timing.push(&["LR".to_string(), fnum(time_of(&|| lr.predict(&x)))]);
                    timing.push(&["DT".to_string(), fnum(time_of(&|| dt.predict(&x)))]);
                    timing.push(&["RF(50)".to_string(), fnum(time_of(&|| rf.predict(&x)))]);
                    timed = true;
                }
            }
            t.row(&row);
        }
    }
    vec![t, timing]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_shapes() {
        let ts = fig3();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].rows.len(), 10);
        // c3 slower than c1 at every quota
        for row in &ts[0].rows {
            let c1: f64 = row[1].parse().unwrap();
            let c3: f64 = row[3].parse().unwrap();
            assert!(c3 > c1);
        }
    }

    #[test]
    fn fig9_knee() {
        let t = &fig9()[0];
        let t1: f64 = t.rows[0][1].parse().unwrap();
        let t3: f64 = t.rows[2][1].parse().unwrap();
        let t6: f64 = t.rows[5][1].parse().unwrap();
        assert!((t1 - t3).abs() / t1 < 0.02, "flat to 3 instances");
        assert!(t6 > t3 * 1.3, "contention beyond 3");
    }

    #[test]
    fn fig11_has_crossover() {
        let t = &fig11()[0];
        assert_eq!(t.rows.first().unwrap()[3], "main-memory");
        assert_eq!(t.rows.last().unwrap()[3], "global-ipc");
    }

    #[test]
    fn fig6_capacity_wall_between_192_and_512() {
        let t = &fig6()[0];
        let fits: Vec<bool> = t.rows.iter().map(|r| r[2] == "true").collect();
        assert!(fits[0], "batch 16 fits");
        assert!(!fits.last().unwrap(), "batch 512 does not fit");
        // memory walls while the needed compute share is still small
        let sm16: u32 = t.rows[0][3].parse().unwrap();
        assert!(sm16 < 25, "batch 16 needs only {sm16}% of the SMs");
    }
}
