//! Macro-benchmark figures: Fig 4 (investigation), Figs 14/15 (peak
//! load on 2×2080Ti), Figs 16/17 (resource usage), Figs 18/20/21 (the
//! 27 artifact pipelines), Fig 19 (DGX-2), and the cluster-level
//! co-location + diurnal-autoscaling scenario (§VIII-C / Cases 1+2 at
//! cluster scope).
//!
//! Every harness fans its independent sweep cells (benchmark × batch ×
//! load level) across cores with `util::par::par_map`; rows are
//! collected back in deterministic input order, so the tables are
//! identical regardless of thread count (see EXPERIMENTS.md).

use crate::allocator::SaParams;
use crate::baselines::{plan, Planner};
use crate::config::ClusterSpec;
use crate::coordinator::{run_closed_loop, AutoscaleConfig, Autoscaler, EpochLoopConfig};
use crate::deploy::reservations_for;
use crate::planner::cache::SolveCache;
use crate::sim::{ClusterSim, SimOptions, Simulator, TenantSpec};
use crate::suite::workload::{ArrivalProcess, DiurnalPattern};
use crate::suite::{artifact, real, Pipeline};
use crate::util::{fnum, par, Table};

use super::common;

const PEAK_PLANNERS: [Planner; 3] = [Planner::EvenAllocation, Planner::Laius, Planner::Camelot];

fn batches() -> [u32; 4] {
    [8, 16, 32, 64]
}

/// Fig 4a: standalone-deployment peak throughput, total vs per-stage.
/// Fig 4b: balanced-deployment contention — offline vs co-located stage
/// times and the resulting normalized p99.
pub fn fig4() -> Vec<Table> {
    let cluster = ClusterSpec::two_2080ti();
    let opts = SimOptions { queries: 3_000, ..common::sweep_opts() };
    let mut a = Table::new(
        "Fig 4a: standalone deployment — peak QPS total and per stage",
        &["benchmark", "total_peak", "stage1_solo", "stage2_solo", "bottleneck"],
    );
    let mut b = Table::new(
        "Fig 4b: balanced deployment — offline vs co-located stage time, p99/QoS",
        &["benchmark", "s1_offline_ms", "s1_coloc_ms", "s2_offline_ms", "s2_coloc_ms", "p99_over_qos"],
    );
    let pipelines = real::all();
    let cells: Vec<(Option<Vec<String>>, Option<Vec<String>>)> =
        par::par_map(&pipelines, |_, p| {
            let preds = common::train_predictors(p, &cluster);
            // 4a: standalone (stage i on GPU i, 100%)
            let row_a = common::planner_peak(Planner::Standalone, p, &cluster, &preds, 32, &opts)
                .map(|(_, peak, _)| {
                    let cost = crate::sim::CostModel::new(cluster.gpu.clone());
                    let s1 = cost.throughput_solo(&p.stages[0], 32, 1.0);
                    let s2 = cost.throughput_solo(&p.stages[1], 32, 1.0);
                    vec![
                        p.name.clone(),
                        fnum(peak),
                        fnum(s1),
                        fnum(s2),
                        if s1 < s2 { "stage1" } else { "stage2" }.to_string(),
                    ]
                });
            // 4b: balanced on a single GPU at its own predicted peak
            let row_b = plan(Planner::Balanced, p, &cluster, &preds, 32, SaParams::default())
                .ok()
                .map(|d| {
                    let single = ClusterSpec { num_gpus: 1, ..cluster.clone() };
                    // the paper's protocol: tune offline (solo profiles, no
                    // contention/comm), predict the peak from those numbers,
                    // then run at that load and watch it violate QoS
                    let cost = crate::sim::CostModel::new(cluster.gpu.clone());
                    let offline: Vec<f64> = d
                        .placements
                        .iter()
                        .map(|pl| cost.duration_solo(&p.stages[pl.stage], 32, pl.sm_frac))
                        .collect();
                    let offline_peak = d
                        .placements
                        .iter()
                        .map(|pl| cost.throughput_solo(&p.stages[pl.stage], 32, pl.sm_frac))
                        .fold(f64::INFINITY, f64::min);
                    let overloaded = Simulator::new(p, &single, &d, opts.clone())
                        .run(offline_peak.max(1.0))
                        .unwrap();
                    vec![
                        p.name.clone(),
                        fnum(offline[0] * 1e3),
                        fnum(overloaded.stage_exec_mean_s[0] * 1e3),
                        fnum(offline[1] * 1e3),
                        fnum(overloaded.stage_exec_mean_s[1] * 1e3),
                        format!("{:.2}", overloaded.p99() / p.qos_target_s),
                    ]
                });
            (row_a, row_b)
        });
    for (row_a, row_b) in cells {
        if let Some(r) = row_a {
            a.row(&r);
        }
        if let Some(r) = row_b {
            b.row(&r);
        }
    }
    vec![a, b]
}

/// Per-cell output of the Fig 14/19 sweep.
struct PeakCell {
    row: Vec<String>,
    alloc_row: Option<Vec<String>>,
}

/// Figs 14 + 15 (and 19 on the DGX-2 cluster): peak load per
/// (benchmark, batch) for EA / Laius / Camelot, plus Camelot's chosen
/// allocation. Cells run concurrently; the table order is the serial
/// sweep order.
pub fn peak_load_comparison(cluster: &ClusterSpec, tag: &str) -> Vec<Table> {
    let opts = common::sweep_opts();
    let mut peaks = Table::new(
        &format!("Fig 14/19 ({tag}): supported peak load (QPS), p99 within QoS"),
        &["benchmark", "batch", "EA", "Laius", "Camelot", "camelot_vs_ea", "camelot_p99_over_qos"],
    );
    let mut alloc = Table::new(
        &format!("Fig 15/20 ({tag}): Camelot allocation per test case"),
        &["benchmark", "batch", "instances", "sm_pct_per_instance"],
    );
    let pipelines = real::all();
    // offline phase once per pipeline, itself fanned across cores
    let preds: Vec<_> = par::par_map(&pipelines, |_, p| common::train_predictors(p, cluster));
    let cells: Vec<(usize, u32)> = (0..pipelines.len())
        .flat_map(|pi| batches().into_iter().map(move |b| (pi, b)))
        .collect();
    let results: Vec<PeakCell> = par::par_map(&cells, |_, &(pi, batch)| {
        peak_cell(&pipelines[pi], cluster, &preds[pi], batch, &opts)
    });
    for cell in results {
        peaks.row(&cell.row);
        if let Some(a) = cell.alloc_row {
            alloc.row(&a);
        }
    }
    vec![peaks, alloc]
}

/// One (benchmark, batch) cell of the Fig 14/18/19 sweeps.
fn peak_cell(
    p: &Pipeline,
    cluster: &ClusterSpec,
    preds: &[crate::predictor::StagePredictor],
    batch: u32,
    opts: &SimOptions,
) -> PeakCell {
    let mut row = vec![p.name.clone(), batch.to_string()];
    let mut alloc_row = None;
    let mut ea_peak = 0.0;
    let mut cam_peak = 0.0;
    let mut cam_p99 = f64::NAN;
    for planner in PEAK_PLANNERS {
        match common::planner_peak(planner, p, cluster, preds, batch, opts) {
            Some((d, peak, report)) => {
                row.push(fnum(peak));
                match planner {
                    Planner::EvenAllocation => ea_peak = peak,
                    Planner::Camelot => {
                        cam_peak = peak;
                        cam_p99 = report.p99() / p.qos_target_s;
                        let ni = d.instances_per_stage(p.n_stages());
                        let mut quotas: Vec<f64> = vec![0.0; p.n_stages()];
                        for pl in &d.placements {
                            quotas[pl.stage] = pl.sm_frac;
                        }
                        alloc_row = Some(vec![
                            p.name.clone(),
                            batch.to_string(),
                            format!("{ni:?}"),
                            format!(
                                "{:?}",
                                quotas
                                    .iter()
                                    .map(|q| (q * 100.0).round() as u32)
                                    .collect::<Vec<_>>()
                            ),
                        ]);
                    }
                    _ => {}
                }
            }
            None => row.push("-".to_string()),
        }
    }
    row.push(if ea_peak > 0.0 {
        format!("{:+.1}%", 100.0 * (cam_peak / ea_peak - 1.0))
    } else {
        "-".to_string()
    });
    row.push(format!("{cam_p99:.2}"));
    PeakCell { row, alloc_row }
}

/// Fig 14 + 15 on the 2×2080Ti testbed.
pub fn fig14() -> Vec<Table> {
    peak_load_comparison(&ClusterSpec::two_2080ti(), "2x2080Ti")
}

/// Fig 19 on the DGX-2 (16×V100).
pub fn fig19() -> Vec<Table> {
    peak_load_comparison(&ClusterSpec::dgx2(), "DGX-2")
}

/// Fig 16: resource usage and p99 at low load (30% of Camelot's peak),
/// Camelot vs Laius, normalized to one-GPU-per-stage.
pub fn fig16() -> Vec<Table> {
    let cluster = ClusterSpec::two_2080ti();
    let opts = common::sweep_opts();
    let mut t = Table::new(
        "Fig 16: normalized resource usage and p99/QoS at 30% load",
        &["benchmark", "camelot_usage", "camelot_p99", "laius_usage", "laius_p99"],
    );
    let pipelines = real::all();
    let rows: Vec<Option<Vec<String>>> = par::par_map(&pipelines, |_, p| {
        let preds = common::train_predictors(p, &cluster);
        let (_, peak, _) =
            common::planner_peak(Planner::Camelot, p, &cluster, &preds, 32, &opts)?;
        let low = peak * 0.3;
        let mut row = vec![p.name.clone()];
        for planner in [Planner::Camelot, Planner::Laius] {
            match common::plan_low_load(planner, p, &cluster, &preds, 32, low) {
                Some(d) => {
                    let r = Simulator::new(p, &cluster, &d, opts.clone()).run(low.max(1.0));
                    match r {
                        Ok(rep) => {
                            row.push(fnum(common::normalized_usage(p, &d)));
                            row.push(format!("{:.2}", rep.p99() / p.qos_target_s));
                        }
                        Err(_) => {
                            row.push("-".into());
                            row.push("-".into());
                        }
                    }
                }
                None => {
                    row.push("-".into());
                    row.push("-".into());
                }
            }
        }
        Some(row)
    });
    for row in rows.into_iter().flatten() {
        t.row(&row);
    }
    vec![t]
}

/// Per-benchmark output of the Fig 17 sweep.
struct Fig17Out {
    rows: Vec<Vec<String>>,
    violations: u32,
    cases: u32,
}

/// Fig 17: Camelot's usage + p99 across load levels, and the Camelot-NC
/// ablation's p99 (unmanaged bandwidth contention).
pub fn fig17() -> Vec<Table> {
    let cluster = ClusterSpec::two_2080ti();
    let opts = common::sweep_opts();
    let mut t = Table::new(
        "Fig 17: usage and p99 across load levels; Camelot-NC ablation",
        &["benchmark", "load_pct", "usage", "p99_over_qos", "nc_p99_over_qos"],
    );
    // real benchmarks + the memory-heavy artifact composites, where the
    // bandwidth constraint has the most to protect (on this substrate
    // the real pipelines' bandwidth pressure is milder than the
    // paper's testbed — see EXPERIMENTS.md §Deviations)
    let mut benches = real::all();
    benches.push(artifact::pipeline(1, 1, 3));
    benches.push(artifact::pipeline(2, 2, 3));
    benches.push(artifact::pipeline(1, 3, 3));
    benches.push(artifact::pipeline(3, 1, 3));
    let outs: Vec<Option<Fig17Out>> = par::par_map(&benches, |_, p| {
        let preds = common::train_predictors(p, &cluster);
        let (_, peak, _) =
            common::planner_peak(Planner::Camelot, p, &cluster, &preds, 32, &opts)?;
        let mut out = Fig17Out { rows: Vec::new(), violations: 0, cases: 0 };
        for load_pct in [50u32, 95] {
            let load = peak * load_pct as f64 / 100.0;
            let cam = common::plan_low_load(Planner::Camelot, p, &cluster, &preds, 32, load);
            let nc = common::plan_low_load(Planner::CamelotNC, p, &cluster, &preds, 32, load);
            let mut row = vec![p.name.clone(), load_pct.to_string()];
            match cam {
                Some(d) => {
                    let rep = Simulator::new(p, &cluster, &d, opts.clone())
                        .run(load.max(1.0))
                        .unwrap();
                    row.push(fnum(common::normalized_usage(p, &d)));
                    row.push(format!("{:.2}", rep.p99() / p.qos_target_s));
                }
                None => {
                    row.push("-".into());
                    row.push("-".into());
                }
            }
            match nc {
                Some(d) => {
                    let rep = Simulator::new(p, &cluster, &d, opts.clone())
                        .run(load.max(1.0))
                        .unwrap();
                    let ratio = rep.p99() / p.qos_target_s;
                    out.cases += 1;
                    if ratio > 1.0 {
                        out.violations += 1;
                    }
                    row.push(format!("{ratio:.2}"));
                }
                None => row.push("-".into()),
            }
            out.rows.push(row);
        }
        Some(out)
    });
    let mut violations = 0;
    let mut cases = 0;
    for out in outs.into_iter().flatten() {
        for row in &out.rows {
            t.row(row);
        }
        violations += out.violations;
        cases += out.cases;
    }
    let mut summary = Table::new("Fig 17 summary", &["metric", "value"]);
    summary.push(&["NC QoS violations".to_string(), format!("{violations}/{cases}")]);
    vec![t, summary]
}

/// Per-pipeline output of the Fig 18/20/21 sweep.
struct ArtifactCell {
    row: Vec<String>,
    alloc_row: Option<Vec<String>>,
    lowload_row: Option<Vec<String>>,
}

/// Figs 18/20/21: the 27 artifact pipelines — peak loads (EA / Laius /
/// Camelot), Camelot's allocations, and low-load resource usage.
pub fn fig18() -> Vec<Table> {
    let cluster = ClusterSpec::two_2080ti();
    let opts = SimOptions { queries: 2_500, ..common::sweep_opts() };
    let batch = 32;
    let mut peaks = Table::new(
        "Fig 18: artifact-pipeline peak loads (QPS)",
        &["benchmark", "EA", "Laius", "Camelot", "camelot_vs_ea"],
    );
    let mut alloc = Table::new(
        "Fig 20: Camelot allocation for the artifact pipelines",
        &["benchmark", "instances", "sm_pct_per_instance"],
    );
    let mut lowload = Table::new(
        "Fig 21: low-load (30%) usage and p99/QoS for the artifact pipelines",
        &["benchmark", "usage", "p99_over_qos"],
    );
    let pipelines = artifact::all27();
    let cells: Vec<ArtifactCell> = par::par_map(&pipelines, |_, p| {
        let preds = common::train_predictors(p, &cluster);
        let mut row = vec![p.name.clone()];
        let mut alloc_row = None;
        let mut ea_peak = 0.0;
        let mut cam_peak = 0.0;
        for planner in PEAK_PLANNERS {
            match common::planner_peak(planner, p, &cluster, &preds, batch, &opts) {
                Some((d, peak, _)) => {
                    row.push(fnum(peak));
                    match planner {
                        Planner::EvenAllocation => ea_peak = peak,
                        Planner::Camelot => {
                            cam_peak = peak;
                            let ni = d.instances_per_stage(p.n_stages());
                            let mut quotas = vec![0.0; p.n_stages()];
                            for pl in &d.placements {
                                quotas[pl.stage] = pl.sm_frac;
                            }
                            alloc_row = Some(vec![
                                p.name.clone(),
                                format!("{ni:?}"),
                                format!(
                                    "{:?}",
                                    quotas
                                        .iter()
                                        .map(|q| (q * 100.0).round() as u32)
                                        .collect::<Vec<_>>()
                                ),
                            ]);
                        }
                        _ => {}
                    }
                }
                None => row.push("-".to_string()),
            }
        }
        row.push(if ea_peak > 0.0 {
            format!("{:+.1}%", 100.0 * (cam_peak / ea_peak - 1.0))
        } else {
            "-".into()
        });
        // Fig 21
        let low = cam_peak * 0.3;
        let mut lowload_row = None;
        if low > 0.0 {
            if let Some(d) =
                common::plan_low_load(Planner::Camelot, p, &cluster, &preds, batch, low)
            {
                if let Ok(rep) = Simulator::new(p, &cluster, &d, opts.clone()).run(low.max(1.0))
                {
                    lowload_row = Some(vec![
                        p.name.clone(),
                        fnum(common::normalized_usage(p, &d)),
                        format!("{:.2}", rep.p99() / p.qos_target_s),
                    ]);
                }
            }
        }
        ArtifactCell { row, alloc_row, lowload_row }
    });
    for cell in cells {
        peaks.row(&cell.row);
        if let Some(a) = cell.alloc_row {
            alloc.row(&a);
        }
        if let Some(l) = cell.lowload_row {
            lowload.row(&l);
        }
    }
    vec![peaks, alloc, lowload]
}

/// Parameters of the co-location / diurnal-autoscaling scenario (the
/// `camelot colocate` subcommand exposes them).
#[derive(Debug, Clone)]
pub struct ColocateConfig {
    /// Tenant A's constant planning load (queries/s).
    pub load_a: f64,
    /// Tenant B's constant planning load (queries/s).
    pub load_b: f64,
    /// Diurnal peak for the closed-loop day (queries/s).
    pub diurnal_peak: f64,
    /// Plan epochs over the simulated day.
    pub epochs: usize,
    /// Queries per simulation trial.
    pub queries: usize,
    /// Batch size both tenants plan and serve at.
    pub batch: u32,
    /// The shared cluster both tenants co-locate on.
    pub cluster: ClusterSpec,
    pub seed: u64,
    /// Solve-cache payload ([`SolveCache::to_json`]) to warm-start every
    /// autoscaler in the scenario with (the `camelot colocate
    /// --cache-load` path). Plans are bit-identical warm or cold; only
    /// the cache counters move.
    pub warm_cache: Option<String>,
}

impl Default for ColocateConfig {
    fn default() -> Self {
        ColocateConfig {
            load_a: 150.0,
            load_b: 100.0,
            diurnal_peak: 400.0,
            epochs: 12,
            queries: 1_500,
            batch: AutoscaleConfig::default().batch,
            cluster: ClusterSpec::two_2080ti(),
            seed: 42,
            warm_cache: None,
        }
    }
}

/// Cluster-level co-location + diurnal savings: tenant A plans first,
/// tenant B plans into the capacity A's reservations leave free, both
/// run together in one [`ClusterSim`] (constant and diurnally modulated
/// arrivals), and each pipeline's diurnal day runs closed-loop through
/// `coordinator::run_closed_loop`.
pub fn colocate_tables(
    pipe_a: &Pipeline,
    pipe_b: &Pipeline,
    cfg: &ColocateConfig,
) -> Result<Vec<Table>, String> {
    colocate_tables_io(pipe_a, pipe_b, cfg, false).map(|(tables, _)| tables)
}

/// [`colocate_tables`] with cache I/O: when `save_cache` is set the
/// second return value carries the merged solve-cache contents of every
/// controller the scenario ran (both placement autoscalers plus both
/// closed diurnal loops) as a [`SolveCache::to_json`] payload — what
/// `camelot colocate --cache-save` writes and a later `--cache-load`
/// run warms from. [`ColocateConfig::warm_cache`] is validated up front
/// so a malformed payload errors instead of silently running cold.
pub fn colocate_tables_io(
    pipe_a: &Pipeline,
    pipe_b: &Pipeline,
    cfg: &ColocateConfig,
    save_cache: bool,
) -> Result<(Vec<Table>, Option<String>), String> {
    if !(cfg.load_a > 0.0 && cfg.load_b > 0.0 && cfg.diurnal_peak > 0.0) {
        return Err("loads and diurnal peak must be positive".into());
    }
    if cfg.epochs == 0 || cfg.queries == 0 || cfg.batch == 0 {
        return Err("epochs, queries, and batch must be at least 1".into());
    }
    if let Some(json) = &cfg.warm_cache {
        SolveCache::from_json(json).map_err(|e| format!("warm-cache payload: {e}"))?;
    }
    let cluster = cfg.cluster.clone();
    let pipes = [pipe_a, pipe_b];
    let preds: Vec<_> = par::par_map(&pipes, |_, p| common::train_predictors(p, &cluster));
    let scale_cfg = AutoscaleConfig {
        batch: cfg.batch,
        warm_cache: cfg.warm_cache.clone(),
        ..Default::default()
    };

    // --- co-located deployment: A first, B into the remainder ---
    let mut sa = Autoscaler::new(pipe_a, &cluster, &preds[0], scale_cfg.clone());
    sa.observe(cfg.load_a)
        .ok_or_else(|| format!("tenant A ({}) has no feasible plan", pipe_a.name))?;
    let da = sa.current().unwrap().deployment.clone();
    let usage_a = sa.current().unwrap().usage;
    let held = reservations_for(pipe_a, &cluster, &da);
    let mut sb = Autoscaler::new(pipe_b, &cluster, &preds[1], scale_cfg.clone());
    sb.observe_with_reservations(cfg.load_b, &held)
        .ok_or_else(|| format!("tenant B ({}) does not fit the remainder", pipe_b.name))?;
    let db = sb.current().unwrap().deployment.clone();
    let usage_b = sb.current().unwrap().usage;

    let opts = SimOptions { seed: cfg.seed, queries: cfg.queries, ..Default::default() };
    // solo baselines (same deployments, exclusive cluster)
    let solo_a = Simulator::new(pipe_a, &cluster, &da, opts.clone())
        .run(cfg.load_a.max(1.0))
        .map_err(|e| format!("solo A: {e}"))?;
    let solo_b = Simulator::new(pipe_b, &cluster, &db, opts.clone())
        .run(cfg.load_b.max(1.0))
        .map_err(|e| format!("solo B: {e}"))?;
    // co-located, constant rates
    let coloc = ClusterSim::new(
        &cluster,
        vec![
            TenantSpec {
                pipeline: pipe_a,
                deployment: &da,
                arrivals: ArrivalProcess::constant(cfg.load_a),
            },
            TenantSpec {
                pipeline: pipe_b,
                deployment: &db,
                arrivals: ArrivalProcess::constant(cfg.load_b),
            },
        ],
        opts.clone(),
    )
    .run()
    .map_err(|e| format!("co-located run: {e}"))?;
    // co-located, diurnally modulated arrivals (compressed day so the
    // fixed query budget actually sees the rate move)
    let day_a = DiurnalPattern { peak_qps: cfg.load_a, trough_frac: 0.3, period_s: 30.0 };
    let day_b = DiurnalPattern { peak_qps: cfg.load_b, trough_frac: 0.3, period_s: 30.0 };
    let diurnal = ClusterSim::new(
        &cluster,
        vec![
            TenantSpec {
                pipeline: pipe_a,
                deployment: &da,
                arrivals: ArrivalProcess::diurnal(day_a),
            },
            TenantSpec {
                pipeline: pipe_b,
                deployment: &db,
                arrivals: ArrivalProcess::diurnal(day_b),
            },
        ],
        opts,
    )
    .run()
    .map_err(|e| format!("diurnal co-located run: {e}"))?;

    let mut t1 = Table::new(
        "Co-location: two pipelines share the cluster (B planned into A's remainder)",
        &["tenant", "arrivals", "load_qps", "usage", "p99_solo_ms", "p99_coloc_ms", "p99_over_qos"],
    );
    for (name, load, usage, solo, co, dz, qos) in [
        (&pipe_a.name, cfg.load_a, usage_a, &solo_a, &coloc[0], &diurnal[0], pipe_a.qos_target_s),
        (&pipe_b.name, cfg.load_b, usage_b, &solo_b, &coloc[1], &diurnal[1], pipe_b.qos_target_s),
    ] {
        t1.push(&[
            name.clone(),
            "poisson".into(),
            fnum(load),
            format!("{usage:.2}"),
            format!("{:.1}", solo.p99() * 1e3),
            format!("{:.1}", co.p99() * 1e3),
            format!("{:.2}", co.p99() / qos),
        ]);
        t1.push(&[
            name.clone(),
            "diurnal".into(),
            fnum(dz.offered_qps),
            format!("{usage:.2}"),
            "-".into(),
            format!("{:.1}", dz.p99() * 1e3),
            format!("{:.2}", dz.p99() / qos),
        ]);
    }

    // --- closed-loop diurnal day per pipeline ---
    let day = DiurnalPattern::new(cfg.diurnal_peak);
    let loop_cfg = EpochLoopConfig {
        epochs: cfg.epochs,
        epoch_s: day.period_s / cfg.epochs as f64,
        queries_per_epoch: cfg.queries,
        seed: cfg.seed,
        ..Default::default()
    };
    let loops: Vec<Option<crate::coordinator::ClosedLoopReport>> =
        par::par_map(&pipes, |i, p| {
            run_closed_loop(p, &cluster, &preds[i], scale_cfg.clone(), &day, &loop_cfg)
        });

    let mut t2 = Table::new(
        "Diurnal closed loop: per-epoch usage follows the load while p99 holds",
        &["benchmark", "hour", "load_qps", "replanned", "churn", "usage", "p99_ms", "qos_met"],
    );
    let mut t3 = Table::new(
        "Diurnal savings vs static peak provisioning (§VIII-C)",
        &["benchmark", "mean_usage", "static_usage", "savings_pct", "replans", "churn_s", "qos_violations"],
    );
    for (p, rep) in pipes.iter().zip(&loops) {
        let Some(rep) = rep else {
            t3.push(&[p.name.clone(), "-".into(), "-".into(), "-".into(), "-".into(), "-".into(), "-".into()]);
            continue;
        };
        for e in &rep.epochs {
            t2.push(&[
                p.name.clone(),
                format!("{:02.0}:00", e.t_s / 3_600.0),
                fnum(e.load_qps),
                if e.replanned { "yes" } else { "" }.to_string(),
                e.churn_instances.to_string(),
                format!("{:.2}", e.usage),
                format!("{:.1}", e.p99_s * 1e3),
                e.qos_met.to_string(),
            ]);
        }
        t3.push(&[
            p.name.clone(),
            format!("{:.2}", rep.mean_usage),
            format!("{:.2}", rep.static_usage),
            format!("{:.1}%", rep.savings_vs_static() * 100.0),
            rep.replans.to_string(),
            format!("{:.1}", rep.churn_s),
            rep.qos_violations.to_string(),
        ]);
    }

    // control-loop memoization observability (closed-loop autoscaler)
    let mut t4 = Table::new(
        "Control-loop solve cache (closed-loop autoscaler)",
        &["benchmark", "hits", "misses", "hit_rate", "evictions"],
    );
    for (p, rep) in pipes.iter().zip(&loops) {
        let Some(rep) = rep else { continue };
        let sc = &rep.solve_cache;
        t4.push(&[
            p.name.clone(),
            sc.hits.to_string(),
            sc.misses.to_string(),
            format!("{:.1}%", sc.hit_rate() * 100.0),
            sc.evictions.to_string(),
        ]);
    }
    // warm runs start the counters at zero post-load, so the hit rates
    // above already *are* the warm hit rates; this row just surfaces
    // how many entries each controller was seeded with
    if cfg.warm_cache.is_some() {
        t4.push(&[
            "(warm-start)".into(),
            format!("{} entries/controller", sa.warm_loaded()),
            "-".into(),
            "-".into(),
            "-".into(),
        ]);
    }
    let saved = if save_cache {
        // one payload warms every controller: merge the placement
        // autoscalers' caches with both closed loops' final contents
        // (content-addressed keys, so same-request entries coincide)
        let per = scale_cfg.solve_cache;
        let merged = SolveCache::new(per.saturating_mul(4).max(1));
        merged.load_json(&sa.cache_json())?;
        merged.load_json(&sb.cache_json())?;
        for rep in loops.iter().flatten() {
            merged.load_json(&rep.cache_json)?;
        }
        Some(merged.to_json())
    } else {
        None
    };
    Ok((vec![t1, t2, t3, t4], saved))
}

/// The registered `colocate` experiment: img-to-text + text-to-text on
/// the 2×2080Ti testbed with default loads.
pub fn colocate() -> Result<Vec<Table>, String> {
    colocate_tables(
        &real::img_to_text(),
        &real::text_to_text(),
        &ColocateConfig::default(),
    )
}

/// Parameters of the N-tenant online-admission scenario (the `camelot
/// admit` subcommand exposes them).
#[derive(Debug, Clone)]
pub struct AdmissionExpConfig {
    /// Tenant arrivals in the trace.
    pub tenants: usize,
    /// Mean gap between tenant arrivals / mean residency (seconds).
    pub mean_interarrival_s: f64,
    pub mean_lifetime_s: f64,
    /// Per-tenant diurnal peak band (queries/s).
    pub peak_qps_lo: f64,
    pub peak_qps_hi: f64,
    /// Queries per tenant in each between-event validation simulation.
    pub queries: usize,
    pub seed: u64,
    /// Cells the cluster splits into (1 = the flat controller; > 1
    /// routes through `coordinator::cells` and adds a per-cell table).
    pub cells: usize,
}

impl Default for AdmissionExpConfig {
    fn default() -> Self {
        AdmissionExpConfig {
            tenants: 10,
            mean_interarrival_s: 600.0,
            mean_lifetime_s: 2_400.0,
            peak_qps_lo: 50.0,
            peak_qps_hi: 150.0,
            queries: 1_000,
            seed: 42,
            cells: 1,
        }
    }
}

/// N-tenant online admission with departure re-packing vs static whole-
/// GPU partitioning: generate a seed-reproducible [`TenantTrace`],
/// replay it through `coordinator::admission` (every between-event
/// interval validated end-to-end in `ClusterSim`), replay the same
/// trace against the dedicated-GPU baseline, and table the decision
/// log, the measured per-interval QoS, and the admitted-count /
/// utilization comparison.
pub fn admission_tables(cfg: &AdmissionExpConfig) -> Result<Vec<Table>, String> {
    admission_tables_io(cfg, &AdmitIo::default()).map(|(tables, _)| tables)
}

/// [`admission_tables`] with durability / cache I/O (the `camelot
/// admit` flag surface).
pub fn admission_tables_io(
    cfg: &AdmissionExpConfig,
    io: &AdmitIo,
) -> Result<(Vec<Table>, Option<String>), String> {
    use crate::suite::workload::{TenantTrace, TenantTraceConfig};

    if cfg.tenants == 0 || cfg.queries == 0 {
        return Err("tenants and queries must be at least 1".into());
    }
    if cfg.cells == 0 {
        return Err("cells must be at least 1".into());
    }
    if !(cfg.peak_qps_lo > 0.0 && cfg.peak_qps_hi >= cfg.peak_qps_lo) {
        return Err("peak band must be positive and ordered".into());
    }
    if !(cfg.mean_interarrival_s > 0.0 && cfg.mean_lifetime_s > 0.0) {
        return Err("mean interarrival and lifetime must be positive".into());
    }
    let cluster = ClusterSpec::two_2080ti();
    let trace = TenantTrace::generate(
        &TenantTraceConfig {
            tenants: cfg.tenants,
            mean_interarrival_s: cfg.mean_interarrival_s,
            mean_lifetime_s: cfg.mean_lifetime_s,
            peak_qps_lo: cfg.peak_qps_lo,
            peak_qps_hi: cfg.peak_qps_hi,
            ..Default::default()
        },
        cfg.seed,
    );
    let knobs = ReplayKnobs {
        queries: cfg.queries,
        batch: crate::coordinator::AdmissionConfig::default().batch,
        seed: cfg.seed,
        cells: cfg.cells,
        break_qos: false,
    };
    admission_tables_for_trace_io(&cluster, &trace, knobs, io)
}

/// Bundled replay knobs for [`admission_tables_for_trace`].
#[derive(Debug, Clone, Copy)]
pub struct ReplayKnobs {
    pub queries: usize,
    pub batch: u32,
    pub seed: u64,
    /// Cells the cluster splits into (≤ 1 = the flat controller).
    pub cells: usize,
    /// Dev mode (`camelot admit --spec <dump> --break-qos`): disable
    /// the admission-side QoS checks and over-commit the planner the
    /// same way `camelot fuzz --break-qos` does, and run the
    /// predicted-QoS audit — the reproduction path for specs the
    /// fuzzer dumps.
    pub break_qos: bool,
}

/// Durability and cache I/O surface of `camelot admit` / `camelot
/// recover`, threaded through [`admission_tables_for_trace_io`]. The
/// default (no WAL, no cache files) leaves every replay path — and its
/// table output — byte-identical to the plain
/// [`admission_tables_for_trace`].
#[derive(Debug, Clone, Default)]
pub struct AdmitIo {
    /// Solve-cache payload ([`SolveCache::to_json`]) to warm-start the
    /// replay's controller(s) with (`--cache-load`).
    pub warm_cache: Option<String>,
    /// Return the final solve-cache contents for persistence
    /// (`--cache-save`). Incompatible with a WAL: snapshots already
    /// embed the cache.
    pub save_cache: bool,
    /// Durable replay: append every accepted event to `DIR/wal.log` and
    /// snapshot into `DIR` (`--wal DIR`).
    pub wal_dir: Option<std::path::PathBuf>,
    /// Snapshot cadence in events (0 = never — WAL-only recovery;
    /// `--snapshot-every N`).
    pub snapshot_every: usize,
    /// `camelot recover`: reconverge from `wal_dir`'s latest snapshot +
    /// WAL tail instead of replaying from scratch. Requires `wal_dir`.
    pub recover: bool,
}

/// The admission experiment over an *explicit* tenant trace — the
/// entry `camelot admit --spec` uses for [`crate::planner::ScenarioSpec`]
/// scenarios (arrive/shrink/depart events, cluster + batch from the
/// spec).
pub fn admission_tables_for_trace(
    cluster: &ClusterSpec,
    trace: &crate::suite::workload::TenantTrace,
    knobs: ReplayKnobs,
) -> Result<Vec<Table>, String> {
    admission_tables_for_trace_io(cluster, trace, knobs, &AdmitIo::default())
        .map(|(tables, _)| tables)
}

/// [`admission_tables_for_trace`] with durability / cache I/O. The
/// replay routes through one of four equivalent drivers — plain,
/// durable (WAL + snapshots), recovery (snapshot + WAL tail), or a
/// manual drive that extracts the solve cache before the measurement
/// phase consumes the state — all pinned bit-identical by the crash
/// golden suite. The second return value is the final solve-cache
/// payload when [`AdmitIo::save_cache`] is set.
pub fn admission_tables_for_trace_io(
    cluster: &ClusterSpec,
    trace: &crate::suite::workload::TenantTrace,
    knobs: ReplayKnobs,
    io: &AdmitIo,
) -> Result<(Vec<Table>, Option<String>), String> {
    use crate::coordinator::admission::{
        replay_trace, static_partition_replay, ReplayConfig, ReplayState,
    };
    use crate::coordinator::cells::{replay_trace_cells, CellsReplayConfig, CellsReplayState};
    use crate::coordinator::recovery::trace_event_list;
    use crate::coordinator::{recover, recover_cells, replay_durable, replay_durable_cells, DirStore};

    if knobs.queries == 0 {
        return Err("queries must be at least 1".into());
    }
    if knobs.batch == 0 {
        return Err("batch must be at least 1".into());
    }
    if io.save_cache && io.wal_dir.is_some() {
        return Err(
            "--cache-save is incompatible with --wal: snapshots already embed the solve \
             cache; recover from the WAL directory instead"
                .into(),
        );
    }
    if io.recover && io.wal_dir.is_none() {
        return Err("recovery needs the durable store: pass --wal DIR".into());
    }
    let warm_entries = match &io.warm_cache {
        Some(json) => Some(
            SolveCache::from_json(json)
                .map_err(|e| format!("warm-cache payload: {e}"))?
                .stats()
                .entries,
        ),
        None => None,
    };
    let mut replay_cfg = ReplayConfig { queries: knobs.queries, ..Default::default() };
    replay_cfg.admission.seed = knobs.seed;
    replay_cfg.admission.batch = knobs.batch;
    replay_cfg.warm_cache = io.warm_cache.clone();
    if knobs.break_qos {
        replay_cfg.admission.qos_headroom = 10.0;
        replay_cfg.admission.qos_slack = f64::INFINITY;
        replay_cfg.audit_qos = true;
    }
    // cells ≤ 1 keeps the flat controller path (and its exact output);
    // > 1 routes through the cluster-of-cells shard and reports the
    // merged fleet view plus a per-cell breakdown table
    let mut saved_cache: Option<String> = None;
    let (shared, celled) = if let Some(dir) = &io.wal_dir {
        let mut store = DirStore::open(dir)?;
        if knobs.cells > 1 {
            let cells_cfg = CellsReplayConfig::from_replay(knobs.cells, &replay_cfg);
            let rep = if io.recover {
                recover_cells(cluster, trace, &cells_cfg, &mut store, &[])?
            } else {
                replay_durable_cells(
                    cluster,
                    trace,
                    &cells_cfg,
                    &mut store,
                    io.snapshot_every,
                    None,
                )?
                .ok_or_else(|| "durable replay stopped without a crash injected".to_string())?
            };
            (rep.merged.clone(), Some(rep))
        } else {
            let rep = if io.recover {
                recover(cluster, trace, &replay_cfg, &mut store, &[])?
            } else {
                replay_durable(cluster, trace, &replay_cfg, &mut store, io.snapshot_every, None)?
                    .ok_or_else(|| "durable replay stopped without a crash injected".to_string())?
            };
            (rep, None)
        }
    } else if io.save_cache {
        // drive the state by hand: the cache must be read out before
        // finish() consumes the state for the measurement phase (the
        // event loop is the only thing that moves the cache, so this is
        // the exact final content)
        let events = trace_event_list(trace);
        if knobs.cells > 1 {
            let cells_cfg = CellsReplayConfig::from_replay(knobs.cells, &replay_cfg);
            let mut state = CellsReplayState::new(cluster, cells_cfg)?;
            for e in &events {
                state.apply_event(e)?;
            }
            saved_cache = Some(state.cache_json()?);
            let rep = state.finish()?;
            (rep.merged.clone(), Some(rep))
        } else {
            let mut state = ReplayState::new(cluster, replay_cfg.clone());
            state.warm_start()?;
            for e in &events {
                state.apply_event(e)?;
            }
            saved_cache = Some(state.cache_json());
            (state.finish()?, None)
        }
    } else if knobs.cells > 1 {
        let cells_cfg = CellsReplayConfig::from_replay(knobs.cells, &replay_cfg);
        let rep = replay_trace_cells(cluster, trace, &cells_cfg)?;
        (rep.merged.clone(), Some(rep))
    } else {
        (replay_trace(cluster, trace, &replay_cfg)?, None)
    };
    let dedicated = static_partition_replay(cluster, trace, &replay_cfg.admission)?;

    let mut t1 = Table::new(
        "Admission: online decision log (contention-aware shared cluster)",
        &["t_s", "tenant", "event", "decision", "residents", "gpus", "usage"],
    );
    for e in &shared.events {
        t1.push(&[
            format!("{:.0}", e.t_s),
            format!("#{}", e.tenant),
            e.desc.clone(),
            e.decision.clone(),
            e.residents.to_string(),
            e.gpus_in_use.to_string(),
            format!("{:.2}", e.usage),
        ]);
    }

    let mut t2 = Table::new(
        "Admission: measured per-interval p99 (merged ClusterSim validation)",
        &["t_start_s", "tenants", "p99_ms", "qos_met"],
    );
    for iv in &shared.intervals {
        t2.push(&[
            format!("{:.0}", iv.t_start_s),
            // comma, not '+': artifact pipeline names (p1+c2+m3) may
            // appear in tenant names
            iv.tenants.join(","),
            iv.p99_s
                .iter()
                .map(|p| format!("{:.1}", p * 1e3))
                .collect::<Vec<_>>()
                .join("/"),
            iv.qos_met
                .iter()
                .map(|m| if *m { "y" } else { "N" }.to_string())
                .collect::<Vec<_>>()
                .join("/"),
        ]);
    }

    let mut t3 = Table::new(
        "Admission: shared spatial multitasking vs static whole-GPU partitioning",
        &["policy", "admitted", "rejected", "peak_residents", "mean_gpus_in_use"],
    );
    t3.push(&[
        "camelot (shared)".into(),
        shared.admitted.to_string(),
        shared.rejected.to_string(),
        shared.peak_residents.to_string(),
        format!("{:.2}", shared.mean_gpus_in_use),
    ]);
    t3.push(&[
        "static partition".into(),
        dedicated.admitted.to_string(),
        dedicated.rejected.to_string(),
        dedicated.peak_residents.to_string(),
        format!("{:.2}", dedicated.mean_gpus_in_use),
    ]);
    let mut t4 = Table::new("Admission summary", &["metric", "value"]);
    t4.push(&[
        "admitted uplift vs static".to_string(),
        if dedicated.admitted > 0 {
            format!(
                "{:+.1}%",
                100.0 * (shared.admitted as f64 / dedicated.admitted as f64 - 1.0)
            )
        } else {
            "-".to_string()
        },
    ]);
    t4.push(&["repacks applied".to_string(), shared.repacks_applied.to_string()]);
    if replay_cfg.audit_qos {
        t4.push(&[
            "predicted-QoS audit violations".to_string(),
            shared.qos_violations.len().to_string(),
        ]);
    }
    // control-loop memoization observability: how much planning and
    // simulation the caches absorbed for this trace
    let sc = &shared.solve_cache;
    t4.push(&[
        "solve-cache hits/misses".to_string(),
        format!("{}/{}", sc.hits, sc.misses),
    ]);
    t4.push(&[
        "solve-cache hit rate".to_string(),
        format!("{:.1}%", sc.hit_rate() * 100.0),
    ]);
    t4.push(&["solve-cache evictions".to_string(), sc.evictions.to_string()]);
    // warm runs reset the counters after loading, so the hit-rate row
    // above already is the warm hit rate; this row records the seed size
    if let Some(n) = warm_entries {
        t4.push(&[
            "solve-cache warm-start entries".to_string(),
            n.min(replay_cfg.admission.solve_cache).to_string(),
        ]);
    }
    if let Some(dir) = &io.wal_dir {
        t4.push(&[
            "durability".to_string(),
            if io.recover {
                format!("recovered from {}", dir.display())
            } else if io.snapshot_every > 0 {
                format!("WAL {} (snapshot every {} events)", dir.display(), io.snapshot_every)
            } else {
                format!("WAL {} (no snapshots)", dir.display())
            },
        ]);
    }
    t4.push(&[
        "intervals simulated (of total)".to_string(),
        format!("{}/{}", shared.intervals_simulated, shared.intervals.len()),
    ]);
    let mut tables = vec![t1, t2, t3, t4];
    if let Some(rep) = &celled {
        tables[3].push(&["cells".to_string(), rep.cells.to_string()]);
        tables[3].push(&[
            "cross-cell migrations".to_string(),
            rep.migrations.to_string(),
        ]);
        // per-cell solve-cache and admission breakdown, with the
        // fleet-wide aggregate as the closing row (per-cell counters
        // are attempts — router fall-through retries included — while
        // the fleet row carries router-level arrivals)
        let mut t5 = Table::new(
            "Admission: per-cell breakdown (cluster-of-cells router)",
            &[
                "cell",
                "gpus",
                "admitted",
                "rejected",
                "peak_residents",
                "cache hits/misses",
                "hit_rate",
                "intervals sim/total",
            ],
        );
        for s in &rep.per_cell {
            t5.push(&[
                s.cell.to_string(),
                s.gpus.to_string(),
                s.admitted.to_string(),
                s.rejected.to_string(),
                s.peak_residents.to_string(),
                format!("{}/{}", s.solve_cache.hits, s.solve_cache.misses),
                format!("{:.1}%", s.solve_cache.hit_rate() * 100.0),
                format!("{}/{}", s.intervals_simulated, s.intervals),
            ]);
        }
        let fleet = &rep.merged.solve_cache;
        t5.push(&[
            "fleet".to_string(),
            cluster.num_gpus.to_string(),
            rep.merged.admitted.to_string(),
            rep.merged.rejected.to_string(),
            rep.merged.peak_residents.to_string(),
            format!("{}/{}", fleet.hits, fleet.misses),
            format!("{:.1}%", fleet.hit_rate() * 100.0),
            format!("{}/{}", rep.merged.intervals_simulated, rep.merged.intervals.len()),
        ]);
        tables.push(t5);
    }
    // mixed pools (cluster.gpu_classes): per-class occupancy breakdown
    // — the headline table of `camelot admit --spec
    // examples/scenario_hetero_pool.json`. Homogeneous clusters skip it,
    // keeping the legacy table shapes byte-identical.
    if !shared.class_utilization.is_empty() {
        let mut tc = Table::new(
            "Admission: per-class GPU utilization (heterogeneous pool)",
            &["class", "gpus", "mean_sm_util", "peak_sm_util"],
        );
        for cu in &shared.class_utilization {
            tc.push(&[
                cu.class.clone(),
                cu.gpus.to_string(),
                format!("{:.1}%", cu.mean_sm_frac * 100.0),
                format!("{:.1}%", cu.peak_sm_frac * 100.0),
            ]);
        }
        tables.push(tc);
    }
    // KV-bearing residents (LLM workloads): per-GPU peak dynamic
    // KV-cache residency across the replay — the headline table of
    // `camelot admit --spec examples/scenario_llm_colocate.json`.
    // Traces without KV stages leave the vector all-zero and skip the
    // table, keeping the legacy table shapes byte-identical.
    if shared.kv_peak_bytes.iter().any(|&b| b > 0.0) {
        let mut tk = Table::new(
            "Admission: per-GPU peak KV-cache residency (LLM workloads)",
            &["gpu", "class", "peak_kv_gib", "mem_gib", "peak_util"],
        );
        for (g, &peak) in shared.kv_peak_bytes.iter().enumerate() {
            let spec = cluster.gpu_at(g);
            let mem = spec.mem_bytes as f64;
            tk.push(&[
                g.to_string(),
                spec.name.to_string(),
                format!("{:.3}", peak / (1u64 << 30) as f64),
                format!("{:.1}", mem / (1u64 << 30) as f64),
                format!("{:.1}%", 100.0 * peak / mem),
            ]);
        }
        tables.push(tk);
    }
    Ok((tables, saved_cache))
}

/// The registered `admission` experiment, at the default trace shape.
pub fn admission() -> Result<Vec<Table>, String> {
    admission_tables(&AdmissionExpConfig::default())
}

#[cfg(test)]
mod tests {
    //! Smoke tests on reduced workloads; the ordering assertions
    //! (Camelot ≥ Laius ≥ EA) live in the integration suite where the
    //! full protocol runs.

    use super::*;

    #[test]
    fn colocate_emits_coherent_tables() {
        let cfg = ColocateConfig {
            epochs: 6,
            queries: 800,
            ..Default::default()
        };
        let ts = colocate_tables(&real::img_to_text(), &real::text_to_text(), &cfg)
            .expect("scenario runs");
        assert_eq!(ts.len(), 4);
        // two tenants × (poisson + diurnal) rows
        assert_eq!(ts[0].rows.len(), 4);
        // per-epoch rows for both pipelines
        assert_eq!(ts[1].rows.len(), 2 * cfg.epochs);
        // savings summary: positive savings, QoS mostly held
        for row in &ts[2].rows {
            let savings: f64 = row[3].trim_end_matches('%').parse().unwrap();
            assert!(savings > 5.0, "{}: savings {savings}%", row[0]);
        }
    }

    #[test]
    fn admission_emits_coherent_tables() {
        let cfg = AdmissionExpConfig {
            tenants: 4,
            queries: 400,
            ..Default::default()
        };
        let ts = admission_tables(&cfg).expect("scenario runs");
        assert_eq!(ts.len(), 4);
        // one decision-log row per trace event (arrive + depart each)
        assert_eq!(ts[0].rows.len(), 2 * cfg.tenants);
        // every interval row reports as many p99s as resident tenants
        for row in &ts[1].rows {
            assert_eq!(
                row[1].split(',').count(),
                row[2].split('/').count(),
                "tenants and p99s must align: {row:?}"
            );
        }
        // the comparison table has both policies, and sharing never
        // admits fewer tenants than dedicated whole GPUs
        assert_eq!(ts[2].rows.len(), 2);
        let admitted: Vec<usize> =
            ts[2].rows.iter().map(|r| r[1].parse().unwrap()).collect();
        assert!(admitted[0] >= admitted[1], "shared {admitted:?}");
    }

    #[test]
    fn admission_with_cells_adds_per_cell_breakdown() {
        let cfg = AdmissionExpConfig {
            tenants: 4,
            queries: 300,
            cells: 2, // the 2-GPU testbed splits into two 1-GPU cells
            ..Default::default()
        };
        let ts = admission_tables(&cfg).expect("scenario runs");
        assert_eq!(ts.len(), 5, "cells > 1 appends the per-cell table");
        assert_eq!(ts[0].rows.len(), 2 * cfg.tenants);
        // per-cell rows plus the fleet aggregate row
        assert_eq!(ts[4].rows.len(), cfg.cells + 1);
        assert_eq!(ts[4].rows[cfg.cells][0], "fleet");
        // the summary table gained the cells and migrations rows
        assert!(ts[3].rows.iter().any(|r| r[0] == "cells" && r[1] == "2"));
        assert!(ts[3].rows.iter().any(|r| r[0] == "cross-cell migrations"));
        // per-cell GPU counts partition the cluster
        let gpus: usize = ts[4].rows[..cfg.cells]
            .iter()
            .map(|r| r[1].parse::<usize>().unwrap())
            .sum();
        assert_eq!(gpus, 2);
        // invalid cell counts are rejected, not panicked on
        assert!(admission_tables(&AdmissionExpConfig { cells: 0, ..Default::default() })
            .is_err());
        assert!(admission_tables(&AdmissionExpConfig {
            cells: 3, // 2-GPU testbed cannot hold 3 cells
            tenants: 2,
            queries: 100,
            ..Default::default()
        })
        .is_err());
    }

    #[test]
    fn fig4_produces_rows() {
        let ts = fig4();
        assert_eq!(ts[0].rows.len(), 4);
        assert_eq!(ts[1].rows.len(), 4);
        // 4b: co-located times exceed offline times for stage 1
        for row in &ts[1].rows {
            let off: f64 = row[1].parse().unwrap();
            let co: f64 = row[2].parse().unwrap();
            assert!(co >= off * 0.95, "{}: coloc {co} vs offline {off}", row[0]);
        }
    }
}
