//! The four real-system benchmarks of the Camelot suite (Table I),
//! encoded as calibrated resource signatures.
//!
//! The constants below are *paper-scale*: model footprints in the
//! hundreds of MB to GBs (so global-memory capacity is a live
//! constraint, §IV-C), per-stage solo latencies in the tens of ms at
//! batch 32 on a 2080Ti-class device, and communication payloads sized
//! so the main-memory path spends 32–47% of end-to-end latency on PCIe
//! transfers (Fig 5). The PJRT serving path (examples/) uses the AOT
//! proxy artifacts instead; see `runtime::manifest`.

use super::service::{Pipeline, StageKind, StageProfile};

const KB: f64 = 1e3;
const MB: f64 = 1e6;
const GB: f64 = 1e9;

#[allow(clippy::too_many_arguments)]
fn stage(
    name: &str,
    kind: StageKind,
    gflops_q: f64,
    hbm_mb_q: f64,
    model_gb: f64,
    act_mb_q: f64,
    in_b: f64,
    out_b: f64,
    serial: f64,
) -> StageProfile {
    StageProfile {
        name: name.into(),
        kind,
        flops_per_query: gflops_q * 1e9,
        hbm_bytes_per_query: hbm_mb_q * MB,
        model_bytes: model_gb * GB,
        act_bytes_per_query: act_mb_q * MB,
        in_bytes_per_query: in_b,
        out_bytes_per_query: out_b,
        serial_frac: serial,
        batch_half: 16.0,
        mem_bytes_per_query: 0.0,
    }
}

/// Img-to-img: face recognition (FR-API) → image enhancement (FSRCNN).
/// Stage 1 dominates (Fig 4a: peak bound by stage 1); its activation
/// slope reproduces Fig 6 (batch 256 ≈ fills a 2080Ti's 11 GB).
pub fn img_to_img() -> Pipeline {
    Pipeline {
        name: "img-to-img".into(),
        stages: vec![
            stage("face_recognition", StageKind::Compute, 6.0, 70.0, 1.2, 38.0,
                  900.0 * KB, 450.0 * KB, 0.08),
            stage("fsrcnn_enhance", StageKind::Compute, 2.4, 42.0, 0.10, 11.0,
                  450.0 * KB, 1.3 * MB, 0.06),
        ],
        qos_target_s: 0.300,
    }
}

/// Img-to-text: VGG feature extraction → LSTM captioning.
/// Stage 2's high serial fraction makes it the bottleneck (Fig 4a).
pub fn img_to_text() -> Pipeline {
    Pipeline {
        name: "img-to-text".into(),
        stages: vec![
            stage("vgg_features", StageKind::Compute, 8.0, 80.0, 0.55, 24.0,
                  800.0 * KB, 3.0 * MB, 0.05),
            stage("lstm_caption", StageKind::Memory, 3.5, 95.0, 0.22, 6.0,
                  3.0 * MB, 2.0 * KB, 0.45),
        ],
        qos_target_s: 0.300,
    }
}

/// Text-to-img: LSTM semantic understanding → DC-GAN generation.
pub fn text_to_img() -> Pipeline {
    Pipeline {
        name: "text-to-img".into(),
        stages: vec![
            stage("lstm_semantic", StageKind::Memory, 1.8, 55.0, 0.15, 4.0,
                  4.0 * KB, 2.5 * MB, 0.40),
            stage("dcgan_generate", StageKind::Compute, 7.5, 95.0, 0.35, 30.0,
                  2.5 * MB, 700.0 * KB, 0.07),
        ],
        qos_target_s: 0.350,
    }
}

/// Text-to-text: BERT summarization → OpenNMT translation.
pub fn text_to_text() -> Pipeline {
    Pipeline {
        name: "text-to-text".into(),
        stages: vec![
            stage("bert_summarize", StageKind::Compute, 9.0, 110.0, 1.30, 20.0,
                  6.0 * KB, 4.5 * MB, 0.06),
            stage("nmt_translate", StageKind::Memory, 4.5, 115.0, 0.50, 9.0,
                  4.5 * MB, 4.0 * KB, 0.35),
        ],
        qos_target_s: 0.320,
    }
}

/// All four real benchmarks, in the order the paper's figures list them.
pub fn all() -> Vec<Pipeline> {
    vec![img_to_img(), img_to_text(), text_to_img(), text_to_text()]
}

/// Table I rendered for `camelot suite list`.
pub fn table1() -> crate::util::Table {
    let mut t = crate::util::Table::new(
        "Table I: End-to-end GPU microservices in Camelot suite",
        &["Workload", "Microservices", "Proxy artifact", "QoS (ms)"],
    );
    let proxies = [
        ("img-to-img", vec![("Face recognition", "face_recognition"),
                            ("Image enhancement", "fsrcnn_enhance")]),
        ("img-to-text", vec![("Image feature extraction", "vgg_features"),
                             ("Image caption", "lstm_caption")]),
        ("text-to-img", vec![("Semantic understanding", "lstm_semantic"),
                             ("Image generation", "dcgan_generate")]),
        ("text-to-text", vec![("Text summarization", "bert_summarize"),
                              ("Text translation", "nmt_translate")]),
    ];
    for (p, (wl, stages)) in all().iter().zip(proxies.iter()) {
        for (i, (ms, proxy)) in stages.iter().enumerate() {
            t.push(&[
                if i == 0 { *wl } else { "" }.to_string(),
                ms.to_string(),
                proxy.to_string(),
                if i == 0 {
                    format!("{:.0}", p.qos_target_s * 1e3)
                } else {
                    String::new()
                },
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_pipelines_validate() {
        for p in all() {
            p.validate().unwrap_or_else(|e| panic!("{e}"));
            assert_eq!(p.n_stages(), 2);
        }
    }

    #[test]
    fn fig6_memory_slope() {
        // Fig 6: img-to-img stage 1 at batch 256 fills a 2080Ti (11 GB).
        let s1 = &img_to_img().stages[0];
        let at256 = s1.mem_footprint(256);
        assert!(at256 > 10.0 * GB && at256 < 12.0 * GB, "got {at256}");
        // and batch 64 fits comfortably
        assert!(s1.mem_footprint(64) < 5.0 * GB);
    }

    #[test]
    fn lstm_stages_scale_poorly() {
        // Fig 3a/4a: the sequential language models have high serial
        // fractions, the dense vision models low ones.
        assert!(img_to_text().stages[1].serial_frac > 0.2);
        assert!(img_to_text().stages[0].serial_frac < 0.1);
    }

    #[test]
    fn table1_has_eight_stage_rows() {
        assert_eq!(table1().rows.len(), 8);
    }

    #[test]
    fn memory_kind_stages_have_low_intensity() {
        for p in all() {
            for s in &p.stages {
                match s.kind {
                    StageKind::Memory => assert!(s.arithmetic_intensity() < 50.0,
                        "{} intensity {}", s.name, s.arithmetic_intensity()),
                    StageKind::Compute => assert!(s.arithmetic_intensity() > 50.0,
                        "{} intensity {}", s.name, s.arithmetic_intensity()),
                    StageKind::Pcie => {}
                }
            }
        }
    }
}
