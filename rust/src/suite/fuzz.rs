//! Chaos & burst scenario fuzzer (`camelot fuzz`): seed-reproducible
//! generation of valid [`ScenarioSpec`]s — mixed service tiers, flash
//! crowds, diurnal offered load, GPU failure/recovery windows — plus a
//! property harness that replays every generated scenario through the
//! admission/cells stack and checks the QoS invariants end to end:
//!
//!  (a) **QoS audit clean** — with [`ReplayConfig::audit_qos`] on, no
//!      admitted tenant's predicted p99 exceeds its target at any
//!      event (the controller's own admission / enforcement / re-pack
//!      gates must make this hold by construction);
//!  (b) **re-pack never strands capacity** — a departure re-pack that
//!      is applied never leaves the fleet on *more* GPUs than before
//!      ([`ReplayReport::repack_regressions`] stays 0);
//!  (c) **thread-count determinism** — the full replay fingerprint is
//!      bit-identical across 1/2/8 worker threads, in the flat
//!      controller and the cluster-of-cells router alike;
//!  (d) **replayable failures** — any violated scenario is surfaced as
//!      the exact generated JSON text (plus the run seed), which
//!      `camelot admit --spec <dump.json>` replays verbatim;
//!  (e) **KV residency bounded** — per-GPU resident KV-cache bytes
//!      never exceed the device's `mem_bytes` in any replayed interval
//!      ([`ReplayReport::kv_peak_bytes`] stays under the physical
//!      capacity; trivially true without LLM tenants, load-bearing
//!      with [`FuzzConfig::llm`]);
//!  (f) **crash recovery reconverges** — with [`FuzzConfig::crash`],
//!      the durable replay is killed at sampled event boundaries
//!      (middle and end) and recovered from its WAL + snapshots
//!      ([`crate::coordinator::recovery`]); the recovered fingerprint
//!      must equal the uninterrupted replay's bit-for-bit.
//!
//! The generator emits JSON *text* and the harness re-parses it via
//! [`ScenarioSpec::parse`], so the dumped artifact — not some internal
//! struct — is what was actually checked: a dump always reproduces.
//! Scenario `index` under run seed `S` draws from
//! `Rng::new(mix_seed(S, index))`, so single scenarios re-run in
//! isolation bit-identically.

use std::fmt::Write as _;
use std::path::PathBuf;

use crate::coordinator::admission::{replay_trace, ReplayConfig};
use crate::coordinator::cells::{replay_trace_cells, CellsConfig, CellsReplayConfig};
use crate::coordinator::recovery::{
    trace_event_list, verify_crash_recovery, verify_crash_recovery_cells,
};
use crate::coordinator::AdmissionConfig;
use crate::planner::ScenarioSpec;
use crate::util::rng::{mix_seed, Rng};

/// Thread counts every scenario's replay is checked across
/// (invariant (c)).
pub const THREAD_MATRIX: [usize; 3] = [1, 2, 8];

/// Knobs for one fuzzing run.
#[derive(Debug, Clone)]
pub struct FuzzConfig {
    /// Scenarios to generate and check.
    pub scenarios: usize,
    /// Run seed; scenario `i` draws from `mix_seed(seed, i)`.
    pub seed: u64,
    /// Queries per interval validation, written into every generated
    /// spec (small keeps a 1000-scenario run brisk; the dump carries
    /// the value so `admit --spec` re-simulates identically).
    pub queries: usize,
    /// Dev switch: plan with `qos_headroom = 10` and disable the
    /// admission-side QoS checks (`qos_slack = ∞`) so over-committed
    /// tenants are let in and the audit provably fires — the
    /// end-to-end demonstration that invariant (a) violations are
    /// caught and dumped as replayable specs.
    pub break_qos: bool,
    /// Where violated scenarios are dumped as replayable JSON
    /// (`fuzz-<seed>-<index>.json`); `None` skips dumping.
    pub dump_dir: Option<PathBuf>,
    /// Mix LLM tenants (`"workload": "llm"`, ~25% of tenant slots)
    /// into the generated population, exercising the KV-cache
    /// admission/sim path and invariant (e). Off keeps generation
    /// byte-identical to the legacy population.
    pub llm: bool,
    /// Mix partial GPU-degrade windows (`"gpu_degrades"` — ECC/thermal
    /// slowdowns with optional restores) into the generated population.
    /// Off keeps generation byte-identical to the legacy population.
    pub degrade: bool,
    /// Check invariant (f): run each clean scenario through the
    /// crash-injection harness
    /// ([`crate::coordinator::recovery::verify_crash_recovery`]),
    /// killing the durable controller at the trace's middle and final
    /// event boundaries and requiring the recovered replay to
    /// fingerprint-match the uninterrupted one.
    pub crash: bool,
}

impl Default for FuzzConfig {
    fn default() -> Self {
        FuzzConfig {
            scenarios: 200,
            seed: 42,
            queries: 120,
            break_qos: false,
            dump_dir: None,
            llm: false,
            degrade: false,
            crash: false,
        }
    }
}

/// One invariant violation, with everything needed to reproduce it.
#[derive(Debug, Clone)]
pub struct FuzzViolation {
    /// Scenario index within the run (seeded by `mix_seed(seed, index)`).
    pub index: usize,
    /// Which invariant broke: `invalid-spec`, `replay-error`,
    /// `qos-audit`, `repack-regression`, `kv-overflow`,
    /// `thread-divergence`, or `crash-recovery`.
    pub kind: String,
    pub detail: String,
    /// The exact generated spec text — feed to `camelot admit --spec`.
    pub spec_json: String,
    /// Where the spec was dumped (when a dump dir was configured and
    /// the write succeeded).
    pub dump_path: Option<PathBuf>,
}

/// Outcome of a fuzzing run.
#[derive(Debug, Clone)]
pub struct FuzzReport {
    pub scenarios: usize,
    pub seed: u64,
    /// Replay events checked across all clean scenarios.
    pub events_checked: usize,
    pub violations: Vec<FuzzViolation>,
}

impl FuzzReport {
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }
}

fn pick(rng: &mut Rng, xs: &[&'static str]) -> &'static str {
    xs[rng.below(xs.len())]
}

/// Generate scenario `index` of run `seed` as ScenarioSpec JSON text.
///
/// Every sampled value stays inside the bounds `ScenarioSpec::parse`
/// enforces (burst windows within residency, failure GPU ids within
/// the sampled cluster, recovery after failure), so a parse error on
/// the output is itself a harness bug the fuzzer reports. All numbers
/// are emitted as small integers or fixed decimal strings: the text
/// round-trips through the f64-based JSON parser exactly.
pub fn generate_spec_json(seed: u64, index: usize, queries: usize) -> String {
    generate_spec_json_with(seed, index, queries, false, false)
}

/// [`generate_spec_json`] with the LLM-tenant and GPU-degrade mix
/// switches. With both off, exactly the legacy RNG draw sequence is
/// consumed, so existing seeds keep generating byte-identical
/// scenarios. `llm: true` converts ~25% of tenant slots into
/// `"workload": "llm"` tenants with sampled prompt/output/KV shapes
/// (and a lower load range — decode-bound pipelines saturate far below
/// the vision benchmarks). `degrade: true` appends a `"gpu_degrades"`
/// window (sampled GPUs, scale > 1.0, usually restored) to ~40% of
/// scenarios, exercising the partial-slowdown path end to end.
pub fn generate_spec_json_with(
    seed: u64,
    index: usize,
    queries: usize,
    llm: bool,
    degrade: bool,
) -> String {
    let mut rng = Rng::new(mix_seed(seed, index as u64));
    let gpus = 2 + rng.below(3); // 2..=4 keeps per-decision solves cheap
    let cells = if rng.f64() < 0.35 { 2 } else { 1 };
    let batch = ["16", "32"][rng.below(2)];
    // the spec's seed drives the controller; keep it < 2^53 so the
    // JSON number round-trips exactly through the f64 parser
    let spec_seed = mix_seed(seed, index as u64) % 1_000_000;

    // heterogeneous pools (~30%): base 2080ti GPUs plus one faster
    // class, sometimes with an explicit compute_scale (else the parser
    // derives it from the GFLOPS ratio), sometimes MIG-sliced
    let mut cluster = format!("{{\"preset\": \"2080ti\", \"gpus\": {gpus}");
    if rng.f64() < 0.2 {
        cluster.push_str(", \"partition_mode\": \"discrete\"");
    }
    if rng.f64() < 0.3 {
        let fast = pick(&mut rng, &["v100", "a100", "h100"]);
        let fast_n = 1 + rng.below(gpus - 1); // both classes non-empty
        let base_n = gpus - fast_n;
        let _ = write!(
            cluster,
            ", \"gpu_classes\": [{{\"gpu\": \"2080ti\", \"count\": {base_n}}}, {{\"gpu\": \"{fast}\", \"count\": {fast_n}"
        );
        if rng.f64() < 0.5 {
            let scale = pick(&mut rng, &["0.5", "0.6", "0.8"]);
            let _ = write!(cluster, ", \"compute_scale\": {scale}");
        }
        cluster.push_str("}]");
    }
    cluster.push('}');

    let mut json = String::new();
    let _ = write!(
        json,
        "{{\n  \"name\": \"fuzz-{seed}-{index}\",\n  \"cluster\": {cluster},\n  \"batch\": {batch},\n  \"seed\": {spec_seed},\n  \"queries\": {queries},\n  \"cells\": {cells},\n  \"tenants\": ["
    );

    let n_tenants = 2 + rng.below(4); // 2..=5
    for i in 0..n_tenants {
        let pipeline = pick(
            &mut rng,
            &["img-to-img", "img-to-text", "text-to-img", "text-to-text"],
        );
        // `llm &&` short-circuits: with the switch off no extra RNG
        // draw is consumed and the legacy byte stream is preserved
        let workload = if llm && rng.f64() < 0.25 {
            let prompt = pick(&mut rng, &["128", "256", "512", "1024"]);
            let output = pick(&mut rng, &["64", "128", "256"]);
            let kv = pick(&mut rng, &["65536", "131072", "262144"]);
            Some((prompt, output, kv))
        } else {
            None
        };
        let qps = if workload.is_some() {
            5 + rng.below(16) // 5..=20 qps: decode-bound pipelines
        } else {
            20 + rng.below(81) // 20..=100 qps
        };
        let arrive = rng.below(300);
        let lifetime = 200 + rng.below(601); // 200..=800 s
        let departs = rng.f64() < 0.75;

        if let Some((prompt, output, kv)) = workload {
            let _ = write!(
                json,
                "{}\n    {{\"name\": \"t{i}\", \"workload\": \"llm\", \"prompt_tokens\": {prompt}, \"output_tokens\": {output}, \"kv_bytes_per_token\": {kv}, \"plan_qps\": {qps}, \"arrive_s\": {arrive}",
                if i == 0 { "" } else { "," }
            );
        } else {
            let _ = write!(
                json,
                "{}\n    {{\"name\": \"t{i}\", \"pipeline\": \"{pipeline}\", \"plan_qps\": {qps}, \"arrive_s\": {arrive}",
                if i == 0 { "" } else { "," }
            );
        }
        if departs {
            let _ = write!(json, ", \"depart_s\": {}", arrive + lifetime);
        }
        if rng.f64() < 0.5 {
            let period = 20 + rng.below(41);
            let trough = pick(&mut rng, &["0.2", "0.3", "0.4", "0.5", "0.6"]);
            let _ = write!(
                json,
                ", \"arrivals\": \"diurnal\", \"period_s\": {period}, \"trough_frac\": {trough}"
            );
        }
        if rng.f64() < 0.3 {
            json.push_str(", \"priority\": \"best-effort\"");
        }
        if departs && rng.f64() < 0.25 {
            // shrink inside the residency window, to half the load
            let shrink_at = arrive + 1 + rng.below(lifetime - 2);
            let _ = write!(
                json,
                ", \"shrink_to\": {}, \"shrink_at_s\": {shrink_at}",
                qps / 2
            );
        }
        let n_bursts = rng.below(3);
        if n_bursts > 0 {
            json.push_str(", \"bursts\": [");
            for b in 0..n_bursts {
                // at ∈ [arrive, arrive + lifetime) — within the window
                // even when the tenant departs at arrive + lifetime
                let at = arrive + rng.below(lifetime);
                let mult = pick(&mut rng, &["1.5", "2.0", "2.5", "3.0"]);
                let duration = 10 + rng.below(51);
                let _ = write!(
                    json,
                    "{}{{\"at_s\": {at}, \"rate_mult\": {mult}, \"duration_s\": {duration}}}",
                    if b == 0 { "" } else { ", " }
                );
            }
            json.push(']');
        }
        json.push('}');
    }
    json.push_str("\n  ]");

    let n_failures = rng.below(3);
    if n_failures > 0 {
        json.push_str(",\n  \"gpu_failures\": [");
        for f in 0..n_failures {
            let at = 50 + rng.below(500);
            let k = 1 + rng.below(gpus.min(2));
            let mut ids: Vec<usize> = (0..gpus).collect();
            rng.shuffle(&mut ids);
            ids.truncate(k);
            ids.sort_unstable();
            let ids: Vec<String> = ids.iter().map(|g| g.to_string()).collect();
            let _ = write!(
                json,
                "{}\n    {{\"at_s\": {at}, \"gpus\": [{}]",
                if f == 0 { "" } else { "," },
                ids.join(", ")
            );
            if rng.f64() < 0.8 {
                let _ = write!(json, ", \"recover_s\": {}", at + 50 + rng.below(300));
            }
            json.push('}');
        }
        json.push_str("\n  ]");
    }
    // `degrade &&` short-circuits like the llm switch above: with the
    // switch off no RNG draw is consumed and the legacy byte stream is
    // preserved
    if degrade && rng.f64() < 0.4 {
        let at = 50 + rng.below(500);
        let k = 1 + rng.below(gpus.min(2));
        let mut ids: Vec<usize> = (0..gpus).collect();
        rng.shuffle(&mut ids);
        ids.truncate(k);
        ids.sort_unstable();
        let ids: Vec<String> = ids.iter().map(|g| g.to_string()).collect();
        let scale = pick(&mut rng, &["1.25", "1.5", "2.0"]);
        let _ = write!(
            json,
            ",\n  \"gpu_degrades\": [\n    {{\"at_s\": {at}, \"gpus\": [{}], \"scale\": {scale}",
            ids.join(", ")
        );
        if rng.f64() < 0.8 {
            let _ = write!(json, ", \"restore_s\": {}", at + 50 + rng.below(300));
        }
        json.push_str("}\n  ]");
    }
    json.push_str("\n}\n");
    json
}

/// The controller configuration a fuzz replay (and the `camelot admit
/// --spec` reproduction of a dump) runs under: spec-driven seed and
/// batch, plus the `--break-qos` sabotage knobs when requested.
pub fn admission_config(spec: &ScenarioSpec, break_qos: bool) -> AdmissionConfig {
    let mut admission = if break_qos {
        AdmissionConfig {
            qos_headroom: 10.0,
            qos_slack: f64::INFINITY,
            ..Default::default()
        }
    } else {
        AdmissionConfig::default()
    };
    admission.seed = spec.seed;
    admission.batch = spec.batch;
    admission
}

/// Check one generated scenario against invariants (a)–(c), plus (f)
/// when `crash` is set. Returns the number of replay events checked,
/// or the list of `(kind, detail)` problems found.
pub fn check_scenario(
    spec_json: &str,
    break_qos: bool,
    crash: bool,
) -> Result<usize, Vec<(String, String)>> {
    let spec = match ScenarioSpec::parse(spec_json) {
        Ok(spec) => spec,
        Err(e) => {
            return Err(vec![(
                "invalid-spec".into(),
                format!("generator emitted a spec its own parser rejects: {e}"),
            )])
        }
    };
    let trace = spec.trace();
    let admission = admission_config(&spec, break_qos);

    // one replay per thread count; the threads=1 report is the oracle
    // for (a) and (b), the rest must fingerprint-match it for (c)
    let mut problems: Vec<(String, String)> = Vec::new();
    let mut oracle: Option<(Vec<String>, usize)> = None;
    for &threads in &THREAD_MATRIX {
        let rep = if spec.cells > 1 {
            let cfg = CellsReplayConfig {
                router: CellsConfig {
                    cells: spec.cells,
                    admission: admission.clone(),
                    ..Default::default()
                },
                queries: spec.queries,
                threads,
                dedup: true,
                audit_qos: true,
                ..Default::default()
            };
            match replay_trace_cells(&spec.cluster, &trace, &cfg) {
                Ok(rep) => rep.merged,
                Err(e) => {
                    problems.push((
                        "replay-error".into(),
                        format!("cells replay failed at {threads} threads: {e}"),
                    ));
                    continue;
                }
            }
        } else {
            let cfg = ReplayConfig {
                admission: admission.clone(),
                queries: spec.queries,
                threads,
                dedup: true,
                audit_qos: true,
                ..Default::default()
            };
            match replay_trace(&spec.cluster, &trace, &cfg) {
                Ok(rep) => rep,
                Err(e) => {
                    problems.push((
                        "replay-error".into(),
                        format!("flat replay failed at {threads} threads: {e}"),
                    ));
                    continue;
                }
            }
        };
        match &oracle {
            None => {
                // (a) the predicted-QoS audit must be clean
                if let Some(v) = rep.qos_violations.first() {
                    problems.push((
                        "qos-audit".into(),
                        format!(
                            "{} violation(s); first: t={:.0}s {} predicted p99 {:.4}s > target {:.4}s",
                            rep.qos_violations.len(),
                            v.t_s,
                            v.tenant,
                            v.predicted_p99_s,
                            v.target_s
                        ),
                    ));
                }
                // (b) applied re-packs never grow the footprint
                if rep.repack_regressions > 0 {
                    problems.push((
                        "repack-regression".into(),
                        format!(
                            "{} applied re-pack(s) left the fleet on more GPUs than before",
                            rep.repack_regressions
                        ),
                    ));
                }
                // (e) per-GPU resident KV bytes stay under physical
                // memory in every replayed interval (the sim's issue
                // gate must make this hold by construction)
                for (g, &peak) in rep.kv_peak_bytes.iter().enumerate() {
                    let cap = spec.cluster.gpu_at(g).mem_bytes as f64;
                    if peak > cap {
                        problems.push((
                            "kv-overflow".into(),
                            format!(
                                "gpu {g}: peak KV residency {peak:.3e} B exceeds mem_bytes {cap:.3e} B"
                            ),
                        ));
                    }
                }
                oracle = Some((rep.fingerprint(), rep.events.len()));
            }
            Some((fp, _)) => {
                // (c) bit-identical across the thread matrix
                if *fp != rep.fingerprint() {
                    problems.push((
                        "thread-divergence".into(),
                        format!(
                            "replay fingerprint at {threads} threads differs from 1 thread ({} cells)",
                            spec.cells
                        ),
                    ));
                }
            }
        }
    }
    // (f) crash recovery: kill the durable controller at the trace's
    // middle and final event boundaries and require the recovered
    // replay to fingerprint-match the uninterrupted one (single
    // thread, snapshot every 2 events so both the snapshot-restore and
    // the WAL-tail paths are exercised)
    if crash && problems.is_empty() {
        let n = trace_event_list(&trace).len();
        let boundaries = [n / 2, n];
        let res = if spec.cells > 1 {
            let cfg = CellsReplayConfig {
                router: CellsConfig {
                    cells: spec.cells,
                    admission: admission.clone(),
                    ..Default::default()
                },
                queries: spec.queries,
                threads: 1,
                dedup: true,
                audit_qos: false,
                ..Default::default()
            };
            verify_crash_recovery_cells(&spec.cluster, &trace, &cfg, 2, &boundaries, &[])
        } else {
            let cfg = ReplayConfig {
                admission: admission.clone(),
                queries: spec.queries,
                threads: 1,
                dedup: true,
                audit_qos: false,
                ..Default::default()
            };
            verify_crash_recovery(&spec.cluster, &trace, &cfg, 2, &boundaries, &[])
        };
        if let Err(e) = res {
            problems.push(("crash-recovery".into(), e));
        }
    }
    if problems.is_empty() {
        Ok(oracle.map(|(_, events)| events).unwrap_or(0))
    } else {
        Err(problems)
    }
}

fn dump_spec(cfg: &FuzzConfig, index: usize, spec_json: &str) -> Option<PathBuf> {
    let dir = cfg.dump_dir.as_ref()?;
    if std::fs::create_dir_all(dir).is_err() {
        return None;
    }
    let path = dir.join(format!("fuzz-{}-{}.json", cfg.seed, index));
    std::fs::write(&path, spec_json).ok()?;
    Some(path)
}

/// Run the fuzzer: generate `cfg.scenarios` specs, check each against
/// invariants (a)–(c), dump violated specs as replayable JSON (d).
pub fn run_fuzz(cfg: &FuzzConfig) -> Result<FuzzReport, String> {
    if cfg.scenarios == 0 {
        return Err("scenarios must be at least 1".into());
    }
    if cfg.queries == 0 {
        return Err("queries must be at least 1".into());
    }
    let mut report = FuzzReport {
        scenarios: cfg.scenarios,
        seed: cfg.seed,
        events_checked: 0,
        violations: Vec::new(),
    };
    for index in 0..cfg.scenarios {
        let spec_json =
            generate_spec_json_with(cfg.seed, index, cfg.queries, cfg.llm, cfg.degrade);
        match check_scenario(&spec_json, cfg.break_qos, cfg.crash) {
            Ok(events) => report.events_checked += events,
            Err(problems) => {
                let dump_path = dump_spec(cfg, index, &spec_json);
                for (kind, detail) in problems {
                    report.violations.push(FuzzViolation {
                        index,
                        kind,
                        detail,
                        spec_json: spec_json.clone(),
                        dump_path: dump_path.clone(),
                    });
                }
            }
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_seed_reproducible_and_valid() {
        for index in 0..25 {
            let a = generate_spec_json(7, index, 80);
            let b = generate_spec_json(7, index, 80);
            assert_eq!(a, b, "scenario {index} not reproducible");
            let spec = ScenarioSpec::parse(&a)
                .unwrap_or_else(|e| panic!("scenario {index} invalid: {e}\n{a}"));
            assert_eq!(spec.name, format!("fuzz-7-{index}"));
            assert_eq!(spec.queries, 80);
            assert!(!spec.tenants.is_empty());
        }
    }

    #[test]
    fn different_indices_differ() {
        // mix_seed must actually decorrelate scenarios
        let a = generate_spec_json(7, 0, 80);
        let b = generate_spec_json(7, 1, 80);
        assert_ne!(a, b);
    }

    #[test]
    fn generated_population_covers_the_chaos_vocabulary() {
        let (mut bursts, mut failures, mut best_effort, mut diurnal, mut cells) =
            (0, 0, 0, 0, 0);
        let (mut hetero, mut discrete) = (0, 0);
        for index in 0..60 {
            let json = generate_spec_json(11, index, 80);
            let spec = ScenarioSpec::parse(&json).expect("valid spec");
            hetero += usize::from(!spec.cluster.classes.is_empty());
            discrete += usize::from(matches!(
                spec.cluster.partition,
                crate::config::PartitionMode::Discrete(_)
            ));
            if !spec.cluster.classes.is_empty() {
                // generated classes always cover the whole pool
                assert_eq!(
                    spec.cluster.classes.iter().map(|c| c.count).sum::<usize>(),
                    spec.cluster.num_gpus
                );
            }
            bursts += spec.tenants.iter().map(|t| t.bursts.len()).sum::<usize>();
            failures += spec.gpu_failures.len();
            best_effort += spec
                .tenants
                .iter()
                .filter(|t| {
                    t.priority == crate::suite::workload::Priority::BestEffort
                })
                .count();
            diurnal += spec
                .tenants
                .iter()
                .filter(|t| {
                    matches!(
                        t.arrivals,
                        crate::suite::workload::ArrivalProcess::Diurnal { .. }
                    )
                })
                .count();
            cells += usize::from(spec.cells > 1);
        }
        assert!(bursts > 0, "no bursts generated in 60 scenarios");
        assert!(failures > 0, "no GPU failures generated");
        assert!(best_effort > 0, "no best-effort tenants generated");
        assert!(diurnal > 0, "no diurnal arrivals generated");
        assert!(cells > 0, "no multi-cell scenarios generated");
        assert!(hetero > 0, "no mixed gpu_classes pools generated");
        assert!(discrete > 0, "no discrete partition_mode generated");
    }

    #[test]
    fn mixed_pool_scenarios_replay_without_violations() {
        // a small targeted sweep: the first few generated specs with
        // gpu_classes must clear invariants (a)-(c) like any other
        let mut checked = 0;
        for index in 0..40 {
            if checked >= 2 {
                break; // two full thread-matrix replays keep this brisk
            }
            let json = generate_spec_json(11, index, 60);
            let spec = ScenarioSpec::parse(&json).expect("valid spec");
            if spec.cluster.classes.is_empty() {
                continue;
            }
            checked += 1;
            if let Err(problems) = check_scenario(&json, false, false) {
                panic!("mixed-pool scenario {index} violated: {problems:?}\n{json}");
            }
        }
        assert!(checked > 0, "no mixed-pool scenario in the first 40");
    }

    #[test]
    fn llm_switch_off_preserves_legacy_generation() {
        // the llm=false path must consume the exact legacy RNG stream
        for index in 0..25 {
            assert_eq!(
                generate_spec_json(7, index, 80),
                generate_spec_json_with(7, index, 80, false, false),
                "scenario {index} diverged with llm off"
            );
        }
    }

    #[test]
    fn llm_population_mixes_workloads_and_stays_valid() {
        let mut llm_tenants = 0;
        let mut vision_tenants = 0;
        for index in 0..40 {
            let json = generate_spec_json_with(11, index, 80, true, false);
            let spec = ScenarioSpec::parse(&json)
                .unwrap_or_else(|e| panic!("scenario {index} invalid: {e}\n{json}"));
            for t in &spec.tenants {
                if t.pipeline.starts_with("llm:") {
                    llm_tenants += 1;
                } else {
                    vision_tenants += 1;
                }
            }
        }
        assert!(llm_tenants > 0, "no LLM tenants in 40 llm-enabled scenarios");
        assert!(vision_tenants > 0, "LLM mix crowded out the vision tenants");
    }

    #[test]
    fn llm_scenarios_replay_without_violations() {
        // the first generated scenario containing an LLM tenant must
        // clear invariants (a)-(e) through the full thread matrix
        let mut checked = 0;
        for index in 0..40 {
            if checked >= 2 {
                break;
            }
            let json = generate_spec_json_with(11, index, 60, true, false);
            let spec = ScenarioSpec::parse(&json).expect("valid spec");
            if !spec.tenants.iter().any(|t| t.pipeline.starts_with("llm:")) {
                continue;
            }
            checked += 1;
            if let Err(problems) = check_scenario(&json, false, false) {
                panic!("llm scenario {index} violated: {problems:?}\n{json}");
            }
        }
        assert!(checked > 0, "no LLM scenario in the first 40");
    }

    #[test]
    fn degrade_switch_off_preserves_legacy_generation() {
        // the degrade=false path must consume the exact legacy RNG
        // stream
        for index in 0..25 {
            assert_eq!(
                generate_spec_json(7, index, 80),
                generate_spec_json_with(7, index, 80, false, false),
                "scenario {index} diverged with degrade off"
            );
        }
    }

    #[test]
    fn degrade_population_parses_and_replays_cleanly() {
        // the first generated scenario with a gpu_degrades window must
        // clear invariants (a)-(c) like any other
        let mut with_degrade = 0;
        for index in 0..40 {
            let json = generate_spec_json_with(11, index, 60, false, true);
            let spec = ScenarioSpec::parse(&json)
                .unwrap_or_else(|e| panic!("scenario {index} invalid: {e}\n{json}"));
            if spec.gpu_degrades.is_empty() {
                continue;
            }
            with_degrade += 1;
            if with_degrade > 1 {
                break; // one full thread-matrix replay keeps this brisk
            }
            if let Err(problems) = check_scenario(&json, false, false) {
                panic!("degrade scenario {index} violated: {problems:?}\n{json}");
            }
        }
        assert!(with_degrade > 0, "no gpu_degrades window in the first 40");
    }

    #[test]
    fn crash_invariant_holds_on_first_scenarios() {
        // invariant (f) end to end: durable replay, kill at middle and
        // final boundaries, recover, fingerprint-match
        for index in 0..2 {
            let json = generate_spec_json(7, index, 60);
            if let Err(problems) = check_scenario(&json, false, true) {
                panic!("crash recovery violated on scenario {index}: {problems:?}\n{json}");
            }
        }
    }

    #[test]
    fn rejects_degenerate_configs() {
        assert!(run_fuzz(&FuzzConfig { scenarios: 0, ..Default::default() }).is_err());
        assert!(run_fuzz(&FuzzConfig { queries: 0, ..Default::default() }).is_err());
    }
}
