//! The artifact benchmark family (§III-B, §VIII-E): compute-intensive
//! `c1..c3`, memory-intensive `m1..m3`, and PCIe-intensive `p1..p3`
//! microservices, composable into the 27 three-stage pipelines
//! `p_i + c_j + m_k` the paper evaluates in Figs 18, 20, 21.
//!
//! Intensities scale by powers of two, matching Fig 3's c1<c2<c3 and
//! m1<m2<m3 ordering (i > j ⇒ more intensive).

use super::service::{Pipeline, StageKind, StageProfile};

const KB: f64 = 1e3;
const MB: f64 = 1e6;

/// Compute-intensive artifact microservice `c<level>` (level 1..=3).
pub fn compute(level: u32) -> StageProfile {
    assert!((1..=3).contains(&level));
    let scale = (1u32 << (level - 1)) as f64; // 1, 2, 4
    StageProfile {
        name: format!("c{level}"),
        kind: StageKind::Compute,
        flops_per_query: 3.0e9 * scale,
        hbm_bytes_per_query: 60.0 * MB,
        model_bytes: 180.0 * MB,
        act_bytes_per_query: 6.0 * MB,
        in_bytes_per_query: 64.0 * KB,
        out_bytes_per_query: 64.0 * KB,
        serial_frac: 0.05,
        batch_half: 16.0,
        mem_bytes_per_query: 0.0,
    }
}

/// Memory-bandwidth-intensive artifact microservice `m<level>`.
pub fn memory(level: u32) -> StageProfile {
    assert!((1..=3).contains(&level));
    let scale = (1u32 << (level - 1)) as f64;
    StageProfile {
        name: format!("m{level}"),
        kind: StageKind::Memory,
        flops_per_query: 0.4e9,
        hbm_bytes_per_query: 220.0 * MB * scale,
        model_bytes: 120.0 * MB,
        act_bytes_per_query: 10.0 * MB,
        in_bytes_per_query: 64.0 * KB,
        out_bytes_per_query: 32.0 * KB,
        serial_frac: 0.10,
        batch_half: 16.0,
        mem_bytes_per_query: 0.0,
    }
}

/// PCIe-intensive artifact microservice `p<level>` (large input uploads).
pub fn pcie(level: u32) -> StageProfile {
    assert!((1..=3).contains(&level));
    let scale = (1u32 << (level - 1)) as f64;
    StageProfile {
        name: format!("p{level}"),
        kind: StageKind::Pcie,
        flops_per_query: 0.5e9,
        hbm_bytes_per_query: 40.0 * MB,
        model_bytes: 90.0 * MB,
        act_bytes_per_query: 4.0 * MB,
        in_bytes_per_query: 1.0 * MB * scale,
        out_bytes_per_query: 64.0 * KB,
        serial_frac: 0.08,
        batch_half: 16.0,
        mem_bytes_per_query: 0.0,
    }
}

/// One synthetic three-stage pipeline `p_i + c_j + m_k` (paper naming).
pub fn pipeline(pi: u32, cj: u32, mk: u32) -> Pipeline {
    let mut p_stage = pcie(pi);
    let mut c_stage = compute(cj);
    let m_stage = memory(mk);
    // chain payload sizes so the pipeline validates
    p_stage.out_bytes_per_query = 64.0 * KB;
    c_stage.in_bytes_per_query = 64.0 * KB;
    Pipeline {
        name: format!("p{pi}+c{cj}+m{mk}"),
        stages: vec![p_stage, c_stage, m_stage],
        qos_target_s: 0.300,
    }
}

/// The 27 composite benchmarks, in the paper's enumeration order.
pub fn all27() -> Vec<Pipeline> {
    let mut out = Vec::with_capacity(27);
    for pi in 1..=3 {
        for cj in 1..=3 {
            for mk in 1..=3 {
                out.push(pipeline(pi, cj, mk));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intensity_ordering() {
        assert!(compute(3).flops_per_query > compute(2).flops_per_query);
        assert!(compute(2).flops_per_query > compute(1).flops_per_query);
        assert!(memory(3).hbm_bytes_per_query > memory(1).hbm_bytes_per_query);
        assert!(pcie(3).in_bytes_per_query > pcie(1).in_bytes_per_query);
    }

    #[test]
    fn twenty_seven_valid_pipelines() {
        let ps = all27();
        assert_eq!(ps.len(), 27);
        let mut names = std::collections::HashSet::new();
        for p in &ps {
            p.validate().unwrap_or_else(|e| panic!("{e}"));
            assert_eq!(p.n_stages(), 3);
            assert!(names.insert(p.name.clone()), "duplicate {}", p.name);
        }
        assert_eq!(ps[0].name, "p1+c1+m1");
        assert_eq!(ps[26].name, "p3+c3+m3");
    }

    #[test]
    fn kinds_are_distinguishable_on_roofline() {
        assert!(compute(1).arithmetic_intensity() > 10.0 * memory(1).arithmetic_intensity());
    }

    #[test]
    #[should_panic]
    fn rejects_level_zero() {
        compute(0);
    }
}
