//! Workload generation: open-loop Poisson arrivals (the datacenter
//! measurement protocol), diurnal load shaping (Google's pattern, [1] in
//! the paper), and the peak-load ramp search used by every "supported
//! peak load" figure.

use crate::util::Rng;

/// Open-loop Poisson arrival process at `rate` queries/second.
#[derive(Debug, Clone)]
pub struct PoissonArrivals {
    rate: f64,
    next: f64,
    rng: Rng,
}

impl PoissonArrivals {
    pub fn new(rate_qps: f64, seed: u64) -> Self {
        assert!(rate_qps > 0.0, "rate must be positive");
        let mut rng = Rng::new(seed);
        let first = rng.exponential(rate_qps);
        PoissonArrivals { rate: rate_qps, next: first, rng }
    }

    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Generate all arrival timestamps in `[0, horizon_s)`.
    pub fn times_until(&mut self, horizon_s: f64) -> Vec<f64> {
        let mut out = Vec::with_capacity((self.rate * horizon_s) as usize + 8);
        while self.next < horizon_s {
            out.push(self.next);
            self.next += self.rng.exponential(self.rate);
        }
        out
    }
}

/// Diurnal modulation: scales a base rate by a day-shaped curve,
/// min at `trough` (default 0.3 — the paper's "low load" operating
/// point), max 1.0 at midday.
#[derive(Debug, Clone)]
pub struct DiurnalPattern {
    pub peak_qps: f64,
    pub trough_frac: f64,
    pub period_s: f64,
}

impl DiurnalPattern {
    pub fn new(peak_qps: f64) -> Self {
        DiurnalPattern { peak_qps, trough_frac: 0.3, period_s: 86_400.0 }
    }

    /// Instantaneous rate at time `t` (sinusoid between trough and peak).
    pub fn rate_at(&self, t_s: f64) -> f64 {
        let phase = (2.0 * std::f64::consts::PI * t_s / self.period_s).cos();
        let lo = self.trough_frac * self.peak_qps;
        // cos=1 at t=0 → treat t=0 as midnight trough
        lo + (self.peak_qps - lo) * 0.5 * (1.0 - phase)
    }
}

/// Result of a single load trial.
#[derive(Debug, Clone, Copy)]
pub struct LoadTrial {
    pub rate_qps: f64,
    pub p99_s: f64,
    pub qos_met: bool,
}

/// Binary-search the peak supported load: the highest arrival rate whose
/// p99 stays within QoS, per the paper's measurement protocol
/// ("gradually increase the load of each benchmark until its 99%-ile
/// latency achieves the QoS target").
///
/// `eval(rate) -> p99 seconds`. Returns (peak_qps, trials).
pub fn peak_load_search<F>(
    mut eval: F,
    qos_s: f64,
    hi_start: f64,
    rel_tol: f64,
) -> (f64, Vec<LoadTrial>)
where
    F: FnMut(f64) -> f64,
{
    assert!(qos_s > 0.0 && hi_start > 0.0);
    let mut trials = Vec::new();
    let mut check = |rate: f64, trials: &mut Vec<LoadTrial>| -> bool {
        let p99 = eval(rate);
        let ok = p99 <= qos_s;
        trials.push(LoadTrial { rate_qps: rate, p99_s: p99, qos_met: ok });
        ok
    };

    // grow until infeasible
    let mut lo = 0.0;
    let mut hi = hi_start;
    let mut grow_budget = 24;
    while check(hi, &mut trials) {
        lo = hi;
        hi *= 2.0;
        grow_budget -= 1;
        if grow_budget == 0 {
            return (lo, trials); // effectively unbounded on this testbed
        }
    }
    if lo == 0.0 {
        // even hi_start violates: shrink to find any feasible point
        let mut probe = hi_start / 2.0;
        let mut budget = 24;
        while probe > 1e-3 && !check(probe, &mut trials) {
            probe /= 2.0;
            budget -= 1;
            if budget == 0 {
                return (0.0, trials);
            }
        }
        if probe <= 1e-3 {
            return (0.0, trials);
        }
        lo = probe;
    }
    // bisect
    while (hi - lo) / hi.max(1e-9) > rel_tol {
        let mid = 0.5 * (lo + hi);
        if check(mid, &mut trials) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    (lo, trials)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit;

    #[test]
    fn poisson_rate_matches() {
        let mut gen = PoissonArrivals::new(100.0, 7);
        let times = gen.times_until(200.0);
        testkit::assert_close(times.len() as f64, 20_000.0, 0.03, 0.0);
        // strictly increasing
        assert!(times.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn poisson_deterministic_per_seed() {
        let a = PoissonArrivals::new(50.0, 3).times_until(10.0);
        let b = PoissonArrivals::new(50.0, 3).times_until(10.0);
        assert_eq!(a, b);
    }

    #[test]
    fn diurnal_bounds() {
        let d = DiurnalPattern::new(1000.0);
        let mut lo = f64::INFINITY;
        let mut hi = 0.0f64;
        for i in 0..100 {
            let r = d.rate_at(i as f64 * 864.0);
            lo = lo.min(r);
            hi = hi.max(r);
        }
        testkit::assert_close(lo, 300.0, 0.01, 0.0);
        testkit::assert_close(hi, 1000.0, 0.01, 0.0);
    }

    #[test]
    fn peak_search_finds_threshold() {
        // synthetic system: p99 = rate/100 seconds; QoS 1 s ⇒ peak = 100
        let (peak, trials) =
            peak_load_search(|r| r / 100.0, 1.0, 10.0, 0.01);
        testkit::assert_close(peak, 100.0, 0.02, 0.0);
        assert!(!trials.is_empty());
    }

    #[test]
    fn peak_search_handles_infeasible_start() {
        // p99 = rate (QoS 0.5) with hi_start way past peak
        let (peak, _) = peak_load_search(|r| r, 0.5, 64.0, 0.02);
        testkit::assert_close(peak, 0.5, 0.05, 0.0);
    }

    #[test]
    fn peak_search_zero_when_nothing_feasible() {
        let (peak, _) = peak_load_search(|_| 10.0, 0.5, 8.0, 0.02);
        assert_eq!(peak, 0.0);
    }
}
