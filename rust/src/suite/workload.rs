//! Workload generation: open-loop Poisson arrivals (the datacenter
//! measurement protocol), diurnal load shaping (Google's pattern, [1] in
//! the paper), non-homogeneous arrivals over the diurnal curve
//! (Lewis–Shedler thinning, for the co-location simulator), and the
//! peak-load ramp search used by every "supported peak load" figure.

use crate::util::Rng;

/// Open-loop Poisson arrival process at `rate` queries/second.
#[derive(Debug, Clone)]
pub struct PoissonArrivals {
    rate: f64,
    next: f64,
    rng: Rng,
}

impl PoissonArrivals {
    pub fn new(rate_qps: f64, seed: u64) -> Self {
        assert!(rate_qps > 0.0, "rate must be positive");
        let mut rng = Rng::new(seed);
        let first = rng.exponential(rate_qps);
        PoissonArrivals { rate: rate_qps, next: first, rng }
    }

    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Generate all arrival timestamps in `[0, horizon_s)`.
    pub fn times_until(&mut self, horizon_s: f64) -> Vec<f64> {
        let mut out = Vec::with_capacity((self.rate * horizon_s) as usize + 8);
        while self.next < horizon_s {
            out.push(self.next);
            self.next += self.rng.exponential(self.rate);
        }
        out
    }

    /// Pop the next arrival timestamp, advancing the stream. The
    /// sequence is identical to what [`times_until`](Self::times_until)
    /// materializes — this is the lazy form the event engine uses so it
    /// never has to guess a horizon and retry.
    #[inline]
    pub fn next_time(&mut self) -> f64 {
        let t = self.next;
        self.next += self.rng.exponential(self.rate);
        t
    }

    /// Generate exactly `n` arrival timestamps (the first `n` of the
    /// stream, bit-identical to a sufficient-horizon `times_until`
    /// truncated to `n`).
    pub fn take_times(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.next_time()).collect()
    }
}

/// Diurnal modulation: scales a base rate by a day-shaped curve,
/// min at `trough` (default 0.3 — the paper's "low load" operating
/// point), max 1.0 at midday.
#[derive(Debug, Clone)]
pub struct DiurnalPattern {
    pub peak_qps: f64,
    pub trough_frac: f64,
    pub period_s: f64,
}

impl DiurnalPattern {
    pub fn new(peak_qps: f64) -> Self {
        DiurnalPattern { peak_qps, trough_frac: 0.3, period_s: 86_400.0 }
    }

    /// Instantaneous rate at time `t` (sinusoid between trough and peak).
    pub fn rate_at(&self, t_s: f64) -> f64 {
        let phase = (2.0 * std::f64::consts::PI * t_s / self.period_s).cos();
        let lo = self.trough_frac * self.peak_qps;
        // cos=1 at t=0 → treat t=0 as midnight trough
        lo + (self.peak_qps - lo) * 0.5 * (1.0 - phase)
    }

    /// Time-averaged rate over a whole period: the sinusoid spends half
    /// its excursion above the midpoint, so the mean is (trough+peak)/2.
    pub fn mean_qps(&self) -> f64 {
        0.5 * (self.trough_frac * self.peak_qps + self.peak_qps)
    }

    /// Same day shape with every instantaneous rate scaled by `k`
    /// (trough fraction and period unchanged).
    pub fn scaled(&self, k: f64) -> DiurnalPattern {
        DiurnalPattern { peak_qps: self.peak_qps * k, ..*self }
    }
}

/// Non-homogeneous Poisson arrivals over a [`DiurnalPattern`], generated
/// by Lewis–Shedler thinning: candidates stream from a homogeneous
/// process at a dominating rate `λ_max ≥ max_t rate_at(t)` and survive
/// with probability `rate_at(t)/λ_max`.
///
/// Determinism contract: every candidate consumes exactly two RNG draws
/// (one exponential, one uniform) whether or not it survives, so two
/// streams built with the same seed and the *same dominating rate* see
/// identical candidate times and acceptance draws. Pointwise-larger
/// patterns (under a shared dominating rate) therefore accept a
/// superset of arrivals — per-seed monotonicity in rate scale, which
/// `tests/golden_engine.rs` pins.
#[derive(Debug, Clone)]
pub struct NonHomogeneousArrivals {
    pattern: DiurnalPattern,
    dominating_qps: f64,
    t: f64,
    /// Accepted arrival drawn past a [`times_until`](Self::times_until)
    /// horizon, buffered so windowed and lazy access interleave without
    /// losing it (mirrors `PoissonArrivals` keeping its overshoot in
    /// `next`).
    pending: Option<f64>,
    rng: Rng,
}

impl NonHomogeneousArrivals {
    /// Thin at the pattern's own peak (the tight dominating rate).
    pub fn new(pattern: DiurnalPattern, seed: u64) -> Self {
        let dominating_qps = pattern.peak_qps;
        Self::with_dominating_rate(pattern, dominating_qps, seed)
    }

    /// Thin at an explicit dominating rate — share it across streams to
    /// couple them (the monotonicity property above).
    pub fn with_dominating_rate(
        pattern: DiurnalPattern,
        dominating_qps: f64,
        seed: u64,
    ) -> Self {
        assert!(
            dominating_qps > 0.0 && dominating_qps >= pattern.peak_qps * (1.0 - 1e-12),
            "dominating rate {dominating_qps} must cover the pattern peak {}",
            pattern.peak_qps
        );
        NonHomogeneousArrivals {
            pattern,
            dominating_qps,
            t: 0.0,
            pending: None,
            rng: Rng::new(seed),
        }
    }

    /// Pop the next arrival timestamp, advancing the stream (lazy form,
    /// mirrors [`PoissonArrivals::next_time`]).
    pub fn next_time(&mut self) -> f64 {
        if let Some(t) = self.pending.take() {
            return t;
        }
        loop {
            self.t += self.rng.exponential(self.dominating_qps);
            let u = self.rng.f64();
            if u * self.dominating_qps <= self.pattern.rate_at(self.t) {
                return self.t;
            }
        }
    }

    /// Generate exactly `n` arrival timestamps.
    pub fn take_times(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.next_time()).collect()
    }

    /// Generate all arrival timestamps in `[0, horizon_s)`. The first
    /// accepted arrival past the horizon stays buffered, so follow-up
    /// windows (or [`next_time`](Self::next_time) calls) see the exact
    /// continuation of the stream — same contract as
    /// [`PoissonArrivals::times_until`].
    pub fn times_until(&mut self, horizon_s: f64) -> Vec<f64> {
        let mut out = Vec::new();
        loop {
            let t = self.next_time();
            if t >= horizon_s {
                self.pending = Some(t);
                return out;
            }
            out.push(t);
        }
    }
}

/// A tenant's offered-load model for the cluster simulator: either the
/// classic constant-rate Poisson stream or a diurnally modulated
/// non-homogeneous one. Rates are in *queries*/s; the engine divides by
/// the tenant's batch to get the request-granular stream.
#[derive(Debug, Clone)]
pub enum ArrivalProcess {
    Constant { rate_qps: f64 },
    Diurnal { pattern: DiurnalPattern },
}

/// A materialized request-granular arrival stream (one request = `batch`
/// queries), lazily poppable by the event engine.
#[derive(Debug, Clone)]
pub enum ArrivalStream {
    Poisson(PoissonArrivals),
    NonHomogeneous(NonHomogeneousArrivals),
}

impl ArrivalStream {
    #[inline]
    pub fn next_time(&mut self) -> f64 {
        match self {
            ArrivalStream::Poisson(s) => s.next_time(),
            ArrivalStream::NonHomogeneous(s) => s.next_time(),
        }
    }
}

impl ArrivalProcess {
    pub fn constant(rate_qps: f64) -> Self {
        ArrivalProcess::Constant { rate_qps }
    }

    pub fn diurnal(pattern: DiurnalPattern) -> Self {
        ArrivalProcess::Diurnal { pattern }
    }

    /// Highest instantaneous query rate the process ever offers.
    pub fn peak_qps(&self) -> f64 {
        match self {
            ArrivalProcess::Constant { rate_qps } => *rate_qps,
            ArrivalProcess::Diurnal { pattern } => pattern.peak_qps,
        }
    }

    /// Long-run average query rate (what `SimReport::offered_qps`
    /// reports for the tenant).
    pub fn mean_qps(&self) -> f64 {
        match self {
            ArrivalProcess::Constant { rate_qps } => *rate_qps,
            ArrivalProcess::Diurnal { pattern } => pattern.mean_qps(),
        }
    }

    /// The same process shape re-pinned to a new peak rate (constant
    /// rate replaced, diurnal pattern re-peaked with its shape kept) —
    /// what a resident-shrink re-admission does to the tenant's offered
    /// load model.
    pub fn scaled_to_peak(&self, peak_qps: f64) -> ArrivalProcess {
        assert!(peak_qps > 0.0, "peak must be positive");
        match self {
            ArrivalProcess::Constant { .. } => ArrivalProcess::Constant { rate_qps: peak_qps },
            ArrivalProcess::Diurnal { pattern } => ArrivalProcess::Diurnal {
                pattern: DiurnalPattern { peak_qps, ..*pattern },
            },
        }
    }

    /// Build the request-granular stream for a tenant with the given
    /// batch size. The constant case is bit-identical to the stream
    /// `Simulator::run` draws for `offered_qps = rate_qps` at the same
    /// seed — the degenerate-equivalence golden test depends on this.
    pub fn request_stream(&self, batch: u32, seed: u64) -> ArrivalStream {
        let b = batch.max(1) as f64;
        match self {
            ArrivalProcess::Constant { rate_qps } => {
                ArrivalStream::Poisson(PoissonArrivals::new(rate_qps / b, seed))
            }
            ArrivalProcess::Diurnal { pattern } => ArrivalStream::NonHomogeneous(
                NonHomogeneousArrivals::new(pattern.scaled(1.0 / b), seed),
            ),
        }
    }
}

/// A tenant's service tier: whether admission may evict it to make room
/// for someone else.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Priority {
    /// QoS-guaranteed; never preempted once admitted.
    LatencyCritical,
    /// Opportunistic; evictable when a latency-critical arrival would
    /// otherwise be rejected.
    BestEffort,
}

impl Default for Priority {
    fn default() -> Self {
        Priority::LatencyCritical
    }
}

/// What a tenant does at one point of a [`TenantTrace`].
#[derive(Debug, Clone)]
pub enum TraceEventKind {
    /// A tenant arrives asking for admission: a pipeline (resolvable by
    /// [`crate::suite::pipeline_by_name`]), an offered-load model while
    /// resident, and the load the admission controller must plan for
    /// (the arrival process's instantaneous peak).
    Arrive {
        pipeline: String,
        /// Display name for decision logs; `None` synthesizes
        /// `"<pipeline>#<tenant>"` (what generated traces use).
        name: Option<String>,
        arrivals: ArrivalProcess,
        plan_qps: f64,
        /// Service tier: latency-critical arrivals may preempt resident
        /// best-effort tenants when they would otherwise be rejected.
        priority: Priority,
    },
    /// The tenant leaves; its capacity can be re-packed.
    Depart,
    /// The tenant's offered load fell and it asks to be re-admitted at
    /// a smaller plan (`coordinator::admission` shrinks the resident via
    /// `planner::Objective::Shrink`, freeing the difference).
    Shrink { target_qps: f64 },
    /// Flash crowd: the tenant's *offered* load is multiplied by
    /// `rate_mult` for `duration_s` seconds (the admitted plan is
    /// untouched — bursts stress the measured latency, not the planner).
    /// Replay synthesizes the matching [`BurstEnd`](Self::BurstEnd) at
    /// `t_s + duration_s` via [`TenantTrace::expanded_events`]. Bursts
    /// nest: the rate restores to the pre-burst base only when the last
    /// open burst ends. Correlated multi-tenant bursts are just several
    /// `Burst` events sharing one `t_s`.
    Burst { rate_mult: f64, duration_s: f64 },
    /// End of a flash crowd (synthesized; not part of the declarative
    /// vocabulary).
    BurstEnd,
    /// The listed GPUs fail: residents with instances on them are
    /// displaced and re-packed onto the surviving fleet (evicted when
    /// nothing fits), and the GPUs stay masked out of placement until a
    /// matching [`GpuRecover`](Self::GpuRecover). The `tenant` id on
    /// these events is ignored (use 0 by convention).
    GpuFail { gpu_ids: Vec<usize> },
    /// The listed GPUs return to service; a normal churn-gated re-pack
    /// may spread residents back onto them.
    GpuRecover { gpu_ids: Vec<usize> },
    /// The listed GPUs *partially* degrade (ECC row retirement, thermal
    /// throttling): each keeps serving but at `scale` × its healthy
    /// service time (`scale` > 1.0 — the multiplier lands on
    /// [`ClusterSpec::scale_at`](crate::config::ClusterSpec::scale_at)
    /// and flows through the QoS gate and the interval simulations).
    /// Unlike [`GpuFail`](Self::GpuFail), placements stay: the
    /// controller sheds residents only if the slowdown breaks their
    /// predicted QoS. The `tenant` id is ignored (use 0 by convention).
    GpuDegrade { gpu_ids: Vec<usize>, scale: f64 },
    /// The listed GPUs return to full speed; a normal churn-gated
    /// re-pack may follow.
    GpuRestore { gpu_ids: Vec<usize> },
}

/// One arrival or departure of a tenant trace.
#[derive(Debug, Clone)]
pub struct TenantTraceEvent {
    pub t_s: f64,
    /// Trace-unique tenant id; arrival and departure share it.
    pub tenant: u64,
    pub kind: TraceEventKind,
}

/// Knobs of the seed-reproducible tenant arrival/departure generator.
#[derive(Debug, Clone)]
pub struct TenantTraceConfig {
    /// Tenant arrivals to draw (each gets a matching departure).
    pub tenants: usize,
    /// Mean gap between tenant arrivals (exponential).
    pub mean_interarrival_s: f64,
    /// Mean residency before departure (exponential).
    pub mean_lifetime_s: f64,
    /// Diurnal peak of each tenant, uniform in `[peak_qps_lo, peak_qps_hi]`.
    pub peak_qps_lo: f64,
    pub peak_qps_hi: f64,
    /// Period of each tenant's diurnal arrival process (compressed so a
    /// fixed query budget spans several periods, as in `colocate`).
    pub period_s: f64,
    /// Pipeline names drawn uniformly per tenant.
    pub catalog: Vec<String>,
}

impl Default for TenantTraceConfig {
    fn default() -> Self {
        TenantTraceConfig {
            tenants: 8,
            mean_interarrival_s: 600.0,
            mean_lifetime_s: 2_400.0,
            peak_qps_lo: 60.0,
            peak_qps_hi: 180.0,
            period_s: 30.0,
            catalog: vec![
                "img-to-img".into(),
                "img-to-text".into(),
                "text-to-img".into(),
                "text-to-text".into(),
            ],
        }
    }
}

/// A time-ordered tenant arrival/departure trace: the input the
/// N-tenant admission controller (`coordinator::admission`) replays.
///
/// Determinism contract: [`generate`](Self::generate) draws a fixed
/// number of RNG values per tenant (one inter-arrival gap, one
/// lifetime, one peak, one catalog pick) from a single seeded stream,
/// so the same `(config, seed)` always yields the identical event list,
/// and the sort breaks time ties by `(tenant, departure-first)` — the
/// trace is bit-reproducible.
#[derive(Debug, Clone)]
pub struct TenantTrace {
    pub events: Vec<TenantTraceEvent>,
}

impl TenantTrace {
    /// Draw a seed-reproducible trace.
    pub fn generate(cfg: &TenantTraceConfig, seed: u64) -> TenantTrace {
        assert!(cfg.tenants > 0, "trace needs at least one tenant");
        assert!(!cfg.catalog.is_empty(), "trace needs a pipeline catalog");
        assert!(cfg.mean_interarrival_s > 0.0 && cfg.mean_lifetime_s > 0.0);
        assert!(cfg.peak_qps_lo > 0.0 && cfg.peak_qps_hi >= cfg.peak_qps_lo);
        let mut rng = Rng::new(seed);
        let mut events = Vec::with_capacity(cfg.tenants * 2);
        let mut t = 0.0;
        for tenant in 0..cfg.tenants as u64 {
            t += rng.exponential(1.0 / cfg.mean_interarrival_s);
            let lifetime = rng.exponential(1.0 / cfg.mean_lifetime_s);
            let peak = rng.range_f64(cfg.peak_qps_lo, cfg.peak_qps_hi);
            let pipeline = rng.choose(&cfg.catalog).clone();
            let pattern = DiurnalPattern {
                peak_qps: peak,
                trough_frac: 0.3,
                period_s: cfg.period_s,
            };
            events.push(TenantTraceEvent {
                t_s: t,
                tenant,
                kind: TraceEventKind::Arrive {
                    pipeline,
                    name: None,
                    arrivals: ArrivalProcess::diurnal(pattern),
                    plan_qps: peak,
                    priority: Priority::LatencyCritical,
                },
            });
            events.push(TenantTraceEvent {
                t_s: t + lifetime,
                tenant,
                kind: TraceEventKind::Depart,
            });
        }
        // departures first at equal times (free capacity before the next
        // admission decision), then tenant id — a total, stable order
        Self::sort_events(&mut events);
        TenantTrace { events }
    }

    /// A small repeated-configuration admission trace: one long-lived
    /// constant-rate resident plus arrive/shrink/depart cycles of an
    /// identical second tenant. This is the canonical workload for the
    /// memoized control loop — the same configurations recur, which is
    /// exactly what the planner solve cache and the replay's interval
    /// dedup exploit (diurnal traffic looks like this). Shared by the
    /// golden suite (`tests/control_loop_cache.rs`) and
    /// `benches/bench_admission.rs` so the benched workload is the
    /// golden-gated one.
    pub fn repeated_cycle() -> TenantTrace {
        let mk = |t_s: f64, tenant: u64, kind: TraceEventKind| TenantTraceEvent {
            t_s,
            tenant,
            kind,
        };
        let arrive = |pipeline: &str, qps: f64| TraceEventKind::Arrive {
            pipeline: pipeline.into(),
            name: None,
            arrivals: ArrivalProcess::constant(qps),
            plan_qps: qps,
            priority: Priority::LatencyCritical,
        };
        TenantTrace {
            events: vec![
                mk(0.0, 0, arrive("img-to-text", 100.0)),
                mk(10.0, 1, arrive("text-to-text", 70.0)),
                mk(20.0, 1, TraceEventKind::Depart),
                mk(30.0, 2, arrive("text-to-text", 70.0)),
                mk(40.0, 2, TraceEventKind::Shrink { target_qps: 40.0 }),
                mk(50.0, 2, TraceEventKind::Depart),
                mk(60.0, 3, arrive("text-to-text", 70.0)),
                mk(70.0, 3, TraceEventKind::Depart),
                mk(80.0, 4, arrive("text-to-text", 70.0)),
                mk(90.0, 4, TraceEventKind::Depart),
            ],
        }
    }

    /// The canonical event order: time, then capacity-freeing events
    /// first at equal times (departures, then shrinks, then arrivals),
    /// then tenant id — a total, stable order shared with
    /// [`crate::planner::ScenarioSpec`]-built traces.
    pub fn sort_events(events: &mut [TenantTraceEvent]) {
        events.sort_by(|a, b| {
            a.t_s
                .partial_cmp(&b.t_s)
                .unwrap()
                .then_with(|| {
                    // new chaos kinds interleave with the legacy ranks
                    // (Depart=0, Shrink=1→2, Arrive=2→4) without
                    // reordering any legacy-only trace: capacity comes
                    // back first (recover), rates restore before new
                    // demand lands (burst-end before arrive), and
                    // capacity is torn down last (fail after arrivals)
                    let rank = |k: &TraceEventKind| match k {
                        TraceEventKind::Depart => 0u8,
                        TraceEventKind::GpuRecover { .. } => 1,
                        TraceEventKind::GpuRestore { .. } => 2,
                        TraceEventKind::Shrink { .. } => 3,
                        TraceEventKind::BurstEnd => 4,
                        TraceEventKind::Arrive { .. } => 5,
                        TraceEventKind::Burst { .. } => 6,
                        TraceEventKind::GpuDegrade { .. } => 7,
                        TraceEventKind::GpuFail { .. } => 8,
                    };
                    rank(&a.kind).cmp(&rank(&b.kind))
                })
                .then(a.tenant.cmp(&b.tenant))
        });
    }

    /// Whether any event is a [`TraceEventKind::Burst`] — replay paths
    /// only pay for [`expanded_events`](Self::expanded_events) (a clone
    /// plus re-sort) when this holds, so hand-built burst-free traces
    /// replay their event list verbatim, in the exact order given.
    pub fn has_bursts(&self) -> bool {
        self.events.iter().any(|e| matches!(e.kind, TraceEventKind::Burst { .. }))
    }

    /// The event list with a synthesized [`TraceEventKind::BurstEnd`]
    /// appended at `t_s + duration_s` for every burst, re-sorted into
    /// the canonical order. This is what the replay loops walk when
    /// [`has_bursts`](Self::has_bursts) — burst windows close without
    /// the trace author writing end events.
    pub fn expanded_events(&self) -> Vec<TenantTraceEvent> {
        let mut events = self.events.clone();
        for e in &self.events {
            if let TraceEventKind::Burst { duration_s, .. } = e.kind {
                events.push(TenantTraceEvent {
                    t_s: e.t_s + duration_s,
                    tenant: e.tenant,
                    kind: TraceEventKind::BurstEnd,
                });
            }
        }
        Self::sort_events(&mut events);
        events
    }

    /// Highest number of tenants ever resident at once, assuming every
    /// arrival were admitted (an upper bound on controller occupancy).
    pub fn peak_concurrency(&self) -> usize {
        let mut now = 0usize;
        let mut peak = 0usize;
        for e in &self.events {
            match e.kind {
                TraceEventKind::Arrive { .. } => {
                    now += 1;
                    peak = peak.max(now);
                }
                TraceEventKind::Depart => now = now.saturating_sub(1),
                // a shrink changes a resident's plan, not the head
                // count; bursts and GPU chaos never add tenants either
                TraceEventKind::Shrink { .. }
                | TraceEventKind::Burst { .. }
                | TraceEventKind::BurstEnd
                | TraceEventKind::GpuFail { .. }
                | TraceEventKind::GpuRecover { .. }
                | TraceEventKind::GpuDegrade { .. }
                | TraceEventKind::GpuRestore { .. } => {}
            }
        }
        peak
    }
}

/// Result of a single load trial.
#[derive(Debug, Clone, Copy)]
pub struct LoadTrial {
    pub rate_qps: f64,
    pub p99_s: f64,
    pub qos_met: bool,
}

/// Establish the bisection invariant — `lo` feasible, `hi` infeasible —
/// from a starting bracket. Shared by the serial and speculative
/// searches so the grow/halve scaffolding exists once. Returns
/// `Err(peak)` when the search is already decided: `Err(0.0)` if no
/// feasible rate exists, or the last feasible rate if the bracket grew
/// past its budget (effectively unbounded on this testbed).
fn establish_bracket<C>(mut check: C, lo_hint: f64, hi_start: f64) -> Result<(f64, f64), f64>
where
    C: FnMut(f64) -> bool,
{
    let mut lo = 0.0;
    let mut hi = hi_start;
    if lo_hint > 0.0 && check(lo_hint) {
        lo = lo_hint;
    }
    if check(hi) {
        // top of the bracket is feasible: grow until infeasible
        let mut grow_budget = 24;
        loop {
            lo = hi;
            hi *= 2.0;
            grow_budget -= 1;
            if grow_budget == 0 {
                return Err(lo);
            }
            if !check(hi) {
                break;
            }
        }
    }
    if lo == 0.0 {
        // no feasible point yet: halve down from the bracket top
        let mut probe = hi / 2.0;
        let mut budget = 24;
        while probe > 1e-3 && !check(probe) {
            hi = probe;
            probe /= 2.0;
            budget -= 1;
            if budget == 0 {
                return Err(0.0);
            }
        }
        if probe <= 1e-3 {
            return Err(0.0);
        }
        lo = probe;
    }
    Ok((lo, hi))
}

/// Binary-search the peak supported load: the highest arrival rate whose
/// p99 stays within QoS, per the paper's measurement protocol
/// ("gradually increase the load of each benchmark until its 99%-ile
/// latency achieves the QoS target").
///
/// `eval(rate) -> p99 seconds`. Returns (peak_qps, trials).
pub fn peak_load_search<F>(
    mut eval: F,
    qos_s: f64,
    hi_start: f64,
    rel_tol: f64,
) -> (f64, Vec<LoadTrial>)
where
    F: FnMut(f64) -> f64,
{
    assert!(qos_s > 0.0 && hi_start > 0.0);
    let mut trials = Vec::new();
    let mut check = |rate: f64, trials: &mut Vec<LoadTrial>| -> bool {
        let p99 = eval(rate);
        let ok = p99 <= qos_s;
        trials.push(LoadTrial { rate_qps: rate, p99_s: p99, qos_met: ok });
        ok
    };

    let (mut lo, mut hi) = match establish_bracket(|r| check(r, &mut trials), 0.0, hi_start) {
        Ok(bracket) => bracket,
        Err(peak) => return (peak, trials),
    };
    // bisect
    while (hi - lo) / hi.max(1e-9) > rel_tol {
        let mid = 0.5 * (lo + hi);
        if check(mid, &mut trials) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    (lo, trials)
}

/// Speculative bracketed peak search: like [`peak_load_search`], but
/// takes an initial bracket hint and evaluates *batches* of candidate
/// rates through `eval_many` so the caller can fan the trials of one
/// round across threads (`util::par`). Each refinement round probes
/// `probes_per_round` evenly spaced interior points and keeps the
/// sub-bracket that straddles the QoS threshold — a `(k+1)×` bracket
/// shrink per parallel round. Use `probes_per_round = 1` (classic
/// bisection, fewest total evaluations) when the evaluations will run
/// serially anyway (e.g. from inside a `par_map` worker), and 3 when
/// the probes genuinely fan across threads.
///
/// `eval_many(&rates) -> p99s` must return one p99 per rate, position
/// for position, and must be deterministic per rate — given that, the
/// returned peak and trial list are identical regardless of how many
/// threads the caller uses.
pub fn peak_load_search_bracketed<F>(
    mut eval_many: F,
    qos_s: f64,
    lo_hint: f64,
    hi_hint: f64,
    rel_tol: f64,
    probes_per_round: usize,
) -> (f64, Vec<LoadTrial>)
where
    F: FnMut(&[f64]) -> Vec<f64>,
{
    assert!(qos_s > 0.0 && hi_hint > 0.0 && lo_hint >= 0.0 && lo_hint < hi_hint);
    let k = probes_per_round.clamp(1, 8);
    let mut trials: Vec<LoadTrial> = Vec::new();
    let mut check_many = |rates: &[f64], trials: &mut Vec<LoadTrial>| -> Vec<bool> {
        let p99s = eval_many(rates);
        assert_eq!(p99s.len(), rates.len(), "eval_many must answer every rate");
        rates
            .iter()
            .zip(&p99s)
            .map(|(&rate_qps, &p99_s)| {
                let ok = p99_s <= qos_s;
                trials.push(LoadTrial { rate_qps, p99_s, qos_met: ok });
                ok
            })
            .collect()
    };

    let (mut lo, mut hi) = match establish_bracket(
        |r| check_many(&[r], &mut trials)[0],
        lo_hint,
        hi_hint,
    ) {
        Ok(bracket) => bracket,
        Err(peak) => return (peak, trials),
    };

    // speculative rounds: k concurrent probes, keep the straddling slice
    while (hi - lo) / hi.max(1e-9) > rel_tol {
        let d = hi - lo;
        let probes: Vec<f64> = (1..=k)
            .map(|i| lo + d * i as f64 / (k + 1) as f64)
            .collect();
        let ok = check_many(&probes, &mut trials);
        match ok.iter().position(|&b| !b) {
            Some(0) => hi = probes[0],
            Some(i) => {
                lo = probes[i - 1];
                hi = probes[i];
            }
            None => lo = probes[k - 1],
        }
    }
    (lo, trials)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit;

    #[test]
    fn poisson_rate_matches() {
        let mut gen = PoissonArrivals::new(100.0, 7);
        let times = gen.times_until(200.0);
        testkit::assert_close(times.len() as f64, 20_000.0, 0.03, 0.0);
        // strictly increasing
        assert!(times.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn poisson_deterministic_per_seed() {
        let a = PoissonArrivals::new(50.0, 3).times_until(10.0);
        let b = PoissonArrivals::new(50.0, 3).times_until(10.0);
        assert_eq!(a, b);
    }

    #[test]
    fn diurnal_bounds() {
        let d = DiurnalPattern::new(1000.0);
        let mut lo = f64::INFINITY;
        let mut hi = 0.0f64;
        for i in 0..100 {
            let r = d.rate_at(i as f64 * 864.0);
            lo = lo.min(r);
            hi = hi.max(r);
        }
        testkit::assert_close(lo, 300.0, 0.01, 0.0);
        testkit::assert_close(hi, 1000.0, 0.01, 0.0);
    }

    #[test]
    fn peak_search_finds_threshold() {
        // synthetic system: p99 = rate/100 seconds; QoS 1 s ⇒ peak = 100
        let (peak, trials) =
            peak_load_search(|r| r / 100.0, 1.0, 10.0, 0.01);
        testkit::assert_close(peak, 100.0, 0.02, 0.0);
        assert!(!trials.is_empty());
    }

    #[test]
    fn peak_search_handles_infeasible_start() {
        // p99 = rate (QoS 0.5) with hi_start way past peak
        let (peak, _) = peak_load_search(|r| r, 0.5, 64.0, 0.02);
        testkit::assert_close(peak, 0.5, 0.05, 0.0);
    }

    #[test]
    fn peak_search_zero_when_nothing_feasible() {
        let (peak, _) = peak_load_search(|_| 10.0, 0.5, 8.0, 0.02);
        assert_eq!(peak, 0.0);
    }

    #[test]
    fn lazy_stream_matches_materialized() {
        let mut eager = PoissonArrivals::new(80.0, 11);
        let times = eager.times_until(50.0);
        let mut lazy = PoissonArrivals::new(80.0, 11);
        let streamed = lazy.take_times(times.len());
        assert_eq!(times, streamed, "lazy stream must be bit-identical");
        let mut one_by_one = PoissonArrivals::new(80.0, 11);
        for &t in times.iter().take(100) {
            assert_eq!(t, one_by_one.next_time());
        }
    }

    #[test]
    fn nonhomogeneous_rate_tracks_pattern() {
        // counts in a window should approximate ∫ rate dt (compressed
        // day so the test stays cheap: 10 periods of 600 s)
        let pattern = DiurnalPattern { peak_qps: 200.0, trough_frac: 0.3, period_s: 600.0 };
        let mut gen = NonHomogeneousArrivals::new(pattern.clone(), 13);
        let horizon = 10.0 * pattern.period_s;
        let times = gen.times_until(horizon);
        let expect = pattern.mean_qps() * horizon;
        testkit::assert_close(times.len() as f64, expect, 0.02, 0.0);
        // the trough slice is sparser than the midday slice
        let slice = pattern.period_s / 10.0;
        let trough = times.iter().filter(|&&t| t < slice).count();
        let midday_start = pattern.period_s / 2.0;
        let midday = times
            .iter()
            .filter(|&&t| t >= midday_start && t < midday_start + slice)
            .count();
        assert!(
            (midday as f64) > 2.0 * trough as f64,
            "midday {midday} vs trough {trough}"
        );
        // strictly increasing
        assert!(times.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn nonhomogeneous_windowed_matches_lazy() {
        // the overshoot arrival at each horizon stays buffered, so
        // windowed reads concatenate to the lazy stream exactly
        let p = DiurnalPattern { peak_qps: 120.0, trough_frac: 0.3, period_s: 300.0 };
        let mut windowed = NonHomogeneousArrivals::new(p.clone(), 21);
        let mut all = windowed.times_until(100.0);
        all.extend(windowed.times_until(200.0));
        let mut lazy = NonHomogeneousArrivals::new(p, 21);
        assert_eq!(all, lazy.take_times(all.len()));
    }

    #[test]
    fn nonhomogeneous_deterministic_per_seed() {
        let p = DiurnalPattern::new(150.0);
        let a = NonHomogeneousArrivals::new(p.clone(), 5).take_times(500);
        let b = NonHomogeneousArrivals::new(p, 5).take_times(500);
        assert_eq!(a, b);
    }

    #[test]
    fn constant_request_stream_matches_poisson() {
        // ArrivalProcess::Constant must reproduce the engine's stream
        // bit-for-bit (degenerate-equivalence contract)
        let mut direct = PoissonArrivals::new(120.0 / 16.0, 42);
        let mut via = ArrivalProcess::constant(120.0).request_stream(16, 42);
        for _ in 0..200 {
            assert_eq!(direct.next_time(), via.next_time());
        }
    }

    #[test]
    fn scaled_pattern_scales_pointwise() {
        let p = DiurnalPattern::new(400.0);
        let q = p.scaled(0.25);
        for i in 0..50 {
            let t = i as f64 * 1_000.0;
            testkit::assert_close(q.rate_at(t), p.rate_at(t) * 0.25, 1e-12, 0.0);
        }
        testkit::assert_close(p.mean_qps(), 0.5 * (400.0 + 120.0), 1e-12, 0.0);
    }

    #[test]
    fn bracketed_search_finds_threshold() {
        // same synthetic system as the serial test: peak = 100
        let (peak, trials) = peak_load_search_bracketed(
            |rates| rates.iter().map(|r| r / 100.0).collect(),
            1.0,
            40.0,
            160.0,
            0.01,
            3,
        );
        testkit::assert_close(peak, 100.0, 0.02, 0.0);
        assert!(!trials.is_empty());
    }

    #[test]
    fn bracketed_search_recovers_from_bad_hints() {
        // bracket entirely below the true peak: must grow
        let (peak, _) = peak_load_search_bracketed(
            |rates| rates.iter().map(|r| r / 100.0).collect(),
            1.0,
            5.0,
            20.0,
            0.02,
            3,
        );
        testkit::assert_close(peak, 100.0, 0.05, 0.0);
        // bracket entirely above: must halve down, then refine
        let (peak, _) = peak_load_search_bracketed(
            |rates| rates.iter().map(|r| r / 100.0).collect(),
            1.0,
            400.0,
            800.0,
            0.02,
            3,
        );
        testkit::assert_close(peak, 100.0, 0.05, 0.0);
        // nothing feasible at all
        let (peak, _) =
            peak_load_search_bracketed(|rates| vec![10.0; rates.len()], 0.5, 1.0, 8.0, 0.02, 3);
        assert_eq!(peak, 0.0);
    }

    #[test]
    fn tenant_trace_reproducible_and_ordered() {
        let cfg = TenantTraceConfig::default();
        let a = TenantTrace::generate(&cfg, 17);
        let b = TenantTrace::generate(&cfg, 17);
        assert_eq!(a.events.len(), cfg.tenants * 2);
        assert_eq!(a.events.len(), b.events.len());
        for (x, y) in a.events.iter().zip(&b.events) {
            assert_eq!(x.t_s.to_bits(), y.t_s.to_bits());
            assert_eq!(x.tenant, y.tenant);
            match (&x.kind, &y.kind) {
                (
                    TraceEventKind::Arrive { pipeline: pa, plan_qps: qa, .. },
                    TraceEventKind::Arrive { pipeline: pb, plan_qps: qb, .. },
                ) => {
                    assert_eq!(pa, pb);
                    assert_eq!(qa.to_bits(), qb.to_bits());
                }
                (TraceEventKind::Depart, TraceEventKind::Depart) => {}
                _ => panic!("event kinds diverge"),
            }
        }
        // time-ordered, every tenant arrives before it departs, and the
        // peaks sit inside the configured band
        assert!(a.events.windows(2).all(|w| w[0].t_s <= w[1].t_s));
        for tenant in 0..cfg.tenants as u64 {
            let idx = |want_arrive: bool| {
                a.events
                    .iter()
                    .position(|e| {
                        e.tenant == tenant
                            && matches!(e.kind, TraceEventKind::Arrive { .. }) == want_arrive
                    })
                    .unwrap()
            };
            assert!(idx(true) < idx(false), "tenant {tenant} departs before arriving");
        }
        for e in &a.events {
            if let TraceEventKind::Arrive { plan_qps, pipeline, .. } = &e.kind {
                assert!((cfg.peak_qps_lo..=cfg.peak_qps_hi).contains(plan_qps));
                assert!(cfg.catalog.contains(pipeline));
            }
        }
        assert!(a.peak_concurrency() >= 1 && a.peak_concurrency() <= cfg.tenants);
        // different seeds give different traces
        let c = TenantTrace::generate(&cfg, 18);
        assert!(a
            .events
            .iter()
            .zip(&c.events)
            .any(|(x, y)| x.t_s.to_bits() != y.t_s.to_bits()));
    }

    #[test]
    fn burst_expansion_closes_windows_in_canonical_order() {
        // a burst at t=10 for 20 s must synthesize a BurstEnd at t=30,
        // and that end must sort *before* an arrival at the same time
        let mk = |t_s: f64, tenant: u64, kind: TraceEventKind| TenantTraceEvent {
            t_s,
            tenant,
            kind,
        };
        let arrive = |qps: f64| TraceEventKind::Arrive {
            pipeline: "text-to-text".into(),
            name: None,
            arrivals: ArrivalProcess::constant(qps),
            plan_qps: qps,
            priority: Priority::LatencyCritical,
        };
        let trace = TenantTrace {
            events: vec![
                mk(0.0, 0, arrive(50.0)),
                mk(10.0, 0, TraceEventKind::Burst { rate_mult: 4.0, duration_s: 20.0 }),
                mk(30.0, 1, arrive(40.0)),
            ],
        };
        assert!(trace.has_bursts());
        let expanded = trace.expanded_events();
        assert_eq!(expanded.len(), 4);
        assert!(matches!(expanded[2].kind, TraceEventKind::BurstEnd));
        assert_eq!(expanded[2].t_s, 30.0);
        assert_eq!(expanded[2].tenant, 0);
        assert!(matches!(expanded[3].kind, TraceEventKind::Arrive { .. }));
        // burst-free traces take the verbatim-borrow path
        assert!(!TenantTrace::repeated_cycle().has_bursts());
        // chaos kinds never change the concurrency bound
        assert_eq!(trace.peak_concurrency(), 2);
    }

    #[test]
    fn chaos_sort_ranks_are_stable_at_equal_times() {
        // at one instant: recover before restore before shrink before
        // burst-end before arrive before burst before degrade before
        // fail, departures first of all
        let mk = |tenant: u64, kind: TraceEventKind| TenantTraceEvent { t_s: 5.0, tenant, kind };
        let mut events = vec![
            mk(0, TraceEventKind::GpuFail { gpu_ids: vec![0] }),
            mk(1, TraceEventKind::Burst { rate_mult: 2.0, duration_s: 1.0 }),
            mk(2, TraceEventKind::Arrive {
                pipeline: "img-to-text".into(),
                name: None,
                arrivals: ArrivalProcess::constant(10.0),
                plan_qps: 10.0,
                priority: Priority::BestEffort,
            }),
            mk(3, TraceEventKind::BurstEnd),
            mk(4, TraceEventKind::Shrink { target_qps: 5.0 }),
            mk(5, TraceEventKind::GpuRecover { gpu_ids: vec![1] }),
            mk(6, TraceEventKind::Depart),
            mk(7, TraceEventKind::GpuDegrade { gpu_ids: vec![0], scale: 1.5 }),
            mk(8, TraceEventKind::GpuRestore { gpu_ids: vec![0] }),
        ];
        TenantTrace::sort_events(&mut events);
        let order: Vec<u64> = events.iter().map(|e| e.tenant).collect();
        assert_eq!(order, vec![6, 5, 8, 4, 3, 2, 1, 7, 0]);
    }

    #[test]
    fn bracketed_and_serial_search_agree() {
        for probes in [1usize, 3, 5] {
            for qos in [0.4, 1.0, 3.0] {
                let (serial, _) = peak_load_search(|r| r / 100.0, qos, 10.0, 0.01);
                let (bracketed, _) = peak_load_search_bracketed(
                    |rates| rates.iter().map(|r| r / 100.0).collect(),
                    qos,
                    serial * 0.5,
                    serial * 1.5,
                    0.01,
                    probes,
                );
                testkit::assert_close(bracketed, serial, 0.03, 0.0);
            }
        }
    }
}
