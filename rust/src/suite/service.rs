//! Microservice & pipeline abstraction — the domain model everything
//! else (simulator, predictors, allocator, baselines, figures) consumes.
//!
//! A [`StageProfile`] is the *resource signature* of one GPU
//! microservice: analytic FLOPs / HBM traffic / memory footprint / PCIe
//! payloads as functions of batch size, plus an Amdahl serial fraction
//! that shapes SM scalability (Fig 3a). A [`Pipeline`] chains stages and
//! carries the end-to-end QoS target.

/// Broad resource class of a microservice (paper §III-B taxonomy).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StageKind {
    /// MXU/ALU-bound (VGG, BERT, DC-GAN style dense stacks).
    Compute,
    /// Global-memory-bandwidth-bound (streaming artifact microservices).
    Memory,
    /// PCIe-transfer-bound (upload-heavy artifact microservices).
    Pcie,
}

/// Analytic resource signature of one microservice stage.
///
/// All per-query quantities are for batch size 1; batched quantities are
/// linear in batch (the paper's LR captures exactly this, §VII-A).
#[derive(Debug, Clone)]
pub struct StageProfile {
    pub name: String,
    pub kind: StageKind,
    /// FLOPs per query (C(i,s)/s in Table II).
    pub flops_per_query: f64,
    /// HBM bytes moved per query during the kernel.
    pub hbm_bytes_per_query: f64,
    /// Weight footprint in bytes — shared by instances of the same stage
    /// co-located on one GPU (§VII-D model sharing).
    pub model_bytes: f64,
    /// Activation/workspace bytes per query in flight (M(i,s) slope;
    /// Fig 6 is linear in batch).
    pub act_bytes_per_query: f64,
    /// Input payload per query arriving over PCIe or from the previous
    /// stage.
    pub in_bytes_per_query: f64,
    /// Output payload per query handed to the next stage.
    pub out_bytes_per_query: f64,
    /// Amdahl serial fraction: exec time ~ serial + (1-serial)/p.
    /// Higher ⇒ poorer SM scaling (Fig 3a saturation).
    pub serial_frac: f64,
    /// Fixed per-kernel work expressed in query-equivalents: every
    /// batch pays `batch_half` extra queries of compute/traffic (weight
    /// reads, launch ramp, underfilled waves). This is what makes large
    /// batches more efficient — the paper's motivation for batching.
    pub batch_half: f64,
    /// Dynamic per-query GPU-memory residency in bytes (KV cache for
    /// LLM stages), held from kernel issue to completion — *on top of*
    /// the static `model_bytes`/`act_bytes_per_query` footprint. The
    /// simulator stalls issue when a GPU's resident bytes would exceed
    /// [`crate::config::GpuSpec::mem_bytes`], and the planner rejects
    /// allocations that can never fit with
    /// [`crate::planner::Infeasible::NoMemory`]. Zero for classic
    /// vision/artifact stages (and zero means every memory code path is
    /// skipped, preserving legacy behavior bit for bit).
    pub mem_bytes_per_query: f64,
}

impl StageProfile {
    /// Effective work units for a batch (affine: fixed + per-query).
    #[inline]
    pub fn work_units(&self, batch: u32) -> f64 {
        batch as f64 + self.batch_half
    }

    /// Total FLOPs for a batch (C(i,s) in Table II) — affine in batch.
    pub fn flops(&self, batch: u32) -> f64 {
        self.flops_per_query * self.work_units(batch)
    }

    /// Global-memory footprint of one instance at batch `s`
    /// (M(i,s) in Table II).
    pub fn mem_footprint(&self, batch: u32) -> f64 {
        self.model_bytes + self.act_bytes_per_query * batch as f64
    }

    /// HBM traffic for a batch (weights re-read per kernel ⇒ affine).
    pub fn hbm_bytes(&self, batch: u32) -> f64 {
        self.hbm_bytes_per_query * self.work_units(batch)
    }

    /// Arithmetic intensity (FLOPs / HBM byte) — classifies the stage on
    /// the roofline.
    pub fn arithmetic_intensity(&self) -> f64 {
        self.flops_per_query / self.hbm_bytes_per_query.max(1.0)
    }
}

/// An end-to-end user-facing service: a linear chain of stages
/// (the paper's pipelines are 2–3 stages; the model supports any length).
#[derive(Debug, Clone)]
pub struct Pipeline {
    pub name: String,
    pub stages: Vec<StageProfile>,
    /// End-to-end 99%-ile latency target, seconds.
    pub qos_target_s: f64,
}

impl Pipeline {
    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Payload size on the hop out of stage `i` (into `i + 1`).
    pub fn hop_bytes(&self, i: usize, batch: u32) -> f64 {
        self.stages[i].out_bytes_per_query * batch as f64
    }

    /// Sanity: adjacent stages must agree on payload sizes.
    pub fn validate(&self) -> Result<(), String> {
        if self.stages.is_empty() {
            return Err(format!("pipeline {} has no stages", self.name));
        }
        if !(self.qos_target_s > 0.0) {
            return Err(format!("pipeline {} has no QoS target", self.name));
        }
        for (i, w) in self.stages.windows(2).enumerate() {
            if (w[0].out_bytes_per_query - w[1].in_bytes_per_query).abs()
                > 1e-6 * w[0].out_bytes_per_query.max(1.0)
            {
                return Err(format!(
                    "pipeline {}: stage {} out ({} B) != stage {} in ({} B)",
                    self.name,
                    i,
                    w[0].out_bytes_per_query,
                    i + 1,
                    w[1].in_bytes_per_query
                ));
            }
        }
        Ok(())
    }
}

/// How many SMs a fractional quota maps to (MPS percentages are coarse).
pub fn quota_to_sms(sm_frac: f64, total_sms: u32) -> u32 {
    ((sm_frac * total_sms as f64).round() as u32).clamp(1, total_sms)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stage(name: &str, out_b: f64, in_b: f64) -> StageProfile {
        StageProfile {
            name: name.into(),
            kind: StageKind::Compute,
            flops_per_query: 1e9,
            hbm_bytes_per_query: 1e6,
            model_bytes: 1e8,
            act_bytes_per_query: 1e5,
            in_bytes_per_query: in_b,
            out_bytes_per_query: out_b,
            serial_frac: 0.05,
            batch_half: 16.0,
            mem_bytes_per_query: 0.0,
        }
    }

    #[test]
    fn affine_in_batch() {
        let s = stage("s", 10.0, 10.0);
        // fixed work of batch_half query-equivalents, then linear
        assert_eq!(s.flops(4), 20e9);
        assert_eq!(s.flops(8) - s.flops(4), 4e9);
        assert_eq!(s.hbm_bytes(16) - s.hbm_bytes(8), 8e6);
        assert_eq!(s.mem_footprint(10), 1e8 + 1e6);
        // batching amortizes the fixed work: throughput-per-query improves
        assert!(s.flops(64) / 64.0 < s.flops(8) / 8.0);
    }

    #[test]
    fn validate_catches_mismatched_hops() {
        let p = Pipeline {
            name: "bad".into(),
            stages: vec![stage("a", 100.0, 10.0), stage("b", 5.0, 999.0)],
            qos_target_s: 0.2,
        };
        assert!(p.validate().is_err());
        let ok = Pipeline {
            name: "ok".into(),
            stages: vec![stage("a", 100.0, 10.0), stage("b", 5.0, 100.0)],
            qos_target_s: 0.2,
        };
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn validate_rejects_empty_and_no_qos() {
        let p = Pipeline { name: "e".into(), stages: vec![], qos_target_s: 0.1 };
        assert!(p.validate().is_err());
        let p2 = Pipeline {
            name: "q".into(),
            stages: vec![stage("a", 1.0, 1.0)],
            qos_target_s: 0.0,
        };
        assert!(p2.validate().is_err());
    }

    #[test]
    fn quota_mapping_clamps() {
        assert_eq!(quota_to_sms(0.0, 68), 1);
        assert_eq!(quota_to_sms(1.0, 68), 68);
        assert_eq!(quota_to_sms(0.5, 68), 34);
    }

    #[test]
    fn intensity_orders_kinds() {
        let mut c = stage("c", 1.0, 1.0);
        c.flops_per_query = 1e10;
        let mut m = stage("m", 1.0, 1.0);
        m.hbm_bytes_per_query = 1e9;
        assert!(c.arithmetic_intensity() > m.arithmetic_intensity());
    }
}
