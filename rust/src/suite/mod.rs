//! Camelot suite: the microservice/pipeline domain model, the four
//! real-system benchmarks (Table I), the 27 artifact benchmarks
//! (§VIII-E), and the workload generators used by the evaluation.

pub mod artifact;
pub mod fuzz;
pub mod real;
pub mod service;
pub mod workload;

pub use service::{Pipeline, StageKind, StageProfile};

/// Resolve a benchmark name to its [`Pipeline`]: one of the four real
/// benchmarks, an LLM serving pipeline `llm:p<prompt>:o<output>:kv<bytes>`
/// (see [`crate::llm`]), or an artifact composite `p<i>+c<j>+m<k>` with
/// levels in 1..=3. The CLI, the admission controller's trace replay, and
/// the tenant-trace catalog all share this resolver.
pub fn pipeline_by_name(name: &str) -> Option<Pipeline> {
    match name {
        "img-to-img" => Some(real::img_to_img()),
        "img-to-text" => Some(real::img_to_text()),
        "text-to-img" => Some(real::text_to_img()),
        "text-to-text" => Some(real::text_to_text()),
        _ => {
            if let Some(params) = crate::llm::LlmParams::parse_name(name) {
                return Some(crate::llm::pipeline(&params));
            }
            let parts: Vec<&str> = name.split('+').collect();
            if parts.len() == 3 {
                let lvl = |s: &str, c: char| -> Option<u32> { s.strip_prefix(c)?.parse().ok() };
                let (pi, cj, mk) =
                    (lvl(parts[0], 'p')?, lvl(parts[1], 'c')?, lvl(parts[2], 'm')?);
                if (1..=3).contains(&pi) && (1..=3).contains(&cj) && (1..=3).contains(&mk) {
                    return Some(artifact::pipeline(pi, cj, mk));
                }
            }
            None
        }
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn pipeline_by_name_resolves_real_and_artifact() {
        assert_eq!(super::pipeline_by_name("img-to-text").unwrap().name, "img-to-text");
        assert!(super::pipeline_by_name("p1+c2+m3").is_some());
        assert!(super::pipeline_by_name("p0+c2+m3").is_none());
        assert!(super::pipeline_by_name("nope").is_none());
    }

    #[test]
    fn pipeline_by_name_resolves_llm_grammar() {
        let p = super::pipeline_by_name("llm:p512:o128:kv65536").unwrap();
        assert_eq!(p.name, "llm:p512:o128:kv65536");
        assert_eq!(p.n_stages(), 2);
        assert!(p.stages.iter().all(|s| s.mem_bytes_per_query > 0.0));
        assert!(super::pipeline_by_name("llm:p0:o128:kv65536").is_none());
        assert!(super::pipeline_by_name("llm:p512").is_none());
    }
}
