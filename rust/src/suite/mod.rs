//! Camelot suite: the microservice/pipeline domain model, the four
//! real-system benchmarks (Table I), the 27 artifact benchmarks
//! (§VIII-E), and the workload generators used by the evaluation.

pub mod artifact;
pub mod real;
pub mod service;
pub mod workload;

pub use service::{Pipeline, StageKind, StageProfile};
