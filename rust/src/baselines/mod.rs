//! Comparison systems from the paper's evaluation (§VIII) and
//! investigation (§IV): Even Allocation (EA), Laius [15], the standalone
//! and balanced deployments of §IV-A, and Camelot itself (with the
//! Camelot-NC ablation).
//!
//! Every planner consumes the same inputs and produces a runnable
//! [`Deployment`], so the figure harnesses compare them symmetrically on
//! the simulator.

use crate::allocator::SaParams;
use crate::comm::CommMode;
use crate::config::ClusterSpec;
use crate::deploy::{self, Allocation};
use crate::planner::{
    CamelotPlanner, ClusterState, Objective, PlanRequest, Planner as _,
};
use crate::predictor::StagePredictor;
use crate::sim::{Deployment, InstancePlacement};
use crate::suite::Pipeline;

/// Which system plans the deployment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Planner {
    /// Even allocation: every stage gets the same share of every GPU,
    /// one instance per stage per GPU, main-memory communication.
    EvenAllocation,
    /// Laius (ICS'19), adapted as the paper does (§VIII): per-GPU
    /// balanced throughputs via predicted durations, one instance per
    /// stage per GPU, no cross-GPU instance tuning, no bandwidth
    /// constraint, main-memory communication.
    Laius,
    /// §IV-A standalone: each stage owns a whole GPU.
    Standalone,
    /// §IV-A balanced: single-GPU SM split equalizing *offline-profiled*
    /// throughputs (contention-oblivious), main-memory communication.
    Balanced,
    /// Camelot (Case 1 planner + global-memory IPC + all constraints).
    Camelot,
    /// Camelot without the bandwidth constraint (§VIII-D ablation).
    CamelotNC,
}

impl Planner {
    pub fn name(&self) -> &'static str {
        match self {
            Planner::EvenAllocation => "EA",
            Planner::Laius => "Laius",
            Planner::Standalone => "Standalone",
            Planner::Balanced => "Balanced",
            Planner::Camelot => "Camelot",
            Planner::CamelotNC => "Camelot-NC",
        }
    }
}

/// Plan a deployment for `pipeline` on `cluster` at batch size `batch`.
///
/// Returns `Err` when the planner cannot produce a valid deployment
/// (e.g. Standalone with fewer GPUs than stages).
pub fn plan(
    planner: Planner,
    pipeline: &Pipeline,
    cluster: &ClusterSpec,
    predictors: &[StagePredictor],
    batch: u32,
    sa: SaParams,
) -> Result<Deployment, String> {
    let n = pipeline.n_stages();
    match planner {
        Planner::EvenAllocation => {
            let quota = 1.0 / n as f64;
            let alloc = Allocation {
                instances: vec![cluster.num_gpus as u32; n],
                quotas: vec![quota; n],
            };
            deploy::deploy(
                pipeline,
                &ClusterState::exclusive(cluster),
                &alloc,
                batch,
                CommMode::MainMemory,
                None,
            )
            .map_err(|e| e.to_string())
        }
        Planner::Laius => {
            // balance per-GPU: quotas ∝ predicted full-GPU duration so
            // the stage throughputs equalize; replicate on every GPU.
            let quotas = balanced_quotas(predictors, batch);
            let mut placements = Vec::new();
            for g in 0..cluster.num_gpus {
                for (stage, &q) in quotas.iter().enumerate() {
                    placements.push(InstancePlacement { stage, gpu: g, sm_frac: q });
                }
            }
            Ok(Deployment { placements, batch, comm: CommMode::MainMemory })
        }
        Planner::Standalone => {
            if cluster.num_gpus < n {
                return Err(format!(
                    "standalone needs {} GPUs, cluster has {}",
                    n, cluster.num_gpus
                ));
            }
            Ok(Deployment {
                placements: (0..n)
                    .map(|stage| InstancePlacement { stage, gpu: stage, sm_frac: 1.0 })
                    .collect(),
                batch,
                comm: CommMode::MainMemory,
            })
        }
        Planner::Balanced => {
            let quotas = balanced_quotas(predictors, batch);
            Ok(Deployment {
                placements: quotas
                    .iter()
                    .enumerate()
                    .map(|(stage, &q)| InstancePlacement { stage, gpu: 0, sm_frac: q })
                    .collect(),
                batch,
                comm: CommMode::MainMemory,
            })
        }
        Planner::Camelot | Planner::CamelotNC => {
            let req = PlanRequest::new(
                Objective::MaxLoad,
                ClusterState::exclusive(cluster),
                pipeline,
                predictors,
            )
            .batch(batch)
            .sa(sa)
            .enforce_bw(matches!(planner, Planner::Camelot));
            CamelotPlanner
                .plan(&req)
                .map(|s| s.deployment)
                .map_err(|e| e.to_string())
        }
    }
}

/// SM split equalizing predicted stage throughputs on one GPU
/// (used by both Laius and the §IV balanced deployment).
pub fn balanced_quotas(predictors: &[StagePredictor], batch: u32) -> Vec<f64> {
    // duration at full GPU approximates relative weight; iterate once to
    // refine against the predictor's nonlinearity.
    let n = predictors.len();
    let mut quotas = vec![1.0 / n as f64; n];
    for _ in 0..8 {
        let thr: Vec<f64> = predictors
            .iter()
            .zip(&quotas)
            .map(|(p, &q)| p.throughput(batch, q).max(1e-6))
            .collect();
        // shift quota from fast stages to slow ones, then renormalize
        for i in 0..n {
            quotas[i] = (quotas[i] / thr[i]).clamp(1e-6, 1e6);
        }
        let total: f64 = quotas.iter().sum();
        for q in quotas.iter_mut() {
            *q = (*q / total).clamp(0.02, 0.96);
        }
    }
    // the clamp can push the sum past 1.0 (raising starved stages);
    // renormalize so the split always fits one GPU
    let total: f64 = quotas.iter().sum();
    if total > 1.0 {
        for q in quotas.iter_mut() {
            *q /= total * 1.0001;
        }
    }
    quotas
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;
    use crate::predictor::ProfileConfig;
    use crate::suite::real;

    fn fixture(p: &Pipeline) -> (ClusterSpec, Vec<StagePredictor>) {
        let cluster = ClusterSpec::two_2080ti();
        let preds = p
            .stages
            .iter()
            .map(|s| StagePredictor::train(s, &GpuSpec::rtx2080ti(), &ProfileConfig::default()))
            .collect();
        (cluster, preds)
    }

    #[test]
    fn all_planners_produce_admissible_deployments() {
        let p = real::img_to_text();
        let (c, preds) = fixture(&p);
        for planner in [
            Planner::EvenAllocation,
            Planner::Laius,
            Planner::Standalone,
            Planner::Balanced,
            Planner::Camelot,
            Planner::CamelotNC,
        ] {
            let d = plan(planner, &p, &c, &preds, 16, SaParams::default())
                .unwrap_or_else(|e| panic!("{}: {e}", planner.name()));
            let sim = crate::sim::Simulator::new(
                &p,
                &c,
                &d,
                crate::sim::SimOptions { queries: 1, ..Default::default() },
            );
            sim.admit().unwrap_or_else(|e| panic!("{}: {e}", planner.name()));
        }
    }

    #[test]
    fn standalone_requires_enough_gpus() {
        let p = crate::suite::artifact::pipeline(1, 1, 1); // 3 stages
        let (_, preds) = fixture(&p);
        let c2 = ClusterSpec::two_2080ti();
        assert!(plan(Planner::Standalone, &p, &c2, &preds, 16, SaParams::default()).is_err());
    }

    #[test]
    fn balanced_gives_slow_stage_more_sm() {
        let p = real::img_to_text(); // stage 0 (vgg) is much heavier
        let (_, preds) = fixture(&p);
        let q = balanced_quotas(&preds, 16);
        assert!(q[0] > q[1], "vgg should get more SM: {q:?}");
        crate::util::testkit::assert_close(q.iter().sum::<f64>(), 1.0, 1e-6, 0.0);
    }

    #[test]
    fn camelot_uses_ipc_and_baselines_do_not() {
        let p = real::text_to_text();
        let (c, preds) = fixture(&p);
        let ea = plan(Planner::EvenAllocation, &p, &c, &preds, 16, SaParams::default()).unwrap();
        let cam = plan(Planner::Camelot, &p, &c, &preds, 16, SaParams::default()).unwrap();
        assert_eq!(ea.comm, CommMode::MainMemory);
        assert_eq!(cam.comm, CommMode::GlobalIpc);
    }
}
