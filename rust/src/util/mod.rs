//! Cross-cutting utilities: deterministic RNG, minimal JSON, result
//! tables/CSV, and the in-house property-testing kit.
//!
//! The build environment is fully offline with a small vendored crate
//! set (no `rand`, `serde_json`, `proptest`, `criterion`), so these are
//! implemented here from scratch — see DESIGN.md §Environment-Substitutions.

pub mod bench;
pub mod json;
pub mod par;
pub mod rng;
pub mod table;
pub mod testkit;

pub use json::Json;
pub use par::{par_map, par_map_threads};
pub use rng::Rng;
pub use table::{fnum, Table};
