//! Deterministic PRNG (xoshiro256**) — the environment has no `rand`
//! crate, and determinism is a feature: every experiment in
//! EXPERIMENTS.md reproduces bit-for-bit from its seed.

/// Derive the k-th member of a seed family: golden-ratio XOR mix, with
/// `mix_seed(base, 0) == base` so "member 0" keeps the base stream
/// exactly (the cluster simulator's degenerate-equivalence contract and
/// the closed loop's epoch seeds both rely on this).
#[inline]
pub fn mix_seed(base: u64, k: u64) -> u64 {
    base ^ k.wrapping_mul(0x9E3779B97F4A7C15)
}

/// xoshiro256** by Blackman & Vigna (public domain reference).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that small/sequential seeds decorrelate.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (n > 0). Lemire-style rejection-free
    /// multiply-shift; bias is negligible for the n used here.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (inter-arrival times of a Poisson
    /// process — the open-loop workload generator).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(5);
        let lambda = 4.0;
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
