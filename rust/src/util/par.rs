//! Std-only deterministic parallel map over scoped threads.
//!
//! The figure harnesses fan `pipeline × planner × load` sweep cells
//! across cores with [`par_map`]; the peak-load search evaluates its
//! speculative bisection probes the same way. Every result lands in the
//! output slot of its input index and every cell derives its randomness
//! from its own inputs (seeds, SA params), so the output is identical
//! regardless of the thread count — including `threads == 1`. The
//! determinism test in `tests/golden_engine.rs` pins that property.
//!
//! Nested fans (the cell-sharded replay fans per-cell interval
//! simulations inside a fan over cells) are kept from oversubscribing
//! the machine by a **process-wide worker budget**: every fan registers
//! the extra workers it spawns, [`par_map`] sizes itself from what is
//! left, and [`split_budget`] carves an explicit two-level split for
//! callers that know both fan widths up front. Budgeting only ever
//! changes *thread counts*, never results — determinism is by input
//! index, so any split yields bit-identical output.
//!
//! No rayon in this environment; `std::thread::scope` (Rust ≥ 1.63) is
//! all that is needed for a work-stealing index queue.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

thread_local! {
    static IN_WORKER: Cell<bool> = Cell::new(false);
}

/// Whether the current code runs inside a [`par_map`] call — on a
/// spawned worker thread, or on the calling thread when the map ran
/// serially (`threads == 1`). Nested `par_map` calls use this to
/// degrade to serial execution instead of oversubscribing the machine,
/// and `peak_load` uses it to pick its probe width. Marking the serial
/// path too keeps the answer a static property of the call structure,
/// not of `CAMELOT_THREADS` — required for thread-count-invariant
/// sweep results.
pub fn in_worker() -> bool {
    IN_WORKER.with(|c| c.get())
}

/// Sets IN_WORKER for a scope, restoring the previous value on drop
/// (panic-safe).
struct WorkerFlag {
    prev: bool,
}

impl WorkerFlag {
    fn set() -> WorkerFlag {
        WorkerFlag { prev: IN_WORKER.with(|c| c.replace(true)) }
    }
}

impl Drop for WorkerFlag {
    fn drop(&mut self) {
        let prev = self.prev;
        IN_WORKER.with(|c| c.set(prev));
    }
}

/// Worker count: `CAMELOT_THREADS` if set (≥ 1), else the machine's
/// available parallelism.
pub fn max_threads() -> usize {
    std::env::var("CAMELOT_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
}

/// Process-wide count of *extra* workers (beyond their calling threads)
/// currently spawned by active fans. Fans register here so nested
/// [`par_map`] calls can size themselves from what is actually left of
/// the machine instead of multiplying against it.
static EXTRA_WORKERS: AtomicUsize = AtomicUsize::new(0);

/// Extra workers currently registered process-wide (observability; the
/// budget tests read it from inside a fan).
pub fn reserved_workers() -> usize {
    EXTRA_WORKERS.load(Ordering::Acquire)
}

/// Unconditional registration of `extra` workers for a fan's lifetime
/// (explicit thread counts are honored as given, but still show up in
/// the budget so nested adaptive fans back off).
struct Registration {
    extra: usize,
}

impl Registration {
    fn add(extra: usize) -> Registration {
        if extra > 0 {
            EXTRA_WORKERS.fetch_add(extra, Ordering::AcqRel);
        }
        Registration { extra }
    }
}

impl Drop for Registration {
    fn drop(&mut self) {
        if self.extra > 0 {
            EXTRA_WORKERS.fetch_sub(self.extra, Ordering::AcqRel);
        }
    }
}

/// Reserve up to `want` extra workers from the remaining budget
/// (`max_threads() − 1 − reserved`), atomically, returning how many
/// were actually granted. A fully spent budget grants 0 — the caller
/// then runs serially on its own thread, exactly like the old
/// hard-serialize behavior under full load.
fn reserve_extra(want: usize) -> Registration {
    if want == 0 {
        return Registration { extra: 0 };
    }
    let cap = max_threads().saturating_sub(1);
    loop {
        let cur = EXTRA_WORKERS.load(Ordering::Acquire);
        let take = want.min(cap.saturating_sub(cur));
        if take == 0 {
            return Registration { extra: 0 };
        }
        if EXTRA_WORKERS
            .compare_exchange(cur, cur + take, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
        {
            return Registration { extra: take };
        }
    }
}

/// Split a worker budget across a two-level fan: returns
/// `(outer, inner)` worker counts with `outer ≤ outer_items`,
/// `outer × inner ≤ budget`, and both ≥ 1. The cell-sharded replay uses
/// this to fan per-cell interval simulations inside the fan over cells
/// without oversubscribing (e.g. budget 16 over 4 cells → 4 outer × 4
/// inner, not 4 × 16).
pub fn split_budget(budget: usize, outer_items: usize) -> (usize, usize) {
    let budget = budget.max(1);
    let outer = budget.min(outer_items.max(1));
    let inner = (budget / outer).max(1);
    (outer, inner)
}

/// The shared map body: no budget bookkeeping (callers register).
fn run_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        let _flag = WorkerFlag::set();
        return items.iter().enumerate().map(|(i, x)| f(i, x)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let _flag = WorkerFlag::set();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = f(i, &items[i]);
                    *slots[i].lock().unwrap() = Some(r);
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker filled every slot"))
        .collect()
}

/// Apply `f(index, item)` to every item with up to `threads` workers;
/// results are returned in input order. `f` must be deterministic per
/// (index, item) — then the output does not depend on `threads`. The
/// explicit count is honored as given but registered against the
/// process-wide budget so nested [`par_map`] calls back off.
pub fn par_map_threads<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    let _reg = Registration::add(threads - 1);
    run_map(items, threads, f)
}

/// [`par_map_threads`] with a budget-aware worker count: takes whatever
/// the process-wide budget still allows (its own calling thread plus up
/// to `max_threads() − 1` reserved extras), so nested fans *split* the
/// machine instead of multiplying against it — and degrade to serial
/// when enclosing fans already hold every core.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let want = max_threads().min(items.len().max(1)).saturating_sub(1);
    let lease = reserve_extra(want);
    let threads = 1 + lease.extra;
    run_map(items, threads, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<usize> = (0..257).collect();
        let out = par_map_threads(&items, 8, |i, &x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn identical_across_thread_counts() {
        let items: Vec<u64> = (0..64).collect();
        let work = |_: usize, &x: &u64| -> u64 {
            // deterministic per-item "randomness" from the item itself
            let mut r = crate::util::Rng::new(x);
            (0..100).map(|_| r.next_u64() % 1000).sum()
        };
        let serial = par_map_threads(&items, 1, work);
        for threads in [2, 3, 8, 64] {
            assert_eq!(serial, par_map_threads(&items, threads, work));
        }
    }

    #[test]
    fn empty_and_singleton() {
        let empty: Vec<u32> = Vec::new();
        assert!(par_map_threads(&empty, 4, |_, &x| x).is_empty());
        assert_eq!(par_map_threads(&[7u32], 4, |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn max_threads_is_positive() {
        assert!(max_threads() >= 1);
    }

    #[test]
    fn nested_par_map_stays_deterministic_under_the_budget() {
        assert!(!in_worker(), "test thread is not a worker");
        let outer: Vec<u32> = (0..4).collect();
        // serial reference for the nested computation (seeded per item,
        // so any thread split must reproduce it bit for bit)
        let expect_row = |x: u32| -> Vec<u64> {
            (0..8u64)
                .map(|i| {
                    let mut r = crate::util::Rng::new(x as u64 * 100 + i);
                    (0..50).map(|_| r.next_u64() % 1000).sum()
                })
                .collect()
        };
        for threads in [1usize, 2, 4] {
            // in_worker must be a property of the call structure, not of
            // the thread count — the serial path marks the caller too
            let out = par_map_threads(&outer, threads, |_, &x| {
                assert!(in_worker(), "par_map must mark its execution scope");
                // the nested fan sizes itself from the leftover budget;
                // whatever it gets, results stay ordered and identical
                let inner: Vec<u64> = (0..8).map(|i| x as u64 * 100 + i).collect();
                par_map(&inner, |_, &s| {
                    let mut r = crate::util::Rng::new(s);
                    (0..50).map(|_| r.next_u64() % 1000).sum::<u64>()
                })
            });
            for (x, row) in out.iter().enumerate() {
                assert_eq!(row, &expect_row(x as u32), "threads={threads}");
            }
            assert!(!in_worker(), "flag must not leak back to the caller");
        }
    }

    #[test]
    fn explicit_fans_register_against_the_budget() {
        let items: Vec<u32> = (0..8).collect();
        let out = par_map_threads(&items, 8, |_, &x| {
            // the enclosing fan's 7 extra workers are visible in the
            // process-wide budget (other tests may add more; ≥ holds)
            assert!(
                reserved_workers() >= 7,
                "explicit fan must register its extra workers"
            );
            let inner: Vec<u32> = (0..5).map(|i| x * 10 + i).collect();
            // budget-aware nested fan: correct and ordered whatever it
            // was granted (possibly nothing — then it runs serially)
            par_map(&inner, |_, &y| y + 1)
        });
        for (x, row) in out.iter().enumerate() {
            let want: Vec<u32> = (0..5).map(|i| x as u32 * 10 + i + 1).collect();
            assert_eq!(row, &want);
        }
    }

    #[test]
    fn split_budget_never_oversubscribes() {
        for budget in 1..=32usize {
            for outer_items in 1..=20usize {
                let (outer, inner) = split_budget(budget, outer_items);
                assert!(outer >= 1 && inner >= 1);
                assert!(outer <= outer_items.max(1));
                assert!(
                    outer * inner <= budget.max(1),
                    "budget {budget} outer_items {outer_items} -> {outer}x{inner}"
                );
            }
        }
        // degenerate corners
        assert_eq!(split_budget(0, 0), (1, 1));
        assert_eq!(split_budget(16, 4), (4, 4));
        assert_eq!(split_budget(3, 8), (3, 1));
    }

    #[test]
    fn cells_by_intervals_fan_honors_split_budget_and_restores_it() {
        // the cells × intervals shape of `replay_trace_cells`: an outer
        // fan over cells, each running an inner fan over interval
        // simulations, the two levels split with split_budget so they
        // multiply to ≤ budget — whatever the split, results must be
        // bit-identical to the serial reference, and the process-wide
        // worker budget must come back after every fan
        let cells: Vec<u64> = (0..5).collect();
        let expect: Vec<Vec<u64>> = cells
            .iter()
            .map(|&c| {
                (0..12u64)
                    .map(|i| {
                        let mut r = crate::util::Rng::new(c * 1_000 + i);
                        (0..40).map(|_| r.next_u64() % 997).sum()
                    })
                    .collect()
            })
            .collect();
        let initial = reserved_workers();
        for _ in 0..64 {
            for budget in [1usize, 2, 3, 4, 8, 16] {
                let (outer, inner) = split_budget(budget, cells.len());
                assert!(outer * inner <= budget.max(1));
                let out = par_map_threads(&cells, outer, |_, &c| {
                    // the outer fan's registration is visible while the
                    // inner fan runs (other tests may add more; ≥ holds)
                    assert!(reserved_workers() >= outer - 1);
                    let items: Vec<u64> = (0..12u64).map(|i| c * 1_000 + i).collect();
                    par_map_threads(&items, inner, |_, &s| {
                        let mut r = crate::util::Rng::new(s);
                        (0..40).map(|_| r.next_u64() % 997).sum::<u64>()
                    })
                });
                assert_eq!(out, expect, "budget {budget} -> {outer}x{inner}");
            }
        }
        // restoration: a leaked registration would accumulate ≥ 1 per
        // fan across the 64 × 6 fans above (≥ 384 by now); fans of
        // concurrently running tests only add transiently, well under
        // the 64 of slack granted here
        assert!(
            reserved_workers() < initial + 64,
            "worker budget not restored: {initial} -> {}",
            reserved_workers()
        );
    }
}
