//! Std-only deterministic parallel map over scoped threads.
//!
//! The figure harnesses fan `pipeline × planner × load` sweep cells
//! across cores with [`par_map`]; the peak-load search evaluates its
//! speculative bisection probes the same way. Every result lands in the
//! output slot of its input index and every cell derives its randomness
//! from its own inputs (seeds, SA params), so the output is identical
//! regardless of the thread count — including `threads == 1`. The
//! determinism test in `tests/golden_engine.rs` pins that property.
//!
//! No rayon in this environment; `std::thread::scope` (Rust ≥ 1.63) is
//! all that is needed for a work-stealing index queue.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

thread_local! {
    static IN_WORKER: Cell<bool> = Cell::new(false);
}

/// Whether the current code runs inside a [`par_map`] call — on a
/// spawned worker thread, or on the calling thread when the map ran
/// serially (`threads == 1`). Nested `par_map` calls use this to
/// degrade to serial execution instead of oversubscribing the machine,
/// and `peak_load` uses it to pick its probe width. Marking the serial
/// path too keeps the answer a static property of the call structure,
/// not of `CAMELOT_THREADS` — required for thread-count-invariant
/// sweep results.
pub fn in_worker() -> bool {
    IN_WORKER.with(|c| c.get())
}

/// Sets IN_WORKER for a scope, restoring the previous value on drop
/// (panic-safe).
struct WorkerFlag {
    prev: bool,
}

impl WorkerFlag {
    fn set() -> WorkerFlag {
        WorkerFlag { prev: IN_WORKER.with(|c| c.replace(true)) }
    }
}

impl Drop for WorkerFlag {
    fn drop(&mut self) {
        let prev = self.prev;
        IN_WORKER.with(|c| c.set(prev));
    }
}

/// Worker count: `CAMELOT_THREADS` if set (≥ 1), else the machine's
/// available parallelism.
pub fn max_threads() -> usize {
    std::env::var("CAMELOT_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
}

/// Apply `f(index, item)` to every item with up to `threads` workers;
/// results are returned in input order. `f` must be deterministic per
/// (index, item) — then the output does not depend on `threads`.
pub fn par_map_threads<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        let _flag = WorkerFlag::set();
        return items.iter().enumerate().map(|(i, x)| f(i, x)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let _flag = WorkerFlag::set();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = f(i, &items[i]);
                    *slots[i].lock().unwrap() = Some(r);
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker filled every slot"))
        .collect()
}

/// [`par_map_threads`] with the default worker count — serial when
/// already inside a worker (no nested oversubscription).
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = if in_worker() { 1 } else { max_threads() };
    par_map_threads(items, threads, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<usize> = (0..257).collect();
        let out = par_map_threads(&items, 8, |i, &x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn identical_across_thread_counts() {
        let items: Vec<u64> = (0..64).collect();
        let work = |_: usize, &x: &u64| -> u64 {
            // deterministic per-item "randomness" from the item itself
            let mut r = crate::util::Rng::new(x);
            (0..100).map(|_| r.next_u64() % 1000).sum()
        };
        let serial = par_map_threads(&items, 1, work);
        for threads in [2, 3, 8, 64] {
            assert_eq!(serial, par_map_threads(&items, threads, work));
        }
    }

    #[test]
    fn empty_and_singleton() {
        let empty: Vec<u32> = Vec::new();
        assert!(par_map_threads(&empty, 4, |_, &x| x).is_empty());
        assert_eq!(par_map_threads(&[7u32], 4, |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn max_threads_is_positive() {
        assert!(max_threads() >= 1);
    }

    #[test]
    fn nested_par_map_degrades_to_serial() {
        assert!(!in_worker(), "test thread is not a worker");
        let outer: Vec<u32> = (0..4).collect();
        for threads in [1usize, 4] {
            // in_worker must be a property of the call structure, not of
            // the thread count — the serial path marks the caller too
            let out = par_map_threads(&outer, threads, |_, &x| {
                assert!(in_worker(), "par_map must mark its execution scope");
                // nested call still produces correct, ordered results
                let inner: Vec<u32> = (0..8).map(|i| x * 10 + i).collect();
                par_map(&inner, |_, &y| y + 1)
            });
            for (x, row) in out.iter().enumerate() {
                let want: Vec<u32> = (0..8).map(|i| x as u32 * 10 + i + 1).collect();
                assert_eq!(row, &want);
            }
            assert!(!in_worker(), "flag must not leak back to the caller");
        }
    }
}
