//! Tiny property-testing harness (no `proptest` in this environment).
//!
//! `forall(seed, cases, gen, prop)` draws `cases` random inputs from
//! `gen` and asserts `prop`; on failure it performs a simple greedy
//! shrink by re-drawing with decreasing "size" and reports the seed so
//! the case replays deterministically.

use super::rng::Rng;

/// Run `prop` over `cases` random inputs. Panics with the failing input's
/// Debug form and the draw index (replayable: same seed → same inputs).
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property failed at case {case} (seed {seed}):\n  input = {input:?}"
            );
        }
    }
}

/// Like [`forall`] but the property returns `Result` so failures can
/// carry a message.
pub fn forall_res<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case} (seed {seed}): {msg}\n  input = {input:?}"
            );
        }
    }
}

/// Assert two floats are close (relative + absolute tolerance).
#[track_caller]
pub fn assert_close(a: f64, b: f64, rtol: f64, atol: f64) {
    let tol = atol + rtol * b.abs().max(a.abs());
    assert!(
        (a - b).abs() <= tol,
        "not close: {a} vs {b} (tol {tol})"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial() {
        forall(1, 100, |r| r.below(10), |&x| x < 10);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(1, 100, |r| r.below(10), |&x| x < 5);
    }

    #[test]
    fn close_accepts_and_rejects() {
        assert_close(1.0, 1.0 + 1e-12, 1e-9, 0.0);
        let r = std::panic::catch_unwind(|| assert_close(1.0, 2.0, 1e-9, 0.0));
        assert!(r.is_err());
    }
}
