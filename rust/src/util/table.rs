//! Result output helpers: aligned console tables (the "paper rows" every
//! figure harness prints) and CSV files under `results/`.

use std::fs;
use std::io::Write;
use std::path::Path;

/// An in-memory table with a header row; renders aligned text and CSV.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells.to_vec());
    }

    /// Convenience: accept anything displayable.
    pub fn push<T: ToString>(&mut self, cells: &[T]) {
        self.row(&cells.iter().map(|c| c.to_string()).collect::<Vec<_>>());
    }

    /// Render the aligned console form.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:width$}", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Write `results/<name>.csv` (creates the directory).
    pub fn write_csv(&self, dir: &Path, name: &str) -> std::io::Result<std::path::PathBuf> {
        fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        let mut f = fs::File::create(&path)?;
        writeln!(f, "{}", self.header.join(","))?;
        for row in &self.rows {
            let escaped: Vec<String> = row
                .iter()
                .map(|c| {
                    if c.contains(',') || c.contains('"') {
                        format!("\"{}\"", c.replace('"', "\"\""))
                    } else {
                        c.clone()
                    }
                })
                .collect();
            writeln!(f, "{}", escaped.join(","))?;
        }
        Ok(path)
    }
}

/// Format a float with engineering-friendly precision.
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 1000.0 || x.abs() < 0.01 {
        format!("{x:.3e}")
    } else if x.abs() >= 10.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.push(&["a", "1"]);
        t.push(&["longer", "22"]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("longer  22"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.push(&["only-one"]);
    }

    #[test]
    fn csv_roundtrip_with_escapes() {
        let dir = std::env::temp_dir().join("camelot_table_test");
        let mut t = Table::new("x", &["a", "b"]);
        t.push(&["plain", "has,comma"]);
        let path = t.write_csv(&dir, "t").unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert_eq!(text, "a,b\nplain,\"has,comma\"\n");
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(12345.0), "1.234e4");
        assert_eq!(fnum(42.0), "42.0");
        assert_eq!(fnum(1.5), "1.500");
    }
}
