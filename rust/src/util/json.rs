//! Minimal JSON parser — just enough to read `artifacts/manifest.json`
//! and config files. The environment has no `serde_json`; this keeps the
//! runtime free of heavyweight deps while staying a strict-enough parser
//! (it rejects trailing garbage and malformed literals).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["key"]` convenience with a readable error path.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Json::as_f64)
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Json::as_str)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => self.string().map(Json::Str),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte '{}'", c as char))),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // multi-byte UTF-8: copy raw bytes through.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get_str("b"), Some("x"));
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parses_manifest_like() {
        let v = Json::parse(
            r#"[{"name":"s_b8","flops":1.0e9,"input_shape":[8,512]}]"#,
        )
        .unwrap();
        let e = &v.as_arr().unwrap()[0];
        assert_eq!(e.get_str("name"), Some("s_b8"));
        assert_eq!(e.get_f64("flops"), Some(1.0e9));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn unicode_escape_and_raw() {
        assert_eq!(
            Json::parse("\"\\u00e9é\"").unwrap(),
            Json::Str("éé".to_string())
        );
    }
}
