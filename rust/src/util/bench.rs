//! Minimal benchmarking harness (the environment has no criterion).
//!
//! Measures wall-clock per iteration with warmup, reports
//! min/median/mean, and prints rows `cargo bench` style. Used by the
//! `benches/` targets (declared `harness = false`).

use std::time::Instant;

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:44} {:>12} {:>12} {:>12}   ({} iters)",
            self.name,
            humanize(self.mean_s),
            humanize(self.median_s),
            humanize(self.min_s),
            self.iters
        )
    }
}

/// Pretty-print a duration in s/ms/µs/ns.
pub fn humanize(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Run `f` repeatedly: a few warmup calls, then `iters` timed calls.
/// Each call's return value passes through `black_box`.
pub fn bench<T>(name: &str, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..iters.min(3) {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let result = BenchResult {
        name: name.to_string(),
        iters,
        mean_s: times.iter().sum::<f64>() / iters as f64,
        median_s: times[iters / 2],
        min_s: times[0],
    };
    println!("{}", result.row());
    result
}

/// Print the standard header once per bench binary.
pub fn header(title: &str) {
    println!("\n== {title} ==");
    println!(
        "{:44} {:>12} {:>12} {:>12}",
        "benchmark", "mean", "median", "min"
    );
    println!("{}", "-".repeat(90));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_positive_times() {
        let r = bench("noop", 10, || 1 + 1);
        assert!(r.mean_s >= 0.0);
        assert!(r.min_s <= r.median_s && r.median_s <= r.mean_s * 10.0);
    }

    #[test]
    fn humanize_ranges() {
        assert!(humanize(2.0).ends_with(" s"));
        assert!(humanize(2e-3).ends_with(" ms"));
        assert!(humanize(2e-6).ends_with(" µs"));
        assert!(humanize(2e-9).ends_with(" ns"));
    }
}
