//! Minimal benchmarking harness (the environment has no criterion).
//!
//! Measures wall-clock per iteration with warmup, reports
//! min/median/mean, and prints rows `cargo bench` style. Used by the
//! `benches/` targets (declared `harness = false`).
//!
//! [`JsonReport`] additionally persists results machine-readably
//! (`BENCH_sim.json` at the repo root) so successive PRs accumulate a
//! perf trajectory — see EXPERIMENTS.md §Benchmarks.

use std::path::Path;
use std::time::Instant;

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:44} {:>12} {:>12} {:>12}   ({} iters)",
            self.name,
            humanize(self.mean_s),
            humanize(self.median_s),
            humanize(self.min_s),
            self.iters
        )
    }
}

/// Pretty-print a duration in s/ms/µs/ns.
pub fn humanize(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Run `f` repeatedly: a few warmup calls, then `iters` timed calls.
/// Each call's return value passes through `black_box`.
pub fn bench<T>(name: &str, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..iters.min(3) {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let result = BenchResult {
        name: name.to_string(),
        iters,
        mean_s: times.iter().sum::<f64>() / iters as f64,
        median_s: times[iters / 2],
        min_s: times[0],
    };
    println!("{}", result.row());
    result
}

/// Print the standard header once per bench binary.
pub fn header(title: &str) {
    println!("\n== {title} ==");
    println!(
        "{:44} {:>12} {:>12} {:>12}",
        "benchmark", "mean", "median", "min"
    );
    println!("{}", "-".repeat(90));
}

/// Machine-readable benchmark report: bench name → median/mean/min plus
/// optional per-bench extras (e.g. simulated-queries/s) and top-level
/// derived metrics (e.g. speedup ratios). Hand-rendered JSON — the
/// environment has no serde.
#[derive(Debug, Clone, Default)]
pub struct JsonReport {
    entries: Vec<(BenchResult, Vec<(String, f64)>)>,
    derived: Vec<(String, f64)>,
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

impl JsonReport {
    pub fn new() -> JsonReport {
        JsonReport::default()
    }

    /// Record a result with no extra metrics.
    pub fn add(&mut self, result: &BenchResult) {
        self.entries.push((result.clone(), Vec::new()));
    }

    /// Record a result plus derived per-bench metrics.
    pub fn add_with(&mut self, result: &BenchResult, extras: &[(&str, f64)]) {
        self.entries.push((
            result.clone(),
            extras.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        ));
    }

    /// Record a top-level derived metric (e.g. a speedup ratio).
    pub fn derived(&mut self, key: &str, value: f64) {
        self.derived.push((key.to_string(), value));
    }

    /// Render the full JSON document.
    pub fn render(&self, note: &str) -> String {
        let mut out = String::from("{\n  \"schema\": \"camelot-bench-v1\",\n");
        out.push_str(&format!("  \"note\": \"{}\",\n", json_escape(note)));
        out.push_str("  \"benches\": {\n");
        for (i, (r, extras)) in self.entries.iter().enumerate() {
            out.push_str(&format!(
                "    \"{}\": {{\"median_s\": {}, \"mean_s\": {}, \"min_s\": {}, \"iters\": {}",
                json_escape(&r.name),
                json_num(r.median_s),
                json_num(r.mean_s),
                json_num(r.min_s),
                r.iters
            ));
            for (k, v) in extras {
                out.push_str(&format!(", \"{}\": {}", json_escape(k), json_num(*v)));
            }
            out.push('}');
            if i + 1 < self.entries.len() {
                out.push(',');
            }
            out.push('\n');
        }
        out.push_str("  },\n  \"derived\": {\n");
        for (i, (k, v)) in self.derived.iter().enumerate() {
            out.push_str(&format!("    \"{}\": {}", json_escape(k), json_num(*v)));
            if i + 1 < self.derived.len() {
                out.push(',');
            }
            out.push('\n');
        }
        out.push_str("  }\n}\n");
        out
    }

    /// Write the report to `path`.
    pub fn write(&self, path: &Path, note: &str) -> std::io::Result<()> {
        std::fs::write(path, self.render(note))
    }

    /// Write the report to `path`, preserving any benches/derived
    /// entries an existing report at that path carries which this one
    /// does not redefine. Multiple bench binaries (`bench_sim`,
    /// `bench_admission`) contribute sections to one `BENCH_sim.json`
    /// this way instead of clobbering each other; retained entries keep
    /// their values exactly (nulls round-trip as nulls).
    pub fn merge_write(&self, path: &Path, note: &str) -> std::io::Result<()> {
        let mut merged = self.clone();
        if let Ok(text) = std::fs::read_to_string(path) {
            if let Ok(doc) = crate::util::Json::parse(&text) {
                merged.absorb_existing(&doc);
            }
        }
        std::fs::write(path, merged.render(note))
    }

    /// Prepend entries from a previously written report that this one
    /// does not redefine (retained entries come first so stable section
    /// order is kept run over run).
    fn absorb_existing(&mut self, doc: &crate::util::Json) {
        use crate::util::Json;
        let num = |v: Option<&Json>| v.and_then(Json::as_f64).unwrap_or(f64::NAN);
        if let Some(benches) = doc.get("benches").and_then(Json::as_obj) {
            let have: std::collections::HashSet<String> =
                self.entries.iter().map(|(r, _)| r.name.clone()).collect();
            let mut retained: Vec<(BenchResult, Vec<(String, f64)>)> = Vec::new();
            for (name, entry) in benches {
                if have.contains(name) {
                    continue;
                }
                let Some(obj) = entry.as_obj() else { continue };
                let result = BenchResult {
                    name: name.clone(),
                    iters: num(obj.get("iters")).max(0.0) as usize,
                    mean_s: num(obj.get("mean_s")),
                    median_s: num(obj.get("median_s")),
                    min_s: num(obj.get("min_s")),
                };
                let extras: Vec<(String, f64)> = obj
                    .iter()
                    .filter(|(k, _)| {
                        !matches!(k.as_str(), "median_s" | "mean_s" | "min_s" | "iters")
                    })
                    .map(|(k, v)| (k.clone(), v.as_f64().unwrap_or(f64::NAN)))
                    .collect();
                retained.push((result, extras));
            }
            retained.append(&mut self.entries);
            self.entries = retained;
        }
        if let Some(derived) = doc.get("derived").and_then(Json::as_obj) {
            let have: std::collections::HashSet<String> =
                self.derived.iter().map(|(k, _)| k.clone()).collect();
            let mut retained: Vec<(String, f64)> = derived
                .iter()
                .filter(|(k, _)| !have.contains(k.as_str()))
                .map(|(k, v)| (k.clone(), v.as_f64().unwrap_or(f64::NAN)))
                .collect();
            retained.append(&mut self.derived);
            self.derived = retained;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_positive_times() {
        let r = bench("noop", 10, || 1 + 1);
        assert!(r.mean_s >= 0.0);
        assert!(r.min_s <= r.median_s && r.median_s <= r.mean_s * 10.0);
    }

    #[test]
    fn humanize_ranges() {
        assert!(humanize(2.0).ends_with(" s"));
        assert!(humanize(2e-3).ends_with(" ms"));
        assert!(humanize(2e-6).ends_with(" µs"));
        assert!(humanize(2e-9).ends_with(" ns"));
    }

    #[test]
    fn merge_preserves_foreign_entries_and_overrides_own() {
        // first binary writes sim entries...
        let mut sim = JsonReport::new();
        sim.add_with(
            &BenchResult {
                name: "sim/a".into(),
                iters: 10,
                mean_s: 0.02,
                median_s: 0.01,
                min_s: 0.005,
            },
            &[("sim_queries_per_s", 1000.0)],
        );
        sim.derived("engine_speedup", 3.0);
        let existing = crate::util::Json::parse(&sim.render("sim run")).unwrap();
        // ...the second binary absorbs them and adds its own sections
        let mut adm = JsonReport::new();
        adm.add_with(
            &BenchResult {
                name: "admission/replay".into(),
                iters: 5,
                mean_s: 0.2,
                median_s: 0.1,
                min_s: 0.05,
            },
            &[("replay_events_per_s", 80.0)],
        );
        adm.derived("control_loop_speedup", 2.5);
        adm.absorb_existing(&existing);
        let merged = crate::util::Json::parse(&adm.render("merged")).unwrap();
        let benches = merged.get("benches").unwrap();
        assert_eq!(
            benches.get("sim/a").unwrap().get_f64("sim_queries_per_s"),
            Some(1000.0)
        );
        assert_eq!(
            benches
                .get("admission/replay")
                .unwrap()
                .get_f64("replay_events_per_s"),
            Some(80.0)
        );
        let derived = merged.get("derived").unwrap();
        assert_eq!(derived.get_f64("engine_speedup"), Some(3.0));
        assert_eq!(derived.get_f64("control_loop_speedup"), Some(2.5));
        // null placeholders round-trip as nulls, not as numbers
        let placeholder = crate::util::Json::parse(
            r#"{"benches": {"old/null": {"median_s": null, "mean_s": null, "min_s": null, "iters": 0}}, "derived": {"d": null}}"#,
        )
        .unwrap();
        let mut rep = JsonReport::new();
        rep.absorb_existing(&placeholder);
        let out = crate::util::Json::parse(&rep.render("x")).unwrap();
        assert_eq!(
            out.get("benches").unwrap().get("old/null").unwrap().get("median_s"),
            Some(&crate::util::Json::Null)
        );
        assert_eq!(out.get("derived").unwrap().get("d"), Some(&crate::util::Json::Null));
    }

    #[test]
    fn json_report_parses_back() {
        let mut rep = JsonReport::new();
        let r = BenchResult {
            name: "sim/16k queries".into(),
            iters: 10,
            mean_s: 0.012,
            median_s: 0.011,
            min_s: 0.010,
        };
        rep.add_with(&r, &[("sim_queries_per_s", 1.45e6)]);
        rep.add(&BenchResult { name: "other".into(), ..r.clone() });
        rep.derived("speedup_vs_reference", 4.2);
        rep.derived("nan_becomes_null", f64::NAN);
        let text = rep.render("unit test");
        let json = crate::util::Json::parse(&text).expect("valid json");
        let benches = json.get("benches").unwrap();
        let e = benches.get("sim/16k queries").unwrap();
        assert_eq!(e.get_f64("median_s"), Some(0.011));
        assert_eq!(e.get_f64("sim_queries_per_s"), Some(1.45e6));
        assert_eq!(
            json.get("derived").unwrap().get_f64("speedup_vs_reference"),
            Some(4.2)
        );
        assert_eq!(
            json.get("derived").unwrap().get("nan_becomes_null"),
            Some(&crate::util::Json::Null)
        );
    }
}
