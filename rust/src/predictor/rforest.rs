//! Random forest (bagged CART trees) — the third candidate of the
//! Fig 12 comparison. Camelot ultimately rejects it: its accuracy is
//! comparable to the single tree but its prediction latency (>5 ms in
//! the paper for large forests) violates the online budget.

use super::dtree::{DecisionTree, TreeParams};
use crate::util::Rng;

#[derive(Debug, Clone, Copy)]
pub struct ForestParams {
    pub n_trees: usize,
    pub tree: TreeParams,
    /// Bootstrap sample fraction.
    pub subsample: f64,
}

impl Default for ForestParams {
    fn default() -> Self {
        ForestParams { n_trees: 50, tree: TreeParams::default(), subsample: 0.8 }
    }
}

/// A fitted forest.
#[derive(Debug, Clone)]
pub struct RandomForest {
    trees: Vec<DecisionTree>,
}

impl RandomForest {
    pub fn fit(xs: &[Vec<f64>], ys: &[f64], params: ForestParams, seed: u64) -> RandomForest {
        assert!(!xs.is_empty() && xs.len() == ys.len(), "bad training set");
        let mut rng = Rng::new(seed);
        let m = ((xs.len() as f64 * params.subsample) as usize).max(1);
        let trees = (0..params.n_trees)
            .map(|_| {
                let mut bx = Vec::with_capacity(m);
                let mut by = Vec::with_capacity(m);
                for _ in 0..m {
                    let i = rng.below(xs.len());
                    bx.push(xs[i].clone());
                    by.push(ys[i]);
                }
                DecisionTree::fit(&bx, &by, params.tree)
            })
            .collect();
        RandomForest { trees }
    }

    pub fn predict(&self, x: &[f64]) -> f64 {
        self.trees.iter().map(|t| t.predict(x)).sum::<f64>() / self.trees.len() as f64
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn forest_tracks_smooth_surface() {
        let mut r = Rng::new(4);
        let f = |b: f64, p: f64| 0.01 * b * (0.1 + 0.9 / p);
        let xs: Vec<Vec<f64>> = (0..1500)
            .map(|_| vec![r.range_f64(1.0, 64.0), r.range_f64(0.05, 1.0)])
            .collect();
        let ys: Vec<f64> = xs.iter().map(|x| f(x[0], x[1]) * (1.0 + 0.05 * r.normal())).collect();
        let rf = RandomForest::fit(&xs, &ys, ForestParams::default(), 7);
        let mut mape = 0.0;
        for _ in 0..200 {
            let (b, p) = (r.range_f64(2.0, 60.0), r.range_f64(0.1, 1.0));
            let truth = f(b, p);
            mape += ((rf.predict(&[b, p]) - truth) / truth).abs();
        }
        mape /= 200.0;
        assert!(mape < 0.15, "MAPE {mape}");
    }

    #[test]
    fn deterministic_per_seed() {
        let xs: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64]).collect();
        let ys: Vec<f64> = (0..100).map(|i| (i * i) as f64).collect();
        let a = RandomForest::fit(&xs, &ys, ForestParams::default(), 1);
        let b = RandomForest::fit(&xs, &ys, ForestParams::default(), 1);
        assert_eq!(a.predict(&[42.0]), b.predict(&[42.0]));
    }

    #[test]
    fn respects_tree_count() {
        let xs: Vec<Vec<f64>> = (0..20).map(|i| vec![i as f64]).collect();
        let ys: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let p = ForestParams { n_trees: 7, ..Default::default() };
        assert_eq!(RandomForest::fit(&xs, &ys, p, 0).n_trees(), 7);
    }
}
