//! CART regression tree — the modeling technique Camelot selects
//! (§VII-A): accuracy comparable to a random forest at <1 ms prediction
//! latency. Implemented from scratch: variance-reduction splits,
//! depth/leaf-size stopping, mean-leaf prediction.

/// Hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct TreeParams {
    pub max_depth: usize,
    pub min_leaf: usize,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams { max_depth: 12, min_leaf: 2 }
    }
}

#[derive(Debug, Clone)]
enum Node {
    Leaf { value: f64 },
    Split { feature: usize, threshold: f64, left: Box<Node>, right: Box<Node> },
}

/// A fitted regression tree.
#[derive(Debug, Clone)]
pub struct DecisionTree {
    root: Node,
    n_features: usize,
}

impl DecisionTree {
    /// Fit on row-major samples. Panics on empty input.
    pub fn fit(xs: &[Vec<f64>], ys: &[f64], params: TreeParams) -> DecisionTree {
        assert!(!xs.is_empty() && xs.len() == ys.len(), "bad training set");
        let n_features = xs[0].len();
        let idx: Vec<usize> = (0..xs.len()).collect();
        let root = build(xs, ys, &idx, params, 0);
        DecisionTree { root, n_features }
    }

    pub fn predict(&self, x: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), self.n_features);
        let mut node = &self.root;
        loop {
            match node {
                Node::Leaf { value } => return *value,
                Node::Split { feature, threshold, left, right } => {
                    node = if x[*feature] <= *threshold { left } else { right };
                }
            }
        }
    }

    /// Tree depth (for tests / perf accounting).
    pub fn depth(&self) -> usize {
        fn d(n: &Node) -> usize {
            match n {
                Node::Leaf { .. } => 0,
                Node::Split { left, right, .. } => 1 + d(left).max(d(right)),
            }
        }
        d(&self.root)
    }
}

fn mean(ys: &[f64], idx: &[usize]) -> f64 {
    idx.iter().map(|&i| ys[i]).sum::<f64>() / idx.len() as f64
}

fn build(xs: &[Vec<f64>], ys: &[f64], idx: &[usize], params: TreeParams, depth: usize) -> Node {
    if depth >= params.max_depth || idx.len() < 2 * params.min_leaf {
        return Node::Leaf { value: mean(ys, idx) };
    }
    // best split = max variance reduction, found by scanning each
    // feature's sorted values
    let n = idx.len() as f64;
    let sum: f64 = idx.iter().map(|&i| ys[i]).sum();
    let sum2: f64 = idx.iter().map(|&i| ys[i] * ys[i]).sum();
    let parent_sse = sum2 - sum * sum / n;
    if parent_sse <= 1e-12 {
        return Node::Leaf { value: mean(ys, idx) };
    }

    let mut best: Option<(f64, usize, f64)> = None; // (sse, feature, threshold)
    let n_features = xs[0].len();
    let mut order: Vec<usize> = idx.to_vec();
    for f in 0..n_features {
        order.sort_unstable_by(|&a, &b| xs[a][f].partial_cmp(&xs[b][f]).unwrap());
        // prefix sums over the sorted order
        let (mut ls, mut ls2, mut ln) = (0.0, 0.0, 0.0);
        for k in 0..order.len() - 1 {
            let y = ys[order[k]];
            ls += y;
            ls2 += y * y;
            ln += 1.0;
            // candidate split between k and k+1
            if xs[order[k]][f] == xs[order[k + 1]][f] {
                continue; // no threshold separates equal values
            }
            let rn = n - ln;
            if (ln as usize) < params.min_leaf || (rn as usize) < params.min_leaf {
                continue;
            }
            let rs = sum - ls;
            let rs2 = sum2 - ls2;
            let sse = (ls2 - ls * ls / ln) + (rs2 - rs * rs / rn);
            let threshold = 0.5 * (xs[order[k]][f] + xs[order[k + 1]][f]);
            if best.map_or(true, |(b, _, _)| sse < b) {
                best = Some((sse, f, threshold));
            }
        }
    }

    match best {
        Some((sse, feature, threshold)) if sse < parent_sse - 1e-12 => {
            let (li, ri): (Vec<usize>, Vec<usize>) =
                idx.iter().partition(|&&i| xs[i][feature] <= threshold);
            Node::Split {
                feature,
                threshold,
                left: Box::new(build(xs, ys, &li, params, depth + 1)),
                right: Box::new(build(xs, ys, &ri, params, depth + 1)),
            }
        }
        _ => Node::Leaf { value: mean(ys, idx) },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{testkit, Rng};

    #[test]
    fn fits_step_function_exactly() {
        let xs: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64]).collect();
        let ys: Vec<f64> = (0..100).map(|i| if i < 50 { 1.0 } else { 5.0 }).collect();
        let t = DecisionTree::fit(&xs, &ys, TreeParams::default());
        assert_eq!(t.predict(&[10.0]), 1.0);
        assert_eq!(t.predict(&[80.0]), 5.0);
    }

    #[test]
    fn approximates_smooth_2d_surface() {
        // the actual prediction task: duration(batch, quota)
        let mut r = Rng::new(3);
        let f = |b: f64, p: f64| 0.01 * b * (0.1 + 0.9 / p);
        let xs: Vec<Vec<f64>> = (0..2000)
            .map(|_| vec![r.range_f64(1.0, 64.0), r.range_f64(0.05, 1.0)])
            .collect();
        let ys: Vec<f64> = xs.iter().map(|x| f(x[0], x[1])).collect();
        let t = DecisionTree::fit(&xs, &ys, TreeParams::default());
        let mut err_sum = 0.0;
        let mut n = 0;
        for _ in 0..200 {
            let (b, p) = (r.range_f64(2.0, 60.0), r.range_f64(0.1, 1.0));
            let truth = f(b, p);
            err_sum += ((t.predict(&[b, p]) - truth) / truth).abs();
            n += 1;
        }
        let mape = err_sum / n as f64;
        assert!(mape < 0.15, "MAPE {mape}");
    }

    #[test]
    fn respects_min_leaf_and_depth() {
        let xs: Vec<Vec<f64>> = (0..64).map(|i| vec![i as f64]).collect();
        let ys: Vec<f64> = (0..64).map(|i| i as f64).collect();
        let t = DecisionTree::fit(&xs, &ys, TreeParams { max_depth: 3, min_leaf: 1 });
        assert!(t.depth() <= 3);
    }

    #[test]
    fn constant_target_single_leaf() {
        let xs: Vec<Vec<f64>> = (0..10).map(|i| vec![i as f64]).collect();
        let ys = vec![7.0; 10];
        let t = DecisionTree::fit(&xs, &ys, TreeParams::default());
        assert_eq!(t.depth(), 0);
        assert_eq!(t.predict(&[3.0]), 7.0);
    }

    #[test]
    fn predictions_within_target_range_property() {
        testkit::forall_res(
            9,
            20,
            |r| r.next_u64(),
            |&seed| {
                let mut r = Rng::new(seed);
                let xs: Vec<Vec<f64>> =
                    (0..100).map(|_| vec![r.range_f64(0.0, 1.0), r.range_f64(0.0, 1.0)]).collect();
                let ys: Vec<f64> = (0..100).map(|_| r.range_f64(-5.0, 5.0)).collect();
                let t = DecisionTree::fit(&xs, &ys, TreeParams::default());
                let lo = ys.iter().cloned().fold(f64::INFINITY, f64::min);
                let hi = ys.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                for _ in 0..50 {
                    let x = vec![r.range_f64(-1.0, 2.0), r.range_f64(-1.0, 2.0)];
                    let p = t.predict(&x);
                    // mean-of-subset predictions can never escape [lo, hi]
                    if !(lo - 1e-9 <= p && p <= hi + 1e-9) {
                        return Err(format!("prediction {p} outside [{lo}, {hi}]"));
                    }
                }
                Ok(())
            },
        );
    }
}
