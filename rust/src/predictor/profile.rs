//! Offline profiling: collect (batch, SM-quota) → performance samples
//! for each microservice by solo-running it (§VII-A: "queries are
//! executed in solo-run mode to avoid interference"), then train the
//! per-stage predictors.
//!
//! On the real testbed this is a day of Nsight-Compute runs; here the
//! solo runs execute on the simulator's cost model with multiplicative
//! measurement noise (profilers are not noise-free; this is also what
//! makes the Fig 12 error comparison non-degenerate).

use crate::config::GpuSpec;
use crate::sim::CostModel;
use crate::suite::StageProfile;
use crate::util::Rng;

/// One profiled sample.
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    pub batch: f64,
    pub sm_frac: f64,
    pub duration_s: f64,
    pub bw_bytes_per_s: f64,
    pub throughput_qps: f64,
    pub flops: f64,
    pub mem_bytes: f64,
}

/// Profiling configuration.
#[derive(Debug, Clone)]
pub struct ProfileConfig {
    pub batches: Vec<u32>,
    pub quotas: Vec<f64>,
    /// Repetitions per grid point.
    pub reps: usize,
    /// Multiplicative measurement noise std-dev (e.g. 0.03 = 3%).
    pub noise: f64,
    pub seed: u64,
}

impl Default for ProfileConfig {
    fn default() -> Self {
        ProfileConfig {
            batches: vec![1, 2, 4, 8, 16, 24, 32, 48, 64, 96, 128],
            quotas: (1..=20).map(|i| i as f64 * 0.05).collect(),
            reps: 3,
            noise: 0.03,
            seed: 1234,
        }
    }
}

/// Solo-run profile of one stage over the full grid.
pub fn profile_stage(stage: &StageProfile, gpu: &GpuSpec, cfg: &ProfileConfig) -> Vec<Sample> {
    let cost = CostModel::new(gpu.clone());
    let mut rng = Rng::new(cfg.seed ^ hash_name(&stage.name));
    let mut out = Vec::with_capacity(cfg.batches.len() * cfg.quotas.len() * cfg.reps);
    for &b in &cfg.batches {
        for &p in &cfg.quotas {
            for _ in 0..cfg.reps {
                let noise = |r: &mut Rng| 1.0 + cfg.noise * r.normal();
                let d = cost.duration_solo(stage, b, p) * noise(&mut rng);
                out.push(Sample {
                    batch: b as f64,
                    sm_frac: p,
                    duration_s: d,
                    bw_bytes_per_s: stage.hbm_bytes(b) / d,
                    throughput_qps: b as f64 / d,
                    flops: stage.flops(b),
                    mem_bytes: stage.mem_footprint(b),
                });
            }
        }
    }
    out
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a, stable across runs
    let mut h = 0xcbf29ce484222325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// 70/30 train/test split in the paper's protocol.
pub fn split(samples: &[Sample], train_frac: f64, seed: u64) -> (Vec<Sample>, Vec<Sample>) {
    let mut idx: Vec<usize> = (0..samples.len()).collect();
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut idx);
    let cut = (samples.len() as f64 * train_frac) as usize;
    let train = idx[..cut].iter().map(|&i| samples[i]).collect();
    let test = idx[cut..].iter().map(|&i| samples[i]).collect();
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suite::artifact;

    #[test]
    fn grid_coverage() {
        let cfg = ProfileConfig::default();
        let s = profile_stage(&artifact::compute(2), &GpuSpec::rtx2080ti(), &cfg);
        assert_eq!(s.len(), cfg.batches.len() * cfg.quotas.len() * cfg.reps);
        assert!(s.iter().all(|x| x.duration_s > 0.0 && x.throughput_qps > 0.0));
    }

    #[test]
    fn noise_centered_on_model() {
        let cfg = ProfileConfig { reps: 50, ..Default::default() };
        let gpu = GpuSpec::rtx2080ti();
        let stage = artifact::compute(1);
        let cost = CostModel::new(gpu.clone());
        let samples = profile_stage(&stage, &gpu, &cfg);
        let b = 32.0;
        let p = 0.5;
        let subset: Vec<f64> = samples
            .iter()
            .filter(|s| s.batch == b && (s.sm_frac - p).abs() < 1e-9)
            .map(|s| s.duration_s)
            .collect();
        assert_eq!(subset.len(), 50);
        let mean = subset.iter().sum::<f64>() / 50.0;
        let truth = cost.duration_solo(&stage, 32, 0.5);
        crate::util::testkit::assert_close(mean, truth, 0.03, 0.0);
    }

    #[test]
    fn split_partitions() {
        let cfg = ProfileConfig::default();
        let s = profile_stage(&artifact::memory(1), &GpuSpec::rtx2080ti(), &cfg);
        let (tr, te) = split(&s, 0.7, 1);
        assert_eq!(tr.len() + te.len(), s.len());
        assert!((tr.len() as f64 / s.len() as f64 - 0.7).abs() < 0.01);
    }

    #[test]
    fn deterministic() {
        let cfg = ProfileConfig::default();
        let gpu = GpuSpec::rtx2080ti();
        let a = profile_stage(&artifact::pcie(1), &gpu, &cfg);
        let b = profile_stage(&artifact::pcie(1), &gpu, &cfg);
        assert_eq!(a.len(), b.len());
        assert!(a.iter().zip(&b).all(|(x, y)| x.duration_s == y.duration_s));
    }
}
