//! Multivariate linear regression via the normal equations.
//!
//! The paper uses LR for the quantities that really are linear — FLOPs
//! C(i,s) and global-memory footprint M(i,s) versus batch size — and as
//! one of the three candidates in the Fig 12 accuracy comparison.

/// Fitted linear model: `y = w·x + b`.
#[derive(Debug, Clone)]
pub struct LinReg {
    pub weights: Vec<f64>,
    pub bias: f64,
}

impl LinReg {
    /// Least-squares fit. `xs` is row-major (n_samples × n_features).
    /// Solves (XᵀX)w = Xᵀy with Gaussian elimination + partial pivoting
    /// (augmented with a bias column). Returns None on degenerate input.
    pub fn fit(xs: &[Vec<f64>], ys: &[f64]) -> Option<LinReg> {
        let n = xs.len();
        if n == 0 || n != ys.len() {
            return None;
        }
        let d = xs[0].len() + 1; // + bias
        // build normal-equation system a (d×d), rhs (d)
        let mut a = vec![vec![0.0; d]; d];
        let mut rhs = vec![0.0; d];
        for (x, &y) in xs.iter().zip(ys) {
            debug_assert_eq!(x.len() + 1, d);
            let mut xb = x.clone();
            xb.push(1.0);
            for i in 0..d {
                rhs[i] += xb[i] * y;
                for j in 0..d {
                    a[i][j] += xb[i] * xb[j];
                }
            }
        }
        // ridge epsilon keeps near-singular systems solvable
        for (i, row) in a.iter_mut().enumerate() {
            row[i] += 1e-9;
        }
        let w = solve(&mut a, &mut rhs)?;
        let bias = w[d - 1];
        Some(LinReg { weights: w[..d - 1].to_vec(), bias })
    }

    pub fn predict(&self, x: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), self.weights.len());
        self.bias + self.weights.iter().zip(x).map(|(w, v)| w * v).sum::<f64>()
    }
}

/// In-place Gaussian elimination with partial pivoting.
fn solve(a: &mut [Vec<f64>], b: &mut [f64]) -> Option<Vec<f64>> {
    let n = b.len();
    for col in 0..n {
        // pivot
        let pivot = (col..n).max_by(|&i, &j| {
            a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap()
        })?;
        if a[pivot][col].abs() < 1e-12 {
            return None;
        }
        a.swap(col, pivot);
        b.swap(col, pivot);
        for row in col + 1..n {
            let f = a[row][col] / a[col][col];
            for k in col..n {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    // back substitution
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut v = b[i];
        for j in i + 1..n {
            v -= a[i][j] * x[j];
        }
        x[i] = v / a[i][i];
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{testkit, Rng};

    #[test]
    fn recovers_exact_linear_function() {
        // y = 3x₀ - 2x₁ + 5
        let mut r = Rng::new(1);
        let xs: Vec<Vec<f64>> = (0..50)
            .map(|_| vec![r.range_f64(-5.0, 5.0), r.range_f64(-5.0, 5.0)])
            .collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x[0] - 2.0 * x[1] + 5.0).collect();
        let m = LinReg::fit(&xs, &ys).unwrap();
        testkit::assert_close(m.weights[0], 3.0, 1e-6, 1e-6);
        testkit::assert_close(m.weights[1], -2.0, 1e-6, 1e-6);
        testkit::assert_close(m.bias, 5.0, 1e-6, 1e-6);
        testkit::assert_close(m.predict(&[1.0, 1.0]), 6.0, 1e-6, 1e-6);
    }

    #[test]
    fn robust_to_noise() {
        let mut r = Rng::new(2);
        let xs: Vec<Vec<f64>> = (0..500).map(|_| vec![r.range_f64(0.0, 10.0)]).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x[0] + 1.0 + 0.1 * r.normal()).collect();
        let m = LinReg::fit(&xs, &ys).unwrap();
        testkit::assert_close(m.weights[0], 2.0, 0.02, 0.0);
    }

    #[test]
    fn rejects_empty_and_mismatched() {
        assert!(LinReg::fit(&[], &[]).is_none());
        assert!(LinReg::fit(&[vec![1.0]], &[1.0, 2.0]).is_none());
    }

    #[test]
    fn property_fits_random_linear_models() {
        testkit::forall_res(
            5,
            20,
            |r| {
                let d = 1 + r.below(4);
                let w: Vec<f64> = (0..d).map(|_| r.range_f64(-3.0, 3.0)).collect();
                let b = r.range_f64(-3.0, 3.0);
                (w, b, r.next_u64())
            },
            |(w, b, seed)| {
                let mut r = Rng::new(*seed);
                let xs: Vec<Vec<f64>> = (0..80)
                    .map(|_| (0..w.len()).map(|_| r.range_f64(-4.0, 4.0)).collect())
                    .collect();
                let ys: Vec<f64> = xs
                    .iter()
                    .map(|x| b + w.iter().zip(x).map(|(wi, xi)| wi * xi).sum::<f64>())
                    .collect();
                let m = LinReg::fit(&xs, &ys).ok_or("fit failed")?;
                for (xi, yi) in xs.iter().zip(&ys) {
                    let p = m.predict(xi);
                    if (p - yi).abs() > 1e-5 * (1.0 + yi.abs()) {
                        return Err(format!("pred {p} vs {yi}"));
                    }
                }
                Ok(())
            },
        );
    }
}
