//! Performance prediction (§VII-A): per-microservice models that map
//! (batch size, SM quota) → processing duration, global-memory-bandwidth
//! usage, and throughput; plus LR models for FLOPs and memory footprint
//! (linear in batch).
//!
//! The modeling techniques — linear regression, CART decision tree, and
//! random forest — are implemented from scratch in this module, and
//! `figures::fig12` reproduces the paper's accuracy comparison. Camelot
//! uses the decision tree online (<1 ms predictions, §VIII-G).

pub mod dtree;
pub mod linreg;
pub mod profile;
pub mod rforest;

pub use dtree::{DecisionTree, TreeParams};
pub use linreg::LinReg;
pub use profile::{profile_stage, split, ProfileConfig, Sample};
pub use rforest::{ForestParams, RandomForest};

use crate::config::GpuSpec;
use crate::suite::{Pipeline, StageProfile};

/// Train one [`StagePredictor`] per stage of a pipeline with the
/// default profiling grid — the offline phase every planner runs. One
/// definition so the figure harnesses, the admission controller, and
/// the static baseline cannot drift apart.
pub fn train_pipeline(pipeline: &Pipeline, gpu: &GpuSpec) -> Vec<StagePredictor> {
    pipeline
        .stages
        .iter()
        .map(|s| StagePredictor::train(s, gpu, &ProfileConfig::default()))
        .collect()
}

/// The trained per-microservice predictor bundle Camelot consults at
/// allocation time (Table II: f(p), g(p)/b(p), M(i,s), C(i,s)).
#[derive(Debug, Clone)]
pub struct StagePredictor {
    pub stage_name: String,
    duration: DecisionTree,
    bandwidth: DecisionTree,
    throughput: DecisionTree,
    flops: LinReg,
    mem: LinReg,
}

impl StagePredictor {
    /// Profile a stage solo and train all five models (the §VIII-G
    /// "offline overhead" path).
    pub fn train(stage: &StageProfile, gpu: &GpuSpec, cfg: &ProfileConfig) -> StagePredictor {
        let samples = profile_stage(stage, gpu, cfg);
        Self::train_from_samples(&stage.name, &samples)
    }

    pub fn train_from_samples(name: &str, samples: &[Sample]) -> StagePredictor {
        let xs: Vec<Vec<f64>> = samples.iter().map(|s| vec![s.batch, s.sm_frac]).collect();
        let dur: Vec<f64> = samples.iter().map(|s| s.duration_s).collect();
        let bw: Vec<f64> = samples.iter().map(|s| s.bw_bytes_per_s).collect();
        let thr: Vec<f64> = samples.iter().map(|s| s.throughput_qps).collect();
        let xb: Vec<Vec<f64>> = samples.iter().map(|s| vec![s.batch]).collect();
        let fl: Vec<f64> = samples.iter().map(|s| s.flops).collect();
        let mm: Vec<f64> = samples.iter().map(|s| s.mem_bytes).collect();
        let tp = TreeParams::default();
        StagePredictor {
            stage_name: name.to_string(),
            duration: DecisionTree::fit(&xs, &dur, tp),
            bandwidth: DecisionTree::fit(&xs, &bw, tp),
            throughput: DecisionTree::fit(&xs, &thr, tp),
            flops: LinReg::fit(&xb, &fl).expect("flops fit"),
            mem: LinReg::fit(&xb, &mm).expect("mem fit"),
        }
    }

    /// Predicted processing duration (seconds) of one batch.
    pub fn duration(&self, batch: u32, sm_frac: f64) -> f64 {
        self.duration.predict(&[batch as f64, sm_frac]).max(1e-6)
    }

    /// Predicted global-memory-bandwidth usage (bytes/s) — g/b in Eq. 1.
    pub fn bandwidth(&self, batch: u32, sm_frac: f64) -> f64 {
        self.bandwidth.predict(&[batch as f64, sm_frac]).max(0.0)
    }

    /// Predicted instance throughput (queries/s) — f(p) in Eq. 1.
    pub fn throughput(&self, batch: u32, sm_frac: f64) -> f64 {
        self.throughput.predict(&[batch as f64, sm_frac]).max(0.0)
    }

    /// Predicted FLOPs per batch — C(i,s) in Eq. 2.
    pub fn flops(&self, batch: u32) -> f64 {
        self.flops.predict(&[batch as f64]).max(0.0)
    }

    /// Predicted global-memory footprint — M(i,s) in Eq. 2/3.
    pub fn mem_bytes(&self, batch: u32) -> f64 {
        self.mem.predict(&[batch as f64]).max(0.0)
    }
}

/// Mean absolute percentage error of `pred` on held-out samples — the
/// Fig 12 metric.
pub fn mape<F: Fn(&Sample) -> (f64, f64)>(samples: &[Sample], pred: F) -> f64 {
    let mut sum = 0.0;
    let mut n = 0;
    for s in samples {
        let (p, truth) = pred(s);
        if truth.abs() > 1e-12 {
            sum += ((p - truth) / truth).abs();
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::CostModel;
    use crate::suite::{artifact, real};

    fn gpu() -> GpuSpec {
        GpuSpec::rtx2080ti()
    }

    #[test]
    fn predictor_tracks_cost_model() {
        let stage = artifact::compute(2);
        let p = StagePredictor::train(&stage, &gpu(), &ProfileConfig::default());
        let cost = CostModel::new(gpu());
        for &(b, q) in &[(8u32, 0.2f64), (32, 0.5), (64, 0.9)] {
            let truth = cost.duration_solo(&stage, b, q);
            let got = p.duration(b, q);
            assert!(
                (got - truth).abs() / truth < 0.15,
                "duration({b},{q}): {got} vs {truth}"
            );
            let t_truth = cost.throughput_solo(&stage, b, q);
            let t_got = p.throughput(b, q);
            assert!(
                (t_got - t_truth).abs() / t_truth < 0.15,
                "throughput({b},{q}): {t_got} vs {t_truth}"
            );
        }
    }

    #[test]
    fn flops_and_mem_linear_models_exact() {
        let stage = real::img_to_img().stages[0].clone();
        let p = StagePredictor::train(&stage, &gpu(), &ProfileConfig::default());
        for b in [4u32, 40, 200] {
            crate::util::testkit::assert_close(p.flops(b), stage.flops(b), 1e-3, 1e6);
            crate::util::testkit::assert_close(p.mem_bytes(b), stage.mem_footprint(b), 1e-3, 1e6);
        }
    }

    #[test]
    fn dt_accuracy_beats_lr_on_duration() {
        // the Fig 12 headline: LR cannot capture the 1/p shape
        let stage = artifact::compute(3);
        let samples = profile_stage(&stage, &gpu(), &ProfileConfig::default());
        let (train, test) = split(&samples, 0.7, 9);
        let xs: Vec<Vec<f64>> = train.iter().map(|s| vec![s.batch, s.sm_frac]).collect();
        let ys: Vec<f64> = train.iter().map(|s| s.duration_s).collect();
        let dt = DecisionTree::fit(&xs, &ys, TreeParams::default());
        let lr = LinReg::fit(&xs, &ys).unwrap();
        let dt_err = mape(&test, |s| (dt.predict(&[s.batch, s.sm_frac]), s.duration_s));
        let lr_err = mape(&test, |s| (lr.predict(&[s.batch, s.sm_frac]), s.duration_s));
        assert!(dt_err < lr_err, "dt {dt_err} vs lr {lr_err}");
        assert!(dt_err < 0.10, "dt error {dt_err}");
    }

    #[test]
    fn predictions_are_positive() {
        let p = StagePredictor::train(&artifact::memory(3), &gpu(), &ProfileConfig::default());
        crate::util::testkit::forall(3, 200, |r| {
            (1 + r.below(128) as u32, r.range_f64(0.01, 1.0))
        }, |&(b, q)| {
            p.duration(b, q) > 0.0 && p.throughput(b, q) >= 0.0 && p.bandwidth(b, q) >= 0.0
        });
    }
}
