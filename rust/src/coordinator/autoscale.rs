//! Load adaptation (§VIII-C): track the offered load and re-run the
//! Case-2 (min-resource) policy whenever it drifts, so resource usage
//! follows the diurnal curve while the 99%-ile QoS holds.
//!
//! The controller is deliberately hysteretic: replanning has a cost
//! (~10 ms solve + instance churn), so it only fires when the load
//! moves by more than `replan_threshold` relative to the load the
//! current plan was provisioned for, and each plan carries a headroom
//! factor so transient upticks don't immediately violate QoS.

use crate::allocator::SaParams;
use crate::config::ClusterSpec;
use crate::deploy::{Allocation, GpuReservation};
use crate::planner::cache::{CacheStats, SolveCache};
use crate::planner::{ClusterState, Objective, PlanRequest};
use crate::predictor::StagePredictor;
use crate::sim::{Deployment, InstancePlacement, SimOptions, Simulator};
use crate::suite::workload::DiurnalPattern;
use crate::suite::Pipeline;
use crate::util::par;

/// Controller configuration.
#[derive(Debug, Clone)]
pub struct AutoscaleConfig {
    /// Relative load change that triggers a replan (e.g. 0.2 = ±20%).
    pub replan_threshold: f64,
    /// Provision for `load × headroom` so short bursts stay in QoS.
    pub headroom: f64,
    pub batch: u32,
    pub sa: SaParams,
    /// Capacity of the controller's planner [`SolveCache`] (0 disables
    /// memoization). Diurnal days revisit the same load levels, so
    /// replans at a previously seen `(target, holds)` return the cached
    /// — bit-identical — plan instead of re-running the solver.
    pub solve_cache: usize,
    /// Solve-cache payload ([`SolveCache::to_json`]) to warm-start the
    /// controller with (the `camelot colocate --cache-load` path).
    /// Plans are bit-identical warm or cold; only the hit/miss counters
    /// move. Callers validate the payload up front (e.g. via
    /// [`SolveCache::from_json`]) — a malformed payload here loads
    /// nothing, so construction stays infallible.
    pub warm_cache: Option<String>,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            replan_threshold: 0.20,
            headroom: 1.15,
            batch: 32,
            sa: SaParams::default(),
            solve_cache: 256,
            warm_cache: None,
        }
    }
}

/// One autoscaling decision.
#[derive(Debug, Clone)]
pub struct Plan {
    pub allocation: Allocation,
    pub deployment: Deployment,
    /// Load (queries/s) this plan was provisioned for.
    pub provisioned_qps: f64,
    /// Σ N·p resource usage.
    pub usage: f64,
}

/// The §VIII-C controller: owns the predictors and the current plan.
pub struct Autoscaler<'a> {
    pipeline: &'a Pipeline,
    cluster: &'a ClusterSpec,
    predictors: &'a [StagePredictor],
    config: AutoscaleConfig,
    current: Option<Plan>,
    replans: usize,
    /// Reservations the current plan was solved against — a change in
    /// the co-tenants' holds forces a replan even when the load is
    /// inside the hysteresis band (the old plan may overlap capacity
    /// the neighbors now claim).
    last_reserved: Vec<GpuReservation>,
    /// Memoized planner: replans at a previously seen (target, holds)
    /// return the cached solution bit-identically.
    cache: SolveCache,
    /// Entries [`AutoscaleConfig::warm_cache`] loaded at construction.
    warm_loaded: usize,
}

impl<'a> Autoscaler<'a> {
    pub fn new(
        pipeline: &'a Pipeline,
        cluster: &'a ClusterSpec,
        predictors: &'a [StagePredictor],
        config: AutoscaleConfig,
    ) -> Self {
        let cache = SolveCache::new(config.solve_cache);
        let warm_loaded = match &config.warm_cache {
            Some(json) => cache.load_json(json).unwrap_or(0),
            None => 0,
        };
        Autoscaler {
            pipeline,
            cluster,
            predictors,
            config,
            current: None,
            replans: 0,
            last_reserved: Vec::new(),
            cache,
            warm_loaded,
        }
    }

    pub fn current(&self) -> Option<&Plan> {
        self.current.as_ref()
    }

    /// Number of replans performed so far (hysteresis effectiveness).
    pub fn replans(&self) -> usize {
        self.replans
    }

    /// Planner solve-cache counters (hits/misses/evictions).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Entries [`AutoscaleConfig::warm_cache`] loaded at construction
    /// (0 without a payload).
    pub fn warm_loaded(&self) -> usize {
        self.warm_loaded
    }

    /// The planner-cache contents ([`SolveCache::to_json`]) — the
    /// `camelot colocate --cache-save` payload a later run warm-starts
    /// from.
    pub fn cache_json(&self) -> String {
        self.cache.to_json()
    }

    /// Observe the current offered load; returns a new plan if the
    /// controller decided to re-provision, None if the current plan
    /// stands.
    pub fn observe(&mut self, load_qps: f64) -> Option<&Plan> {
        self.observe_with_reservations(load_qps, &[])
    }

    /// [`observe`](Self::observe) on a shared cluster: plan only into
    /// the capacity co-located tenants leave free (`reserved` is empty
    /// or one entry per GPU, e.g. from
    /// [`crate::deploy::reservations_for`]).
    ///
    /// Returns `Some` with the fresh plan after a replan, `None` when
    /// the current plan stands. A replan that finds *no feasible plan*
    /// also returns `None`, but distinguishes the two failure shapes:
    /// on a load-driven replan the stale plan is kept (graceful
    /// degradation — the old capacity still exists); on a
    /// reservation-driven replan [`current`](Self::current) is cleared,
    /// because the old plan may overlap capacity the co-tenants now
    /// hold and running it would fail merged admission.
    pub fn observe_with_reservations(
        &mut self,
        load_qps: f64,
        reserved: &[GpuReservation],
    ) -> Option<&Plan> {
        let reserved_changed = self.last_reserved.as_slice() != reserved;
        let needs_replan = match &self.current {
            None => true,
            Some(p) => {
                let rel = (load_qps * self.config.headroom - p.provisioned_qps).abs()
                    / p.provisioned_qps.max(1e-9);
                rel > self.config.replan_threshold || reserved_changed
            }
        };
        if !needs_replan {
            return None;
        }
        let target = load_qps * self.config.headroom;
        // one plan-driven path: Case 2 at the target against the shared
        // cluster state; near/above capacity fall back to Case 1
        let state = ClusterState::with_reservations(self.cluster, reserved);
        let request = PlanRequest::new(
            Objective::MinResource { load_qps: target },
            state,
            self.pipeline,
            self.predictors,
        )
        .batch(self.config.batch)
        .sa(self.config.sa);
        let solution = self
            .cache
            .plan(&request)
            .or_else(|_| self.cache.plan(&request.clone().objective(Objective::MaxLoad)));
        let Ok(solution) = solution else {
            if reserved_changed {
                // the old plan was solved against different holds and
                // may now be oversubscribed — do not keep serving it
                self.current = None;
            }
            return None;
        };
        self.replans += 1;
        self.last_reserved = reserved.to_vec();
        self.current = Some(Plan {
            allocation: solution.allocation,
            deployment: solution.deployment,
            provisioned_qps: target,
            usage: solution.usage,
        });
        self.current.as_ref()
    }
}

/// How many instances a replan starts or stops: placements present in
/// one deployment but not the other, multiset-style. This is the unit
/// the closed loop charges churn for (model reload + MPS context spin-up
/// on start, connection draining on stop).
pub fn placement_churn(old: &[InstancePlacement], new: &[InstancePlacement]) -> usize {
    let mut matched = vec![false; old.len()];
    let mut started = 0usize;
    for p in new {
        match (0..old.len()).find(|&i| !matched[i] && old[i] == *p) {
            Some(i) => matched[i] = true,
            None => started += 1,
        }
    }
    let stopped = matched.iter().filter(|&&m| !m).count();
    started + stopped
}

/// Configuration of the closed replanning loop: how often the
/// controller wakes up, how long the simulated day is, and what a
/// replan costs.
#[derive(Debug, Clone)]
pub struct EpochLoopConfig {
    /// Plan-epoch length in seconds of simulated day time.
    pub epoch_s: f64,
    /// Number of epochs to run (epochs × epoch_s should cover the
    /// diurnal period for the savings numbers to mean anything).
    pub epochs: usize,
    /// Queries simulated per epoch to measure that epoch's p99.
    pub queries_per_epoch: usize,
    /// Seconds of provisioning disruption charged per instance started
    /// or stopped at a replan (§VIII-C prices a replan at ~10 ms solve
    /// plus instance churn; the churn dominates).
    pub churn_cost_s: f64,
    pub seed: u64,
}

impl Default for EpochLoopConfig {
    fn default() -> Self {
        EpochLoopConfig {
            epoch_s: 7_200.0,
            epochs: 12,
            queries_per_epoch: 1_500,
            churn_cost_s: 0.5,
            seed: 42,
        }
    }
}

/// One epoch of the closed loop.
#[derive(Debug, Clone)]
pub struct EpochRecord {
    /// Epoch start, seconds into the simulated day.
    pub t_s: f64,
    pub load_qps: f64,
    pub replanned: bool,
    /// Instances started + stopped by this epoch's replan (0 if none).
    pub churn_instances: usize,
    /// Σ N·p of the active plan.
    pub usage: f64,
    pub p99_s: f64,
    pub qos_met: bool,
}

/// Closed-loop outcome over the whole trace.
#[derive(Debug, Clone)]
pub struct ClosedLoopReport {
    pub epochs: Vec<EpochRecord>,
    pub replans: usize,
    /// Time-averaged Σ N·p across epochs.
    pub mean_usage: f64,
    /// Σ N·p of a static plan provisioned for the diurnal peak — the
    /// baseline the §VIII-C savings are measured against.
    pub static_usage: f64,
    /// Total churn charged (instances changed × churn_cost_s).
    pub churn_s: f64,
    pub qos_violations: usize,
    /// Planner solve-cache counters of the loop's autoscaler (diurnal
    /// days revisit load levels, so warm epochs hit).
    pub solve_cache: CacheStats,
    /// The autoscaler's final cache contents ([`SolveCache::to_json`])
    /// — `camelot colocate --cache-save` persists this for the next
    /// run's warm start.
    pub cache_json: String,
}

impl ClosedLoopReport {
    /// Fractional resource savings of following the load vs static peak
    /// provisioning (the paper reports ~35% over a Google diurnal day).
    pub fn savings_vs_static(&self) -> f64 {
        if self.static_usage <= 0.0 {
            return 0.0;
        }
        1.0 - self.mean_usage / self.static_usage
    }
}

/// Drive [`Autoscaler`] through a diurnal day in a closed loop: at each
/// plan epoch the controller observes `pattern.rate_at(t)`, replans if
/// the drift beats its hysteresis threshold (charging churn for every
/// instance started or stopped), and the epoch is then simulated at its
/// offered load to measure the delivered p99.
///
/// Planning is sequential (controller state), but the per-epoch
/// simulations are independent once the plans are fixed, so they fan
/// across cores via [`par::par_map`] — deterministically, as each epoch
/// seeds from `cfg.seed` and its index.
pub fn run_closed_loop(
    pipeline: &Pipeline,
    cluster: &ClusterSpec,
    predictors: &[StagePredictor],
    config: AutoscaleConfig,
    pattern: &DiurnalPattern,
    cfg: &EpochLoopConfig,
) -> Option<ClosedLoopReport> {
    // static baseline: one plan sized for the peak, held all day
    let static_usage = {
        let mut s = Autoscaler::new(pipeline, cluster, predictors, config.clone());
        s.observe(pattern.peak_qps)?;
        s.current().unwrap().usage
    };

    // phase 1 (sequential): run the controller over the trace
    struct EpochPlan {
        t_s: f64,
        load_qps: f64,
        replanned: bool,
        churn_instances: usize,
        usage: f64,
        deployment: Deployment,
    }
    let mut scaler = Autoscaler::new(pipeline, cluster, predictors, config);
    let mut prev_placements: Vec<InstancePlacement> = Vec::new();
    let mut plans: Vec<EpochPlan> = Vec::with_capacity(cfg.epochs);
    for e in 0..cfg.epochs {
        let t_s = e as f64 * cfg.epoch_s;
        let load_qps = pattern.rate_at(t_s);
        let replanned = scaler.observe(load_qps).is_some();
        let plan = scaler.current()?;
        let churn_instances = if replanned {
            placement_churn(&prev_placements, &plan.deployment.placements)
        } else {
            0
        };
        prev_placements = plan.deployment.placements.clone();
        plans.push(EpochPlan {
            t_s,
            load_qps,
            replanned,
            churn_instances,
            usage: plan.usage,
            deployment: plan.deployment.clone(),
        });
    }

    // phase 2 (parallel): simulate every epoch at its offered load
    let p99s: Vec<Option<f64>> = par::par_map(&plans, |e, ep| {
        let opts = SimOptions {
            seed: crate::util::rng::mix_seed(cfg.seed, e as u64),
            queries: cfg.queries_per_epoch,
            ..Default::default()
        };
        Simulator::new(pipeline, cluster, &ep.deployment, opts)
            .run(ep.load_qps.max(1.0))
            .ok()
            .map(|r| r.p99())
    });

    let mut epochs = Vec::with_capacity(cfg.epochs);
    let mut churn_total = 0usize;
    let mut usage_sum = 0.0;
    let mut violations = 0usize;
    for (ep, p99) in plans.into_iter().zip(p99s) {
        let p99_s = p99?;
        let qos_met = p99_s <= pipeline.qos_target_s;
        if !qos_met {
            violations += 1;
        }
        churn_total += ep.churn_instances;
        usage_sum += ep.usage;
        epochs.push(EpochRecord {
            t_s: ep.t_s,
            load_qps: ep.load_qps,
            replanned: ep.replanned,
            churn_instances: ep.churn_instances,
            usage: ep.usage,
            p99_s,
            qos_met,
        });
    }
    let n = epochs.len().max(1) as f64;
    Some(ClosedLoopReport {
        replans: scaler.replans(),
        mean_usage: usage_sum / n,
        static_usage,
        churn_s: churn_total as f64 * cfg.churn_cost_s,
        qos_violations: violations,
        solve_cache: scaler.cache_stats(),
        cache_json: scaler.cache_json(),
        epochs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::figures::common::train_predictors;
    use crate::sim::{SimOptions, Simulator};
    use crate::suite::{real, workload::DiurnalPattern};

    #[test]
    fn scales_usage_with_load() {
        let p = real::img_to_img();
        let c = ClusterSpec::two_2080ti();
        let preds = train_predictors(&p, &c);
        let mut a = Autoscaler::new(&p, &c, &preds, AutoscaleConfig::default());
        a.observe(100.0).expect("initial plan");
        let low = a.current().unwrap().usage;
        a.observe(500.0).expect("replans upward");
        let high = a.current().unwrap().usage;
        assert!(high > low, "usage {high} must grow from {low}");
        a.observe(100.0).expect("replans back down");
        let back = a.current().unwrap().usage;
        assert!(back < high, "usage {back} must shrink from {high}");
    }

    #[test]
    fn hysteresis_suppresses_small_changes() {
        let p = real::text_to_text();
        let c = ClusterSpec::two_2080ti();
        let preds = train_predictors(&p, &c);
        let mut a = Autoscaler::new(&p, &c, &preds, AutoscaleConfig::default());
        a.observe(200.0).expect("initial plan");
        assert_eq!(a.replans(), 1);
        // ±10% wobble: below the 20% threshold, no replans
        for load in [210.0, 190.0, 205.0, 195.0] {
            assert!(a.observe(load).is_none());
        }
        assert_eq!(a.replans(), 1);
    }

    #[test]
    fn replan_fires_above_threshold() {
        let p = real::text_to_text();
        let c = ClusterSpec::two_2080ti();
        let preds = train_predictors(&p, &c);
        let mut a = Autoscaler::new(&p, &c, &preds, AutoscaleConfig::default());
        a.observe(200.0).expect("initial plan");
        assert_eq!(a.replans(), 1);
        // +30% drift: rel change of the headroom-scaled target is 0.30,
        // above the 0.20 threshold — must replan
        assert!(a.observe(260.0).is_some());
        assert_eq!(a.replans(), 2);
        // and back down past the threshold on the other side
        assert!(a.observe(150.0).is_some());
        assert_eq!(a.replans(), 3);
    }

    #[test]
    fn headroom_keeps_qos_across_step_load_trace() {
        // a step trace with jumps the hysteresis absorbs (in-threshold)
        // and jumps it must react to; after every step the delivered p99
        // at the *actual* load must stay within QoS — that is what the
        // headroom factor buys
        let p = real::img_to_text();
        let c = ClusterSpec::two_2080ti();
        let preds = train_predictors(&p, &c);
        let mut a = Autoscaler::new(&p, &c, &preds, AutoscaleConfig::default());
        let trace = [120.0, 130.0, 115.0, 300.0, 320.0, 180.0, 90.0];
        let opts = SimOptions { queries: 1_200, ..Default::default() };
        for (i, &load) in trace.iter().enumerate() {
            a.observe(load);
            let plan = a.current().expect("always provisioned");
            let rep = Simulator::new(&p, &c, &plan.deployment, opts.clone())
                .run(load)
                .unwrap();
            assert!(
                rep.p99() <= p.qos_target_s * 1.1,
                "step {i}: p99 {} at load {load}",
                rep.p99()
            );
        }
        // the ±10% wobbles must not have triggered replans
        assert!(a.replans() <= 4, "replans {}", a.replans());
    }

    #[test]
    fn reservation_change_forces_replan_despite_stable_load() {
        use crate::deploy::GpuReservation;
        let p = real::text_to_text();
        let c = ClusterSpec::two_2080ti();
        let preds = train_predictors(&p, &c);
        let mut a = Autoscaler::new(&p, &c, &preds, AutoscaleConfig::default());
        a.observe(150.0).expect("initial plan");
        assert_eq!(a.replans(), 1);
        // same load, unchanged (empty) reservations: hysteresis holds
        assert!(a.observe_with_reservations(150.0, &[]).is_none());
        // same load, but a co-tenant now holds capacity: must replan —
        // the old plan may overlap the neighbor's new footprint
        let held = vec![
            GpuReservation { sm_frac: 0.3, contexts: 2, ..Default::default() };
            c.num_gpus
        ];
        assert!(a.observe_with_reservations(150.0, &held).is_some());
        assert_eq!(a.replans(), 2);
        // and repeating with the same holds settles again
        assert!(a.observe_with_reservations(150.0, &held).is_none());
    }

    #[test]
    fn placement_churn_counts_starts_and_stops() {
        use crate::sim::InstancePlacement;
        let a = vec![
            InstancePlacement { stage: 0, gpu: 0, sm_frac: 0.5 },
            InstancePlacement { stage: 1, gpu: 1, sm_frac: 0.4 },
        ];
        // identical → zero churn
        assert_eq!(placement_churn(&a, &a), 0);
        // one instance resized: one stop + one start
        let b = vec![
            InstancePlacement { stage: 0, gpu: 0, sm_frac: 0.5 },
            InstancePlacement { stage: 1, gpu: 1, sm_frac: 0.6 },
        ];
        assert_eq!(placement_churn(&a, &b), 2);
        // pure scale-out: only starts
        let c = vec![
            InstancePlacement { stage: 0, gpu: 0, sm_frac: 0.5 },
            InstancePlacement { stage: 1, gpu: 1, sm_frac: 0.4 },
            InstancePlacement { stage: 1, gpu: 0, sm_frac: 0.4 },
        ];
        assert_eq!(placement_churn(&a, &c), 1);
        // from empty: everything starts
        assert_eq!(placement_churn(&[], &a), 2);
    }

    #[test]
    fn closed_loop_saves_resources_and_holds_qos() {
        let p = real::img_to_text();
        let c = ClusterSpec::two_2080ti();
        let preds = train_predictors(&p, &c);
        let pattern = DiurnalPattern::new(400.0);
        let cfg = EpochLoopConfig { queries_per_epoch: 1_200, ..Default::default() };
        let rep = run_closed_loop(
            &p,
            &c,
            &preds,
            AutoscaleConfig::default(),
            &pattern,
            &cfg,
        )
        .expect("closed loop completes");
        assert_eq!(rep.epochs.len(), cfg.epochs);
        // usage follows the load curve: cheaper than static peak
        // provisioning (§VIII-C's savings claim, qualitatively)
        assert!(
            rep.savings_vs_static() > 0.10,
            "savings {:.3} (mean {} vs static {})",
            rep.savings_vs_static(),
            rep.mean_usage,
            rep.static_usage
        );
        // QoS holds while it saves (small tolerance for tail noise)
        assert!(
            rep.qos_violations == 0
                || rep.epochs.iter().all(|e| e.p99_s <= p.qos_target_s * 1.1),
            "violations {}",
            rep.qos_violations
        );
        // hysteresis: replans well below epoch count, and churn is
        // charged exactly when replans happen
        assert!(rep.replans >= 2 && rep.replans < cfg.epochs);
        let churned: usize = rep.epochs.iter().map(|e| e.churn_instances).sum();
        assert!(churned > 0);
        assert!((rep.churn_s - churned as f64 * cfg.churn_cost_s).abs() < 1e-9);
        for e in &rep.epochs {
            if !e.replanned {
                assert_eq!(e.churn_instances, 0, "churn without a replan");
            }
        }
        // trough epochs must use less than peak epochs
        let trough = rep
            .epochs
            .iter()
            .map(|e| e.usage)
            .fold(f64::INFINITY, f64::min);
        let peak = rep.epochs.iter().map(|e| e.usage).fold(0.0f64, f64::max);
        assert!(peak > trough, "usage must track the curve");
    }

    #[test]
    fn shared_cluster_planning_respects_reservations() {
        use crate::deploy::{reservations_for, GpuReservation};
        let pa = real::img_to_text();
        let pb = real::text_to_text();
        let c = ClusterSpec::two_2080ti();
        let preds_a = train_predictors(&pa, &c);
        let preds_b = train_predictors(&pb, &c);
        // tenant A provisions first
        let mut sa = Autoscaler::new(&pa, &c, &preds_a, AutoscaleConfig::default());
        sa.observe(150.0).expect("tenant A plans");
        let da = sa.current().unwrap().deployment.clone();
        let held: Vec<GpuReservation> = reservations_for(&pa, &c, &da);
        // tenant B plans into the remainder
        let mut sb = Autoscaler::new(&pb, &c, &preds_b, AutoscaleConfig::default());
        sb.observe_with_reservations(100.0, &held)
            .expect("tenant B fits the remainder");
        let db = sb.current().unwrap().deployment.clone();
        // the combined deployment must co-exist on the shared GPUs:
        // the multi-tenant engine's merged admission is the arbiter
        use crate::sim::{ClusterSim, TenantSpec};
        use crate::suite::workload::ArrivalProcess;
        let sim = ClusterSim::new(
            &c,
            vec![
                TenantSpec {
                    pipeline: &pa,
                    deployment: &da,
                    arrivals: ArrivalProcess::constant(150.0),
                },
                TenantSpec {
                    pipeline: &pb,
                    deployment: &db,
                    arrivals: ArrivalProcess::constant(100.0),
                },
            ],
            SimOptions { queries: 800, ..Default::default() },
        );
        sim.admit().expect("reservation-planned tenants co-exist");
        let reps = sim.run().unwrap();
        assert_eq!(reps.len(), 2);
        assert!(reps[0].p99() > 0.0 && reps[1].p99() > 0.0);
    }

    #[test]
    fn diurnal_day_meets_qos_with_few_replans() {
        // sample a diurnal day at 2-hour ticks; every plan must meet the
        // QoS at its tick's load on the simulator
        let p = real::img_to_text();
        let c = ClusterSpec::two_2080ti();
        let preds = train_predictors(&p, &c);
        let mut a = Autoscaler::new(&p, &c, &preds, AutoscaleConfig::default());
        let day = DiurnalPattern::new(400.0);
        let opts = SimOptions { queries: 1_200, ..Default::default() };
        for tick in 0..12 {
            let load = day.rate_at(tick as f64 * 7_200.0);
            a.observe(load);
            let plan = a.current().expect("always provisioned");
            let rep = Simulator::new(&p, &c, &plan.deployment, opts.clone())
                .run(load)
                .unwrap();
            assert!(
                rep.p99() <= p.qos_target_s * 1.1,
                "tick {tick}: p99 {} at load {load:.0}",
                rep.p99()
            );
        }
        // hysteresis: far fewer replans than ticks
        assert!(a.replans() < 12, "replans {}", a.replans());
        assert!(a.replans() >= 2, "must adapt at least twice over a day");
    }
}
