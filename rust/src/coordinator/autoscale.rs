//! Load adaptation (§VIII-C): track the offered load and re-run the
//! Case-2 (min-resource) policy whenever it drifts, so resource usage
//! follows the diurnal curve while the 99%-ile QoS holds.
//!
//! The controller is deliberately hysteretic: replanning has a cost
//! (~10 ms solve + instance churn), so it only fires when the load
//! moves by more than `replan_threshold` relative to the load the
//! current plan was provisioned for, and each plan carries a headroom
//! factor so transient upticks don't immediately violate QoS.

use crate::allocator::{max_load, min_resource, AllocContext, SaParams};
use crate::comm::CommMode;
use crate::config::ClusterSpec;
use crate::deploy::{self, Allocation};
use crate::predictor::StagePredictor;
use crate::sim::Deployment;
use crate::suite::Pipeline;

/// Controller configuration.
#[derive(Debug, Clone)]
pub struct AutoscaleConfig {
    /// Relative load change that triggers a replan (e.g. 0.2 = ±20%).
    pub replan_threshold: f64,
    /// Provision for `load × headroom` so short bursts stay in QoS.
    pub headroom: f64,
    pub batch: u32,
    pub sa: SaParams,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            replan_threshold: 0.20,
            headroom: 1.15,
            batch: 32,
            sa: SaParams::default(),
        }
    }
}

/// One autoscaling decision.
#[derive(Debug, Clone)]
pub struct Plan {
    pub allocation: Allocation,
    pub deployment: Deployment,
    /// Load (queries/s) this plan was provisioned for.
    pub provisioned_qps: f64,
    /// Σ N·p resource usage.
    pub usage: f64,
}

/// The §VIII-C controller: owns the predictors and the current plan.
pub struct Autoscaler<'a> {
    pipeline: &'a Pipeline,
    cluster: &'a ClusterSpec,
    predictors: &'a [StagePredictor],
    config: AutoscaleConfig,
    current: Option<Plan>,
    replans: usize,
}

impl<'a> Autoscaler<'a> {
    pub fn new(
        pipeline: &'a Pipeline,
        cluster: &'a ClusterSpec,
        predictors: &'a [StagePredictor],
        config: AutoscaleConfig,
    ) -> Self {
        Autoscaler { pipeline, cluster, predictors, config, current: None, replans: 0 }
    }

    pub fn current(&self) -> Option<&Plan> {
        self.current.as_ref()
    }

    /// Number of replans performed so far (hysteresis effectiveness).
    pub fn replans(&self) -> usize {
        self.replans
    }

    /// Observe the current offered load; returns a new plan if the
    /// controller decided to re-provision, None if the current plan
    /// stands.
    pub fn observe(&mut self, load_qps: f64) -> Option<&Plan> {
        let needs_replan = match &self.current {
            None => true,
            Some(p) => {
                let rel = (load_qps * self.config.headroom - p.provisioned_qps).abs()
                    / p.provisioned_qps.max(1e-9);
                rel > self.config.replan_threshold
            }
        };
        if !needs_replan {
            return None;
        }
        let target = load_qps * self.config.headroom;
        let ctx = AllocContext::new(self.pipeline, self.cluster, self.predictors, self.config.batch);
        // Case 2 at the target; near/above capacity fall back to Case 1
        let allocation = match min_resource::solve(&ctx, target, self.config.sa) {
            Some((r, _gpus)) => r.best,
            None => max_load::solve(&ctx, self.config.sa)?.best,
        };
        let demands = ctx.bw_budget_storage(&allocation);
        let deployment = deploy::deploy(
            self.pipeline,
            self.cluster,
            &allocation,
            self.config.batch,
            CommMode::GlobalIpc,
            demands.as_deref().map(|d| deploy::BwBudget {
                demands: d,
                cap: 0.75 * self.cluster.gpu.mem_bw,
            }),
        )
        .ok()?;
        let usage = allocation.total_quota();
        self.replans += 1;
        self.current = Some(Plan {
            allocation,
            deployment,
            provisioned_qps: target,
            usage,
        });
        self.current.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::figures::common::train_predictors;
    use crate::sim::{SimOptions, Simulator};
    use crate::suite::{real, workload::DiurnalPattern};

    #[test]
    fn scales_usage_with_load() {
        let p = real::img_to_img();
        let c = ClusterSpec::two_2080ti();
        let preds = train_predictors(&p, &c);
        let mut a = Autoscaler::new(&p, &c, &preds, AutoscaleConfig::default());
        a.observe(100.0).expect("initial plan");
        let low = a.current().unwrap().usage;
        a.observe(500.0).expect("replans upward");
        let high = a.current().unwrap().usage;
        assert!(high > low, "usage {high} must grow from {low}");
        a.observe(100.0).expect("replans back down");
        let back = a.current().unwrap().usage;
        assert!(back < high, "usage {back} must shrink from {high}");
    }

    #[test]
    fn hysteresis_suppresses_small_changes() {
        let p = real::text_to_text();
        let c = ClusterSpec::two_2080ti();
        let preds = train_predictors(&p, &c);
        let mut a = Autoscaler::new(&p, &c, &preds, AutoscaleConfig::default());
        a.observe(200.0).expect("initial plan");
        assert_eq!(a.replans(), 1);
        // ±10% wobble: below the 20% threshold, no replans
        for load in [210.0, 190.0, 205.0, 195.0] {
            assert!(a.observe(load).is_none());
        }
        assert_eq!(a.replans(), 1);
    }

    #[test]
    fn diurnal_day_meets_qos_with_few_replans() {
        // sample a diurnal day at 2-hour ticks; every plan must meet the
        // QoS at its tick's load on the simulator
        let p = real::img_to_text();
        let c = ClusterSpec::two_2080ti();
        let preds = train_predictors(&p, &c);
        let mut a = Autoscaler::new(&p, &c, &preds, AutoscaleConfig::default());
        let day = DiurnalPattern::new(400.0);
        let opts = SimOptions { queries: 1_200, ..Default::default() };
        for tick in 0..12 {
            let load = day.rate_at(tick as f64 * 7_200.0);
            a.observe(load);
            let plan = a.current().expect("always provisioned");
            let rep = Simulator::new(&p, &c, &plan.deployment, opts.clone())
                .run(load)
                .unwrap();
            assert!(
                rep.p99() <= p.qos_target_s * 1.1,
                "tick {tick}: p99 {} at load {load:.0}",
                rep.p99()
            );
        }
        // hysteresis: far fewer replans than ticks
        assert!(a.replans() < 12, "replans {}", a.replans());
        assert!(a.replans() >= 2, "must adapt at least twice over a day");
    }
}
