//! Standalone dynamic batcher (§V-B steps 1–2): accumulate queries,
//! flush when the batch is full or the head query's wait hits the
//! QoS-derived deadline.
//!
//! The coordinator workers embed this policy inline against blocking
//! channels; this type exposes the same policy over explicit timestamps
//! so it can be unit-tested, property-tested, and reused by the
//! simulator-side coordinator.

use std::collections::VecDeque;

/// When to flush a pending batch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchPolicy {
    pub batch: usize,
    /// Max head-of-line wait in seconds.
    pub max_wait_s: f64,
}

/// Decision returned by [`Batcher::poll`].
#[derive(Debug, Clone, PartialEq)]
pub enum BatchDecision<T> {
    /// Issue these queries now.
    Flush(Vec<T>),
    /// Nothing to do until this absolute time (None = until new input).
    Wait(Option<f64>),
}

/// Timestamped batching queue.
#[derive(Debug, Clone)]
pub struct Batcher<T> {
    policy: BatchPolicy,
    pending: VecDeque<(T, f64)>,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        assert!(policy.batch >= 1 && policy.max_wait_s >= 0.0);
        Batcher { policy, pending: VecDeque::new() }
    }

    pub fn push(&mut self, item: T, now_s: f64) {
        self.pending.push_back((item, now_s));
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Check the flush condition at time `now_s`.
    pub fn poll(&mut self, now_s: f64) -> BatchDecision<T> {
        if self.pending.is_empty() {
            return BatchDecision::Wait(None);
        }
        let head_t = self.pending.front().unwrap().1;
        let deadline = head_t + self.policy.max_wait_s;
        if self.pending.len() >= self.policy.batch || now_s >= deadline - 1e-12 {
            let n = self.pending.len().min(self.policy.batch);
            return BatchDecision::Flush(
                (0..n).map(|_| self.pending.pop_front().unwrap().0).collect(),
            );
        }
        BatchDecision::Wait(Some(deadline))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit;

    fn policy(batch: usize, wait: f64) -> BatchPolicy {
        BatchPolicy { batch, max_wait_s: wait }
    }

    #[test]
    fn flushes_when_full() {
        let mut b = Batcher::new(policy(3, 10.0));
        b.push(1, 0.0);
        b.push(2, 0.1);
        assert!(matches!(b.poll(0.2), BatchDecision::Wait(Some(_))));
        b.push(3, 0.2);
        assert_eq!(b.poll(0.2), BatchDecision::Flush(vec![1, 2, 3]));
        assert!(b.is_empty());
    }

    #[test]
    fn flushes_partial_on_deadline() {
        let mut b = Batcher::new(policy(8, 0.05));
        b.push("q", 1.0);
        assert_eq!(b.poll(1.01), BatchDecision::Wait(Some(1.05)));
        assert_eq!(b.poll(1.05), BatchDecision::Flush(vec!["q"]));
    }

    #[test]
    fn never_exceeds_batch_size() {
        let mut b = Batcher::new(policy(4, 1.0));
        for i in 0..10 {
            b.push(i, 0.0);
        }
        match b.poll(0.0) {
            BatchDecision::Flush(v) => {
                assert_eq!(v, vec![0, 1, 2, 3]);
                assert_eq!(b.len(), 6);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn fifo_order_property() {
        testkit::forall_res(
            17,
            50,
            |r| {
                let n = 1 + r.below(40);
                let batch = 1 + r.below(8);
                let wait = r.range_f64(0.001, 0.1);
                (n, batch, wait, r.next_u64())
            },
            |&(n, batch, wait, seed)| {
                let mut r = crate::util::Rng::new(seed);
                let mut b = Batcher::new(policy(batch, wait));
                let mut t = 0.0;
                let mut pushed = Vec::new();
                let mut flushed = Vec::new();
                for i in 0..n {
                    t += r.range_f64(0.0, 0.05);
                    b.push(i, t);
                    pushed.push(i);
                    if let BatchDecision::Flush(v) = b.poll(t) {
                        if v.len() > batch {
                            return Err("flush exceeds batch".into());
                        }
                        flushed.extend(v);
                    }
                }
                // drain
                loop {
                    match b.poll(t + 1000.0) {
                        BatchDecision::Flush(v) => flushed.extend(v),
                        BatchDecision::Wait(_) => break,
                    }
                }
                if flushed != pushed {
                    return Err(format!("order broken: {flushed:?} vs {pushed:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn deadline_bounds_wait_property() {
        // no query may sit in the batcher past its deadline if poll is
        // called at the deadline
        testkit::forall(23, 100, |r| (1 + r.below(16), r.range_f64(0.01, 0.2)), |&(batch, wait)| {
            let mut b = Batcher::new(policy(batch, wait));
            b.push(0u32, 5.0);
            match b.poll(5.0 + wait) {
                BatchDecision::Flush(_) => true,
                BatchDecision::Wait(_) => false,
            }
        });
    }
}
