//! Execution backends for the coordinator.
//!
//! * [`PjrtBackend`] — the production path: each stage is an AOT HLO
//!   artifact compiled on the PJRT CPU client; batches of query payloads
//!   are packed into the artifact's batch dimension and executed.
//! * [`MockBackend`] — deterministic stand-in for control-plane tests
//!   and benches (configurable output width and synthetic service time).

use std::path::PathBuf;
use std::sync::mpsc::{self, Sender};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::runtime::Engine;

/// A pipeline-stage executor: takes per-query payload rows, returns
/// per-query output rows.
pub trait ExecBackend: Send + Sync {
    fn execute(&self, stage: usize, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>>;
}

/// One execution request routed to the PJRT executor thread.
struct Job {
    stage: usize,
    rows: Vec<Vec<f32>>,
    reply: Sender<Result<Vec<Vec<f32>>>>,
}

/// Production backend over the PJRT [`Engine`].
///
/// The `xla` crate's PJRT handles are not `Send`, so a dedicated
/// executor thread owns the engine (one CPU "device") and worker
/// threads submit batches over a channel — the same single-device
/// serialization a real accelerator queue imposes.
///
/// Each stage maps to one artifact (stage name + compiled batch size).
/// Incoming batches are zero-padded up to the artifact batch and the
/// padding rows are discarded on output — the AOT program has a static
/// shape, exactly like a real serving deployment with fixed batching.
pub struct PjrtBackend {
    jobs: Mutex<Sender<Job>>,
    n_stages: usize,
    batch: usize,
}

impl PjrtBackend {
    /// Spawn the executor thread and pre-compile all (stage, batch)
    /// artifacts from `artifacts_dir`.
    pub fn new(
        artifacts_dir: impl Into<PathBuf>,
        stages: &[String],
        batch: usize,
    ) -> Result<PjrtBackend> {
        let dir = artifacts_dir.into();
        let stages_owned: Vec<String> = stages.to_vec();
        let (tx, rx) = mpsc::channel::<Job>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        std::thread::spawn(move || {
            // the engine lives entirely on this thread (PJRT handles are
            // not Send)
            let mut engine = match Engine::open(&dir) {
                Ok(e) => e,
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            for s in &stages_owned {
                if let Err(e) = engine.load_stage(s, batch as u32) {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            }
            let _ = ready_tx.send(Ok(()));
            while let Ok(job) = rx.recv() {
                let result = run_job(&mut engine, &stages_owned, batch, job.stage, &job.rows);
                let _ = job.reply.send(result);
            }
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow!("executor thread died during startup"))??;
        Ok(PjrtBackend { jobs: Mutex::new(tx), n_stages: stages.len(), batch })
    }
}

fn run_job(
    engine: &mut Engine,
    stages: &[String],
    batch: usize,
    stage: usize,
    rows: &[Vec<f32>],
) -> Result<Vec<Vec<f32>>> {
    let name = stages
        .get(stage)
        .ok_or_else(|| anyhow!("stage index {stage} out of range"))?;
    if rows.is_empty() || rows.len() > batch {
        return Err(anyhow!(
            "{name}: batch of {} rows (artifact batch {batch})",
            rows.len()
        ));
    }
    let exe = engine.load_stage(name, batch as u32)?;
    let d_in = *exe
        .meta
        .input_shape
        .last()
        .ok_or_else(|| anyhow!("{name}: scalar input shape"))?;
    let d_out = *exe.meta.output_shape.last().unwrap();
    // pack rows + zero-pad to the artifact's static batch
    let mut packed = vec![0.0f32; batch * d_in];
    for (i, row) in rows.iter().enumerate() {
        if row.len() != d_in {
            return Err(anyhow!(
                "{name}: row {i} has {} features, artifact wants {d_in}",
                row.len()
            ));
        }
        packed[i * d_in..(i + 1) * d_in].copy_from_slice(row);
    }
    let out = exe.run(&packed)?;
    Ok((0..rows.len()).map(|i| out[i * d_out..(i + 1) * d_out].to_vec()).collect())
}

impl ExecBackend for PjrtBackend {
    fn execute(&self, stage: usize, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if stage >= self.n_stages {
            return Err(anyhow!("stage index {stage} out of range"));
        }
        if inputs.len() > self.batch {
            return Err(anyhow!("batch {} exceeds artifact batch {}", inputs.len(), self.batch));
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        self.jobs
            .lock()
            .unwrap()
            .send(Job {
                stage,
                rows: inputs.iter().map(|r| r.to_vec()).collect(),
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("executor thread gone"))?;
        reply_rx.recv().map_err(|_| anyhow!("executor thread gone"))?
    }
}

/// Deterministic mock: per-stage synthetic service time, configurable
/// output width (or identity).
pub struct MockBackend {
    n_stages: usize,
    out_width: Option<usize>,
    work: Duration,
}

impl MockBackend {
    pub fn new(n_stages: usize, out_width: usize, work: Duration) -> MockBackend {
        MockBackend { n_stages, out_width: Some(out_width), work }
    }

    /// Pass payloads through unchanged, with zero service time.
    pub fn identity(n_stages: usize) -> MockBackend {
        MockBackend { n_stages, out_width: None, work: Duration::ZERO }
    }
}

impl ExecBackend for MockBackend {
    fn execute(&self, stage: usize, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if stage >= self.n_stages {
            return Err(anyhow!("stage {stage} out of range"));
        }
        if !self.work.is_zero() {
            std::thread::sleep(self.work);
        }
        Ok(inputs
            .iter()
            .map(|row| match self.out_width {
                Some(w) => {
                    let s: f32 = row.iter().sum();
                    vec![s / row.len().max(1) as f32; w]
                }
                None => row.to_vec(),
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_identity_roundtrip() {
        let b = MockBackend::identity(1);
        let out = b.execute(0, &[&[1.0, 2.0], &[3.0, 4.0]]).unwrap();
        assert_eq!(out, vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
    }

    #[test]
    fn mock_rejects_bad_stage() {
        let b = MockBackend::identity(2);
        assert!(b.execute(2, &[&[1.0]]).is_err());
    }

    #[test]
    fn pjrt_backend_runs_real_pipeline_if_artifacts_exist() {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let stages = vec!["vgg_features".to_string(), "lstm_caption".to_string()];
        let b = PjrtBackend::new(dir, &stages, 8).unwrap();
        let row = vec![0.1f32; 512];
        let rows: Vec<&[f32]> = vec![&row, &row, &row];
        let s1 = b.execute(0, &rows).unwrap();
        assert_eq!(s1.len(), 3);
        assert_eq!(s1[0].len(), 512);
        // identical inputs → identical outputs (padding must not leak)
        assert_eq!(s1[0], s1[1]);
        let s1_refs: Vec<&[f32]> = s1.iter().map(|r| r.as_slice()).collect();
        let s2 = b.execute(1, &s1_refs).unwrap();
        assert_eq!(s2[0].len(), 512);
        assert!(s2[0].iter().all(|x| x.is_finite()));
    }
}
