//! Sharded cluster-of-cells scale-out: one flat datacenter cluster is
//! split into N **cells**, each owning its own [`AdmissionController`]
//! (and therefore its own `ClusterState` and planner `SolveCache`),
//! fronted by a [`CellRouter`] that places arriving tenants load-aware
//! and keeps the fleet defragmented with cross-cell migrations.
//!
//! This is the two-level master/local shape MISO and ParvaGPU argue
//! cloud-scale spatial GPU sharing needs: every planning decision runs
//! against a cell of `G/N` GPUs instead of the whole fleet, so the
//! per-decision cost (QoS folds are `O(residents² × GPUs)`, re-pack
//! passes `O(residents × GPUs)`, request fingerprints `O(GPUs)`)
//! shrinks with the cell size — the scale-out win `bench_cells`
//! measures in replay events/s.
//!
//! * **Routing** ([`CellRouter::try_admit`]): cells are tried
//!   least-utilized first (Σ quota / cell GPUs, ties broken by cell
//!   index — fully deterministic), and a rejection falls through to the
//!   next-best cell; the arrival is rejected only when every cell turns
//!   it away, reporting the first-choice cell's reason.
//! * **Migration** ([`CellRouter::depart`]): when a departure's local
//!   re-pack reclaims whole GPUs, the router tries to back-fill the
//!   freed capacity with a *small* tenant (Σ N·p ≤
//!   [`CellsConfig::migrate_max_quota`]) from the most-loaded donor
//!   cell — but only a tenant whose removal immediately frees a whole
//!   GPU in its donor, and at most
//!   [`CellsConfig::migrations_per_repack`] moves per departure. Both
//!   conditions are hysteresis: migrations happen exactly when they
//!   reclaim devices on both ends, never to chase marginal balance.
//! * **Sharded replay** ([`replay_trace_cells`]): admission decisions
//!   stay sequential in global event order (phase 1), but the
//!   between-event interval simulations shard by cell — cells share
//!   nothing, so each cell's intervals dedup and simulate independently
//!   against the cell's own `ClusterSpec`, fanned as a two-level
//!   cell × interval map under [`par::split_budget`]. With `cells = 1`
//!   the merged report is **bit-identical** to the flat
//!   [`replay_trace`](super::admission::replay_trace) (the golden suite
//!   pins it), and any cell count is thread-count-deterministic.

use std::collections::HashMap;
use std::fmt::Write as _;

use crate::config::ClusterSpec;
use crate::coordinator::admission::{
    self, AdmissionConfig, AdmissionController, GpuFailReport, IntervalReport,
    QosViolationRecord, RejectReason, RepackPlan, ReplayConfig, ReplayEvent, ReplayReport,
    ShrinkReport,
};
use crate::deploy::gpus_in_use;
use crate::planner::cache::SolveCache;
use crate::planner::CacheStats;
use crate::sim::{ClusterSim, SimOptions, Simulator, TenantSpec};
use crate::suite::workload::{
    ArrivalProcess, Priority, TenantTrace, TenantTraceEvent, TraceEventKind,
};
use crate::suite::Pipeline;
use crate::util::json::Json;
use crate::util::{par, rng};

/// Router configuration: cell count plus the per-cell admission knobs.
#[derive(Debug, Clone)]
pub struct CellsConfig {
    /// Number of cells the cluster splits into (1 = the flat path).
    pub cells: usize,
    /// Per-cell controller configuration (every cell plans with the
    /// same seed; cells are independent, so this never correlates
    /// their decisions).
    pub admission: AdmissionConfig,
    /// Largest footprint (Σ N·p over stages) a tenant may have and
    /// still be migration-eligible — only *small* tenants move.
    pub migrate_max_quota: f64,
    /// Cross-cell migration attempts per applied departure re-pack
    /// (churn hysteresis; 0 disables migration entirely).
    pub migrations_per_repack: usize,
}

impl Default for CellsConfig {
    fn default() -> Self {
        CellsConfig {
            cells: 1,
            admission: AdmissionConfig::default(),
            migrate_max_quota: 1.0,
            migrations_per_repack: 1,
        }
    }
}

/// Split `spec` into `cells` disjoint sub-clusters, distributing GPUs
/// as evenly as possible (the first `num_gpus mod cells` cells get one
/// extra). Errors when the split is degenerate.
pub fn split_cluster(spec: &ClusterSpec, cells: usize) -> Result<Vec<ClusterSpec>, String> {
    if cells == 0 {
        return Err("cells must be >= 1".into());
    }
    if cells > spec.num_gpus {
        return Err(format!(
            "cannot split {} GPUs into {} cells (each cell needs >= 1 GPU)",
            spec.num_gpus, cells
        ));
    }
    let base = spec.num_gpus / cells;
    let extra = spec.num_gpus % cells;
    let mut start = 0usize;
    Ok((0..cells)
        .map(|i| {
            let len = base + usize::from(i < extra);
            // slice(), not a bare num_gpus override: on a mixed pool
            // each cell inherits exactly the classes of its GPU range
            let cell = spec.slice(start, len);
            start += len;
            cell
        })
        .collect())
}

/// One cross-cell move the router performed during a departure re-pack.
#[derive(Debug, Clone)]
pub struct CellMigration {
    pub tenant: String,
    pub from_cell: usize,
    pub to_cell: usize,
    /// Whether the donor cell's own post-departure re-pack applied.
    pub donor_repack_applied: bool,
}

/// Outcome of [`CellRouter::depart`]: the owning cell's re-pack plan
/// plus any cross-cell migrations it triggered.
#[derive(Debug, Clone)]
pub struct DepartOutcome {
    /// Cell the departing tenant lived in.
    pub cell: usize,
    pub plan: RepackPlan,
    pub migrations: Vec<CellMigration>,
}

/// router resident id -> (cell, cell-local resident id)
#[derive(Debug, Clone, Copy)]
struct Assignment {
    router_id: u64,
    cell: usize,
    local_id: u64,
}

/// The top-level router fronting N per-cell [`AdmissionController`]s.
/// All routing is deterministic: identical call sequences produce
/// identical placements, migrations, and counters.
pub struct CellRouter {
    cfg: CellsConfig,
    specs: Vec<ClusterSpec>,
    cells: Vec<AdmissionController>,
    assignments: Vec<Assignment>,
    next_id: u64,
    admitted: usize,
    rejected: usize,
    migrations: usize,
}

impl CellRouter {
    pub fn new(cluster: &ClusterSpec, cfg: CellsConfig) -> Result<CellRouter, String> {
        let specs = split_cluster(cluster, cfg.cells)?;
        let cells = specs
            .iter()
            .map(|s| AdmissionController::new(s.clone(), cfg.admission.clone()))
            .collect();
        Ok(CellRouter {
            cfg,
            specs,
            cells,
            assignments: Vec::new(),
            next_id: 0,
            admitted: 0,
            rejected: 0,
            migrations: 0,
        })
    }

    pub fn num_cells(&self) -> usize {
        self.cells.len()
    }

    pub fn cell(&self, c: usize) -> &AdmissionController {
        &self.cells[c]
    }

    pub fn cell_spec(&self, c: usize) -> &ClusterSpec {
        &self.specs[c]
    }

    /// Arrivals the router admitted (each counted once, whichever cell
    /// took it; migrations are not arrivals and do not count).
    pub fn admitted(&self) -> usize {
        self.admitted
    }

    /// Arrivals every cell turned away (counted once per arrival; the
    /// per-cell controllers additionally count each *attempt* they saw).
    pub fn rejected(&self) -> usize {
        self.rejected
    }

    /// Cross-cell migrations performed so far.
    pub fn migrations(&self) -> usize {
        self.migrations
    }

    pub fn residents_total(&self) -> usize {
        self.cells.iter().map(|c| c.residents().len()).sum()
    }

    /// Whole GPUs occupied fleet-wide (cells own disjoint devices, so
    /// the per-cell counts just add).
    pub fn gpus_in_use(&self) -> usize {
        self.cells.iter().map(|c| c.gpus_in_use()).sum()
    }

    /// Fleet-wide Σ quota over all residents.
    pub fn total_usage(&self) -> f64 {
        self.cells.iter().map(|c| c.total_usage()).sum()
    }

    /// Summed planner-cache counters across every cell.
    pub fn cache_stats(&self) -> CacheStats {
        merge_cache_stats(self.cells.iter().map(|c| c.cache_stats()))
    }

    /// Summed deadline-degraded plan count across every cell (see
    /// [`AdmissionController::degraded_plans`]).
    pub fn degraded_plans(&self) -> usize {
        self.cells.iter().map(|c| c.degraded_plans()).sum()
    }

    fn utilization(&self, c: usize) -> f64 {
        self.cells[c].total_usage() / self.specs[c].num_gpus as f64
    }

    /// Cells in placement-preference order: least utilized first, ties
    /// broken by cell index (utilizations are exact arithmetic on
    /// deterministic quotas, so this order is reproducible).
    fn placement_order(&self) -> Vec<usize> {
        let mut order: Vec<usize> = (0..self.cells.len()).collect();
        order.sort_by(|&a, &b| {
            self.utilization(a)
                .partial_cmp(&self.utilization(b))
                .expect("utilization is finite")
                .then(a.cmp(&b))
        });
        order
    }

    /// Route an arrival: try cells least-utilized first, falling
    /// through to the next-best cell on rejection. Returns the router
    /// resident id and the cell that took the tenant; when every cell
    /// rejects, the *first-choice* cell's reason is reported.
    pub fn try_admit(
        &mut self,
        name: &str,
        pipeline: &Pipeline,
        arrivals: ArrivalProcess,
        plan_qps: f64,
    ) -> Result<(u64, usize), RejectReason> {
        self.try_admit_prio(name, pipeline, arrivals, plan_qps, Priority::LatencyCritical)
            .map(|(id, cell, _)| (id, cell))
    }

    /// [`try_admit`](Self::try_admit) with an explicit service tier and
    /// best-effort preemption. Two passes over the same
    /// least-utilized-first cell order: plain admission everywhere
    /// first, then — only for a latency-critical arrival every cell
    /// turned away — a preemption pass over the cells that actually
    /// house best-effort residents (so a best-effort-free fleet behaves
    /// exactly like plain routing, counters included). The reported
    /// rejection stays the *first-choice* cell's plain reason; the
    /// returned eviction list is empty when plain admission sufficed.
    pub fn try_admit_prio(
        &mut self,
        name: &str,
        pipeline: &Pipeline,
        arrivals: ArrivalProcess,
        plan_qps: f64,
        priority: Priority,
    ) -> Result<(u64, usize, Vec<String>), RejectReason> {
        let order = self.placement_order();
        let mut first_reason: Option<RejectReason> = None;
        for &c in &order {
            match self.cells[c].admit_with_priority(
                name,
                pipeline,
                arrivals.clone(),
                plan_qps,
                priority,
            ) {
                Ok(local_id) => {
                    let router_id = self.next_id;
                    self.next_id += 1;
                    self.admitted += 1;
                    self.assignments.push(Assignment { router_id, cell: c, local_id });
                    return Ok((router_id, c, Vec::new()));
                }
                Err(reason) => {
                    if first_reason.is_none() {
                        first_reason = Some(reason);
                    }
                }
            }
        }
        if priority == Priority::LatencyCritical {
            for &c in &order {
                let has_best_effort = self.cells[c]
                    .residents()
                    .iter()
                    .any(|r| r.priority == Priority::BestEffort);
                if !has_best_effort {
                    continue;
                }
                if let Ok((local_id, evicted)) = self.cells[c].admit_preempting(
                    name,
                    pipeline,
                    arrivals.clone(),
                    plan_qps,
                    priority,
                ) {
                    // preempted tenants left cell c's resident set
                    self.purge_assignments(c);
                    let router_id = self.next_id;
                    self.next_id += 1;
                    self.admitted += 1;
                    self.assignments.push(Assignment { router_id, cell: c, local_id });
                    return Ok((router_id, c, evicted));
                }
            }
        }
        self.rejected += 1;
        Err(first_reason.expect("router has at least one cell"))
    }

    /// Whether `router_id` still addresses a resident (departures,
    /// preemptions, and failure evictions all retire ids).
    pub fn is_resident(&self, router_id: u64) -> bool {
        self.assignments.iter().any(|a| a.router_id == router_id)
    }

    /// Drop assignments whose resident no longer lives in `cell`
    /// (preemption and failure evictions remove residents cell-side).
    fn purge_assignments(&mut self, cell: usize) {
        let alive: Vec<u64> =
            self.cells[cell].residents().iter().map(|r| r.id).collect();
        self.assignments
            .retain(|a| a.cell != cell || alive.contains(&a.local_id));
    }

    /// Global GPU id -> (owning cell, cell-local id). Cells own
    /// contiguous global ranges in cell-index order —
    /// [`split_cluster`]'s layout. `None` for out-of-range ids.
    fn locate_gpu(&self, gpu: usize) -> Option<(usize, usize)> {
        let mut base = 0usize;
        for (c, spec) in self.specs.iter().enumerate() {
            if gpu < base + spec.num_gpus {
                return Some((c, gpu - base));
            }
            base += spec.num_gpus;
        }
        None
    }

    /// Take the listed *global* GPU ids out of service, routing each to
    /// its owning cell ([`AdmissionController::fail_gpus`] semantics per
    /// cell). Returns `(cell, report)` pairs in ascending cell order;
    /// reports speak cell-local GPU ids. With one cell the raw list is
    /// forwarded verbatim — bit-identical to the flat controller,
    /// out-of-range filtering included.
    pub fn fail_gpus(&mut self, gpu_ids: &[usize]) -> Vec<(usize, GpuFailReport)> {
        if self.cells.len() == 1 {
            let rep = self.cells[0].fail_gpus(gpu_ids);
            self.purge_assignments(0);
            return vec![(0, rep)];
        }
        let mut per_cell: Vec<Vec<usize>> = vec![Vec::new(); self.cells.len()];
        for &g in gpu_ids {
            if let Some((c, local)) = self.locate_gpu(g) {
                per_cell[c].push(local);
            }
        }
        let mut out = Vec::new();
        for (c, locals) in per_cell.into_iter().enumerate() {
            if locals.is_empty() {
                continue;
            }
            let rep = self.cells[c].fail_gpus(&locals);
            self.purge_assignments(c);
            out.push((c, rep));
        }
        out
    }

    /// Return the listed *global* GPU ids to service; each owning cell
    /// runs its normal churn-gated re-pack. Same shape and single-cell
    /// verbatim-forwarding contract as [`fail_gpus`](Self::fail_gpus).
    pub fn recover_gpus(&mut self, gpu_ids: &[usize]) -> Vec<(usize, RepackPlan)> {
        if self.cells.len() == 1 {
            return vec![(0, self.cells[0].recover_gpus(gpu_ids))];
        }
        let mut per_cell: Vec<Vec<usize>> = vec![Vec::new(); self.cells.len()];
        for &g in gpu_ids {
            if let Some((c, local)) = self.locate_gpu(g) {
                per_cell[c].push(local);
            }
        }
        let mut out = Vec::new();
        for (c, locals) in per_cell.into_iter().enumerate() {
            if locals.is_empty() {
                continue;
            }
            out.push((c, self.cells[c].recover_gpus(&locals)));
        }
        out
    }

    /// Slow down the listed *global* GPU ids (ECC/thermal degrade),
    /// routing each to its owning cell
    /// ([`AdmissionController::degrade_gpus`] semantics per cell,
    /// QoS-eviction included). Returns `(cell, (applied locals,
    /// evicted tenants))` pairs in ascending cell order. Same
    /// single-cell verbatim-forwarding contract as
    /// [`fail_gpus`](Self::fail_gpus).
    pub fn degrade_gpus(
        &mut self,
        gpu_ids: &[usize],
        scale: f64,
    ) -> Vec<(usize, (Vec<usize>, Vec<String>))> {
        if self.cells.len() == 1 {
            let rep = self.cells[0].degrade_gpus(gpu_ids, scale);
            self.purge_assignments(0);
            return vec![(0, rep)];
        }
        let mut per_cell: Vec<Vec<usize>> = vec![Vec::new(); self.cells.len()];
        for &g in gpu_ids {
            if let Some((c, local)) = self.locate_gpu(g) {
                per_cell[c].push(local);
            }
        }
        let mut out = Vec::new();
        for (c, locals) in per_cell.into_iter().enumerate() {
            if locals.is_empty() {
                continue;
            }
            let rep = self.cells[c].degrade_gpus(&locals, scale);
            self.purge_assignments(c);
            out.push((c, rep));
        }
        out
    }

    /// Restore the listed *global* GPU ids to full speed; each owning
    /// cell runs its churn-gated re-pack. Same shape and single-cell
    /// contract as [`recover_gpus`](Self::recover_gpus).
    pub fn restore_gpus(&mut self, gpu_ids: &[usize]) -> Vec<(usize, RepackPlan)> {
        if self.cells.len() == 1 {
            return vec![(0, self.cells[0].restore_gpus(gpu_ids))];
        }
        let mut per_cell: Vec<Vec<usize>> = vec![Vec::new(); self.cells.len()];
        for &g in gpu_ids {
            if let Some((c, local)) = self.locate_gpu(g) {
                per_cell[c].push(local);
            }
        }
        let mut out = Vec::new();
        for (c, locals) in per_cell.into_iter().enumerate() {
            if locals.is_empty() {
                continue;
            }
            out.push((c, self.cells[c].restore_gpus(&locals)));
        }
        out
    }

    /// Fleet-wide predicted-QoS audit: the per-cell
    /// [`AdmissionController::qos_audit`] results concatenated in cell
    /// order (cells share nothing, so no cross-cell interference term
    /// exists to add).
    pub fn qos_audit(&self) -> Vec<(String, f64, f64)> {
        self.cells.iter().flat_map(|c| c.qos_audit()).collect()
    }

    /// The offered-load model of a resident, by router id.
    pub fn resident_arrivals(&self, router_id: u64) -> Option<&ArrivalProcess> {
        let a = self.assignments.iter().find(|a| a.router_id == router_id)?;
        self.cells[a.cell].resident_arrivals(a.local_id)
    }

    /// Re-pin a resident's offered-load model (flash-crowd bookkeeping;
    /// the admitted plan is untouched). False when `router_id` is not
    /// resident.
    pub fn set_resident_arrivals(&mut self, router_id: u64, arrivals: ArrivalProcess) -> bool {
        match self.assignments.iter().find(|a| a.router_id == router_id).copied() {
            Some(a) => self.cells[a.cell].set_resident_arrivals(a.local_id, arrivals),
            None => false,
        }
    }

    /// Shrink a resident in place (the owning cell re-plans it).
    pub fn shrink_resident(&mut self, router_id: u64, target_qps: f64) -> Option<ShrinkReport> {
        let a = *self.assignments.iter().find(|a| a.router_id == router_id)?;
        self.cells[a.cell].shrink_resident(a.local_id, target_qps)
    }

    /// Remove a resident; the owning cell re-packs, and when that
    /// re-pack reclaims whole GPUs the router back-fills the freed
    /// capacity by migrating small tenants in from the most-loaded
    /// donor cell (see the module docs for the hysteresis conditions).
    pub fn depart(&mut self, router_id: u64) -> Option<DepartOutcome> {
        let pos = self.assignments.iter().position(|a| a.router_id == router_id)?;
        let a = self.assignments.remove(pos);
        let plan = self.cells[a.cell].depart(a.local_id)?;
        let mut migrations = Vec::new();
        if plan.applied && plan.gpus_after < plan.gpus_before && self.cells.len() > 1 {
            for _ in 0..self.cfg.migrations_per_repack {
                match self.try_migrate_into(a.cell) {
                    Some(m) => migrations.push(m),
                    None => break,
                }
            }
        }
        Some(DepartOutcome { cell: a.cell, plan, migrations })
    }

    /// One migration attempt into `target`: pick the smallest eligible
    /// tenant of the most-loaded donor cell (a tenant is eligible when
    /// its footprint is ≤ `migrate_max_quota` *and* removing it frees a
    /// whole GPU in the donor), admit it into `target`, then depart it
    /// from the donor. At most one candidate is tried — a rejection by
    /// `target` ends the pass (churn hysteresis).
    fn try_migrate_into(&mut self, target: usize) -> Option<CellMigration> {
        let mut donors: Vec<usize> = (0..self.cells.len())
            .filter(|&d| d != target && !self.cells[d].residents().is_empty())
            .collect();
        donors.sort_by(|&x, &y| {
            self.utilization(y)
                .partial_cmp(&self.utilization(x))
                .expect("utilization is finite")
                .then(x.cmp(&y))
        });
        for d in donors {
            let donor_gpus = self.cells[d].gpus_in_use();
            // smallest eligible resident: (quota, local id) minimum
            let mut best: Option<(f64, u64)> = None;
            for r in self.cells[d].residents() {
                let quota = r.allocation.total_quota();
                if quota > self.cfg.migrate_max_quota + 1e-9 {
                    continue;
                }
                let without = gpus_in_use(
                    self.cells[d]
                        .residents()
                        .iter()
                        .filter(|x| x.id != r.id)
                        .map(|x| &x.deployment),
                );
                if without >= donor_gpus {
                    continue; // removing it frees nothing: not worth churn
                }
                let better = match best {
                    None => true,
                    Some((q, id)) => quota < q || (quota == q && r.id < id),
                };
                if better {
                    best = Some((quota, r.id));
                }
            }
            let Some((_, local_id)) = best else { continue };
            let r = self.cells[d]
                .residents()
                .iter()
                .find(|r| r.id == local_id)
                .expect("candidate resident exists");
            let (name, pipeline, arrivals, plan_qps, priority) = (
                r.name.clone(),
                r.pipeline.clone(),
                r.arrivals.clone(),
                r.plan_qps,
                r.priority,
            );
            // plain admission with the migrant's own tier — a migration
            // must never preempt anyone, and a best-effort tenant stays
            // best-effort in its new cell
            return match self.cells[target].admit_with_priority(
                &name, &pipeline, arrivals, plan_qps, priority,
            ) {
                Ok(new_local) => {
                    let donor_plan =
                        self.cells[d].depart(local_id).expect("donor resident departs");
                    if let Some(a) = self
                        .assignments
                        .iter_mut()
                        .find(|a| a.cell == d && a.local_id == local_id)
                    {
                        a.cell = target;
                        a.local_id = new_local;
                    }
                    self.migrations += 1;
                    Some(CellMigration {
                        tenant: name,
                        from_cell: d,
                        to_cell: target,
                        donor_repack_applied: donor_plan.applied,
                    })
                }
                Err(_) => None,
            };
        }
        None
    }

    /// Serialize the full router state — placement counters, the
    /// router-id → (cell, local-id) table, and every per-cell
    /// controller ([`AdmissionController::state_json`]) — as one JSON
    /// object with the same bit-exact conventions.
    /// [`restore_state`](Self::restore_state) inverts it.
    pub fn state_json(&self) -> String {
        let mut out = String::new();
        let _ = write!(
            out,
            "{{\"next_id\": \"{}\", \"admitted\": {}, \"rejected\": {}, \"migrations\": {}",
            self.next_id, self.admitted, self.rejected, self.migrations
        );
        out.push_str(", \"assignments\": [");
        for (i, a) in self.assignments.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            let _ = write!(out, "[\"{}\", {}, \"{}\"]", a.router_id, a.cell, a.local_id);
        }
        out.push_str("], \"cells\": [");
        for (i, c) in self.cells.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str(&c.state_json());
        }
        out.push_str("]}");
        out
    }

    /// Rebuild a router from [`state_json`](Self::state_json) output.
    /// `cluster` and `cfg` are the same inputs the original router was
    /// built with (configuration, not decisions); the snapshot's cell
    /// count must match the configuration's.
    pub fn restore_state(
        cluster: &ClusterSpec,
        cfg: CellsConfig,
        v: &Json,
        pipelines: &[Pipeline],
    ) -> Result<CellRouter, String> {
        let specs = split_cluster(cluster, cfg.cells)?;
        let cells_v =
            v.get("cells").and_then(Json::as_arr).ok_or("router snapshot missing cells")?;
        if cells_v.len() != specs.len() {
            return Err(format!(
                "router snapshot has {} cells, configuration wants {}",
                cells_v.len(),
                specs.len()
            ));
        }
        let cells = specs
            .iter()
            .zip(cells_v)
            .map(|(s, cv)| {
                AdmissionController::restore_state(s.clone(), cfg.admission.clone(), cv, pipelines)
            })
            .collect::<Result<Vec<_>, String>>()?;
        let parse_id = |j: &Json, what: &str| -> Result<u64, String> {
            j.as_str()
                .ok_or_else(|| format!("{what} must be a string"))?
                .parse::<u64>()
                .map_err(|e| format!("bad {what}: {e}"))
        };
        let mut assignments = Vec::new();
        for av in v
            .get("assignments")
            .and_then(Json::as_arr)
            .ok_or("router snapshot missing assignments")?
        {
            let triple = av.as_arr().ok_or("assignment must be a triple")?;
            if triple.len() != 3 {
                return Err("assignment must be [router_id, cell, local_id]".to_string());
            }
            let cell = triple[1].as_f64().ok_or("assignment cell must be a number")? as usize;
            if cell >= cells.len() {
                return Err(format!("assignment references cell {cell} of {}", cells.len()));
            }
            assignments.push(Assignment {
                router_id: parse_id(&triple[0], "router id")?,
                cell,
                local_id: parse_id(&triple[2], "local id")?,
            });
        }
        Ok(CellRouter {
            cfg,
            specs,
            cells,
            assignments,
            next_id: v
                .get_str("next_id")
                .ok_or("router snapshot missing next_id")?
                .parse::<u64>()
                .map_err(|e| format!("bad next_id: {e}"))?,
            admitted: admission::snap_usize(v, "admitted")?,
            rejected: admission::snap_usize(v, "rejected")?,
            migrations: admission::snap_usize(v, "migrations")?,
        })
    }

    /// Test-only: install a hand-built resident directly into `cell`,
    /// registering it with the router (mirrors
    /// `AdmissionController::insert_resident`).
    #[cfg(test)]
    fn insert_for_test(
        &mut self,
        cell: usize,
        name: &str,
        pipeline: &Pipeline,
        allocation: crate::deploy::Allocation,
        deployment: crate::sim::Deployment,
        plan_qps: f64,
    ) -> u64 {
        let local_id =
            self.cells[cell].insert_resident(name, pipeline, allocation, deployment, plan_qps);
        let router_id = self.next_id;
        self.next_id += 1;
        self.assignments.push(Assignment { router_id, cell, local_id });
        router_id
    }
}

fn merge_cache_stats(stats: impl Iterator<Item = CacheStats>) -> CacheStats {
    let mut out = CacheStats::default();
    for s in stats {
        out.hits += s.hits;
        out.misses += s.misses;
        out.evictions += s.evictions;
        out.entries += s.entries;
    }
    out
}

/// Sharded-replay configuration — [`ReplayConfig`]'s knobs with a
/// router configuration in place of the single controller's.
#[derive(Debug, Clone)]
pub struct CellsReplayConfig {
    pub router: CellsConfig,
    /// Queries per tenant in each between-event validation simulation.
    pub queries: usize,
    /// Worker budget for the two-level cell × interval fan (0 = default
    /// pool). Results are identical for any value (golden-pinned).
    pub threads: usize,
    /// Per-cell content-addressed interval dedup (same contract as
    /// [`ReplayConfig::dedup`]: bit-identical on or off).
    pub dedup: bool,
    /// Run the fleet-wide predicted-QoS audit after every event (same
    /// contract as [`ReplayConfig::audit_qos`]: pure observation).
    pub audit_qos: bool,
    /// Solve-cache payload to warm-start *every* cell's planner cache
    /// with (same contract as [`ReplayConfig::warm_cache`]: decisions
    /// are bit-identical warm or cold). Cells plan against disjoint
    /// sub-cluster specs, so each cell hits only the entries keyed to
    /// its own shape — sharing one payload is safe.
    pub warm_cache: Option<String>,
}

impl Default for CellsReplayConfig {
    fn default() -> Self {
        CellsReplayConfig {
            router: CellsConfig::default(),
            queries: 1_000,
            threads: 0,
            dedup: true,
            audit_qos: false,
            warm_cache: None,
        }
    }
}

impl CellsReplayConfig {
    /// Lift a flat [`ReplayConfig`] to `cells` cells (the `camelot
    /// admit --cells N` path).
    pub fn from_replay(cells: usize, replay: &ReplayConfig) -> CellsReplayConfig {
        CellsReplayConfig {
            router: CellsConfig {
                cells,
                admission: replay.admission.clone(),
                ..CellsConfig::default()
            },
            queries: replay.queries,
            threads: replay.threads,
            dedup: replay.dedup,
            audit_qos: replay.audit_qos,
            warm_cache: replay.warm_cache.clone(),
        }
    }
}

/// Per-cell slice of a sharded replay.
#[derive(Debug, Clone)]
pub struct CellReplayStats {
    pub cell: usize,
    /// GPUs this cell owns.
    pub gpus: usize,
    /// Cell-local admissions (router placements + migrations in).
    pub admitted: usize,
    /// Cell-local rejected attempts (router fall-through retries and
    /// failed migrations included — attempts, not arrivals).
    pub rejected: usize,
    pub peak_residents: usize,
    /// Between-event intervals this cell contributed.
    pub intervals: usize,
    /// Distinct interval simulations actually run (≤ `intervals`).
    pub intervals_simulated: usize,
    pub solve_cache: CacheStats,
}

/// Outcome of a cell-sharded replay: the merged fleet-level report
/// (bit-identical to the flat replay when `cells = 1`) plus the
/// per-cell breakdown the aggregate hides.
#[derive(Debug, Clone)]
pub struct CellsReplayReport {
    pub cells: usize,
    /// Fleet-level report: events carry fleet totals, intervals are the
    /// per-cell interval measurements in (event, cell) order, counters
    /// are router-level, `solve_cache` is the per-cell sum.
    pub merged: ReplayReport,
    pub per_cell: Vec<CellReplayStats>,
    /// Cross-cell migrations performed.
    pub migrations: usize,
    /// Which cell each admitted trace tenant was routed to, in
    /// admission order — the router-determinism contract pins this
    /// across thread counts.
    pub tenant_cells: Vec<(u64, usize)>,
}

/// Drive a [`CellRouter`] over a [`TenantTrace`] and validate every
/// between-event interval per cell.
///
/// Phase 1 (sequential): routing + admission decisions in global event
/// order — placement depends only on router state, never on simulation
/// results or thread counts. Phase 2 (parallel, sharded): cells share
/// nothing, so each cell's intervals dedup (per-cell content
/// fingerprints) and simulate independently against the cell's own
/// `ClusterSpec`, seeded `mix_seed(mix_seed(seed, cell), first
/// cell-local snapshot index with that content)` — for cell 0 this
/// collapses to the flat replay's seeds (`mix_seed(s, 0) = s`), which
/// is what makes `cells = 1` bit-identical to
/// [`replay_trace`](admission::replay_trace). The fan is two-level
/// (cells × intervals) under [`par::split_budget`], and every seed is
/// assigned before the fan, so any thread count gives identical output.
pub fn replay_trace_cells(
    cluster: &ClusterSpec,
    trace: &TenantTrace,
    cfg: &CellsReplayConfig,
) -> Result<CellsReplayReport, String> {
    let mut state = CellsReplayState::new(cluster, cfg.clone())?;
    // bursts are expanded (synthesized end events, canonical re-sort)
    // only when present, so burst-free traces replay their event list
    // verbatim — exactly the flat replay's contract
    let expanded;
    let trace_events: &[TenantTraceEvent] = if trace.has_bursts() {
        expanded = trace.expanded_events();
        &expanded
    } else {
        &trace.events
    };
    for e in trace_events {
        state.apply_event(e)?;
    }
    state.finish()
}

/// Incremental form of [`replay_trace_cells`] — the durability seam the
/// recovery layer drives: [`new`](Self::new) →
/// [`apply_event`](Self::apply_event) per trace event (each returns the
/// exact [`ReplayEvent`] a write-ahead log persists) →
/// [`finish`](Self::finish). [`snapshot_json`](Self::snapshot_json) and
/// [`restore`](Self::restore) round-trip the full mid-replay state.
pub struct CellsReplayState {
    router: CellRouter,
    cfg: CellsReplayConfig,
    /// trace tenant id -> router resident id
    resident_ids: Vec<(u64, u64)>,
    events: Vec<ReplayEvent>,
    peak_residents: usize,
    repacks_applied: usize,
    repack_regressions: usize,
    qos_violations: Vec<QosViolationRecord>,
    /// trace tenant id -> (pre-burst base arrivals, open burst depth)
    burst_state: HashMap<u64, (ArrivalProcess, usize)>,
    tenant_cells: Vec<(u64, usize)>,
    cell_snapshots: Vec<Vec<admission::IntervalSnapshot>>,
    /// (cell, cell-local snapshot index) in event-major, cell-minor
    /// order — the merged interval order (= the flat order at 1 cell)
    snapshot_order: Vec<(usize, usize)>,
    cell_peaks: Vec<usize>,
}

impl CellsReplayState {
    /// Fresh mid-replay state over a newly routed cell fleet.
    pub fn new(
        cluster: &ClusterSpec,
        cfg: CellsReplayConfig,
    ) -> Result<CellsReplayState, String> {
        let router = CellRouter::new(cluster, cfg.router.clone())?;
        let n_cells = router.num_cells();
        if let Some(json) = &cfg.warm_cache {
            for c in 0..n_cells {
                router.cell(c).warm_start_cache(json)?;
            }
        }
        Ok(CellsReplayState {
            router,
            cfg,
            resident_ids: Vec::new(),
            events: Vec::new(),
            peak_residents: 0,
            repacks_applied: 0,
            repack_regressions: 0,
            qos_violations: Vec::new(),
            burst_state: HashMap::new(),
            tenant_cells: Vec::new(),
            cell_snapshots: vec![Vec::new(); n_cells],
            snapshot_order: Vec::new(),
            cell_peaks: vec![0usize; n_cells],
        })
    }

    /// Events applied so far (the recovery layer's WAL cursor).
    pub fn applied(&self) -> usize {
        self.events.len()
    }

    /// Every cell's planner-cache contents merged into one
    /// [`SolveCache::to_json`] payload (capacity = the per-cell bound ×
    /// cells, so nothing truncates at save time). Keys embed each
    /// cell's sub-cluster spec, so entries never collide across cells
    /// and a reload ([`CellsReplayConfig::warm_cache`]) warm-starts
    /// each cell with exactly its own entries.
    pub fn cache_json(&self) -> Result<String, String> {
        let per_cell = self.cfg.router.admission.solve_cache;
        let merged = SolveCache::new(per_cell.saturating_mul(self.router.num_cells()).max(1));
        for c in 0..self.router.num_cells() {
            merged.load_json(&self.router.cell(c).cache_json())?;
        }
        Ok(merged.to_json())
    }

    /// The decision log so far.
    pub fn events(&self) -> &[ReplayEvent] {
        &self.events
    }

    /// The underlying router (read-only observation).
    pub fn router(&self) -> &CellRouter {
        &self.router
    }

    /// Route one trace event through the cell fleet, returning the
    /// decision record exactly as [`finish`](Self::finish) will report
    /// it — and exactly as a write-ahead log persists it.
    pub fn apply_event(&mut self, e: &TenantTraceEvent) -> Result<ReplayEvent, String> {
        let n_cells = self.router.num_cells();
        let router = &mut self.router;
        let resident_ids = &mut self.resident_ids;
        let burst_state = &mut self.burst_state;
        let (desc, decision) = match &e.kind {
            TraceEventKind::Arrive { pipeline, name, arrivals, plan_qps, priority } => {
                let desc = format!("arrive {pipeline} @ {plan_qps:.0} qps");
                let p = crate::suite::pipeline_by_name(pipeline)
                    .ok_or_else(|| format!("trace names unknown pipeline '{pipeline}'"))?;
                let name = name
                    .clone()
                    .unwrap_or_else(|| format!("{pipeline}#{}", e.tenant));
                let degraded_before = router.degraded_plans();
                let decision = match router.try_admit_prio(
                    &name,
                    &p,
                    arrivals.clone(),
                    *plan_qps,
                    *priority,
                ) {
                    Ok((id, cell, evicted)) => {
                        resident_ids.push((e.tenant, id));
                        self.tenant_cells.push((e.tenant, cell));
                        // deadline-degraded planning is visible in the
                        // decision log (same marker as the flat replay)
                        let mark = if router.degraded_plans() > degraded_before {
                            " (degraded)"
                        } else {
                            ""
                        };
                        if evicted.is_empty() {
                            format!("admitted{mark}")
                        } else {
                            // preempted tenants left the resident set
                            resident_ids.retain(|&(_, rid)| router.is_resident(rid));
                            format!("admitted{mark}; preempted {}", evicted.join(","))
                        }
                    }
                    Err(reason) => format!("rejected: {reason}"),
                };
                (desc, decision)
            }
            TraceEventKind::Shrink { target_qps } => {
                let desc = format!("shrink to {target_qps:.0} qps");
                let decision = match resident_ids.iter().find(|(t, _)| *t == e.tenant) {
                    Some(&(_, id)) => router
                        .shrink_resident(id, *target_qps)
                        .expect("resident shrinks")
                        .summary(),
                    None => "no-op (was not admitted)".to_string(),
                };
                (desc, decision)
            }
            TraceEventKind::Depart => {
                let desc = "depart".to_string();
                let decision = match resident_ids.iter().position(|(t, _)| *t == e.tenant)
                {
                    Some(pos) => {
                        let (_, id) = resident_ids.remove(pos);
                        let out = router.depart(id).expect("resident departs");
                        if out.plan.applied {
                            self.repacks_applied += 1;
                            if out.plan.gpus_after > out.plan.gpus_before {
                                self.repack_regressions += 1;
                            }
                        }
                        let mut decision = out.plan.summary();
                        for m in &out.migrations {
                            if m.donor_repack_applied {
                                self.repacks_applied += 1;
                            }
                            decision.push_str(&format!(
                                " | migrate '{}' cell {}->{}",
                                m.tenant, m.from_cell, m.to_cell
                            ));
                        }
                        decision
                    }
                    None => "no-op (was not admitted)".to_string(),
                };
                (desc, decision)
            }
            TraceEventKind::Burst { rate_mult, duration_s } => {
                let desc = format!("burst x{rate_mult:.1} for {duration_s:.0}s");
                let decision = match resident_ids.iter().find(|(t, _)| *t == e.tenant) {
                    Some(&(_, id)) => {
                        let cur = router
                            .resident_arrivals(id)
                            .expect("resident has arrivals")
                            .clone();
                        let entry = burst_state
                            .entry(e.tenant)
                            .or_insert_with(|| (cur.clone(), 0));
                        entry.1 += 1;
                        let new_peak = cur.peak_qps() * rate_mult;
                        router.set_resident_arrivals(id, cur.scaled_to_peak(new_peak));
                        format!("offered load x{rate_mult:.1} -> {new_peak:.0} qps peak")
                    }
                    None => "no-op (was not admitted)".to_string(),
                };
                (desc, decision)
            }
            TraceEventKind::BurstEnd => {
                let desc = "burst end".to_string();
                let decision = match resident_ids.iter().find(|(t, _)| *t == e.tenant) {
                    Some(&(_, id)) => match burst_state.get_mut(&e.tenant) {
                        Some(entry) if entry.1 > 1 => {
                            entry.1 -= 1;
                            "nested burst still open".to_string()
                        }
                        Some(_) => {
                            let (base, _) = burst_state.remove(&e.tenant).unwrap();
                            let peak = base.peak_qps();
                            router.set_resident_arrivals(id, base);
                            format!("offered load restored -> {peak:.0} qps peak")
                        }
                        None => "no-op (burst never applied)".to_string(),
                    },
                    None => "no-op (was not admitted)".to_string(),
                };
                (desc, decision)
            }
            TraceEventKind::GpuFail { gpu_ids } => {
                let desc = format!("gpufail {gpu_ids:?}");
                let reports = router.fail_gpus(gpu_ids);
                if reports.iter().any(|(_, r)| !r.evicted.is_empty()) {
                    // evicted tenants leave the id map so later events no-op
                    resident_ids.retain(|&(_, rid)| router.is_resident(rid));
                }
                // one cell prints the bare flat summary (cells = 1 is
                // bit-identical to the flat replay); otherwise each
                // affected cell reports in cell-local GPU ids
                let decision = if n_cells == 1 {
                    reports[0].1.summary()
                } else if reports.is_empty() {
                    "no-op (no owned gpus)".to_string()
                } else {
                    reports
                        .iter()
                        .map(|(c, r)| format!("cell {c}: {}", r.summary()))
                        .collect::<Vec<_>>()
                        .join(" | ")
                };
                (desc, decision)
            }
            TraceEventKind::GpuRecover { gpu_ids } => {
                let desc = format!("gpurecover {gpu_ids:?}");
                let plans = router.recover_gpus(gpu_ids);
                for (_, plan) in &plans {
                    if plan.applied {
                        self.repacks_applied += 1;
                        if plan.gpus_after > plan.gpus_before {
                            self.repack_regressions += 1;
                        }
                    }
                }
                let decision = if n_cells == 1 {
                    plans[0].1.summary()
                } else if plans.is_empty() {
                    "no-op (no owned gpus)".to_string()
                } else {
                    plans
                        .iter()
                        .map(|(c, p)| format!("cell {c}: {}", p.summary()))
                        .collect::<Vec<_>>()
                        .join(" | ")
                };
                (desc, decision)
            }
            TraceEventKind::GpuDegrade { gpu_ids, scale } => {
                let desc = format!("gpudegrade {gpu_ids:?} x{scale:.2}");
                let reports = router.degrade_gpus(gpu_ids, *scale);
                if reports.iter().any(|(_, (_, ev))| !ev.is_empty()) {
                    // QoS-evicted tenants leave the id map too
                    resident_ids.retain(|&(_, rid)| router.is_resident(rid));
                }
                let decision = if n_cells == 1 {
                    let (applied, evicted) = &reports[0].1;
                    admission::degrade_summary(applied, *scale, evicted)
                } else if reports.is_empty() {
                    "no-op (no owned gpus)".to_string()
                } else {
                    reports
                        .iter()
                        .map(|(c, (applied, evicted))| {
                            format!(
                                "cell {c}: {}",
                                admission::degrade_summary(applied, *scale, evicted)
                            )
                        })
                        .collect::<Vec<_>>()
                        .join(" | ")
                };
                (desc, decision)
            }
            TraceEventKind::GpuRestore { gpu_ids } => {
                let desc = format!("gpurestore {gpu_ids:?}");
                let plans = router.restore_gpus(gpu_ids);
                for (_, plan) in &plans {
                    if plan.applied {
                        self.repacks_applied += 1;
                        if plan.gpus_after > plan.gpus_before {
                            self.repack_regressions += 1;
                        }
                    }
                }
                let decision = if n_cells == 1 {
                    plans[0].1.summary()
                } else if plans.is_empty() {
                    "no-op (no owned gpus)".to_string()
                } else {
                    plans
                        .iter()
                        .map(|(c, p)| format!("cell {c}: {}", p.summary()))
                        .collect::<Vec<_>>()
                        .join(" | ")
                };
                (desc, decision)
            }
        };
        if self.cfg.audit_qos {
            for (tenant, predicted_p99_s, target_s) in router.qos_audit() {
                self.qos_violations.push(QosViolationRecord {
                    t_s: e.t_s,
                    tenant,
                    predicted_p99_s,
                    target_s,
                });
            }
        }
        self.peak_residents = self.peak_residents.max(router.residents_total());
        let ev = ReplayEvent {
            t_s: e.t_s,
            tenant: e.tenant,
            desc,
            decision,
            residents: router.residents_total(),
            gpus_in_use: router.gpus_in_use(),
            usage: router.total_usage(),
        };
        self.events.push(ev.clone());
        for c in 0..n_cells {
            let residents = router.cell(c).residents();
            self.cell_peaks[c] = self.cell_peaks[c].max(residents.len());
            if !residents.is_empty() {
                self.cell_snapshots[c].push((
                    e.t_s,
                    residents
                        .iter()
                        .map(|r| {
                            (
                                r.name.clone(),
                                r.pipeline.clone(),
                                r.deployment.clone(),
                                r.arrivals.clone(),
                            )
                        })
                        .collect(),
                    // the degrade overlay this cell's intervals must
                    // simulate under (degrade events mutate it mid-trace)
                    router.cell(c).cluster().degrade.clone(),
                ));
                self.snapshot_order.push((c, self.cell_snapshots[c].len() - 1));
            }
        }
        Ok(ev)
    }

    /// Shard the recorded interval snapshots by cell, simulate them, and
    /// merge the fleet-level report (phase 2). Consumes the state.
    pub fn finish(self) -> Result<CellsReplayReport, String> {
        let cfg = &self.cfg;
        let router = &self.router;
        let n_cells = router.num_cells();
        let cell_snapshots = &self.cell_snapshots;
        // phase 2: per-cell content-addressed dedup and seed assignment,
        // sequential (same scheme as the flat replay, per cell), then the
        // two-level cell × interval fan. Seeds derive from the cell index
        // and the cell-local first-occurrence snapshot index only, so the
        // fan split never touches results.
        let threads = if cfg.threads == 0 { par::max_threads() } else { cfg.threads };
        let seed = cfg.router.admission.seed;
        let queries = cfg.queries;
        struct CellPlan {
            /// (cell-local snapshot index providing the content, sim seed)
            jobs: Vec<(usize, u64)>,
            /// per cell-local snapshot: index of the job measuring it
            measure_by: Vec<usize>,
        }
        let mut cell_plans: Vec<CellPlan> = Vec::with_capacity(n_cells);
        for (c, snaps) in cell_snapshots.iter().enumerate() {
            let cell_seed = rng::mix_seed(seed, c as u64);
            let mut jobs: Vec<(usize, u64)> = Vec::with_capacity(snaps.len());
            let mut measure_by: Vec<usize> = Vec::with_capacity(snaps.len());
            let mut seen: HashMap<String, (usize, usize)> = HashMap::new();
            for (idx, (_, tenants, degrade)) in snaps.iter().enumerate() {
                let key = admission::interval_fingerprint(tenants, queries, degrade);
                match seen.get(&key) {
                    Some(&(_, job)) if cfg.dedup => measure_by.push(job),
                    Some(&(owner, _)) => {
                        jobs.push((idx, rng::mix_seed(cell_seed, owner as u64)));
                        measure_by.push(jobs.len() - 1);
                    }
                    None => {
                        jobs.push((idx, rng::mix_seed(cell_seed, idx as u64)));
                        let job = jobs.len() - 1;
                        seen.insert(key, (idx, job));
                        measure_by.push(job);
                    }
                }
            }
            cell_plans.push(CellPlan { jobs, measure_by });
        }
        let intervals_simulated: usize = cell_plans.iter().map(|p| p.jobs.len()).sum();

        let cell_specs: Vec<ClusterSpec> =
            (0..n_cells).map(|c| router.cell_spec(c).clone()).collect();
        let (outer, inner) = par::split_budget(threads, n_cells);
        let cell_ids: Vec<usize> = (0..n_cells).collect();
        let sims: Vec<Vec<Result<(Vec<f64>, Vec<f64>), String>>> =
            par::par_map_threads(&cell_ids, outer, |_, &c| {
                let snaps = &cell_snapshots[c];
                let cell_cluster = &cell_specs[c];
                par::par_map_threads(&cell_plans[c].jobs, inner, |_, &(snap_idx, sim_seed)| {
                    let (_, tenants, degrade) = &snaps[snap_idx];
                    let opts = SimOptions { seed: sim_seed, queries, ..Default::default() };
                    // simulate under the degrade overlay active when the
                    // interval was captured (borrow the pristine cell
                    // spec on the healthy fast path)
                    let owned;
                    let cl: &ClusterSpec = if *degrade == cell_cluster.degrade {
                        cell_cluster
                    } else {
                        owned = ClusterSpec {
                            degrade: degrade.clone(),
                            ..cell_cluster.clone()
                        };
                        &owned
                    };
                    // degenerate fast path, same contract as the flat replay
                    if let [(_, p, d, ArrivalProcess::Constant { rate_qps })] =
                        tenants.as_slice()
                    {
                        let report = Simulator::new(p, cl, d, opts)
                            .run(*rate_qps)
                            .map_err(|e| format!("cell {c} interval {snap_idx}: {e}"))?;
                        return Ok((vec![report.p99()], report.kv_peak_bytes));
                    }
                    let specs: Vec<TenantSpec> = tenants
                        .iter()
                        .map(|(_, p, d, a)| TenantSpec {
                            pipeline: p,
                            deployment: d,
                            arrivals: a.clone(),
                        })
                        .collect();
                    let reports = ClusterSim::new(cl, specs, opts)
                        .run()
                        .map_err(|e| format!("cell {c} interval {snap_idx}: {e}"))?;
                    let kv = reports
                        .first()
                        .map(|r| r.kv_peak_bytes.clone())
                        .unwrap_or_default();
                    Ok((reports.iter().map(|r| r.p99()).collect(), kv))
                })
            });
        let mut p99_tables: Vec<Vec<Vec<f64>>> = Vec::with_capacity(n_cells);
        // cluster-wide per-GPU peak KV residency: cell-local GPU indices
        // map to contiguous global ranges in cell order (the split_cluster
        // layout), so cell c's vector lands at offset Σ_{c'<c} num_gpus
        let total_gpus: usize = cell_specs.iter().map(|s| s.num_gpus).sum();
        let mut kv_peak_bytes = vec![0.0f64; total_gpus];
        let mut cell_offset = 0usize;
        for (c, cell_sims) in sims.into_iter().enumerate() {
            let tables = cell_sims.into_iter().collect::<Result<Vec<_>, _>>()?;
            let mut p99_only = Vec::with_capacity(tables.len());
            for (p99s, kv) in tables {
                for (g, &v) in kv.iter().enumerate() {
                    let slot = &mut kv_peak_bytes[cell_offset + g];
                    if v > *slot {
                        *slot = v;
                    }
                }
                p99_only.push(p99s);
            }
            p99_tables.push(p99_only);
            cell_offset += cell_specs[c].num_gpus;
        }

        let intervals: Vec<IntervalReport> = self
            .snapshot_order
            .iter()
            .map(|&(c, local_idx)| {
                let (t_start, tenants, _) = &cell_snapshots[c][local_idx];
                let job = cell_plans[c].measure_by[local_idx];
                let p99_s: Vec<f64> = p99_tables[c][job].clone();
                let qos_met: Vec<bool> = tenants
                    .iter()
                    .zip(&p99_s)
                    .map(|((_, p, _, _), &x)| x <= p.qos_target_s)
                    .collect();
                IntervalReport {
                    t_start_s: *t_start,
                    tenants: tenants.iter().map(|(n, _, _, _)| n.clone()).collect(),
                    p99_s,
                    qos_met,
                }
            })
            .collect();

        let with_gpus: Vec<usize> = self
            .events
            .iter()
            .filter(|e| e.residents > 0)
            .map(|e| e.gpus_in_use)
            .collect();
        let mean_gpus_in_use = if with_gpus.is_empty() {
            0.0
        } else {
            with_gpus.iter().sum::<usize>() as f64 / with_gpus.len() as f64
        };
        let per_cell: Vec<CellReplayStats> = (0..n_cells)
            .map(|c| CellReplayStats {
                cell: c,
                gpus: cell_specs[c].num_gpus,
                admitted: router.cell(c).admitted(),
                rejected: router.cell(c).rejected(),
                peak_residents: self.cell_peaks[c],
                intervals: cell_snapshots[c].len(),
                intervals_simulated: cell_plans[c].jobs.len(),
                solve_cache: router.cell(c).cache_stats(),
            })
            .collect();
        Ok(CellsReplayReport {
            cells: n_cells,
            merged: ReplayReport {
                admitted: router.admitted(),
                rejected: router.rejected(),
                repacks_applied: self.repacks_applied,
                peak_residents: self.peak_residents,
                mean_gpus_in_use,
                events: self.events,
                intervals,
                intervals_simulated,
                solve_cache: router.cache_stats(),
                qos_violations: self.qos_violations,
                repack_regressions: self.repack_regressions,
                // per-class occupancy is a flat-replay breakdown; the
                // sharded replay reports per-cell stats instead
                class_utilization: Vec::new(),
                kv_peak_bytes,
            },
            per_cell,
            migrations: router.migrations(),
            tenant_cells: self.tenant_cells,
        })
    }
}

impl CellsReplayState {
    /// Serialize the full phase-1 state — router (every per-cell
    /// controller included), tenant-id and tenant→cell maps, decision
    /// log, burst bookkeeping, and the per-cell interval snapshots with
    /// their degrade overlays — as one JSON object, using the same
    /// bit-exact conventions as
    /// [`AdmissionController::state_json`]. This is what a periodic
    /// durability snapshot persists for a sharded replay;
    /// [`restore`](Self::restore) inverts it.
    pub fn snapshot_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\"router\": ");
        out.push_str(&self.router.state_json());
        out.push_str(", \"resident_ids\": [");
        for (i, (t, id)) in self.resident_ids.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            let _ = write!(out, "[\"{t}\", \"{id}\"]");
        }
        out.push_str("], \"tenant_cells\": [");
        for (i, (t, c)) in self.tenant_cells.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            let _ = write!(out, "[\"{t}\", {c}]");
        }
        let _ = write!(
            out,
            "], \"peak_residents\": {}, \"repacks_applied\": {}, \
             \"repack_regressions\": {}",
            self.peak_residents, self.repacks_applied, self.repack_regressions
        );
        out.push_str(", \"cell_peaks\": [");
        for (i, p) in self.cell_peaks.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            let _ = write!(out, "{p}");
        }
        out.push_str("], \"qos_violations\": ");
        admission::json_qos_violations(&mut out, &self.qos_violations);
        out.push_str(", \"burst_state\": ");
        admission::json_burst_state(&mut out, &self.burst_state);
        out.push_str(", \"events\": ");
        admission::json_replay_events(&mut out, &self.events);
        out.push_str(", \"snapshot_order\": [");
        for (i, (c, idx)) in self.snapshot_order.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            let _ = write!(out, "[{c}, {idx}]");
        }
        out.push_str("], \"cell_snapshots\": [");
        for (i, snaps) in self.cell_snapshots.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            admission::json_interval_snapshots(&mut out, snaps);
        }
        out.push_str("]}");
        out
    }

    /// Rebuild a mid-replay state from
    /// [`snapshot_json`](Self::snapshot_json) output. `cluster` and
    /// `cfg` are the same inputs the original replay started with;
    /// pipelines resolve by name from `pipelines` with the registry as
    /// fallback. Applying the remaining trace events reconverges
    /// bit-identically with the uninterrupted replay — the same
    /// recovery contract as the flat
    /// [`ReplayState`](admission::ReplayState).
    pub fn restore(
        cluster: &ClusterSpec,
        cfg: CellsReplayConfig,
        v: &Json,
        pipelines: &[Pipeline],
    ) -> Result<CellsReplayState, String> {
        let mut st = CellsReplayState::new(cluster, cfg)?;
        let n_cells = st.router.num_cells();
        st.router = CellRouter::restore_state(
            cluster,
            st.cfg.router.clone(),
            v.get("router").ok_or("snapshot missing router")?,
            pipelines,
        )?;
        let parse_id = |j: &Json, what: &str| -> Result<u64, String> {
            j.as_str()
                .ok_or_else(|| format!("{what} must be a string"))?
                .parse::<u64>()
                .map_err(|e| format!("bad {what}: {e}"))
        };
        for pair in v
            .get("resident_ids")
            .and_then(Json::as_arr)
            .ok_or("snapshot missing resident_ids")?
        {
            let pair = pair.as_arr().ok_or("resident_ids entry must be a pair")?;
            if pair.len() != 2 {
                return Err("resident_ids entry must be a pair".to_string());
            }
            st.resident_ids
                .push((parse_id(&pair[0], "trace id")?, parse_id(&pair[1], "resident id")?));
        }
        for pair in v
            .get("tenant_cells")
            .and_then(Json::as_arr)
            .ok_or("snapshot missing tenant_cells")?
        {
            let pair = pair.as_arr().ok_or("tenant_cells entry must be a pair")?;
            if pair.len() != 2 {
                return Err("tenant_cells entry must be a pair".to_string());
            }
            let cell = pair[1].as_f64().ok_or("tenant cell must be a number")? as usize;
            if cell >= n_cells {
                return Err(format!("tenant_cells references cell {cell} of {n_cells}"));
            }
            st.tenant_cells.push((parse_id(&pair[0], "trace id")?, cell));
        }
        st.peak_residents = admission::snap_usize(v, "peak_residents")?;
        st.repacks_applied = admission::snap_usize(v, "repacks_applied")?;
        st.repack_regressions = admission::snap_usize(v, "repack_regressions")?;
        st.cell_peaks = v
            .get("cell_peaks")
            .and_then(Json::as_arr)
            .ok_or("snapshot missing cell_peaks")?
            .iter()
            .map(|x| {
                x.as_f64()
                    .map(|f| f as usize)
                    .ok_or_else(|| "cell_peaks entry must be a number".to_string())
            })
            .collect::<Result<Vec<_>, _>>()?;
        if st.cell_peaks.len() != n_cells {
            return Err("cell_peaks length mismatch".to_string());
        }
        st.qos_violations = admission::parse_qos_violations(
            v.get("qos_violations").ok_or("snapshot missing qos_violations")?,
        )?;
        st.burst_state = admission::parse_burst_state(
            v.get("burst_state").ok_or("snapshot missing burst_state")?,
        )?;
        st.events =
            admission::parse_replay_events(v.get("events").ok_or("snapshot missing events")?)?;
        let mut cell_snapshots = Vec::with_capacity(n_cells);
        for snaps in v
            .get("cell_snapshots")
            .and_then(Json::as_arr)
            .ok_or("snapshot missing cell_snapshots")?
        {
            cell_snapshots.push(admission::parse_interval_snapshots(snaps, pipelines)?);
        }
        if cell_snapshots.len() != n_cells {
            return Err("cell_snapshots length mismatch".to_string());
        }
        st.cell_snapshots = cell_snapshots;
        for pair in v
            .get("snapshot_order")
            .and_then(Json::as_arr)
            .ok_or("snapshot missing snapshot_order")?
        {
            let pair = pair.as_arr().ok_or("snapshot_order entry must be a pair")?;
            if pair.len() != 2 {
                return Err("snapshot_order entry must be a pair".to_string());
            }
            let c = pair[0].as_f64().ok_or("snapshot_order cell must be a number")? as usize;
            let idx = pair[1].as_f64().ok_or("snapshot_order index must be a number")? as usize;
            if c >= n_cells || idx >= st.cell_snapshots[c].len() {
                return Err(format!("snapshot_order entry ({c}, {idx}) out of range"));
            }
            st.snapshot_order.push((c, idx));
        }
        Ok(st)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommMode;
    use crate::deploy::Allocation;
    use crate::sim::{Deployment, InstancePlacement};
    use crate::suite::real;

    #[test]
    fn split_cluster_distributes_gpus_evenly() {
        let spec = ClusterSpec { num_gpus: 10, ..ClusterSpec::two_2080ti() };
        let cells = split_cluster(&spec, 4).expect("splits");
        assert_eq!(cells.iter().map(|c| c.num_gpus).collect::<Vec<_>>(), vec![3, 3, 2, 2]);
        assert_eq!(cells.iter().map(|c| c.num_gpus).sum::<usize>(), 10);
        // identity split
        let one = split_cluster(&spec, 1).expect("splits");
        assert_eq!(one.len(), 1);
        assert_eq!(one[0].num_gpus, 10);
        // degenerate splits error
        assert!(split_cluster(&spec, 0).is_err());
        assert!(split_cluster(&spec, 11).is_err());
    }

    #[test]
    fn split_cluster_preserves_class_composition() {
        use crate::config::{GpuClass, GpuSpec};
        let base = ClusterSpec::two_2080ti();
        let mut spec = ClusterSpec { num_gpus: 4, ..base.clone() };
        spec.classes = vec![
            GpuClass::scaled(base.gpu.clone(), 3, 1.0),
            GpuClass::scaled(GpuSpec::a100_sxm4_80g(), 1, 0.7),
        ];
        spec.validate_classes().unwrap();
        let cells = split_cluster(&spec, 2).expect("splits");
        assert_eq!(cells.len(), 2);
        // cell 0 holds GPUs 0..2 (all 2080ti), cell 1 holds GPUs 2..4
        // (one 2080ti + the a100) — each a valid spec of its own
        assert_eq!(cells[0].num_gpus, 2);
        assert_eq!(cells[0].classes.len(), 1);
        assert_eq!(cells[0].classes[0].count, 2);
        assert_eq!(cells[1].num_gpus, 2);
        assert_eq!(
            cells[1].classes.iter().map(|c| c.count).collect::<Vec<_>>(),
            vec![1, 1]
        );
        assert_eq!(cells[1].classes[1].gpu.name, "A100-SXM4-80GB");
        for c in &cells {
            c.validate_classes().expect("each cell validates");
        }
    }

    #[test]
    fn router_places_least_utilized_with_index_tiebreak() {
        let cluster = ClusterSpec { num_gpus: 4, ..ClusterSpec::two_2080ti() };
        let cfg = CellsConfig { cells: 2, ..CellsConfig::default() };
        let mut router = CellRouter::new(&cluster, cfg).expect("router");
        assert_eq!(router.num_cells(), 2);
        // both cells empty: the tie must break to cell 0
        assert_eq!(router.placement_order(), vec![0, 1]);
        let p = real::text_to_text();
        let (_, cell_a) = router
            .try_admit("a", &p, ArrivalProcess::constant(60.0), 60.0)
            .expect("empty fleet admits");
        assert_eq!(cell_a, 0);
        // cell 0 now carries load: the next arrival must prefer cell 1
        assert_eq!(router.placement_order(), vec![1, 0]);
        let (_, cell_b) = router
            .try_admit("b", &p, ArrivalProcess::constant(60.0), 60.0)
            .expect("half-empty fleet admits");
        assert_eq!(cell_b, 1);
        assert_eq!(router.admitted(), 2);
        assert_eq!(router.residents_total(), 2);
        assert_eq!(router.gpus_in_use(), router.cell(0).gpus_in_use() + router.cell(1).gpus_in_use());
    }

    /// Two fragmented residents in cell 0 (the canonical re-pack
    /// setup) and one small lone tenant in cell 1, installed directly
    /// so the scenario does not depend on planner heuristics.
    fn fragmented_fleet(cfg: CellsConfig) -> (CellRouter, u64 /* departer */) {
        let cluster = ClusterSpec { num_gpus: 4, ..ClusterSpec::two_2080ti() };
        let mut router = CellRouter::new(&cluster, cfg).expect("router");
        let p = real::img_to_text();
        let split = |q: f64| Deployment {
            placements: vec![
                InstancePlacement { stage: 0, gpu: 0, sm_frac: q },
                InstancePlacement { stage: 1, gpu: 1, sm_frac: q },
            ],
            batch: 32,
            comm: CommMode::GlobalIpc,
        };
        router.insert_for_test(
            0,
            "survivor",
            &p,
            Allocation { instances: vec![1, 1], quotas: vec![0.45, 0.45] },
            split(0.45),
            25.0,
        );
        let departer = router.insert_for_test(
            0,
            "departer",
            &p,
            Allocation { instances: vec![1, 1], quotas: vec![0.5, 0.5] },
            split(0.5),
            100.0,
        );
        // lone small tenant in cell 1: both stages on the cell's GPU 0,
        // so its removal immediately frees a whole device
        router.insert_for_test(
            1,
            "nomad",
            &p,
            Allocation { instances: vec![1, 1], quotas: vec![0.15, 0.15] },
            Deployment {
                placements: vec![
                    InstancePlacement { stage: 0, gpu: 0, sm_frac: 0.15 },
                    InstancePlacement { stage: 1, gpu: 0, sm_frac: 0.15 },
                ],
                batch: 32,
                comm: CommMode::GlobalIpc,
            },
            15.0,
        );
        (router, departer)
    }

    #[test]
    fn departure_repack_pulls_small_tenant_across_cells() {
        let cfg = CellsConfig { cells: 2, ..CellsConfig::default() };
        let (mut router, departer) = fragmented_fleet(cfg);
        assert_eq!(router.residents_total(), 3);
        let out = router.depart(departer).expect("resident departs");
        assert_eq!(out.cell, 0);
        assert!(out.plan.applied, "{}", out.plan.summary());
        assert!(out.plan.gpus_after < out.plan.gpus_before);
        // the reclaimed GPU pulled the lone small tenant out of cell 1
        assert_eq!(out.migrations.len(), 1, "one migration per re-pack");
        let m = &out.migrations[0];
        assert_eq!((m.tenant.as_str(), m.from_cell, m.to_cell), ("nomad", 1, 0));
        assert_eq!(router.migrations(), 1);
        assert_eq!(router.residents_total(), 2, "migration conserves residents");
        assert!(router.cell(1).residents().is_empty(), "donor cell drained");
        assert!(
            router.cell(0).residents().iter().any(|r| r.name == "nomad"),
            "nomad now lives in cell 0"
        );
        // the migrated tenant stays addressable through the router
        let nomad_id = router
            .assignments
            .iter()
            .find(|a| router.cell(a.cell).residents().iter().any(
                |r| r.id == a.local_id && r.name == "nomad"))
            .map(|a| a.router_id)
            .expect("nomad is registered");
        assert!(router.depart(nomad_id).is_some(), "router id survives migration");
    }

    #[test]
    fn migration_hysteresis_skips_large_tenants() {
        // same fleet, but the nomad's footprint is above the migration
        // cap: the re-pack applies and nothing moves
        let cfg = CellsConfig {
            cells: 2,
            migrate_max_quota: 0.1,
            ..CellsConfig::default()
        };
        let (mut router, departer) = fragmented_fleet(cfg);
        let out = router.depart(departer).expect("resident departs");
        assert!(out.plan.applied, "{}", out.plan.summary());
        assert!(out.migrations.is_empty(), "0.3 footprint > 0.1 cap: no move");
        assert_eq!(router.migrations(), 0);
        assert_eq!(router.cell(1).residents().len(), 1, "nomad stays put");
    }

    #[test]
    fn migration_disabled_by_zero_budget() {
        let cfg = CellsConfig {
            cells: 2,
            migrations_per_repack: 0,
            ..CellsConfig::default()
        };
        let (mut router, departer) = fragmented_fleet(cfg);
        let out = router.depart(departer).expect("resident departs");
        assert!(out.plan.applied);
        assert!(out.migrations.is_empty());
        assert_eq!(router.cell(1).residents().len(), 1);
    }

    #[test]
    fn all_cells_rejecting_reports_first_choice_reason() {
        // cell 0 carries a resident, so the placement order is [1, 0]:
        // cell 1 is the first choice. An arrival nothing can seat must
        // come back with *cell 1's* typed reason — pinned by replaying
        // the same admission against a standalone controller on cell
        // 1's exact spec (empty, like the router's cell 1).
        let cluster = ClusterSpec { num_gpus: 4, ..ClusterSpec::two_2080ti() };
        let cfg = CellsConfig { cells: 2, ..CellsConfig::default() };
        let mut router = CellRouter::new(&cluster, cfg).expect("router");
        let p = real::text_to_text();
        router
            .try_admit("a", &p, ArrivalProcess::constant(60.0), 60.0)
            .expect("empty fleet admits");
        assert_eq!(router.placement_order(), vec![1, 0]);
        let big = real::img_to_text();
        let err = router
            .try_admit("big", &big, ArrivalProcess::constant(100_000.0), 100_000.0)
            .expect_err("no cell seats an impossible load");
        assert!(
            matches!(err, RejectReason::NoFeasiblePlan { .. }),
            "expected NoFeasiblePlan, got: {err}"
        );
        let mut lone = AdmissionController::new(
            router.cell_spec(1).clone(),
            CellsConfig::default().admission,
        );
        let expect = lone
            .try_admit("big", &big, ArrivalProcess::constant(100_000.0), 100_000.0)
            .expect_err("standalone cell-1 replica rejects too");
        assert_eq!(format!("{err}"), format!("{expect}"), "reason is not cell 1's");
        // the router counted one arrival; each cell saw one attempt
        assert_eq!(router.rejected(), 1);
        assert_eq!(router.cell(0).rejected(), 1);
        assert_eq!(router.cell(1).rejected(), 1);
        // placement order stays deterministic after the rejection
        assert_eq!(router.placement_order(), vec![1, 0]);
    }

    #[test]
    fn single_cell_router_never_migrates() {
        let cluster = ClusterSpec::two_2080ti();
        let cfg = CellsConfig::default();
        let mut router = CellRouter::new(&cluster, cfg).expect("router");
        let pa = real::img_to_text();
        let pb = real::text_to_text();
        let (a, _) = router
            .try_admit("a", &pa, ArrivalProcess::constant(100.0), 100.0)
            .expect("admits");
        router
            .try_admit("b", &pb, ArrivalProcess::constant(80.0), 80.0)
            .expect("admits");
        let out = router.depart(a).expect("departs");
        assert!(out.migrations.is_empty(), "one cell has no migration partner");
        assert_eq!(router.migrations(), 0);
    }
}
