//! N-tenant online admission over the shared cluster — the layer that
//! turns the PR-2 two-tenant co-location demo into a datacenter-shaped
//! control loop (ROADMAP "scale-out next steps"; cf. MISO's and
//! ParvaGPU's finding that multi-tenant GPU sharing lives or dies on
//! the admission/re-packing policy).
//!
//! All planning goes through the unified planner
//! ([`crate::planner::Planner::plan`]): admission, re-packing, and
//! shrinking are each one typed [`PlanRequest`] against a
//! [`ClusterState`] holding the co-tenant remainder.
//!
//! * [`AdmissionController::try_admit`] — a tenant arrives with a
//!   pipeline, a QoS target (carried by the pipeline), and an offered
//!   load; it is admitted iff a reservation-aware plan (Case 2 with
//!   Case-1 fallback, every constraint family seeing the co-tenant
//!   remainder) exists *and* every resident tenant's predicted p99 —
//!   inflated by the cross-tenant bandwidth interference the newcomer
//!   adds — stays within its target. Otherwise the tenant is rejected
//!   with a typed [`RejectReason`].
//! * [`AdmissionController::shrink_resident`] — online re-admission at
//!   a lower load ([`Objective::Shrink`]): a resident whose offered
//!   load fell gets a strictly smaller plan and the difference returns
//!   to the pool (previously residents held their provisioned peak
//!   until departure).
//! * [`AdmissionController::depart`] — when a tenant leaves, a
//!   re-packing pass reclaims fragmented GPU share: a greedy first-fit
//!   re-placement of every surviving allocation (cheapest possible
//!   migration: allocations unchanged, instances just move), with a
//!   simulated-annealing re-solve (`allocator::min_resource`, which
//!   drives [`crate::allocator::sa::anneal`]) as the fallback for any
//!   tenant the greedy pass cannot seat. The resulting migration plan
//!   prices churn per instance started/stopped
//!   ([`placement_churn`]) and is applied only when the reclaimed
//!   whole-GPU gain beats that churn cost — the same hysteresis
//!   philosophy as `run_closed_loop`.
//! * [`replay_trace`] — drives the controller over a seed-reproducible
//!   [`TenantTrace`] and validates every between-event interval
//!   end-to-end in [`ClusterSim`], fanning the interval simulations
//!   across cores deterministically. The replay is *incremental*:
//!   repeated interval configurations are measured once (identical
//!   content ⇒ identical seed ⇒ identical report, deduplicated before
//!   the parallel fan), and degenerate single-tenant constant-rate
//!   intervals route through the optimized single-tenant engine.
//! * [`static_partition_replay`] — the baseline the paper's cluster
//!   claims are measured against: tenants get dedicated whole GPUs,
//!   no spatial sharing.
//!
//! The whole control loop plans through a bounded-LRU
//! [`SolveCache`]: repeated admission attempts, re-pack candidate
//! evaluations, and shrink re-solves with identical inputs return the
//! memoized (bit-identical) solution instead of re-running the SA
//! solver — the same latency argument MISO and ParvaGPU make for
//! keeping reallocation decisions cheap.

use std::cell::{Cell, RefCell};
use std::collections::{BTreeSet, HashMap};
use std::fmt::Write as _;
use std::sync::Arc;

use crate::allocator::{AllocContext, SaParams, StageGrids};
use crate::config::ClusterSpec;
use crate::coordinator::autoscale::placement_churn;
use crate::deploy::{
    gpus_in_use, merge_reservations, reservations_for, Allocation, GpuReservation,
};
use crate::planner::cache::{self, CacheStats, SolveCache};
use crate::planner::{ClusterState, Objective, PlanRequest};
use crate::predictor::StagePredictor;
use crate::sim::{ClusterSim, Deployment, SimOptions, Simulator, TenantSpec};
use crate::suite::workload::{
    ArrivalProcess, Priority, TenantTrace, TenantTraceEvent, TraceEventKind,
};
use crate::suite::Pipeline;
use crate::util::json::Json;
use crate::util::{par, rng};

/// Controller configuration.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Provision each tenant for `plan_qps × headroom` (same role as
    /// [`super::AutoscaleConfig::headroom`]).
    pub headroom: f64,
    pub batch: u32,
    pub sa: SaParams,
    /// Seconds of provisioning disruption charged per instance started
    /// or stopped by a re-pack migration.
    pub churn_cost_s: f64,
    /// Disruption-seconds a whole reclaimed GPU is worth; a re-pack is
    /// applied only when `GPUs freed × this` exceeds the churn cost.
    pub repack_gain_s_per_gpu: f64,
    /// Capacity (entries) of the controller's planner [`SolveCache`].
    /// 0 disables memoization (every decision re-solves from scratch —
    /// the configuration the perf benches and golden tests compare
    /// against). Solutions served from the cache are bit-identical to
    /// fresh solves, so this knob never changes decisions.
    pub solve_cache: usize,
    pub seed: u64,
    /// Fraction of the QoS budget the planner may spend on stage
    /// processing + communication (C5 headroom, forwarded into every
    /// [`PlanRequest`]). The default matches [`PlanRequest::new`]'s
    /// 0.80, so plans — and their cache fingerprints — are unchanged.
    /// Values > 1 deliberately over-commit the budget: the `camelot
    /// fuzz --break-qos` dev mode uses this to seed intentional QoS
    /// violations the property harness must catch.
    pub qos_headroom: f64,
    /// Multiplier on every QoS target in the admission/shrink checks
    /// (`p99 > target × qos_slack` rejects). 1.0 (the default) is the
    /// production contract and bit-identical to the pre-knob behavior;
    /// `f64::INFINITY` disables the checks entirely — the other half of
    /// the `--break-qos` dev mode. The replay's QoS *audit* always uses
    /// the raw targets, so violations let in here are still reported.
    pub qos_slack: f64,
    /// Planner deadline budget for admission solves, in SA candidate
    /// evaluations (the solver's deterministic clock — wall time would
    /// break replay determinism). 0 (the default) disables the budget
    /// and is bit-identical to the pre-knob behavior. When > 0 and the
    /// Case-2 (min-resource) solution reports `evaluated` above the
    /// budget, the controller *degrades deterministically* instead of
    /// stalling admission: it takes the greedy Case-1 (max-load)
    /// fallback when that covers the target — recording the decision as
    /// degraded ([`degraded_plans`](AdmissionController::degraded_plans),
    /// surfaced as `(degraded)` in replay decision logs) — and rejects
    /// with a deadline diagnostic when it does not.
    pub plan_deadline: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            headroom: 1.15,
            batch: 32,
            sa: SaParams::default(),
            churn_cost_s: 0.5,
            repack_gain_s_per_gpu: 10.0,
            solve_cache: 2_048,
            seed: 42,
            qos_headroom: 0.80,
            qos_slack: 1.0,
            plan_deadline: 0,
        }
    }
}

/// Why an arrival was turned away.
#[derive(Debug, Clone, PartialEq)]
pub enum RejectReason {
    /// No reservation-aware allocation + placement exists in the
    /// capacity the residents leave free (C1/C2/placement over the
    /// co-tenant remainder).
    NoFeasiblePlan { detail: String },
    /// A plan exists, but some tenant's predicted p99 (resident or the
    /// newcomer itself, under cross-tenant bandwidth interference)
    /// would leave its QoS target.
    QosViolation {
        tenant: String,
        predicted_p99_s: f64,
        target_s: f64,
    },
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RejectReason::NoFeasiblePlan { detail } => {
                write!(f, "no feasible reservation-aware plan: {detail}")
            }
            RejectReason::QosViolation { tenant, predicted_p99_s, target_s } => write!(
                f,
                "QoS violation for {tenant}: predicted p99 {predicted_p99_s:.4}s > target {target_s:.4}s"
            ),
        }
    }
}

/// One admitted tenant and everything needed to re-plan it.
#[derive(Debug, Clone)]
pub struct Resident {
    pub id: u64,
    pub name: String,
    pub pipeline: Pipeline,
    pub predictors: Vec<StagePredictor>,
    /// Load (queries/s) the plan was provisioned for (pre-headroom).
    pub plan_qps: f64,
    pub arrivals: ArrivalProcess,
    pub allocation: Allocation,
    pub deployment: Deployment,
    /// Service tier; best-effort residents are evictable by
    /// latency-critical arrivals ([`AdmissionController::admit_preempting`]).
    pub priority: Priority,
}

/// One tenant's move in a re-pack migration plan.
#[derive(Debug, Clone)]
pub struct TenantMigration {
    pub tenant: String,
    pub old: Deployment,
    pub new: Deployment,
    /// Instances started + stopped by this move (its churn).
    pub churn_instances: usize,
}

/// Outcome of a departure's re-packing pass.
#[derive(Debug, Clone)]
pub struct RepackPlan {
    /// Moves for tenants whose deployment actually changes.
    pub migrations: Vec<TenantMigration>,
    pub gpus_before: usize,
    pub gpus_after: usize,
    pub churn_instances: usize,
    /// `churn_instances × churn_cost_s`.
    pub churn_cost_s: f64,
    /// `(gpus_before − gpus_after) × repack_gain_s_per_gpu`.
    pub gain_s: f64,
    /// Whether the hysteresis check let the plan through (false = the
    /// churn would cost more than the reclaimed share is worth; the old
    /// placements stay).
    pub applied: bool,
}

impl RepackPlan {
    fn no_op(gpus: usize) -> RepackPlan {
        RepackPlan {
            migrations: Vec::new(),
            gpus_before: gpus,
            gpus_after: gpus,
            churn_instances: 0,
            churn_cost_s: 0.0,
            gain_s: 0.0,
            applied: false,
        }
    }

    /// One-line summary for event logs and determinism comparisons.
    pub fn summary(&self) -> String {
        format!(
            "repack: gpus {}->{} churn {} cost {:.2}s gain {:.2}s {}",
            self.gpus_before,
            self.gpus_after,
            self.churn_instances,
            self.churn_cost_s,
            self.gain_s,
            if self.applied { "applied" } else { "held" }
        )
    }
}

/// Outcome of a GPU-failure event ([`AdmissionController::fail_gpus`]):
/// which devices went down, how many residents it displaced, and what
/// happened to each of them.
#[derive(Debug, Clone)]
pub struct GpuFailReport {
    /// GPUs newly marked failed by this event (already-failed or
    /// out-of-range ids are dropped).
    pub failed: Vec<usize>,
    /// Residents that had at least one instance on a failed GPU.
    pub displaced: usize,
    /// Displaced residents successfully re-placed on the survivors.
    pub replaced: usize,
    /// Residents evicted — displaced tenants nothing could seat, plus
    /// any survivor whose predicted QoS the forced re-pack broke.
    pub evicted: Vec<String>,
}

impl GpuFailReport {
    /// One-line summary for event logs and determinism comparisons.
    pub fn summary(&self) -> String {
        format!(
            "gpufail: gpus {:?} displaced {} replaced {} evicted {}",
            self.failed,
            self.displaced,
            self.replaced,
            if self.evicted.is_empty() { "-".to_string() } else { self.evicted.join(",") }
        )
    }
}

/// Outcome of an online resident shrink
/// ([`AdmissionController::shrink_resident`]).
#[derive(Debug, Clone)]
pub struct ShrinkReport {
    pub tenant: String,
    /// Load the plan was provisioned for before/after (pre-headroom).
    pub old_plan_qps: f64,
    pub target_qps: f64,
    /// Σ N·p before and after (equal when the shrink was held).
    pub old_usage: f64,
    pub new_usage: f64,
    /// Instances started + stopped by the move (0 when held).
    pub churn_instances: usize,
    pub applied: bool,
    /// "shrunk", or the planner's diagnostic when held.
    pub reason: String,
}

impl ShrinkReport {
    /// One-line summary for event logs and determinism comparisons.
    pub fn summary(&self) -> String {
        let status = if self.applied {
            "applied".to_string()
        } else {
            format!("held ({})", self.reason)
        };
        format!(
            "shrink: {:.0}->{:.0} qps usage {:.2}->{:.2} churn {} {}",
            self.old_plan_qps,
            self.target_qps,
            self.old_usage,
            self.new_usage,
            self.churn_instances,
            status
        )
    }
}

/// The online N-tenant admission controller. Owns the resident set;
/// all planning is deterministic (seeded SA, no wall-clock input), so
/// feeding the same arrival/departure sequence always reproduces the
/// same decisions.
pub struct AdmissionController {
    cluster: ClusterSpec,
    cfg: AdmissionConfig,
    residents: Vec<Resident>,
    next_id: u64,
    admitted: usize,
    rejected: usize,
    /// Predictors per pipeline name (training is deterministic, so the
    /// cache is purely a speedup for traces that repeat pipelines).
    predictor_cache: Vec<(String, Vec<StagePredictor>)>,
    /// Per-pipeline predictor-evaluation memos (see
    /// [`StageGrids`]) — shared across every QoS check instead of
    /// rebuilt per resident per decision. Interior-mutable so lookups
    /// work under shared borrows of the resident set.
    grids_cache: RefCell<Vec<(String, Arc<StageGrids>)>>,
    /// Memoized planner: admission attempts, re-pack candidate
    /// evaluations, and shrink re-solves with identical inputs return
    /// the cached (bit-identical) solution.
    solve_cache: SolveCache,
    /// GPUs currently out of service ([`fail_gpus`](Self::fail_gpus));
    /// every placement pass sees them as fully held, so no plan can
    /// touch them until [`recover_gpus`](Self::recover_gpus).
    failed_gpus: BTreeSet<usize>,
    /// Admission solves that exceeded [`AdmissionConfig::plan_deadline`]
    /// and degraded to the Case-1 fallback (interior-mutable: the
    /// degrade happens inside `plan_into`, which runs under `&self`).
    degraded_plans: Cell<usize>,
}

impl AdmissionController {
    pub fn new(cluster: ClusterSpec, cfg: AdmissionConfig) -> Self {
        let solve_cache = SolveCache::new(cfg.solve_cache);
        AdmissionController {
            cluster,
            cfg,
            residents: Vec::new(),
            next_id: 0,
            admitted: 0,
            rejected: 0,
            predictor_cache: Vec::new(),
            grids_cache: RefCell::new(Vec::new()),
            solve_cache,
            failed_gpus: BTreeSet::new(),
            degraded_plans: Cell::new(0),
        }
    }

    /// The cluster this controller plans against (including any live
    /// partial-degradation overlay — see
    /// [`degrade_gpus`](Self::degrade_gpus)).
    pub fn cluster(&self) -> &ClusterSpec {
        &self.cluster
    }

    /// Admission solves that exceeded the
    /// [`plan_deadline`](AdmissionConfig::plan_deadline) budget and
    /// degraded to the Case-1 fallback (0 with the budget disabled).
    pub fn degraded_plans(&self) -> usize {
        self.degraded_plans.get()
    }

    /// Warm-start the planner [`SolveCache`] from
    /// [`SolveCache::to_json`] output (the `camelot admit --cache-load`
    /// path). Returns the number of entries loaded; the controller's
    /// own capacity is kept.
    pub fn warm_start_cache(&self, json: &str) -> Result<usize, String> {
        self.solve_cache.load_json(json)
    }

    /// Serialize the planner cache contents for
    /// [`warm_start_cache`](Self::warm_start_cache) in a later session.
    pub fn cache_json(&self) -> String {
        self.solve_cache.to_json()
    }

    /// Serialize the controller's durable state as one JSON object:
    /// resident set (pipelines referenced *by name* — the trace carries
    /// the definitions), id/decision counters, failed-GPU set, the
    /// degrade overlay, and the embedded planner solve cache. Floats
    /// are bit-exact hex ([`f64::to_bits`]) and u64 ids decimal strings
    /// so the f64-based [`Json`] parser round-trips them losslessly.
    /// Predictor/grid caches are deliberately not captured — training
    /// is deterministic, so [`restore_state`](Self::restore_state)
    /// recomputes them bit-identically on demand.
    pub fn state_json(&self) -> String {
        let mut out = String::new();
        let _ = write!(
            out,
            "{{\"next_id\": \"{}\", \"admitted\": {}, \"rejected\": {}, \
             \"degraded_plans\": {}, \"failed_gpus\": [",
            self.next_id,
            self.admitted,
            self.rejected,
            self.degraded_plans.get()
        );
        for (i, g) in self.failed_gpus.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            let _ = write!(out, "{g}");
        }
        out.push_str("], \"degrade\": ");
        cache::json_bits_arr(&mut out, &self.cluster.degrade);
        out.push_str(", \"residents\": [");
        for (i, r) in self.residents.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            let _ = write!(out, "{{\"id\": \"{}\", \"name\": ", r.id);
            cache::json_str(&mut out, &r.name);
            out.push_str(", \"pipeline\": ");
            cache::json_str(&mut out, &r.pipeline.name);
            out.push_str(", \"plan_qps\": ");
            cache::json_bits(&mut out, r.plan_qps);
            out.push_str(", \"priority\": ");
            cache::json_priority(&mut out, r.priority);
            out.push_str(", \"arrivals\": ");
            cache::json_arrivals(&mut out, &r.arrivals);
            out.push_str(", \"allocation\": ");
            cache::json_alloc(&mut out, &r.allocation);
            out.push_str(", \"deployment\": ");
            cache::json_deployment(&mut out, &r.deployment);
            out.push('}');
        }
        out.push_str("], \"cache\": ");
        out.push_str(&self.solve_cache.to_json());
        out.push('}');
        out
    }

    /// Rebuild a controller from [`state_json`](Self::state_json)
    /// output. `cluster`/`cfg` come from the caller (they are inputs,
    /// not decisions — the snapshot holds only what the event stream
    /// produced); resident pipelines are resolved by name from
    /// `pipelines`, and predictors are retrained deterministically.
    /// The restored controller is decision-identical to the one that
    /// wrote the snapshot: only cache *counters* may differ.
    pub fn restore_state(
        cluster: ClusterSpec,
        cfg: AdmissionConfig,
        v: &Json,
        pipelines: &[Pipeline],
    ) -> Result<AdmissionController, String> {
        let mut ctl = AdmissionController::new(cluster, cfg);
        ctl.next_id = v
            .get_str("next_id")
            .ok_or("state missing next_id")?
            .parse::<u64>()
            .map_err(|e| format!("bad next_id: {e}"))?;
        ctl.admitted = v.get_f64("admitted").ok_or("state missing admitted")? as usize;
        ctl.rejected = v.get_f64("rejected").ok_or("state missing rejected")? as usize;
        ctl.degraded_plans
            .set(v.get_f64("degraded_plans").ok_or("state missing degraded_plans")? as usize);
        for g in v.get("failed_gpus").and_then(Json::as_arr).ok_or("state missing failed_gpus")?
        {
            let g = g.as_f64().ok_or("failed gpu must be a number")? as usize;
            if g >= ctl.cluster.num_gpus {
                return Err(format!("failed gpu {g} out of range"));
            }
            ctl.failed_gpus.insert(g);
        }
        let degrade =
            cache::parse_bits_arr(v.get("degrade").ok_or("state missing degrade")?)?;
        for (g, &s) in degrade.iter().enumerate() {
            if g >= ctl.cluster.num_gpus {
                return Err(format!("degrade entry {g} out of range"));
            }
            ctl.cluster.set_degrade(g, s);
        }
        for r in v.get("residents").and_then(Json::as_arr).ok_or("state missing residents")? {
            let name = r.get_str("pipeline").ok_or("resident missing pipeline")?;
            let pipeline = resolve_pipeline(name, pipelines)?;
            let predictors = ctl.predictors_for(&pipeline);
            ctl.residents.push(Resident {
                id: r
                    .get_str("id")
                    .ok_or("resident missing id")?
                    .parse::<u64>()
                    .map_err(|e| format!("bad resident id: {e}"))?,
                name: r.get_str("name").ok_or("resident missing name")?.to_string(),
                pipeline,
                predictors,
                plan_qps: cache::parse_bits(
                    r.get("plan_qps").ok_or("resident missing plan_qps")?,
                )?,
                arrivals: cache::parse_arrivals(
                    r.get("arrivals").ok_or("resident missing arrivals")?,
                )?,
                allocation: cache::parse_alloc(
                    r.get("allocation").ok_or("resident missing allocation")?,
                )?,
                deployment: cache::parse_deployment(
                    r.get("deployment").ok_or("resident missing deployment")?,
                )?,
                priority: cache::parse_priority(
                    r.get("priority").ok_or("resident missing priority")?,
                )?,
            });
        }
        let cache_v = v.get("cache").ok_or("state missing cache")?;
        ctl.solve_cache.load_json_value(cache_v)?;
        Ok(ctl)
    }

    /// Planner solve-cache counters (hits/misses/evictions) — surfaced
    /// through `camelot admit` so memoization behavior is observable.
    pub fn cache_stats(&self) -> CacheStats {
        self.solve_cache.stats()
    }

    pub fn residents(&self) -> &[Resident] {
        &self.residents
    }

    pub fn admitted(&self) -> usize {
        self.admitted
    }

    pub fn rejected(&self) -> usize {
        self.rejected
    }

    /// Distinct GPUs currently hosting at least one instance.
    pub fn gpus_in_use(&self) -> usize {
        gpus_in_use(self.residents.iter().map(|r| &r.deployment))
    }

    /// Σ N·p across residents (GPU-equivalents of SM share).
    pub fn total_usage(&self) -> f64 {
        self.residents.iter().map(|r| r.allocation.total_quota()).sum()
    }

    fn predictors_for(&mut self, pipeline: &Pipeline) -> Vec<StagePredictor> {
        if let Some((_, preds)) =
            self.predictor_cache.iter().find(|(n, _)| *n == pipeline.name)
        {
            return preds.clone();
        }
        let preds = crate::predictor::train_pipeline(pipeline, &self.cluster.gpu);
        self.predictor_cache.push((pipeline.name.clone(), preds.clone()));
        preds
    }

    /// The shared predictor-evaluation memo for one pipeline (built
    /// once per pipeline name at the controller's batch size).
    fn grids_for(&self, pipeline: &Pipeline, predictors: &[StagePredictor]) -> Arc<StageGrids> {
        let mut grids = self.grids_cache.borrow_mut();
        if let Some((_, g)) = grids.iter().find(|(n, _)| *n == pipeline.name) {
            return g.clone();
        }
        let g = Arc::new(StageGrids::build(predictors, self.cfg.batch));
        grids.push((pipeline.name.clone(), g.clone()));
        g
    }

    /// Per-GPU holds of each resident, in resident order (one
    /// `reservations_for` per resident — callers fold subsets of these
    /// instead of recomputing).
    fn resident_holds(&self) -> Vec<Vec<GpuReservation>> {
        self.residents
            .iter()
            .map(|r| reservations_for(&r.pipeline, &self.cluster, &r.deployment))
            .collect()
    }

    /// The per-GPU holds every placement view starts from: empty
    /// everywhere except failed GPUs, which carry a full-SM poison hold
    /// so no quota can land there (placement feasibility requires
    /// `sm + quota ≤ 1`) until the device recovers.
    fn base_holds(&self) -> Vec<GpuReservation> {
        let mut held = vec![GpuReservation::default(); self.cluster.num_gpus];
        for &g in &self.failed_gpus {
            held[g].sm_frac = 1.0;
        }
        held
    }

    /// Fold `holds` into one per-GPU vector, skipping index `skip`.
    fn fold_holds(
        &self,
        holds: &[Vec<GpuReservation>],
        skip: Option<usize>,
    ) -> Vec<GpuReservation> {
        let mut held = self.base_holds();
        for (i, h) in holds.iter().enumerate() {
            if Some(i) == skip {
                continue;
            }
            merge_reservations(&mut held, h);
        }
        held
    }

    /// Cross-tenant interference inflation for one tenant: the largest
    /// fraction of any single GPU's memory bandwidth its neighbors'
    /// worst-case demands occupy, scaled by the same 30% sensitivity
    /// `AllocContext` uses for self-inflicted congestion. Per-GPU (a
    /// cluster-wide average would dilute contention concentrated on one
    /// device), conservative (assumes all neighbor instances run
    /// concurrently), and monotone in the number of co-tenants —
    /// exactly what an admission test needs.
    fn neighbor_inflation(&self, others: &[GpuReservation]) -> f64 {
        let worst = others
            .iter()
            .map(|r| r.bw_demand / self.cluster.gpu.mem_bw)
            .fold(0.0f64, f64::max);
        1.0 + 0.30 * worst.min(1.0)
    }

    /// Predicted p99 of a (pipeline, allocation) at its planning load,
    /// inflated by its neighbors' bandwidth pressure. The `deployment`
    /// identifies which GPU class the instances landed on (single-class
    /// by the hetero placement invariant), so mixed-pool audits predict
    /// at the class's service speed — the same `compute_scale` the plan
    /// was solved under, never the base GPU's.
    fn tenant_p99(
        &self,
        pipeline: &Pipeline,
        predictors: &[StagePredictor],
        allocation: &Allocation,
        deployment: &Deployment,
        plan_qps: f64,
        others: &[GpuReservation],
    ) -> f64 {
        let mut ctx = AllocContext::shared_with_grids(
            pipeline,
            ClusterState::exclusive(&self.cluster),
            predictors,
            self.cfg.batch,
            self.grids_for(pipeline, predictors),
        );
        ctx.compute_scale = deployment
            .placements
            .first()
            .map_or(1.0, |p| self.cluster.scale_at(p.gpu));
        ctx.predicted_p99(allocation, plan_qps) * self.neighbor_inflation(others)
    }

    /// Plan `pipeline` at `plan_qps` into the capacity `reserved`
    /// leaves free: one unified-planner ladder — Case 2 (min resource)
    /// first, Case-1 (max load) fallback near capacity (accepted only
    /// when its solved peak covers the target) — the same ladder
    /// `Autoscaler::observe_with_reservations` climbs.
    fn plan_into(
        &self,
        pipeline: &Pipeline,
        predictors: &[StagePredictor],
        plan_qps: f64,
        reserved: &[GpuReservation],
    ) -> Result<(Allocation, Deployment), String> {
        let target = plan_qps * self.cfg.headroom;
        let request = PlanRequest::new(
            Objective::MinResource { load_qps: target },
            ClusterState::with_reservations(&self.cluster, reserved),
            pipeline,
            predictors,
        )
        .batch(self.cfg.batch)
        .sa(self.cfg.sa)
        .qos_headroom(self.cfg.qos_headroom);
        let solution = match self.solve_cache.plan(&request) {
            // `evaluated` is a deterministic clock (SA candidate count),
            // so the deadline trips identically across threads/replays
            Ok(s) if self.cfg.plan_deadline > 0 && s.evaluated > self.cfg.plan_deadline => {
                self.degraded_plans.set(self.degraded_plans.get() + 1);
                self.solve_cache
                    .plan(&request.clone().objective(Objective::MaxLoad))
                    .ok()
                    .filter(|c1| c1.objective_value >= target)
                    .ok_or_else(|| {
                        format!(
                            "plan deadline exceeded ({} > {} evaluations) and Case-1 \
                             fallback cannot cover {target:.1} qps",
                            s.evaluated, self.cfg.plan_deadline
                        )
                    })?
            }
            Ok(s) => s,
            // keep the primary planner error: a typed rejection such
            // as `Infeasible::NoMemory` must reach the reject reason
            // verbatim, not collapse into a generic capacity message
            Err(primary) => self
                .solve_cache
                .plan(&request.clone().objective(Objective::MaxLoad))
                .ok()
                .filter(|s| s.objective_value >= target)
                .ok_or_else(|| {
                    format!("no allocation supports {target:.1} qps ({primary})")
                })?,
        };
        Ok((solution.allocation, solution.deployment))
    }

    /// Decide admission for an arriving latency-critical tenant. On
    /// success the tenant becomes resident and its id is returned; on
    /// rejection the cluster state is untouched.
    pub fn try_admit(
        &mut self,
        name: &str,
        pipeline: &Pipeline,
        arrivals: ArrivalProcess,
        plan_qps: f64,
    ) -> Result<u64, RejectReason> {
        self.admit_with_priority(name, pipeline, arrivals, plan_qps, Priority::LatencyCritical)
    }

    /// [`try_admit`](Self::try_admit) with an explicit service tier.
    /// The tier never changes the admission *decision* — best-effort
    /// tenants clear the same feasibility + QoS bar — only whether the
    /// resident is later evictable by preemption or QoS enforcement.
    pub fn admit_with_priority(
        &mut self,
        name: &str,
        pipeline: &Pipeline,
        arrivals: ArrivalProcess,
        plan_qps: f64,
        priority: Priority,
    ) -> Result<u64, RejectReason> {
        assert!(plan_qps > 0.0, "planning load must be positive");
        let predictors = self.predictors_for(pipeline);
        // one reservations_for per resident; every view below folds
        // subsets of these
        let holds = self.resident_holds();
        let reserved = self.fold_holds(&holds, None);
        let (allocation, deployment) = self
            .plan_into(pipeline, &predictors, plan_qps, &reserved)
            .map_err(|detail| {
                self.rejected += 1;
                RejectReason::NoFeasiblePlan { detail }
            })?;

        // QoS check over the hypothetical resident set: every tenant —
        // the newcomer included — must keep its predicted p99 within
        // target once the newcomer's bandwidth pressure is on the bus.
        let new_holds = reservations_for(pipeline, &self.cluster, &deployment);
        let mut worst: Option<(String, f64, f64)> = None;
        for (i, r) in self.residents.iter().enumerate() {
            let mut others = self.fold_holds(&holds, Some(i));
            merge_reservations(&mut others, &new_holds);
            let p99 = self.tenant_p99(
                &r.pipeline,
                &r.predictors,
                &r.allocation,
                &r.deployment,
                r.plan_qps,
                &others,
            );
            if p99 > r.pipeline.qos_target_s * self.cfg.qos_slack
                && worst.as_ref().map_or(true, |(_, w, _)| p99 > *w)
            {
                worst = Some((r.name.clone(), p99, r.pipeline.qos_target_s));
            }
        }
        let own_p99 = self
            .tenant_p99(pipeline, &predictors, &allocation, &deployment, plan_qps, &reserved);
        if own_p99 > pipeline.qos_target_s * self.cfg.qos_slack
            && worst.as_ref().map_or(true, |(_, w, _)| own_p99 > *w)
        {
            worst = Some((name.to_string(), own_p99, pipeline.qos_target_s));
        }
        if let Some((tenant, predicted_p99_s, target_s)) = worst {
            self.rejected += 1;
            return Err(RejectReason::QosViolation { tenant, predicted_p99_s, target_s });
        }

        let id = self.next_id;
        self.next_id += 1;
        self.admitted += 1;
        self.residents.push(Resident {
            id,
            name: name.to_string(),
            pipeline: pipeline.clone(),
            predictors,
            plan_qps,
            arrivals,
            allocation,
            deployment,
            priority,
        });
        Ok(id)
    }

    /// Admission with best-effort preemption: a latency-critical
    /// arrival that plain admission rejects may evict resident
    /// best-effort tenants — largest footprint first, admission order
    /// as the tiebreak — retrying after each eviction until it fits or
    /// no best-effort resident remains. A feasibility guard (can the
    /// arrival be seated even with *every* best-effort tenant gone?)
    /// runs first so a hopeless arrival never evicts anyone, and an
    /// exhausted eviction ladder restores the full resident set — a
    /// rejection leaves the cluster untouched, exactly like
    /// [`try_admit`](Self::try_admit). Returns the admitted id plus the
    /// names of the evicted tenants (empty when plain admission
    /// sufficed).
    pub fn admit_preempting(
        &mut self,
        name: &str,
        pipeline: &Pipeline,
        arrivals: ArrivalProcess,
        plan_qps: f64,
        priority: Priority,
    ) -> Result<(u64, Vec<String>), RejectReason> {
        let rejected_before = self.rejected;
        let first = match self.admit_with_priority(
            name,
            pipeline,
            arrivals.clone(),
            plan_qps,
            priority,
        ) {
            Ok(id) => return Ok((id, Vec::new())),
            Err(reason) => reason,
        };
        let any_best_effort =
            self.residents.iter().any(|r| r.priority == Priority::BestEffort);
        if priority != Priority::LatencyCritical || !any_best_effort {
            return Err(first);
        }
        // guard: plan the arrival into the capacity the latency-critical
        // residents alone leave free — if even that fails, eviction is
        // hopeless and nobody should be displaced
        let predictors = self.predictors_for(pipeline);
        let holds = self.resident_holds();
        let mut lc_held = self.base_holds();
        for (r, h) in self.residents.iter().zip(&holds) {
            if r.priority == Priority::LatencyCritical {
                merge_reservations(&mut lc_held, h);
            }
        }
        if self.plan_into(pipeline, &predictors, plan_qps, &lc_held).is_err() {
            self.rejected = rejected_before + 1;
            return Err(first);
        }
        let saved = self.residents.clone();
        let mut evicted: Vec<String> = Vec::new();
        loop {
            // next victim: the best-effort resident with the largest
            // footprint (Σ N·p), lowest id on ties — deterministic
            let victim = self
                .residents
                .iter()
                .enumerate()
                .filter(|(_, r)| r.priority == Priority::BestEffort)
                .max_by(|(_, a), (_, b)| {
                    a.allocation
                        .total_quota()
                        .partial_cmp(&b.allocation.total_quota())
                        .unwrap()
                        .then(b.id.cmp(&a.id))
                })
                .map(|(pos, r)| (pos, r.name.clone()));
            let Some((pos, victim_name)) = victim else {
                // eviction ladder exhausted: restore everyone, reject
                self.residents = saved;
                self.rejected = rejected_before + 1;
                return Err(first);
            };
            self.residents.remove(pos);
            evicted.push(victim_name);
            if let Ok(id) =
                self.admit_with_priority(name, pipeline, arrivals.clone(), plan_qps, priority)
            {
                // one arrival, one decision: the failed pre-eviction
                // attempts don't count as rejections
                self.rejected = rejected_before;
                return Ok((id, evicted));
            }
        }
    }

    /// Test-only: install a resident with a hand-built plan, bypassing
    /// the planner, so re-packing scenarios are exactly reproducible.
    #[cfg(test)]
    pub(crate) fn insert_resident(
        &mut self,
        name: &str,
        pipeline: &Pipeline,
        allocation: Allocation,
        deployment: Deployment,
        plan_qps: f64,
    ) -> u64 {
        let predictors = self.predictors_for(pipeline);
        let id = self.next_id;
        self.next_id += 1;
        self.admitted += 1;
        self.residents.push(Resident {
            id,
            name: name.to_string(),
            pipeline: pipeline.clone(),
            predictors,
            plan_qps,
            arrivals: ArrivalProcess::constant(plan_qps),
            allocation,
            deployment,
            priority: Priority::LatencyCritical,
        });
        id
    }

    /// Online resident shrink — the ROADMAP's re-admission path: when a
    /// resident's offered load falls, re-plan it for `target_qps` via
    /// [`Objective::Shrink`] into the capacity the *other* residents
    /// leave free, and apply only when the planner finds a strictly
    /// smaller plan (otherwise every placement stays — shrinking would
    /// churn instances for nothing). On apply, the resident's arrival
    /// process is re-pinned to the new peak. Returns `None` when `id`
    /// is not resident.
    pub fn shrink_resident(&mut self, id: u64, target_qps: f64) -> Option<ShrinkReport> {
        assert!(target_qps > 0.0, "shrink target must be positive");
        let pos = self.residents.iter().position(|r| r.id == id)?;
        let holds = self.resident_holds();
        let others = self.fold_holds(&holds, Some(pos));
        let r = &self.residents[pos];
        let target = target_qps * self.cfg.headroom;
        let outcome = self.solve_cache.plan(
            &PlanRequest::new(
                Objective::Shrink { target_qps: target, current: r.allocation.clone() },
                ClusterState::with_reservations(&self.cluster, &others),
                &r.pipeline,
                &r.predictors,
            )
            .batch(self.cfg.batch)
            .sa(self.cfg.sa)
            .qos_headroom(self.cfg.qos_headroom),
        );
        let old_usage = r.allocation.total_quota();
        let held = |reason: String| ShrinkReport {
            tenant: r.name.clone(),
            old_plan_qps: r.plan_qps,
            target_qps,
            old_usage,
            new_usage: old_usage,
            churn_instances: 0,
            applied: false,
            reason,
        };
        let report = match outcome {
            Ok(s) => {
                // same cross-tenant QoS contract as try_admit: the
                // re-placed (smaller) footprint moves bandwidth pressure
                // around, so every tenant's predicted p99 must still
                // hold under the candidate holds before anything moves
                let new_holds = reservations_for(&r.pipeline, &self.cluster, &s.deployment);
                let mut qos_block: Option<String> = None;
                for (i, other) in self.residents.iter().enumerate() {
                    if i == pos {
                        continue;
                    }
                    // tenant i's view: every resident except itself and
                    // the shrinking tenant's OLD footprint, plus the
                    // shrinking tenant's candidate footprint
                    let mut rest = self.base_holds();
                    for (j, h) in holds.iter().enumerate() {
                        if j != pos && j != i {
                            merge_reservations(&mut rest, h);
                        }
                    }
                    merge_reservations(&mut rest, &new_holds);
                    let p99 = self.tenant_p99(
                        &other.pipeline,
                        &other.predictors,
                        &other.allocation,
                        &other.deployment,
                        other.plan_qps,
                        &rest,
                    );
                    if p99 > other.pipeline.qos_target_s * self.cfg.qos_slack {
                        qos_block = Some(format!(
                            "would break QoS for {}: predicted p99 {p99:.4}s > target {:.4}s",
                            other.name, other.pipeline.qos_target_s
                        ));
                        break;
                    }
                }
                if qos_block.is_none() {
                    let own = self.tenant_p99(
                        &r.pipeline,
                        &r.predictors,
                        &s.allocation,
                        &s.deployment,
                        target_qps,
                        &others,
                    );
                    if own > r.pipeline.qos_target_s * self.cfg.qos_slack {
                        qos_block = Some(format!(
                            "own predicted p99 {own:.4}s > target {:.4}s",
                            r.pipeline.qos_target_s
                        ));
                    }
                }
                if let Some(reason) = qos_block {
                    held(reason)
                } else {
                    let churn_instances =
                        placement_churn(&r.deployment.placements, &s.deployment.placements);
                    let report = ShrinkReport {
                        tenant: r.name.clone(),
                        old_plan_qps: r.plan_qps,
                        target_qps,
                        old_usage,
                        new_usage: s.usage,
                        churn_instances,
                        applied: true,
                        reason: "shrunk".to_string(),
                    };
                    let r = &mut self.residents[pos];
                    r.allocation = s.allocation;
                    r.deployment = s.deployment;
                    r.plan_qps = target_qps;
                    r.arrivals = r.arrivals.scaled_to_peak(target_qps);
                    report
                }
            }
            Err(e) => held(e.to_string()),
        };
        Some(report)
    }

    /// Remove a resident and re-pack the survivors. Returns `None` when
    /// `id` is not resident (e.g. the arrival was rejected).
    pub fn depart(&mut self, id: u64) -> Option<RepackPlan> {
        let pos = self.residents.iter().position(|r| r.id == id)?;
        self.residents.remove(pos);
        Some(self.repack())
    }

    /// Re-packing pass (greedy fill first, SA re-solve fallback):
    /// compute a candidate placement for every surviving tenant into a
    /// cluster packed from scratch, price the migration churn, and
    /// apply only if the whole-GPU reclaim is worth it.
    fn repack(&mut self) -> RepackPlan {
        let gpus_before = self.gpus_in_use();
        if self.residents.is_empty() {
            return RepackPlan::no_op(gpus_before);
        }

        // deterministic packing order: big footprints first (classic
        // first-fit-decreasing), admission order as the tiebreak
        let mut order: Vec<usize> = (0..self.residents.len()).collect();
        order.sort_by(|&a, &b| {
            let qa = self.residents[a].allocation.total_quota();
            let qb = self.residents[b].allocation.total_quota();
            qb.partial_cmp(&qa)
                .unwrap()
                .then(self.residents[a].id.cmp(&self.residents[b].id))
        });

        let mut held = self.base_holds();
        let mut planned: Vec<(usize, Allocation, Deployment)> =
            Vec::with_capacity(order.len());
        for &i in &order {
            let r = &self.residents[i];
            // greedy: keep the allocation, just re-place it
            // (Objective::Repack) — the placement heuristic
            // (scarcest-remaining first) packs the freed share without
            // touching instance counts or quotas
            let greedy = self.solve_cache.plan(
                &PlanRequest::new(
                    Objective::Repack { allocation: r.allocation.clone() },
                    ClusterState::with_reservations(&self.cluster, &held),
                    &r.pipeline,
                    &r.predictors,
                )
                .batch(self.cfg.batch)
                .sa(self.cfg.sa)
                .qos_headroom(self.cfg.qos_headroom),
            );
            let (alloc, dep) = match greedy {
                Ok(s) => (s.allocation, s.deployment),
                // fallback: re-solve the tenant from scratch into the
                // remainder (min_resource drives allocator::sa's
                // annealer — quotas and counts may change)
                Err(_) => match self.plan_into(&r.pipeline, &r.predictors, r.plan_qps, &held)
                {
                    Ok(pair) => pair,
                    // even the SA fallback cannot seat this tenant in
                    // the packed prefix: abort, keep every placement
                    Err(_) => return RepackPlan::no_op(gpus_before),
                },
            };
            let res = reservations_for(&r.pipeline, &self.cluster, &dep);
            merge_reservations(&mut held, &res);
            planned.push((i, alloc, dep));
        }

        let gpus_after = gpus_in_use(planned.iter().map(|(_, _, d)| d));
        let mut migrations = Vec::new();
        let mut churn_instances = 0usize;
        for (i, _alloc, dep) in &planned {
            let r = &self.residents[*i];
            let churn = placement_churn(&r.deployment.placements, &dep.placements);
            if churn > 0 {
                churn_instances += churn;
                migrations.push(TenantMigration {
                    tenant: r.name.clone(),
                    old: r.deployment.clone(),
                    new: dep.clone(),
                    churn_instances: churn,
                });
            }
        }
        let churn_cost_s = churn_instances as f64 * self.cfg.churn_cost_s;
        let gain_s =
            gpus_before.saturating_sub(gpus_after) as f64 * self.cfg.repack_gain_s_per_gpu;
        let mut applied = gain_s > churn_cost_s;
        if applied {
            // QoS gate: consolidation concentrates bandwidth pressure on
            // fewer devices, so every tenant's predicted p99 must still
            // hold under the *candidate* holds before anything moves —
            // the same promise admission and shrink enforce (greedy
            // re-placement keeps allocations, so only the neighbor
            // inflation can shift)
            let candidate_holds: Vec<Vec<GpuReservation>> = planned
                .iter()
                .map(|(i, _, d)| {
                    reservations_for(&self.residents[*i].pipeline, &self.cluster, d)
                })
                .collect();
            'gate: for (k, (i, alloc, dep)) in planned.iter().enumerate() {
                let r = &self.residents[*i];
                let mut others = self.base_holds();
                for (k2, h) in candidate_holds.iter().enumerate() {
                    if k2 != k {
                        merge_reservations(&mut others, h);
                    }
                }
                let p99 = self
                    .tenant_p99(&r.pipeline, &r.predictors, alloc, dep, r.plan_qps, &others);
                if p99 > r.pipeline.qos_target_s * self.cfg.qos_slack {
                    applied = false;
                    break 'gate;
                }
            }
        }
        if applied {
            for (i, alloc, dep) in planned {
                self.residents[i].allocation = alloc;
                self.residents[i].deployment = dep;
            }
        }
        RepackPlan {
            migrations,
            gpus_before,
            gpus_after: if applied { gpus_after } else { gpus_before },
            churn_instances,
            churn_cost_s,
            gain_s,
            applied,
        }
    }

    /// GPUs currently out of service.
    pub fn failed_gpu_ids(&self) -> Vec<usize> {
        self.failed_gpus.iter().copied().collect()
    }

    /// Take the listed GPUs out of service. Residents with instances on
    /// a failed device are displaced and re-placed onto the survivors —
    /// biggest footprint first (the re-pack's first-fit-decreasing
    /// order), greedy instance-move ([`Objective::Repack`]) with a full
    /// SA re-solve as the fallback — while every unaffected resident
    /// keeps its placement (its holds are reserved before anyone
    /// moves). Displaced tenants nothing can seat are evicted, as is
    /// any survivor whose predicted p99 the forced consolidation pushes
    /// past target: the controller's QoS promise outranks residency.
    /// No churn hysteresis applies — a failure *must* move the
    /// displaced instances.
    pub fn fail_gpus(&mut self, gpu_ids: &[usize]) -> GpuFailReport {
        let mut failed = Vec::new();
        for &g in gpu_ids {
            if g < self.cluster.num_gpus && self.failed_gpus.insert(g) {
                failed.push(g);
            }
        }
        let displaced_idx: Vec<usize> = self
            .residents
            .iter()
            .enumerate()
            .filter(|(_, r)| {
                r.deployment.placements.iter().any(|p| self.failed_gpus.contains(&p.gpu))
            })
            .map(|(i, _)| i)
            .collect();
        let displaced = displaced_idx.len();
        let mut evicted: Vec<String> = Vec::new();
        let mut replaced = 0usize;
        if displaced > 0 {
            // survivors stay put: their holds are fixed before any
            // displaced tenant is re-seated
            let holds = self.resident_holds();
            let mut held = self.base_holds();
            for (i, h) in holds.iter().enumerate() {
                if !displaced_idx.contains(&i) {
                    merge_reservations(&mut held, h);
                }
            }
            let mut order = displaced_idx.clone();
            order.sort_by(|&a, &b| {
                let qa = self.residents[a].allocation.total_quota();
                let qb = self.residents[b].allocation.total_quota();
                qb.partial_cmp(&qa)
                    .unwrap()
                    .then(self.residents[a].id.cmp(&self.residents[b].id))
            });
            let mut planned: Vec<(usize, Allocation, Deployment)> = Vec::new();
            let mut drop_idx: Vec<usize> = Vec::new();
            for &i in &order {
                let r = &self.residents[i];
                let greedy = self.solve_cache.plan(
                    &PlanRequest::new(
                        Objective::Repack { allocation: r.allocation.clone() },
                        ClusterState::with_reservations(&self.cluster, &held),
                        &r.pipeline,
                        &r.predictors,
                    )
                    .batch(self.cfg.batch)
                    .sa(self.cfg.sa)
                    .qos_headroom(self.cfg.qos_headroom),
                );
                let pair = match greedy {
                    Ok(s) => Some((s.allocation, s.deployment)),
                    Err(_) => {
                        self.plan_into(&r.pipeline, &r.predictors, r.plan_qps, &held).ok()
                    }
                };
                match pair {
                    Some((alloc, dep)) => {
                        let res = reservations_for(&r.pipeline, &self.cluster, &dep);
                        merge_reservations(&mut held, &res);
                        planned.push((i, alloc, dep));
                    }
                    None => drop_idx.push(i),
                }
            }
            replaced = planned.len();
            for (i, alloc, dep) in planned {
                self.residents[i].allocation = alloc;
                self.residents[i].deployment = dep;
            }
            drop_idx.sort_unstable();
            for &i in drop_idx.iter().rev() {
                evicted.push(self.residents[i].name.clone());
                self.residents.remove(i);
            }
            evicted.reverse();
        }
        // QoS enforcement: consolidation concentrates bandwidth pressure
        // on fewer devices; shed load until every survivor's predicted
        // p99 is back within (slack-adjusted) target
        evicted.extend(self.enforce_qos());
        GpuFailReport { failed, displaced, replaced, evicted }
    }

    /// Return the listed GPUs to service. Placement opens up
    /// immediately; whether residents actually spread back is the
    /// normal churn-gated re-pack's call.
    pub fn recover_gpus(&mut self, gpu_ids: &[usize]) -> RepackPlan {
        for g in gpu_ids {
            self.failed_gpus.remove(g);
        }
        self.repack()
    }

    /// Partially degrade the listed GPUs (ECC retirement, thermal
    /// throttling): service time on each is multiplied by `scale`
    /// (> 1.0 = slower) through [`ClusterSpec::set_degrade`].
    /// Placements stay — unlike [`fail_gpus`](Self::fail_gpus) the
    /// device still serves — but predicted p99s inflate, so QoS
    /// enforcement sheds residents the slowdown pushes past target.
    /// Returns the GPUs whose scale actually changed and the evicted
    /// tenant names.
    pub fn degrade_gpus(&mut self, gpu_ids: &[usize], scale: f64) -> (Vec<usize>, Vec<String>) {
        let mut applied = Vec::new();
        for &g in gpu_ids {
            if g < self.cluster.num_gpus && self.cluster.degrade_at(g) != scale {
                self.cluster.set_degrade(g, scale);
                applied.push(g);
            }
        }
        let evicted = if applied.is_empty() { Vec::new() } else { self.enforce_qos() };
        (applied, evicted)
    }

    /// Undo [`degrade_gpus`](Self::degrade_gpus): the listed GPUs return
    /// to full speed and the churn-gated re-pack decides whether
    /// residents spread back.
    pub fn restore_gpus(&mut self, gpu_ids: &[usize]) -> RepackPlan {
        for &g in gpu_ids {
            if g < self.cluster.num_gpus {
                self.cluster.set_degrade(g, 1.0);
            }
        }
        self.repack()
    }

    /// Predicted-QoS audit of the current resident set: every resident
    /// whose predicted p99 under full neighbor pressure exceeds its
    /// *raw* QoS target, as `(name, predicted_p99_s, target_s)`. The
    /// dev `qos_slack` is deliberately ignored — this is the invariant
    /// the fuzz harness checks, so violations a slackened admission let
    /// in are still visible here.
    pub fn qos_audit(&self) -> Vec<(String, f64, f64)> {
        self.audit_against(1.0)
    }

    fn audit_against(&self, slack: f64) -> Vec<(String, f64, f64)> {
        let holds = self.resident_holds();
        let mut out = Vec::new();
        for (i, r) in self.residents.iter().enumerate() {
            let others = self.fold_holds(&holds, Some(i));
            let p99 = self.tenant_p99(
                &r.pipeline,
                &r.predictors,
                &r.allocation,
                &r.deployment,
                r.plan_qps,
                &others,
            );
            if p99 > r.pipeline.qos_target_s * slack {
                out.push((r.name.clone(), p99, r.pipeline.qos_target_s));
            }
        }
        out
    }

    /// Evict residents until every survivor passes the slack-adjusted
    /// QoS audit: best-effort tenants go first (largest footprint,
    /// lowest id on ties — the preemption order), then the worst
    /// relative violator itself. Each round removes one resident, so
    /// this terminates. Returns the evicted names in order.
    fn enforce_qos(&mut self) -> Vec<String> {
        let mut evicted = Vec::new();
        while !self.audit_against(self.cfg.qos_slack).is_empty() {
            let victim = self
                .residents
                .iter()
                .enumerate()
                .filter(|(_, r)| r.priority == Priority::BestEffort)
                .max_by(|(_, a), (_, b)| {
                    a.allocation
                        .total_quota()
                        .partial_cmp(&b.allocation.total_quota())
                        .unwrap()
                        .then(b.id.cmp(&a.id))
                })
                .map(|(pos, _)| pos)
                .or_else(|| {
                    let audit = self.audit_against(self.cfg.qos_slack);
                    let worst = audit.iter().max_by(|a, b| {
                        (a.1 / a.2).partial_cmp(&(b.1 / b.2)).unwrap()
                    })?;
                    self.residents.iter().position(|r| r.name == worst.0)
                });
            match victim {
                Some(pos) => {
                    evicted.push(self.residents[pos].name.clone());
                    self.residents.remove(pos);
                }
                None => break,
            }
        }
        evicted
    }

    /// The offered-load model of a resident (`None` when `id` is not
    /// resident) — the replay's flash-crowd bookkeeping reads this.
    pub fn resident_arrivals(&self, id: u64) -> Option<&ArrivalProcess> {
        self.residents.iter().find(|r| r.id == id).map(|r| &r.arrivals)
    }

    /// Re-pin a resident's offered-load model. The admitted *plan* is
    /// untouched — a flash crowd changes what the tenant offers, not
    /// what it was promised — so every placement and reservation stays.
    /// Returns false when `id` is not resident.
    pub fn set_resident_arrivals(&mut self, id: u64, arrivals: ArrivalProcess) -> bool {
        match self.residents.iter_mut().find(|r| r.id == id) {
            Some(r) => {
                r.arrivals = arrivals;
                true
            }
            None => false,
        }
    }
}

// ---------------------------------------------------------------------
// Trace replay (ClusterSim validation) and the static baseline
// ---------------------------------------------------------------------

/// Replay configuration.
#[derive(Debug, Clone)]
pub struct ReplayConfig {
    pub admission: AdmissionConfig,
    /// Queries per tenant in each between-event validation simulation.
    pub queries: usize,
    /// Worker threads for the interval simulations (0 = default pool).
    pub threads: usize,
    /// Reuse the simulation report of any previously measured identical
    /// interval. Bit-identical either way: duplicates share the first
    /// occurrence's seed by construction, so disabling dedup only
    /// re-runs simulations whose results are already known (the golden
    /// suite pins the equality).
    pub dedup: bool,
    /// Run the predicted-QoS audit ([`AdmissionController::qos_audit`])
    /// after every event and record violations in
    /// [`ReplayReport::qos_violations`]. Off by default — the audit is
    /// pure observation (decisions and fingerprints are unchanged), but
    /// it costs an O(residents²) predictor pass per event, which the
    /// benches should not pay.
    pub audit_qos: bool,
    /// Solve-cache payload ([`SolveCache::to_json`]) to warm-start the
    /// controller's planner cache with before the first event (the
    /// `camelot admit --cache-load` path). Decisions are bit-identical
    /// warm or cold — a hit returns the exact solution a fresh solve
    /// would — so only the hit/miss counters move; they start at zero,
    /// making [`ReplayReport::solve_cache`] the *warm* hit rate.
    pub warm_cache: Option<String>,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        ReplayConfig {
            admission: AdmissionConfig::default(),
            queries: 1_000,
            threads: 0,
            dedup: true,
            audit_qos: false,
            warm_cache: None,
        }
    }
}

/// Canonical content key of one between-event interval: everything the
/// interval simulation reads except the seed (assigned separately by
/// first occurrence) and the cluster (fixed per replay — except the
/// degrade overlay, which GPU-degrade events mutate mid-trace and the
/// simulators read through [`ClusterSpec::scale_at`], so it is part of
/// the content). The degrade block is appended only when an overlay is
/// active, keeping every degrade-free interval's key byte-identical to
/// its pre-overlay form. Tenant names and the interval start time are
/// display-only and excluded.
pub(crate) fn interval_fingerprint(
    tenants: &[(String, Pipeline, Deployment, ArrivalProcess)],
    queries: usize,
    degrade: &[f64],
) -> String {
    use std::fmt::Write as _;
    let mut s = String::with_capacity(256);
    let _ = write!(s, "q={queries}");
    for (_, p, d, a) in tenants {
        s.push('|');
        cache::fp_pipeline(&mut s, p);
        cache::fp_deployment(&mut s, d);
        cache::fp_arrivals(&mut s, a);
    }
    if !degrade.is_empty() {
        s.push_str("|deg=");
        for d in degrade {
            let _ = write!(s, "{:x},", d.to_bits());
        }
    }
    s
}

/// One trace event as the controller saw it.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplayEvent {
    pub t_s: f64,
    pub tenant: u64,
    /// "arrive <pipeline> @ <qps>" or "depart".
    pub desc: String,
    /// "admitted", "rejected: <reason>", or a [`RepackPlan::summary`].
    pub decision: String,
    pub residents: usize,
    pub gpus_in_use: usize,
    pub usage: f64,
}

/// End-to-end measurement of one between-event interval: all residents
/// co-run in a single merged [`ClusterSim`].
#[derive(Debug, Clone)]
pub struct IntervalReport {
    pub t_start_s: f64,
    /// Names of the residents during this interval (admission order).
    pub tenants: Vec<String>,
    /// Per-tenant measured p99 (same order as `tenants`).
    pub p99_s: Vec<f64>,
    /// p99 within the tenant's QoS target.
    pub qos_met: Vec<bool>,
}

/// One predicted-QoS violation observed by the replay audit
/// ([`ReplayConfig::audit_qos`]): at time `t_s`, resident `tenant`'s
/// predicted p99 exceeded its raw target.
#[derive(Debug, Clone)]
pub struct QosViolationRecord {
    pub t_s: f64,
    pub tenant: String,
    pub predicted_p99_s: f64,
    pub target_s: f64,
}

/// Mean/peak SM occupancy of one GPU class across a replay — the
/// per-class breakdown `camelot admit --spec` prints for mixed pools.
///
/// Computed in replay phase 1 (sequential) from the resident
/// deployments after each event, normalized by the class's device
/// count: 1.0 means every GPU of the class fully committed.
#[derive(Debug, Clone)]
pub struct ClassUtilization {
    /// Hardware name of the class (e.g. `"A100-SXM4-80GB"`).
    pub class: String,
    /// Devices in the class.
    pub gpus: usize,
    /// Mean SM share in use across events with residents, in [0, 1].
    pub mean_sm_frac: f64,
    /// Peak SM share in use at any event, in [0, 1].
    pub peak_sm_frac: f64,
}

/// Full outcome of a trace replay.
#[derive(Debug, Clone)]
pub struct ReplayReport {
    pub events: Vec<ReplayEvent>,
    pub intervals: Vec<IntervalReport>,
    pub admitted: usize,
    pub rejected: usize,
    pub repacks_applied: usize,
    pub peak_residents: usize,
    /// Mean GPUs in use across intervals (time-unweighted).
    pub mean_gpus_in_use: f64,
    /// Distinct interval simulations actually run (≤ `intervals.len()`;
    /// the difference is deduplicated repeated configurations).
    pub intervals_simulated: usize,
    /// Planner solve-cache counters of the replay's controller.
    pub solve_cache: CacheStats,
    /// Predicted-QoS violations the per-event audit caught (empty
    /// unless [`ReplayConfig::audit_qos`]; always empty on a healthy
    /// controller — the fuzz harness asserts exactly that). Excluded
    /// from [`fingerprint`](ReplayReport::fingerprint), which predates
    /// the audit.
    pub qos_violations: Vec<QosViolationRecord>,
    /// Applied re-packs that *increased* the GPU count — capacity
    /// stranding, which the hysteresis gate makes impossible by
    /// construction (`gain = GPUs freed × rate` is 0 when nothing
    /// frees); the fuzz harness pins the count at 0. Also excluded from
    /// the fingerprint.
    pub repack_regressions: usize,
    /// Per-class SM occupancy, one entry per declared
    /// [`GpuClass`](crate::config::GpuClass) (empty on homogeneous
    /// pools). Derived from the decision sequence, so it is excluded
    /// from [`fingerprint`](ReplayReport::fingerprint) like the other
    /// derived counters.
    pub class_utilization: Vec<ClassUtilization>,
    /// Per-GPU peak dynamic KV-cache residency (bytes) observed across
    /// every simulated interval — element-wise max of each interval's
    /// [`SimReport::kv_peak_bytes`]. All zeros when no resident carries
    /// a KV-bearing stage. Measurement-derived summary, excluded from
    /// [`fingerprint`](ReplayReport::fingerprint) like the class
    /// utilization table (the golden fingerprints predate it).
    pub kv_peak_bytes: Vec<f64>,
}

impl ReplayReport {
    /// Everything a replay decides or measures, flattened to exact bits
    /// — the golden suites compare replays with `Vec<String>` equality
    /// on this. Cache counters and dedup bookkeeping are deliberately
    /// excluded (they differ between the cached and uncached paths by
    /// design).
    pub fn fingerprint(&self) -> Vec<String> {
        let mut out = Vec::new();
        for e in &self.events {
            out.push(format!(
                "event t={} tenant={} {} -> {} residents={} gpus={} usage={}",
                e.t_s.to_bits(),
                e.tenant,
                e.desc,
                e.decision,
                e.residents,
                e.gpus_in_use,
                e.usage.to_bits()
            ));
        }
        for iv in &self.intervals {
            out.push(format!(
                "interval t={} tenants={:?} p99={:?} qos={:?}",
                iv.t_start_s.to_bits(),
                iv.tenants,
                iv.p99_s.iter().map(|p| p.to_bits()).collect::<Vec<_>>(),
                iv.qos_met
            ));
        }
        out.push(format!(
            "summary admitted={} rejected={} repacks={} peak={} mean_gpus={}",
            self.admitted,
            self.rejected,
            self.repacks_applied,
            self.peak_residents,
            self.mean_gpus_in_use.to_bits()
        ));
        out
    }
}

/// One interval snapshot: start time, owned copies of the resident set,
/// and the degrade overlay active at that moment (GPU-degrade events
/// mutate the controller's cluster mid-trace, so each interval must
/// simulate under the overlay it actually ran with).
pub(crate) type IntervalSnapshot =
    (f64, Vec<(String, Pipeline, Deployment, ArrivalProcess)>, Vec<f64>);

/// Incremental (event-at-a-time) form of [`replay_trace`]'s decision
/// phase — the seam the durable control plane
/// ([`crate::coordinator::recovery`]) logs and snapshots through.
/// [`replay_trace`] is a thin `new → apply_event × N → finish` wrapper,
/// so the durable and in-memory paths run the *same* code and produce
/// bit-identical [`ReplayReport`]s (the crash-recovery golden suite
/// pins the fingerprint equality).
pub struct ReplayState {
    ctl: AdmissionController,
    /// Pristine copy of the input cluster — the controller's own copy
    /// mutates under GPU-degrade events; phase 2 rebuilds each
    /// interval's cluster from the overlay its snapshot recorded.
    base_cluster: ClusterSpec,
    cfg: ReplayConfig,
    /// trace tenant id -> controller resident id
    resident_ids: Vec<(u64, u64)>,
    events: Vec<ReplayEvent>,
    peak_residents: usize,
    repacks_applied: usize,
    repack_regressions: usize,
    qos_violations: Vec<QosViolationRecord>,
    /// trace tenant id -> (pre-burst base arrivals, open burst depth)
    burst_state: HashMap<u64, (ArrivalProcess, usize)>,
    snapshots: Vec<IntervalSnapshot>,
    /// per-class SM occupancy, accumulated per event with residents
    class_ranges: Vec<(usize, usize)>,
    class_sum: Vec<f64>,
    class_peak: Vec<f64>,
    class_events: usize,
}

/// Drive an [`AdmissionController`] over a [`TenantTrace`] and validate
/// every between-event interval in the merged multi-tenant simulator.
///
/// Phase 1 (sequential, inherently): admission decisions in event
/// order — each decision only depends on the controller state, never on
/// simulation results, so the decision sequence is a pure function of
/// `(trace, cfg)`. Phase 2 (parallel, incremental): one merged
/// simulation per *distinct* interval content, seeded
/// `mix_seed(cfg.admission.seed, first snapshot index with that
/// content)` and fanned with [`par::par_map_threads`] — repeated
/// configurations reuse the first occurrence's report, single-tenant
/// constant-rate intervals route through the optimized
/// [`Simulator::run`], and results land by input index, so the report
/// is bit-identical for any `cfg.threads` (the golden suite pins
/// 1/2/8) and for dedup on/off.
pub fn replay_trace(
    cluster: &ClusterSpec,
    trace: &TenantTrace,
    cfg: &ReplayConfig,
) -> Result<ReplayReport, String> {
    let mut state = ReplayState::new(cluster, cfg.clone());
    state.warm_start()?;
    // bursts are expanded (synthesized end events, canonical re-sort)
    // only when present, so burst-free traces replay their event list
    // verbatim — hand-built golden traces included
    let expanded;
    let trace_events: &[TenantTraceEvent] = if trace.has_bursts() {
        expanded = trace.expanded_events();
        &expanded
    } else {
        &trace.events
    };
    for e in trace_events {
        state.apply_event(e)?;
    }
    state.finish()
}

impl ReplayState {
    /// A fresh replay over `cluster`: no events applied yet.
    pub fn new(cluster: &ClusterSpec, cfg: ReplayConfig) -> ReplayState {
        let class_ranges = cluster.class_ranges();
        ReplayState {
            ctl: AdmissionController::new(cluster.clone(), cfg.admission.clone()),
            base_cluster: cluster.clone(),
            cfg,
            resident_ids: Vec::new(),
            events: Vec::new(),
            peak_residents: 0,
            repacks_applied: 0,
            repack_regressions: 0,
            qos_violations: Vec::new(),
            burst_state: HashMap::new(),
            snapshots: Vec::new(),
            class_sum: vec![0.0; class_ranges.len()],
            class_peak: vec![0.0; class_ranges.len()],
            class_ranges,
            class_events: 0,
        }
    }

    /// Load [`ReplayConfig::warm_cache`] (when set) into the
    /// controller's planner cache. Call once, before the first event —
    /// [`replay_trace`] and the recovery layer's fresh-state path both
    /// do. Returns the entries loaded (0 without a payload).
    pub fn warm_start(&self) -> Result<usize, String> {
        match &self.cfg.warm_cache {
            Some(json) => self.ctl.warm_start_cache(json),
            None => Ok(0),
        }
    }

    /// The controller's planner-cache contents
    /// ([`SolveCache::to_json`]) — the `camelot admit --cache-save`
    /// payload a later replay warm-starts from.
    pub fn cache_json(&self) -> String {
        self.ctl.cache_json()
    }

    /// Events applied so far — each [`apply_event`](Self::apply_event)
    /// appends exactly one [`ReplayEvent`], so this doubles as the
    /// replay position a recovery resumes from.
    pub fn applied(&self) -> usize {
        self.events.len()
    }

    /// The decision log so far.
    pub fn events(&self) -> &[ReplayEvent] {
        &self.events
    }

    /// The live controller (read-only: recovery verification and tests
    /// introspect resident state between events).
    pub fn controller(&self) -> &AdmissionController {
        &self.ctl
    }

    /// Apply one trace event and return the decision record appended to
    /// the log — the exact value the WAL persists, so recovery can
    /// verify replayed decisions against logged ones field-for-field.
    pub fn apply_event(&mut self, e: &TenantTraceEvent) -> Result<ReplayEvent, String> {
        let ctl = &mut self.ctl;
        let resident_ids = &mut self.resident_ids;
        let burst_state = &mut self.burst_state;
        let (desc, decision) = match &e.kind {
            TraceEventKind::Arrive { pipeline, name, arrivals, plan_qps, priority } => {
                let desc = format!("arrive {pipeline} @ {plan_qps:.0} qps");
                let p = crate::suite::pipeline_by_name(pipeline)
                    .ok_or_else(|| format!("trace names unknown pipeline '{pipeline}'"))?;
                let name = name
                    .clone()
                    .unwrap_or_else(|| format!("{pipeline}#{}", e.tenant));
                let degraded_before = ctl.degraded_plans();
                let decision = match ctl.admit_preempting(
                    &name,
                    &p,
                    arrivals.clone(),
                    *plan_qps,
                    *priority,
                ) {
                    Ok((id, evicted)) => {
                        resident_ids.push((e.tenant, id));
                        // deadline-degraded solves are flagged in the
                        // decision log (impossible at plan_deadline=0,
                        // so legacy logs are byte-identical)
                        let mark = if ctl.degraded_plans() > degraded_before {
                            " (degraded)"
                        } else {
                            ""
                        };
                        if evicted.is_empty() {
                            format!("admitted{mark}")
                        } else {
                            // preempted tenants left the resident set
                            resident_ids.retain(|&(_, rid)| {
                                ctl.residents().iter().any(|r| r.id == rid)
                            });
                            format!("admitted{mark}; preempted {}", evicted.join(","))
                        }
                    }
                    Err(reason) => format!("rejected: {reason}"),
                };
                (desc, decision)
            }
            TraceEventKind::Shrink { target_qps } => {
                let desc = format!("shrink to {target_qps:.0} qps");
                let decision = match resident_ids.iter().find(|(t, _)| *t == e.tenant) {
                    Some(&(_, id)) => ctl
                        .shrink_resident(id, *target_qps)
                        .expect("resident shrinks")
                        .summary(),
                    None => "no-op (was not admitted)".to_string(),
                };
                (desc, decision)
            }
            TraceEventKind::Depart => {
                let desc = "depart".to_string();
                let decision = match resident_ids.iter().position(|(t, _)| *t == e.tenant)
                {
                    Some(pos) => {
                        let (_, id) = resident_ids.remove(pos);
                        let plan = ctl.depart(id).expect("resident departs");
                        if plan.applied {
                            self.repacks_applied += 1;
                            if plan.gpus_after > plan.gpus_before {
                                self.repack_regressions += 1;
                            }
                        }
                        plan.summary()
                    }
                    None => "no-op (was not admitted)".to_string(),
                };
                (desc, decision)
            }
            TraceEventKind::Burst { rate_mult, duration_s } => {
                let desc = format!("burst x{rate_mult:.1} for {duration_s:.0}s");
                let decision = match resident_ids.iter().find(|(t, _)| *t == e.tenant) {
                    Some(&(_, id)) => {
                        let cur = ctl
                            .resident_arrivals(id)
                            .expect("resident has arrivals")
                            .clone();
                        let entry = burst_state
                            .entry(e.tenant)
                            .or_insert_with(|| (cur.clone(), 0));
                        entry.1 += 1;
                        let new_peak = cur.peak_qps() * rate_mult;
                        ctl.set_resident_arrivals(id, cur.scaled_to_peak(new_peak));
                        format!("offered load x{rate_mult:.1} -> {new_peak:.0} qps peak")
                    }
                    None => "no-op (was not admitted)".to_string(),
                };
                (desc, decision)
            }
            TraceEventKind::BurstEnd => {
                let desc = "burst end".to_string();
                let decision = match resident_ids.iter().find(|(t, _)| *t == e.tenant) {
                    Some(&(_, id)) => match burst_state.get_mut(&e.tenant) {
                        Some(entry) if entry.1 > 1 => {
                            entry.1 -= 1;
                            "nested burst still open".to_string()
                        }
                        Some(_) => {
                            let (base, _) = burst_state.remove(&e.tenant).unwrap();
                            let peak = base.peak_qps();
                            ctl.set_resident_arrivals(id, base);
                            format!("offered load restored -> {peak:.0} qps peak")
                        }
                        None => "no-op (burst never applied)".to_string(),
                    },
                    None => "no-op (was not admitted)".to_string(),
                };
                (desc, decision)
            }
            TraceEventKind::GpuFail { gpu_ids } => {
                let desc = format!("gpufail {gpu_ids:?}");
                let rep = ctl.fail_gpus(gpu_ids);
                // evicted tenants leave the id map so later events no-op
                if !rep.evicted.is_empty() {
                    resident_ids
                        .retain(|&(_, rid)| ctl.residents().iter().any(|r| r.id == rid));
                }
                (desc, rep.summary())
            }
            TraceEventKind::GpuRecover { gpu_ids } => {
                let desc = format!("gpurecover {gpu_ids:?}");
                let plan = ctl.recover_gpus(gpu_ids);
                if plan.applied {
                    self.repacks_applied += 1;
                    if plan.gpus_after > plan.gpus_before {
                        self.repack_regressions += 1;
                    }
                }
                (desc, plan.summary())
            }
            TraceEventKind::GpuDegrade { gpu_ids, scale } => {
                let desc = format!("gpudegrade {gpu_ids:?} x{scale:.2}");
                let (applied, evicted) = ctl.degrade_gpus(gpu_ids, *scale);
                if !evicted.is_empty() {
                    resident_ids
                        .retain(|&(_, rid)| ctl.residents().iter().any(|r| r.id == rid));
                }
                (desc, degrade_summary(&applied, *scale, &evicted))
            }
            TraceEventKind::GpuRestore { gpu_ids } => {
                let desc = format!("gpurestore {gpu_ids:?}");
                let plan = ctl.restore_gpus(gpu_ids);
                if plan.applied {
                    self.repacks_applied += 1;
                    if plan.gpus_after > plan.gpus_before {
                        self.repack_regressions += 1;
                    }
                }
                (desc, plan.summary())
            }
        };
        if self.cfg.audit_qos {
            for (tenant, predicted_p99_s, target_s) in ctl.qos_audit() {
                self.qos_violations.push(QosViolationRecord {
                    t_s: e.t_s,
                    tenant,
                    predicted_p99_s,
                    target_s,
                });
            }
        }
        self.peak_residents = self.peak_residents.max(ctl.residents().len());
        let ev = ReplayEvent {
            t_s: e.t_s,
            tenant: e.tenant,
            desc,
            decision,
            residents: ctl.residents().len(),
            gpus_in_use: ctl.gpus_in_use(),
            usage: ctl.total_usage(),
        };
        self.events.push(ev.clone());
        if !self.class_ranges.is_empty() && !ctl.residents().is_empty() {
            self.class_events += 1;
            for (ci, &(start, count)) in self.class_ranges.iter().enumerate() {
                let held: f64 = ctl
                    .residents()
                    .iter()
                    .flat_map(|r| r.deployment.placements.iter())
                    .filter(|p| p.gpu >= start && p.gpu < start + count)
                    .map(|p| p.sm_frac)
                    .sum();
                let frac = held / count as f64;
                self.class_sum[ci] += frac;
                self.class_peak[ci] = self.class_peak[ci].max(frac);
            }
        }
        if !ctl.residents().is_empty() {
            self.snapshots.push((
                e.t_s,
                ctl.residents()
                    .iter()
                    .map(|r| {
                        (
                            r.name.clone(),
                            r.pipeline.clone(),
                            r.deployment.clone(),
                            r.arrivals.clone(),
                        )
                    })
                    .collect(),
                ctl.cluster().degrade.clone(),
            ));
        }
        Ok(ev)
    }

    /// Phase 2: merged end-to-end measurement per interval, incremental.
    /// Consumes the state and assembles the [`ReplayReport`].
    ///
    /// Interval seeds are content-addressed by FIRST OCCURRENCE: every
    /// distinct interval content (tenant pipelines, deployments, arrival
    /// specs, degrade overlay — names and t_start excluded; they don't
    /// enter the sim) is seeded `mix_seed(seed, first snapshot index
    /// with that content)`. A snapshot whose content differs from all
    /// earlier ones therefore keeps exactly the legacy per-index seed,
    /// while repeated configurations (rejected arrivals, held
    /// shrinks/re-packs, arrive/depart/arrive cycles) are *provably the
    /// same simulation* — with `cfg.dedup` they are measured once and
    /// the report reused. Seed assignment and dedup both happen here,
    /// sequentially, before the `par_map_threads` fan, so thread-count
    /// determinism is preserved by construction, and `dedup: false` runs
    /// every duplicate at the same assigned seed — bit-identical output
    /// either way (the golden suite pins it).
    pub fn finish(self) -> Result<ReplayReport, String> {
        let cfg = &self.cfg;
        let cluster = &self.base_cluster;
        let snapshots = &self.snapshots;
        let threads = if cfg.threads == 0 { par::max_threads() } else { cfg.threads };
        let seed = cfg.admission.seed;
        let queries = cfg.queries;
        // per-job: (snapshot index providing the content, assigned sim seed)
        let mut jobs: Vec<(usize, u64)> = Vec::with_capacity(snapshots.len());
        // per-snapshot: index of the job that measures it
        let mut measure_by: Vec<usize> = Vec::with_capacity(snapshots.len());
        // fingerprint -> (seed-owner snapshot index, its job index)
        let mut seen: HashMap<String, (usize, usize)> = HashMap::new();
        for (idx, (_, tenants, degrade)) in snapshots.iter().enumerate() {
            let key = interval_fingerprint(tenants, queries, degrade);
            match seen.get(&key) {
                Some(&(_, job)) if cfg.dedup => measure_by.push(job),
                Some(&(owner, _)) => {
                    // dedup off: simulate this duplicate too, at the first
                    // occurrence's seed (same inputs ⇒ same report)
                    jobs.push((idx, rng::mix_seed(seed, owner as u64)));
                    measure_by.push(jobs.len() - 1);
                }
                None => {
                    jobs.push((idx, rng::mix_seed(seed, idx as u64)));
                    let job = jobs.len() - 1;
                    seen.insert(key, (idx, job));
                    measure_by.push(job);
                }
            }
        }
        let intervals_simulated = jobs.len();
        let sims: Vec<Result<(Vec<f64>, Vec<f64>), String>> =
            par::par_map_threads(&jobs, threads, |_, &(snap_idx, sim_seed)| {
                let (_, tenants, degrade) = &snapshots[snap_idx];
                // intervals after a GPU-degrade event simulate under
                // the overlay their snapshot recorded; the common
                // (healthy) case borrows the base cluster unchanged
                let owned;
                let cl: &ClusterSpec = if *degrade == cluster.degrade {
                    cluster
                } else {
                    owned = ClusterSpec { degrade: degrade.clone(), ..cluster.clone() };
                    &owned
                };
                let opts = SimOptions { seed: sim_seed, queries, ..Default::default() };
                // degenerate fast path: one constant-rate tenant runs on the
                // optimized single-tenant engine — bit-identical to the
                // merged ClusterSim by the degenerate-equivalence contract
                // (tenant 0 seeds from opts.seed directly; pinned in
                // tests/golden_engine.rs and tests/control_loop_cache.rs)
                if let [(_, p, d, ArrivalProcess::Constant { rate_qps })] =
                    tenants.as_slice()
                {
                    let report = Simulator::new(p, cl, d, opts)
                        .run(*rate_qps)
                        .map_err(|e| format!("interval {snap_idx}: {e}"))?;
                    return Ok((vec![report.p99()], report.kv_peak_bytes));
                }
                let specs: Vec<TenantSpec> = tenants
                    .iter()
                    .map(|(_, p, d, a)| TenantSpec {
                        pipeline: p,
                        deployment: d,
                        arrivals: a.clone(),
                    })
                    .collect();
                let reports = ClusterSim::new(cl, specs, opts)
                    .run()
                    .map_err(|e| format!("interval {snap_idx}: {e}"))?;
                // every tenant report carries the same cluster-wide
                // per-GPU KV peak vector; take the first
                let kv = reports
                    .first()
                    .map(|r| r.kv_peak_bytes.clone())
                    .unwrap_or_default();
                Ok((reports.iter().map(|r| r.p99()).collect(), kv))
            });
        let tables = sims.into_iter().collect::<Result<Vec<_>, _>>()?;
        // replay-wide per-GPU peak KV residency: element-wise max over the
        // distinct simulations (duplicates are bit-identical, so dedup
        // on/off cannot change the max)
        let mut kv_peak_bytes = vec![0.0f64; cluster.num_gpus];
        for (_, kv) in &tables {
            for (slot, &v) in kv_peak_bytes.iter_mut().zip(kv) {
                if v > *slot {
                    *slot = v;
                }
            }
        }
        let p99_tables: Vec<Vec<f64>> = tables.into_iter().map(|(p, _)| p).collect();
        let intervals: Vec<IntervalReport> = snapshots
            .iter()
            .zip(&measure_by)
            .map(|((t_start, tenants, _), &job)| {
                let p99_s: Vec<f64> = p99_tables[job].clone();
                let qos_met: Vec<bool> = tenants
                    .iter()
                    .zip(&p99_s)
                    .map(|((_, p, _, _), &x)| x <= p.qos_target_s)
                    .collect();
                IntervalReport {
                    t_start_s: *t_start,
                    tenants: tenants.iter().map(|(n, _, _, _)| n.clone()).collect(),
                    p99_s,
                    qos_met,
                }
            })
            .collect();

        let with_gpus: Vec<usize> = self
            .events
            .iter()
            .filter(|e| e.residents > 0)
            .map(|e| e.gpus_in_use)
            .collect();
        let mean_gpus_in_use = if with_gpus.is_empty() {
            0.0
        } else {
            with_gpus.iter().sum::<usize>() as f64 / with_gpus.len() as f64
        };
        let class_utilization: Vec<ClassUtilization> = cluster
            .classes
            .iter()
            .zip(self.class_ranges.iter())
            .enumerate()
            .map(|(ci, (c, &(_, count)))| ClassUtilization {
                class: c.gpu.name.to_string(),
                gpus: count,
                mean_sm_frac: if self.class_events == 0 {
                    0.0
                } else {
                    self.class_sum[ci] / self.class_events as f64
                },
                peak_sm_frac: self.class_peak[ci],
            })
            .collect();
        Ok(ReplayReport {
            admitted: self.ctl.admitted(),
            rejected: self.ctl.rejected(),
            repacks_applied: self.repacks_applied,
            peak_residents: self.peak_residents,
            mean_gpus_in_use,
            events: self.events,
            intervals,
            intervals_simulated,
            solve_cache: self.ctl.cache_stats(),
            qos_violations: self.qos_violations,
            repack_regressions: self.repack_regressions,
            class_utilization,
            kv_peak_bytes,
        })
    }
}

impl ReplayState {
    /// Serialize the full phase-1 state — controller, tenant-id map,
    /// decision log, burst bookkeeping, interval snapshots (with their
    /// degrade overlays), and class accumulators — as one JSON object,
    /// using the same bit-exact float / string-wrapped u64 conventions
    /// as [`AdmissionController::state_json`]. This is what a periodic
    /// durability snapshot persists; [`restore`](Self::restore) inverts
    /// it.
    pub fn snapshot_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\"ctl\": ");
        out.push_str(&self.ctl.state_json());
        out.push_str(", \"resident_ids\": [");
        for (i, (t, id)) in self.resident_ids.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            let _ = write!(out, "[\"{t}\", \"{id}\"]");
        }
        let _ = write!(
            out,
            "], \"peak_residents\": {}, \"repacks_applied\": {}, \
             \"repack_regressions\": {}, \"class_events\": {}",
            self.peak_residents,
            self.repacks_applied,
            self.repack_regressions,
            self.class_events
        );
        out.push_str(", \"class_sum\": ");
        cache::json_bits_arr(&mut out, &self.class_sum);
        out.push_str(", \"class_peak\": ");
        cache::json_bits_arr(&mut out, &self.class_peak);
        out.push_str(", \"qos_violations\": ");
        json_qos_violations(&mut out, &self.qos_violations);
        out.push_str(", \"burst_state\": ");
        json_burst_state(&mut out, &self.burst_state);
        out.push_str(", \"events\": ");
        json_replay_events(&mut out, &self.events);
        out.push_str(", \"snapshots\": ");
        json_interval_snapshots(&mut out, &self.snapshots);
        out.push('}');
        out
    }

    /// Rebuild a mid-replay state from
    /// [`snapshot_json`](Self::snapshot_json) output. `cluster` and
    /// `cfg` are the same inputs the original replay started with (they
    /// are configuration, not decisions); pipelines resolve by name
    /// from `pipelines` with the registry
    /// ([`crate::suite::pipeline_by_name`]) as fallback. Applying the
    /// remaining trace events to the restored state reconverges
    /// bit-identically with the uninterrupted replay — the recovery
    /// contract the crash golden suite pins.
    pub fn restore(
        cluster: &ClusterSpec,
        cfg: ReplayConfig,
        v: &Json,
        pipelines: &[Pipeline],
    ) -> Result<ReplayState, String> {
        let mut st = ReplayState::new(cluster, cfg);
        st.ctl = AdmissionController::restore_state(
            cluster.clone(),
            st.cfg.admission.clone(),
            v.get("ctl").ok_or("snapshot missing ctl")?,
            pipelines,
        )?;
        for pair in v
            .get("resident_ids")
            .and_then(Json::as_arr)
            .ok_or("snapshot missing resident_ids")?
        {
            let pair = pair.as_arr().ok_or("resident_ids entry must be a pair")?;
            if pair.len() != 2 {
                return Err("resident_ids entry must be a pair".to_string());
            }
            let parse_id = |j: &Json, what: &str| -> Result<u64, String> {
                j.as_str()
                    .ok_or_else(|| format!("{what} must be a string"))?
                    .parse::<u64>()
                    .map_err(|e| format!("bad {what}: {e}"))
            };
            st.resident_ids
                .push((parse_id(&pair[0], "trace id")?, parse_id(&pair[1], "resident id")?));
        }
        st.peak_residents = snap_usize(v, "peak_residents")?;
        st.repacks_applied = snap_usize(v, "repacks_applied")?;
        st.repack_regressions = snap_usize(v, "repack_regressions")?;
        st.class_events = snap_usize(v, "class_events")?;
        st.class_sum = cache::parse_bits_arr(v.get("class_sum").ok_or("snapshot missing class_sum")?)?;
        st.class_peak =
            cache::parse_bits_arr(v.get("class_peak").ok_or("snapshot missing class_peak")?)?;
        if st.class_sum.len() != st.class_ranges.len()
            || st.class_peak.len() != st.class_ranges.len()
        {
            return Err("class accumulator length mismatch".to_string());
        }
        st.qos_violations =
            parse_qos_violations(v.get("qos_violations").ok_or("snapshot missing qos_violations")?)?;
        st.burst_state =
            parse_burst_state(v.get("burst_state").ok_or("snapshot missing burst_state")?)?;
        st.events = parse_replay_events(v.get("events").ok_or("snapshot missing events")?)?;
        st.snapshots = parse_interval_snapshots(
            v.get("snapshots").ok_or("snapshot missing snapshots")?,
            pipelines,
        )?;
        Ok(st)
    }
}

/// Emit one [`ReplayEvent`] as a JSON object — the WAL record body (the
/// recovery layer prepends a sequence number). Bit-exact: `t`/`usage`
/// as [`f64::to_bits`] hex, the tenant id as a decimal string.
pub(crate) fn json_replay_event(out: &mut String, e: &ReplayEvent) {
    out.push_str("{\"t\": ");
    cache::json_bits(out, e.t_s);
    let _ = write!(out, ", \"tenant\": \"{}\", \"desc\": ", e.tenant);
    cache::json_str(out, &e.desc);
    out.push_str(", \"decision\": ");
    cache::json_str(out, &e.decision);
    let _ = write!(
        out,
        ", \"residents\": {}, \"gpus\": {}, \"usage\": ",
        e.residents, e.gpus_in_use
    );
    cache::json_bits(out, e.usage);
    out.push('}');
}

/// Parse a [`json_replay_event`] object.
pub(crate) fn parse_replay_event(v: &Json) -> Result<ReplayEvent, String> {
    Ok(ReplayEvent {
        t_s: cache::parse_bits(v.get("t").ok_or("event missing t")?)?,
        tenant: v
            .get_str("tenant")
            .ok_or("event missing tenant")?
            .parse::<u64>()
            .map_err(|e| format!("bad tenant id: {e}"))?,
        desc: v.get_str("desc").ok_or("event missing desc")?.to_string(),
        decision: v.get_str("decision").ok_or("event missing decision")?.to_string(),
        residents: snap_usize(v, "residents")?,
        gpus_in_use: snap_usize(v, "gpus")?,
        usage: cache::parse_bits(v.get("usage").ok_or("event missing usage")?)?,
    })
}

/// Emit a list of [`json_replay_event`] objects.
pub(crate) fn json_replay_events(out: &mut String, events: &[ReplayEvent]) {
    out.push('[');
    for (i, ev) in events.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        json_replay_event(out, ev);
    }
    out.push(']');
}

/// Parse a [`json_replay_events`] list.
pub(crate) fn parse_replay_events(v: &Json) -> Result<Vec<ReplayEvent>, String> {
    v.as_arr()
        .ok_or("events must be an array")?
        .iter()
        .map(parse_replay_event)
        .collect()
}

/// Emit a QoS-violation log (bit-exact floats, tenant by name).
pub(crate) fn json_qos_violations(out: &mut String, violations: &[QosViolationRecord]) {
    out.push('[');
    for (i, q) in violations.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str("{\"t\": ");
        cache::json_bits(out, q.t_s);
        out.push_str(", \"tenant\": ");
        cache::json_str(out, &q.tenant);
        out.push_str(", \"p99\": ");
        cache::json_bits(out, q.predicted_p99_s);
        out.push_str(", \"target\": ");
        cache::json_bits(out, q.target_s);
        out.push('}');
    }
    out.push(']');
}

/// Parse a [`json_qos_violations`] list.
pub(crate) fn parse_qos_violations(v: &Json) -> Result<Vec<QosViolationRecord>, String> {
    let mut out = Vec::new();
    for q in v.as_arr().ok_or("qos_violations must be an array")? {
        out.push(QosViolationRecord {
            t_s: cache::parse_bits(q.get("t").ok_or("violation missing t")?)?,
            tenant: q.get_str("tenant").ok_or("violation missing tenant")?.to_string(),
            predicted_p99_s: cache::parse_bits(q.get("p99").ok_or("violation missing p99")?)?,
            target_s: cache::parse_bits(q.get("target").ok_or("violation missing target")?)?,
        });
    }
    Ok(out)
}

/// Emit the open-burst bookkeeping map. HashMap order is
/// nondeterministic; entries sort by tenant id so the same state always
/// serializes to the same bytes.
pub(crate) fn json_burst_state(
    out: &mut String,
    burst_state: &HashMap<u64, (ArrivalProcess, usize)>,
) {
    out.push('[');
    let mut bursts: Vec<_> = burst_state.iter().collect();
    bursts.sort_by_key(|(t, _)| **t);
    for (i, (t, (base, depth))) in bursts.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        let _ = write!(out, "{{\"tenant\": \"{t}\", \"depth\": {depth}, \"base\": ");
        cache::json_arrivals(out, base);
        out.push('}');
    }
    out.push(']');
}

/// Parse a [`json_burst_state`] list.
pub(crate) fn parse_burst_state(
    v: &Json,
) -> Result<HashMap<u64, (ArrivalProcess, usize)>, String> {
    let mut out = HashMap::new();
    for b in v.as_arr().ok_or("burst_state must be an array")? {
        let tenant = b
            .get_str("tenant")
            .ok_or("burst missing tenant")?
            .parse::<u64>()
            .map_err(|e| format!("bad burst tenant: {e}"))?;
        let base = cache::parse_arrivals(b.get("base").ok_or("burst missing base")?)?;
        out.insert(tenant, (base, snap_usize(b, "depth")?));
    }
    Ok(out)
}

/// Emit a list of between-event interval snapshots (pipelines by name,
/// floats bit-exact, the degrade overlay active at capture time).
pub(crate) fn json_interval_snapshots(out: &mut String, snaps: &[IntervalSnapshot]) {
    out.push('[');
    for (i, (t, tenants, degrade)) in snaps.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str("{\"t\": ");
        cache::json_bits(out, *t);
        out.push_str(", \"degrade\": ");
        cache::json_bits_arr(out, degrade);
        out.push_str(", \"tenants\": [");
        for (j, (name, p, d, a)) in tenants.iter().enumerate() {
            if j > 0 {
                out.push_str(", ");
            }
            out.push_str("{\"name\": ");
            cache::json_str(out, name);
            out.push_str(", \"pipeline\": ");
            cache::json_str(out, &p.name);
            out.push_str(", \"deployment\": ");
            cache::json_deployment(out, d);
            out.push_str(", \"arrivals\": ");
            cache::json_arrivals(out, a);
            out.push('}');
        }
        out.push_str("]}");
    }
    out.push(']');
}

/// Parse a [`json_interval_snapshots`] list; pipelines resolve by name.
pub(crate) fn parse_interval_snapshots(
    v: &Json,
    pipelines: &[Pipeline],
) -> Result<Vec<IntervalSnapshot>, String> {
    let mut out = Vec::new();
    for s in v.as_arr().ok_or("snapshots must be an array")? {
        let t = cache::parse_bits(s.get("t").ok_or("interval missing t")?)?;
        let degrade =
            cache::parse_bits_arr(s.get("degrade").ok_or("interval missing degrade")?)?;
        let mut tenants = Vec::new();
        for tn in s.get("tenants").and_then(Json::as_arr).ok_or("interval missing tenants")? {
            let pname = tn.get_str("pipeline").ok_or("tenant missing pipeline")?;
            tenants.push((
                tn.get_str("name").ok_or("tenant missing name")?.to_string(),
                resolve_pipeline(pname, pipelines)?,
                cache::parse_deployment(tn.get("deployment").ok_or("tenant missing deployment")?)?,
                cache::parse_arrivals(tn.get("arrivals").ok_or("tenant missing arrivals")?)?,
            ));
        }
        out.push((t, tenants, degrade));
    }
    Ok(out)
}

/// Decision string for a degrade event — shared with the cells router so
/// the single-cell path reproduces the flat decision byte-for-byte.
pub(crate) fn degrade_summary(applied: &[usize], scale: f64, evicted: &[String]) -> String {
    format!(
        "gpudegrade: gpus {applied:?} x{scale:.2} evicted {}",
        if evicted.is_empty() { "-".to_string() } else { evicted.join(",") }
    )
}

/// Resolve a snapshotted pipeline reference: the caller-provided set
/// first (custom pipelines), then the built-in registry.
fn resolve_pipeline(name: &str, pipelines: &[Pipeline]) -> Result<Pipeline, String> {
    pipelines
        .iter()
        .find(|p| p.name == name)
        .cloned()
        .or_else(|| crate::suite::pipeline_by_name(name))
        .ok_or_else(|| format!("snapshot references unknown pipeline '{name}'"))
}

pub(crate) fn snap_usize(v: &Json, key: &str) -> Result<usize, String> {
    v.get_f64(key).map(|x| x as usize).ok_or_else(|| format!("snapshot missing {key}"))
}

/// Outcome of the static-partitioning baseline replay.
#[derive(Debug, Clone)]
pub struct StaticReplayReport {
    pub admitted: usize,
    pub rejected: usize,
    pub peak_residents: usize,
    /// Mean whole GPUs occupied while at least one tenant is resident.
    pub mean_gpus_in_use: f64,
}

/// Static partitioning baseline: each tenant demands *dedicated whole
/// GPUs* (the smallest exclusive sub-cluster on which Case 2 solves at
/// its planning load) and is admitted iff that many free GPUs remain.
/// No spatial sharing — this is the peak-load ceiling the paper's
/// contention-aware allocation beats by up to 64.5%.
pub fn static_partition_replay(
    cluster: &ClusterSpec,
    trace: &TenantTrace,
    cfg: &AdmissionConfig,
) -> Result<StaticReplayReport, String> {
    let mut free = cluster.num_gpus;
    // failed GPU -> whether it actually debited the free pool (a
    // failure landing on a fully-held pool debits nothing, so its
    // recovery must credit nothing — no phantom capacity)
    let mut failed: std::collections::BTreeMap<usize, bool> = std::collections::BTreeMap::new();
    // trace tenant id -> GPUs held
    let mut holds: Vec<(u64, usize)> = Vec::new();
    let mut admitted = 0usize;
    let mut rejected = 0usize;
    let mut peak_residents = 0usize;
    let mut gpu_samples: Vec<usize> = Vec::new();
    let mut predictor_cache: Vec<(String, Vec<StagePredictor>)> = Vec::new();
    // identical tenants re-run the same sub-cluster ladder; the memo
    // returns each (pipeline, load, k) verdict once
    let solve_cache = SolveCache::new(cfg.solve_cache);

    for e in &trace.events {
        match &e.kind {
            TraceEventKind::Arrive { pipeline, plan_qps, .. } => {
                let p = crate::suite::pipeline_by_name(pipeline)
                    .ok_or_else(|| format!("trace names unknown pipeline '{pipeline}'"))?;
                let preds = match predictor_cache.iter().find(|(n, _)| *n == p.name) {
                    Some((_, preds)) => preds.clone(),
                    None => {
                        let preds = crate::predictor::train_pipeline(&p, &cluster.gpu);
                        predictor_cache.push((p.name.clone(), preds.clone()));
                        preds
                    }
                };
                // smallest dedicated sub-cluster that serves the tenant
                let target = plan_qps * cfg.headroom;
                let mut need = None;
                for k in 1..=free {
                    // prefix(), not a bare num_gpus override: on a
                    // mixed pool the first k devices keep their class
                    // composition (truncated, never re-labeled)
                    let sub = cluster.prefix(k);
                    let req = PlanRequest::new(
                        Objective::MinResource { load_qps: target },
                        ClusterState::exclusive(&sub),
                        &p,
                        &preds,
                    )
                    .batch(cfg.batch)
                    .sa(cfg.sa);
                    if solve_cache.plan(&req).is_ok() {
                        need = Some(k);
                        break;
                    }
                }
                match need {
                    Some(k) => {
                        free -= k;
                        holds.push((e.tenant, k));
                        admitted += 1;
                    }
                    None => rejected += 1,
                }
            }
            TraceEventKind::Depart => {
                if let Some(pos) = holds.iter().position(|(t, _)| *t == e.tenant) {
                    let (_, k) = holds.remove(pos);
                    free += k;
                }
            }
            // static partitioning has no online shrink: dedicated whole
            // GPUs stay dedicated until departure — exactly the rigidity
            // the shared planner's Objective::Shrink removes. Bursts
            // only change offered load, which the baseline never
            // measures.
            TraceEventKind::Shrink { .. }
            | TraceEventKind::Burst { .. }
            | TraceEventKind::BurstEnd => {}
            // whole-GPU accounting: a failed device shrinks the free
            // pool (residents on it are assumed re-seated from the free
            // pool first — the baseline has no placement to displace)
            TraceEventKind::GpuFail { gpu_ids } => {
                for &g in gpu_ids {
                    if g < cluster.num_gpus && !failed.contains_key(&g) {
                        let debited = free > 0;
                        if debited {
                            free -= 1;
                        }
                        failed.insert(g, debited);
                    }
                }
            }
            TraceEventKind::GpuRecover { gpu_ids } => {
                for &g in gpu_ids {
                    if let Some(debited) = failed.remove(&g) {
                        if debited {
                            free += 1;
                        }
                    }
                }
            }
            // a partially degraded device still serves its dedicated
            // tenant — slower, but the baseline never measures latency,
            // so whole-GPU accounting is unchanged
            TraceEventKind::GpuDegrade { .. } | TraceEventKind::GpuRestore { .. } => {}
        }
        peak_residents = peak_residents.max(holds.len());
        if !holds.is_empty() {
            gpu_samples.push(cluster.num_gpus - free);
        }
    }
    let mean_gpus_in_use = if gpu_samples.is_empty() {
        0.0
    } else {
        gpu_samples.iter().sum::<usize>() as f64 / gpu_samples.len() as f64
    };
    Ok(StaticReplayReport { admitted, rejected, peak_residents, mean_gpus_in_use })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommMode;
    use crate::suite::real;

    fn controller() -> AdmissionController {
        AdmissionController::new(ClusterSpec::two_2080ti(), AdmissionConfig::default())
    }

    fn arrive(
        ctl: &mut AdmissionController,
        name: &str,
        pipeline: &Pipeline,
        qps: f64,
    ) -> Result<u64, RejectReason> {
        ctl.try_admit(name, pipeline, ArrivalProcess::constant(qps), qps)
    }

    #[test]
    fn admits_then_rejects_at_capacity_with_reason() {
        let mut ctl = controller();
        let p = real::text_to_text();
        let first = arrive(&mut ctl, "a", &p, 120.0).expect("empty cluster admits");
        assert_eq!(first, 0);
        // keep admitting identical tenants until the cluster is full:
        // the first rejection must carry a typed, non-empty reason
        let mut rejections = 0;
        for i in 1..8 {
            match arrive(&mut ctl, &format!("t{i}"), &p, 120.0) {
                Ok(_) => {}
                Err(reason) => {
                    rejections += 1;
                    match &reason {
                        RejectReason::NoFeasiblePlan { detail } => {
                            assert!(!detail.is_empty())
                        }
                        RejectReason::QosViolation { predicted_p99_s, target_s, .. } => {
                            assert!(predicted_p99_s > target_s)
                        }
                    }
                    assert!(!reason.to_string().is_empty());
                }
            }
        }
        assert!(rejections > 0, "a 2-GPU cluster cannot hold 8 such tenants");
        assert!(ctl.admitted() >= 1 && ctl.rejected() == rejections);
        // rejection left the resident set coherent
        assert_eq!(ctl.residents().len(), ctl.admitted());
        assert!(ctl.gpus_in_use() <= 2);
    }

    #[test]
    fn admission_respects_resident_footprints() {
        // the merged deployment after two admissions must co-exist:
        // ClusterSim's admission check is the arbiter
        let mut ctl = controller();
        let pa = real::img_to_text();
        let pb = real::text_to_text();
        arrive(&mut ctl, "a", &pa, 100.0).expect("A admits");
        arrive(&mut ctl, "b", &pb, 80.0).expect("B fits the remainder");
        let c = ClusterSpec::two_2080ti();
        let specs: Vec<TenantSpec> = ctl
            .residents()
            .iter()
            .map(|r| TenantSpec {
                pipeline: &r.pipeline,
                deployment: &r.deployment,
                arrivals: r.arrivals.clone(),
            })
            .collect();
        ClusterSim::new(&c, specs, SimOptions { queries: 64, ..Default::default() })
            .admit()
            .expect("admitted tenants co-exist on the shared GPUs");
    }

    /// A tenant deliberately fragmented across both GPUs (stage 0 on
    /// GPU 0, stage 1 on GPU 1) next to a departing neighbor — the
    /// canonical re-packing setup, installed directly so the scenario
    /// does not depend on planner heuristics.
    fn fragmented_pair(
        cfg: AdmissionConfig,
    ) -> (AdmissionController, u64 /* survivor */, u64 /* departer */) {
        use crate::sim::InstancePlacement;
        let mut ctl = AdmissionController::new(ClusterSpec::two_2080ti(), cfg);
        let p = real::img_to_text();
        let split = |q: f64| Deployment {
            placements: vec![
                InstancePlacement { stage: 0, gpu: 0, sm_frac: q },
                InstancePlacement { stage: 1, gpu: 1, sm_frac: q },
            ],
            batch: 32,
            comm: CommMode::GlobalIpc,
        };
        let survivor = ctl.insert_resident(
            "survivor",
            &p,
            Allocation { instances: vec![1, 1], quotas: vec![0.3, 0.3] },
            split(0.3),
            40.0,
        );
        let departer = ctl.insert_resident(
            "departer",
            &p,
            Allocation { instances: vec![1, 1], quotas: vec![0.5, 0.5] },
            split(0.5),
            100.0,
        );
        (ctl, survivor, departer)
    }

    #[test]
    fn departure_repack_strictly_reduces_gpu_count() {
        let (mut ctl, survivor, departer) = fragmented_pair(AdmissionConfig::default());
        assert_eq!(ctl.gpus_in_use(), 2);
        let plan = ctl.depart(departer).expect("resident departs");
        // the survivor's two instances (Σ 0.6 SM) fit one GPU: greedy
        // re-placement must reclaim a whole device, and one reclaimed
        // GPU (worth 10 s) beats moving one instance (0.5 s × 2)
        assert!(plan.applied, "{}", plan.summary());
        assert_eq!(plan.gpus_before, 2);
        assert_eq!(plan.gpus_after, 1);
        assert!(
            plan.gpus_after < plan.gpus_before,
            "applied re-pack must strictly reduce the GPU count"
        );
        assert_eq!(ctl.gpus_in_use(), 1);
        assert_eq!(plan.migrations.len(), 1);
        assert_eq!(plan.migrations[0].tenant, "survivor");
        // one instance moved: one stop + one start
        assert_eq!(plan.churn_instances, 2);
        assert!((plan.churn_cost_s - 1.0).abs() < 1e-9);
        assert!(plan.gain_s > plan.churn_cost_s);
        // the survivor's allocation is untouched (greedy pass moves
        // instances, it does not re-solve)
        let r = &ctl.residents()[0];
        assert_eq!(r.id, survivor);
        assert_eq!(r.allocation.instances, vec![1, 1]);
        assert_eq!(r.allocation.quotas, vec![0.3, 0.3]);
    }

    #[test]
    fn repack_noop_when_churn_cost_exceeds_savings() {
        // same fragmentation, but a reclaimed GPU is worth less than
        // moving a single instance: hysteresis must hold every placement
        let cfg = AdmissionConfig {
            repack_gain_s_per_gpu: 0.4,
            churn_cost_s: 0.5,
            ..AdmissionConfig::default()
        };
        let (mut ctl, survivor, departer) = fragmented_pair(cfg);
        let before: Vec<_> = ctl
            .residents()
            .iter()
            .map(|r| (r.id, r.deployment.placements.clone()))
            .collect();
        let plan = ctl.depart(departer).expect("resident departs");
        assert!(!plan.applied, "{}", plan.summary());
        // the candidate would have saved a GPU, but 0.4 s gain < 1.0 s churn
        assert!(plan.gain_s < plan.churn_cost_s);
        assert_eq!(plan.gpus_after, plan.gpus_before, "held plan reports no change");
        assert_eq!(ctl.gpus_in_use(), 2, "no instance may move");
        let r = &ctl.residents()[0];
        assert_eq!(r.id, survivor);
        let (_, old) = before.iter().find(|(id, _)| *id == survivor).unwrap();
        assert_eq!(&r.deployment.placements, old, "survivor must not move");
    }

    #[test]
    fn shrink_frees_capacity_for_the_next_arrival() {
        // provision a tenant for a daytime load, shrink it to its
        // overnight trough, and verify the freed share is real
        let mut ctl = controller();
        let p = real::img_to_text();
        let id = arrive(&mut ctl, "big", &p, 150.0).expect("tenant admits");
        let before = ctl.total_usage();
        let rep = ctl.shrink_resident(id, 30.0).expect("resident shrinks");
        assert!(rep.applied, "{}", rep.summary());
        assert!(
            rep.new_usage < rep.old_usage,
            "shrink must reduce usage: {}",
            rep.summary()
        );
        assert!(ctl.total_usage() < before);
        // the resident's bookkeeping followed the shrink
        let r = &ctl.residents()[0];
        assert_eq!(r.id, id);
        assert!((r.plan_qps - 30.0).abs() < 1e-12);
        assert!((r.arrivals.peak_qps() - 30.0).abs() < 1e-12);
        // freed capacity is real: another tenant fits next to the
        // shrunken resident
        arrive(&mut ctl, "next", &real::text_to_text(), 80.0)
            .expect("freed share admits the next tenant");
    }

    #[test]
    fn shrink_holds_when_no_smaller_plan_exists() {
        let mut ctl = controller();
        let p = real::text_to_text();
        let id = arrive(&mut ctl, "a", &p, 60.0).expect("admits");
        let before: Vec<_> = ctl.residents()[0].deployment.placements.clone();
        let qps_before = ctl.residents()[0].plan_qps;
        // "shrinking" to a larger load cannot use less — must be held
        let rep = ctl.shrink_resident(id, 200.0).expect("resident exists");
        assert!(!rep.applied, "{}", rep.summary());
        assert_eq!(rep.churn_instances, 0);
        assert!((rep.new_usage - rep.old_usage).abs() < 1e-12);
        let r = &ctl.residents()[0];
        assert_eq!(r.deployment.placements, before, "held shrink must not move instances");
        assert!((r.plan_qps - qps_before).abs() < 1e-12);
        // unknown id is None
        assert!(ctl.shrink_resident(999, 10.0).is_none());
    }

    #[test]
    fn depart_unknown_id_is_none_and_departures_free_capacity() {
        let mut ctl = controller();
        let p = real::img_to_text();
        assert!(ctl.depart(99).is_none());
        let id = arrive(&mut ctl, "a", &p, 150.0).expect("admits");
        assert_eq!(ctl.residents().len(), 1);
        let plan = ctl.depart(id).expect("departs");
        assert_eq!(ctl.residents().len(), 0);
        assert_eq!(plan.gpus_after, 0, "empty cluster has no footprint");
        assert_eq!(ctl.gpus_in_use(), 0);
        // capacity is actually free again: the same tenant re-admits
        arrive(&mut ctl, "a2", &p, 150.0).expect("re-admits after departure");
    }

    #[test]
    fn static_baseline_admits_fewer_than_sharing() {
        // the headline claim, qualitatively: contention-aware sharing
        // absorbs at least as many tenants as dedicated whole GPUs
        let c = ClusterSpec::two_2080ti();
        let cfg = ReplayConfig { queries: 300, ..Default::default() };
        let trace = TenantTrace::generate(
            &crate::suite::workload::TenantTraceConfig {
                tenants: 6,
                mean_interarrival_s: 100.0,
                mean_lifetime_s: 100_000.0, // everyone stays: pure fill
                peak_qps_lo: 40.0,
                peak_qps_hi: 80.0,
                ..Default::default()
            },
            7,
        );
        let shared = replay_trace(&c, &trace, &cfg).expect("replay runs");
        let dedicated = static_partition_replay(&c, &trace, &cfg.admission).unwrap();
        assert!(
            shared.admitted >= dedicated.admitted,
            "sharing admitted {} vs static {}",
            shared.admitted,
            dedicated.admitted
        );
        assert!(dedicated.admitted + dedicated.rejected == 6);
        assert!(shared.admitted + shared.rejected == 6);
        assert!(shared.peak_residents >= dedicated.peak_residents);
    }

    #[test]
    fn replayed_intervals_hold_qos_for_admitted_tenants() {
        let c = ClusterSpec::two_2080ti();
        let cfg = ReplayConfig { queries: 600, ..Default::default() };
        let trace = TenantTrace::generate(
            &crate::suite::workload::TenantTraceConfig {
                tenants: 4,
                peak_qps_lo: 50.0,
                peak_qps_hi: 120.0,
                ..Default::default()
            },
            11,
        );
        let rep = replay_trace(&c, &trace, &cfg).expect("replay runs");
        assert_eq!(rep.events.len(), trace.events.len());
        assert!(!rep.intervals.is_empty());
        assert!(rep.admitted >= 1, "at least the first tenant must admit");
        // the controller's promise: what it admits, it serves — allow a
        // small tail tolerance as every QoS test in this repo does
        let mut checked = 0;
        for iv in &rep.intervals {
            for (name, &p99) in iv.tenants.iter().zip(&iv.p99_s) {
                let pname = name.split('#').next().unwrap();
                let q = crate::suite::pipeline_by_name(pname).unwrap().qos_target_s;
                assert!(
                    p99 <= q * 1.25,
                    "{name}: measured p99 {p99:.4}s vs target {q:.4}s"
                );
                checked += 1;
            }
        }
        assert!(checked > 0);
        // diurnal pattern means offered load is usually below the peak
        // the plan provisioned for, so most intervals should meet QoS
        let met: usize = rep
            .intervals
            .iter()
            .flat_map(|iv| iv.qos_met.iter())
            .filter(|&&m| m)
            .count();
        assert!(met * 2 >= checked, "QoS met in {met}/{checked} tenant-intervals");
    }

    #[test]
    fn mixed_pool_replay_reports_per_class_utilization() {
        use crate::config::GpuClass;
        let base = ClusterSpec::two_2080ti();
        let mut c = ClusterSpec { num_gpus: 4, ..base.clone() };
        c.classes = vec![
            GpuClass::scaled(base.gpu.clone(), 2, 1.0),
            GpuClass::scaled(crate::config::GpuSpec::a100_sxm4_80g(), 2, 0.7),
        ];
        c.validate_classes().unwrap();
        let cfg = ReplayConfig { queries: 200, ..Default::default() };
        let trace = TenantTrace::generate(
            &crate::suite::workload::TenantTraceConfig {
                tenants: 3,
                peak_qps_lo: 40.0,
                peak_qps_hi: 90.0,
                ..Default::default()
            },
            7,
        );
        let rep = replay_trace(&c, &trace, &cfg).expect("mixed-pool replay runs");
        assert!(rep.admitted >= 1);
        assert_eq!(rep.class_utilization.len(), 2);
        assert_eq!(rep.class_utilization[0].class, "RTX 2080Ti");
        assert_eq!(rep.class_utilization[1].class, "A100-SXM4-80GB");
        let mut any_load = 0.0f64;
        for cu in &rep.class_utilization {
            assert_eq!(cu.gpus, 2);
            assert!(
                (0.0..=1.0 + 1e-9).contains(&cu.mean_sm_frac),
                "mean in [0,1]: {}",
                cu.mean_sm_frac
            );
            assert!(cu.peak_sm_frac + 1e-9 >= cu.mean_sm_frac);
            any_load = any_load.max(cu.peak_sm_frac);
        }
        assert!(any_load > 0.0, "admitted tenants must occupy some class");

        // homogeneous pools keep the report shape unchanged
        let flat = replay_trace(&base, &trace, &cfg).expect("flat replay runs");
        assert!(flat.class_utilization.is_empty());
    }
}
