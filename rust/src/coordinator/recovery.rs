//! Durable control plane: write-ahead logging and periodic snapshots
//! for the trace-replay coordinator, with crash recovery that
//! reconverges *bit-identically* with the uninterrupted replay.
//!
//! The design leans on the replay's own determinism contract: phase-1
//! decisions are a pure function of `(trace, cfg, controller state)`,
//! so durability only has to persist (a) every accepted decision — one
//! WAL record per event, appended through [`WalStore::append_event`] —
//! and (b) a periodic [`ReplayState::snapshot_json`] /
//! [`CellsReplayState::snapshot_json`] checkpoint. Recovery restores
//! the latest snapshot (or a fresh state when none exists), re-applies
//! the trace tail, and *verifies* each re-derived decision against the
//! logged record — any divergence is a determinism bug and recovery
//! fails loudly rather than silently forking history. The crash
//! golden suite and the fuzzer's `--crash` invariant kill the
//! controller at every event boundary and pin
//! [`ReplayReport::fingerprint`] equality.
//!
//! WAL format: one JSON object per line,
//! `{"seq": N, "event": {...}}`, where the event body is the bit-exact
//! [`ReplayEvent`] encoding (`t`/`usage` as [`f64::to_bits`] hex, the
//! tenant id as a decimal string). Snapshots are whole-state JSON
//! documents named `snapshot-NNNNNN.json` (event count, zero-padded) so
//! the latest sorts last lexicographically. Solve-cache contents ride
//! inside the controller snapshot, so a recovered controller re-plans
//! warm.

use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::config::ClusterSpec;
use crate::suite::workload::{TenantTrace, TenantTraceEvent};
use crate::suite::Pipeline;
use crate::util::json::Json;

use super::admission::{
    self, replay_trace, ReplayConfig, ReplayEvent, ReplayReport, ReplayState,
};
use super::cells::{
    replay_trace_cells, CellsReplayConfig, CellsReplayReport, CellsReplayState,
};

// ---------------------------------------------------------------------
// Stores
// ---------------------------------------------------------------------

/// Where the WAL and snapshots live. [`MemStore`] backs tests and the
/// fuzzer's crash invariant (no filesystem in the hot loop);
/// [`DirStore`] is what `camelot admit --wal DIR` persists through.
pub trait WalStore {
    /// Append one WAL record (a single line, no trailing newline).
    fn append_event(&mut self, line: &str) -> Result<(), String>;
    /// Persist a snapshot taken after `applied` events.
    fn write_snapshot(&mut self, applied: usize, json: &str) -> Result<(), String>;
    /// The most recent snapshot, as `(applied, json)`.
    fn latest_snapshot(&self) -> Result<Option<(usize, String)>, String>;
    /// Every WAL record, in append order.
    fn wal_lines(&self) -> Result<Vec<String>, String>;
}

/// In-memory [`WalStore`] — the crash-injection harness's store.
#[derive(Debug, Clone, Default)]
pub struct MemStore {
    /// WAL records in append order.
    pub wal: Vec<String>,
    /// `(applied, json)` snapshots in write order.
    pub snapshots: Vec<(usize, String)>,
}

impl MemStore {
    /// An empty store.
    pub fn new() -> MemStore {
        MemStore::default()
    }
}

impl WalStore for MemStore {
    fn append_event(&mut self, line: &str) -> Result<(), String> {
        self.wal.push(line.to_string());
        Ok(())
    }

    fn write_snapshot(&mut self, applied: usize, json: &str) -> Result<(), String> {
        self.snapshots.push((applied, json.to_string()));
        Ok(())
    }

    fn latest_snapshot(&self) -> Result<Option<(usize, String)>, String> {
        Ok(self
            .snapshots
            .iter()
            .max_by_key(|(applied, _)| *applied)
            .map(|(applied, json)| (*applied, json.clone())))
    }

    fn wal_lines(&self) -> Result<Vec<String>, String> {
        Ok(self.wal.clone())
    }
}

/// Filesystem [`WalStore`]: `DIR/wal.log` plus
/// `DIR/snapshot-NNNNNN.json` checkpoints.
#[derive(Debug, Clone)]
pub struct DirStore {
    dir: PathBuf,
}

impl DirStore {
    /// Open (creating if missing) a WAL directory.
    pub fn open(dir: &Path) -> Result<DirStore, String> {
        fs::create_dir_all(dir)
            .map_err(|e| format!("cannot create WAL dir {}: {e}", dir.display()))?;
        Ok(DirStore { dir: dir.to_path_buf() })
    }

    /// Path of the append-only log file.
    pub fn wal_path(&self) -> PathBuf {
        self.dir.join("wal.log")
    }

    fn snapshot_path(&self, applied: usize) -> PathBuf {
        self.dir.join(format!("snapshot-{applied:06}.json"))
    }
}

impl WalStore for DirStore {
    fn append_event(&mut self, line: &str) -> Result<(), String> {
        let path = self.wal_path();
        let mut f = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .map_err(|e| format!("cannot open {}: {e}", path.display()))?;
        writeln!(f, "{line}").map_err(|e| format!("cannot append to {}: {e}", path.display()))
    }

    fn write_snapshot(&mut self, applied: usize, json: &str) -> Result<(), String> {
        let path = self.snapshot_path(applied);
        fs::write(&path, json).map_err(|e| format!("cannot write {}: {e}", path.display()))
    }

    fn latest_snapshot(&self) -> Result<Option<(usize, String)>, String> {
        let entries = fs::read_dir(&self.dir)
            .map_err(|e| format!("cannot read WAL dir {}: {e}", self.dir.display()))?;
        let mut best: Option<usize> = None;
        for entry in entries {
            let entry = entry.map_err(|e| format!("cannot read WAL dir entry: {e}"))?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if let Some(n) = name
                .strip_prefix("snapshot-")
                .and_then(|s| s.strip_suffix(".json"))
                .and_then(|s| s.parse::<usize>().ok())
            {
                best = Some(best.map_or(n, |b| b.max(n)));
            }
        }
        match best {
            None => Ok(None),
            Some(applied) => {
                let path = self.snapshot_path(applied);
                let json = fs::read_to_string(&path)
                    .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
                Ok(Some((applied, json)))
            }
        }
    }

    fn wal_lines(&self) -> Result<Vec<String>, String> {
        match fs::read_to_string(self.wal_path()) {
            Ok(text) => Ok(text
                .lines()
                .filter(|l| !l.trim().is_empty())
                .map(str::to_string)
                .collect()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(Vec::new()),
            Err(e) => Err(format!("cannot read {}: {e}", self.wal_path().display())),
        }
    }
}

// ---------------------------------------------------------------------
// WAL records
// ---------------------------------------------------------------------

fn wal_line(seq: usize, ev: &ReplayEvent) -> String {
    let mut out = String::with_capacity(192);
    out.push_str("{\"seq\": ");
    out.push_str(&seq.to_string());
    out.push_str(", \"event\": ");
    admission::json_replay_event(&mut out, ev);
    out.push('}');
    out
}

fn parse_wal_line(line: &str) -> Result<(usize, ReplayEvent), String> {
    let v = Json::parse(line).map_err(|e| format!("bad WAL record: {e}"))?;
    let seq = v.get_f64("seq").ok_or("WAL record missing seq")? as usize;
    let ev = admission::parse_replay_event(v.get("event").ok_or("WAL record missing event")?)?;
    Ok((seq, ev))
}

/// The event list a replay walks: burst traces expand synthesized end
/// events, burst-free traces replay verbatim — identical to what
/// [`replay_trace`] / [`replay_trace_cells`] iterate, so WAL sequence
/// numbers index into this list one-to-one.
pub fn trace_event_list(trace: &TenantTrace) -> Vec<TenantTraceEvent> {
    if trace.has_bursts() {
        trace.expanded_events()
    } else {
        trace.events.clone()
    }
}

// ---------------------------------------------------------------------
// Generic driver over the flat / cells replay seams
// ---------------------------------------------------------------------

/// The incremental-replay surface durability drives — implemented by
/// the flat [`ReplayState`] and the sharded [`CellsReplayState`], so
/// the WAL/snapshot/recover logic exists exactly once.
trait DurableState: Sized {
    type Cfg: Clone;
    type Report;
    fn fresh(cluster: &ClusterSpec, cfg: Self::Cfg) -> Result<Self, String>;
    fn restore_from(
        cluster: &ClusterSpec,
        cfg: Self::Cfg,
        v: &Json,
        pipelines: &[Pipeline],
    ) -> Result<Self, String>;
    fn apply(&mut self, e: &TenantTraceEvent) -> Result<ReplayEvent, String>;
    fn position(&self) -> usize;
    fn logged(&self) -> &[ReplayEvent];
    fn snapshot(&self) -> String;
    fn complete(self) -> Result<Self::Report, String>;
}

impl DurableState for ReplayState {
    type Cfg = ReplayConfig;
    type Report = ReplayReport;

    fn fresh(cluster: &ClusterSpec, cfg: ReplayConfig) -> Result<ReplayState, String> {
        let state = ReplayState::new(cluster, cfg);
        state.warm_start()?;
        Ok(state)
    }

    fn restore_from(
        cluster: &ClusterSpec,
        cfg: ReplayConfig,
        v: &Json,
        pipelines: &[Pipeline],
    ) -> Result<ReplayState, String> {
        ReplayState::restore(cluster, cfg, v, pipelines)
    }

    fn apply(&mut self, e: &TenantTraceEvent) -> Result<ReplayEvent, String> {
        self.apply_event(e)
    }

    fn position(&self) -> usize {
        self.applied()
    }

    fn logged(&self) -> &[ReplayEvent] {
        self.events()
    }

    fn snapshot(&self) -> String {
        self.snapshot_json()
    }

    fn complete(self) -> Result<ReplayReport, String> {
        self.finish()
    }
}

impl DurableState for CellsReplayState {
    type Cfg = CellsReplayConfig;
    type Report = CellsReplayReport;

    fn fresh(cluster: &ClusterSpec, cfg: CellsReplayConfig) -> Result<CellsReplayState, String> {
        CellsReplayState::new(cluster, cfg)
    }

    fn restore_from(
        cluster: &ClusterSpec,
        cfg: CellsReplayConfig,
        v: &Json,
        pipelines: &[Pipeline],
    ) -> Result<CellsReplayState, String> {
        CellsReplayState::restore(cluster, cfg, v, pipelines)
    }

    fn apply(&mut self, e: &TenantTraceEvent) -> Result<ReplayEvent, String> {
        self.apply_event(e)
    }

    fn position(&self) -> usize {
        self.applied()
    }

    fn logged(&self) -> &[ReplayEvent] {
        self.events()
    }

    fn snapshot(&self) -> String {
        self.snapshot_json()
    }

    fn complete(self) -> Result<CellsReplayReport, String> {
        self.finish()
    }
}

fn run_durable<S: DurableState>(
    cluster: &ClusterSpec,
    trace: &TenantTrace,
    cfg: S::Cfg,
    store: &mut dyn WalStore,
    snapshot_every: usize,
    stop_after: Option<usize>,
) -> Result<Option<S::Report>, String> {
    let mut state = S::fresh(cluster, cfg)?;
    let events = trace_event_list(trace);
    for e in &events {
        if stop_after == Some(state.position()) {
            return Ok(None);
        }
        let ev = state.apply(e)?;
        store.append_event(&wal_line(state.position() - 1, &ev))?;
        if snapshot_every > 0 && state.position() % snapshot_every == 0 {
            store.write_snapshot(state.position(), &state.snapshot())?;
        }
    }
    if stop_after == Some(state.position()) {
        // crash after the last event but before the measurement phase —
        // the WAL holds every decision, recovery re-runs phase 2
        return Ok(None);
    }
    state.complete().map(Some)
}

fn run_recover<S: DurableState>(
    cluster: &ClusterSpec,
    trace: &TenantTrace,
    cfg: S::Cfg,
    store: &mut dyn WalStore,
    pipelines: &[Pipeline],
) -> Result<S::Report, String> {
    let wal = store.wal_lines()?;
    let mut logged = Vec::with_capacity(wal.len());
    for (i, line) in wal.iter().enumerate() {
        let (seq, ev) = parse_wal_line(line)?;
        if seq != i {
            return Err(format!("WAL sequence gap: record {i} carries seq {seq}"));
        }
        logged.push(ev);
    }
    let mut state = match store.latest_snapshot()? {
        Some((applied, json)) => {
            if applied > logged.len() {
                return Err(format!(
                    "snapshot at {applied} events is ahead of the WAL ({} records)",
                    logged.len()
                ));
            }
            let v = Json::parse(&json).map_err(|e| format!("bad snapshot: {e}"))?;
            let st = S::restore_from(cluster, cfg, &v, pipelines)?;
            if st.position() != applied {
                return Err(format!(
                    "snapshot named for {applied} events holds {}",
                    st.position()
                ));
            }
            st
        }
        None => S::fresh(cluster, cfg)?,
    };
    // integrity: the snapshot's embedded decision log must be a prefix
    // of the WAL (both persisted the same events)
    for (i, ev) in state.logged().iter().enumerate() {
        if *ev != logged[i] {
            return Err(format!("snapshot/WAL divergence at event {i}"));
        }
    }
    let events = trace_event_list(trace);
    if logged.len() > events.len() {
        return Err(format!(
            "WAL has {} records but the trace has only {} events",
            logged.len(),
            events.len()
        ));
    }
    for e in &events[state.position()..] {
        let idx = state.position();
        let ev = state.apply(e)?;
        if idx < logged.len() {
            // determinism audit: the re-derived decision must equal the
            // one logged before the crash — a mismatch means history
            // would fork, so fail instead of continuing
            if ev != logged[idx] {
                return Err(format!(
                    "recovery divergence at event {idx}: WAL logged {:?}, replay produced {ev:?}",
                    logged[idx]
                ));
            }
        } else {
            store.append_event(&wal_line(idx, &ev))?;
        }
    }
    state.complete()
}

// ---------------------------------------------------------------------
// Public API — flat and cells variants of the same driver
// ---------------------------------------------------------------------

/// [`replay_trace`] with durability: every decision lands in the WAL
/// before the next event is considered, and a full snapshot is written
/// every `snapshot_every` events (0 = never — WAL-only recovery).
///
/// `stop_after = Some(k)` simulates a crash at event boundary `k`: the
/// first `k` events run (and persist) normally, then the controller
/// dies and `Ok(None)` is returned — the crash-injection harness's
/// hook. `None` runs to completion and returns the report, bit-identical
/// to the non-durable [`replay_trace`] (the WAL is observation only).
pub fn replay_durable(
    cluster: &ClusterSpec,
    trace: &TenantTrace,
    cfg: &ReplayConfig,
    store: &mut dyn WalStore,
    snapshot_every: usize,
    stop_after: Option<usize>,
) -> Result<Option<ReplayReport>, String> {
    run_durable::<ReplayState>(cluster, trace, cfg.clone(), store, snapshot_every, stop_after)
}

/// Cells variant of [`replay_durable`].
pub fn replay_durable_cells(
    cluster: &ClusterSpec,
    trace: &TenantTrace,
    cfg: &CellsReplayConfig,
    store: &mut dyn WalStore,
    snapshot_every: usize,
    stop_after: Option<usize>,
) -> Result<Option<CellsReplayReport>, String> {
    run_durable::<CellsReplayState>(
        cluster,
        trace,
        cfg.clone(),
        store,
        snapshot_every,
        stop_after,
    )
}

/// Recover a crashed durable replay: restore the latest snapshot (or
/// start fresh), re-apply the trace from the snapshot position —
/// verifying every re-derived decision against its WAL record,
/// appending fresh records past the WAL's end — and run the measurement
/// phase. The result is bit-identical to the uninterrupted replay
/// ([`ReplayReport::fingerprint`] equality, pinned by the crash golden
/// suite). Custom pipelines referenced by the snapshot resolve from
/// `pipelines`; registry pipelines (including synthesized LLM names)
/// resolve automatically.
pub fn recover(
    cluster: &ClusterSpec,
    trace: &TenantTrace,
    cfg: &ReplayConfig,
    store: &mut dyn WalStore,
    pipelines: &[Pipeline],
) -> Result<ReplayReport, String> {
    run_recover::<ReplayState>(cluster, trace, cfg.clone(), store, pipelines)
}

/// Cells variant of [`recover`].
pub fn recover_cells(
    cluster: &ClusterSpec,
    trace: &TenantTrace,
    cfg: &CellsReplayConfig,
    store: &mut dyn WalStore,
    pipelines: &[Pipeline],
) -> Result<CellsReplayReport, String> {
    run_recover::<CellsReplayState>(cluster, trace, cfg.clone(), store, pipelines)
}

fn diff_line(got: &[String], want: &[String]) -> String {
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        if g != w {
            return format!("line {i}: recovered `{g}` vs uninterrupted `{w}`");
        }
    }
    format!("length {} vs {}", got.len(), want.len())
}

/// Crash-injection harness: replay durably, kill the controller at
/// each listed event boundary (`boundaries` empty = *every* boundary,
/// `0..=n_events`), recover from the store, and require the recovered
/// fingerprint to equal the uninterrupted replay's. Errors describe
/// the first diverging boundary and fingerprint line — this is fuzz
/// invariant (f) and the core of the crash golden suite.
pub fn verify_crash_recovery(
    cluster: &ClusterSpec,
    trace: &TenantTrace,
    cfg: &ReplayConfig,
    snapshot_every: usize,
    boundaries: &[usize],
    pipelines: &[Pipeline],
) -> Result<(), String> {
    let baseline = replay_trace(cluster, trace, cfg)?.fingerprint();
    let n = trace_event_list(trace).len();
    let every: Vec<usize>;
    let bounds: &[usize] = if boundaries.is_empty() {
        every = (0..=n).collect();
        &every
    } else {
        boundaries
    };
    for &b in bounds {
        let k = b.min(n);
        let mut store = MemStore::new();
        if replay_durable(cluster, trace, cfg, &mut store, snapshot_every, Some(k))?.is_some() {
            return Err(format!("crash at boundary {k} did not take effect"));
        }
        let report = recover(cluster, trace, cfg, &mut store, pipelines)?;
        let fp = report.fingerprint();
        if fp != baseline {
            return Err(format!(
                "crash boundary {k}: recovered replay diverges ({})",
                diff_line(&fp, &baseline)
            ));
        }
    }
    Ok(())
}

/// Cells variant of [`verify_crash_recovery`]: the merged fingerprint,
/// the tenant→cell routing, and the migration count must all match the
/// uninterrupted sharded replay.
pub fn verify_crash_recovery_cells(
    cluster: &ClusterSpec,
    trace: &TenantTrace,
    cfg: &CellsReplayConfig,
    snapshot_every: usize,
    boundaries: &[usize],
    pipelines: &[Pipeline],
) -> Result<(), String> {
    let base = replay_trace_cells(cluster, trace, cfg)?;
    let baseline = base.merged.fingerprint();
    let n = trace_event_list(trace).len();
    let every: Vec<usize>;
    let bounds: &[usize] = if boundaries.is_empty() {
        every = (0..=n).collect();
        &every
    } else {
        boundaries
    };
    for &b in bounds {
        let k = b.min(n);
        let mut store = MemStore::new();
        if replay_durable_cells(cluster, trace, cfg, &mut store, snapshot_every, Some(k))?
            .is_some()
        {
            return Err(format!("crash at boundary {k} did not take effect"));
        }
        let report = recover_cells(cluster, trace, cfg, &mut store, pipelines)?;
        let fp = report.merged.fingerprint();
        if fp != baseline {
            return Err(format!(
                "crash boundary {k} (cells): recovered replay diverges ({})",
                diff_line(&fp, &baseline)
            ));
        }
        if report.tenant_cells != base.tenant_cells {
            return Err(format!(
                "crash boundary {k} (cells): tenant routing diverged after recovery"
            ));
        }
        if report.migrations != base.migrations {
            return Err(format!(
                "crash boundary {k} (cells): migration count diverged ({} vs {})",
                report.migrations, base.migrations
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::suite::workload::{TenantTrace, TenantTraceConfig};

    fn small_trace(seed: u64) -> TenantTrace {
        let cfg = TenantTraceConfig {
            tenants: 5,
            ..TenantTraceConfig::default()
        };
        TenantTrace::generate(&cfg, seed)
    }

    fn fast_cfg() -> ReplayConfig {
        ReplayConfig { queries: 60, ..ReplayConfig::default() }
    }

    #[test]
    fn wal_line_round_trips() {
        let ev = ReplayEvent {
            t_s: 12.75,
            tenant: 3,
            desc: "arrive img-to-text @ 40".to_string(),
            decision: "admitted".to_string(),
            residents: 2,
            gpus_in_use: 3,
            usage: 0.375,
        };
        let line = wal_line(7, &ev);
        let (seq, back) = parse_wal_line(&line).unwrap();
        assert_eq!(seq, 7);
        assert_eq!(back, ev);
    }

    #[test]
    fn durable_replay_matches_plain_replay() {
        let cluster = ClusterSpec::two_2080ti();
        let trace = small_trace(11);
        let cfg = fast_cfg();
        let plain = replay_trace(&cluster, &trace, &cfg).unwrap();
        let mut store = MemStore::new();
        let durable = replay_durable(&cluster, &trace, &cfg, &mut store, 2, None)
            .unwrap()
            .expect("no crash requested");
        assert_eq!(durable.fingerprint(), plain.fingerprint());
        assert_eq!(store.wal.len(), trace.events.len());
        assert!(!store.snapshots.is_empty());
        // the WAL mirrors the decision log exactly
        for (i, line) in store.wal.iter().enumerate() {
            let (seq, ev) = parse_wal_line(line).unwrap();
            assert_eq!(seq, i);
            assert_eq!(ev, plain.events[i]);
        }
    }

    #[test]
    fn recovers_from_every_boundary() {
        let cluster = ClusterSpec::two_2080ti();
        let trace = small_trace(5);
        verify_crash_recovery(&cluster, &trace, &fast_cfg(), 2, &[], &[]).unwrap();
    }

    #[test]
    fn recovers_without_any_snapshot() {
        // snapshot_every = 0: recovery replays the whole WAL from a
        // fresh state
        let cluster = ClusterSpec::two_2080ti();
        let trace = small_trace(5);
        verify_crash_recovery(&cluster, &trace, &fast_cfg(), 0, &[], &[]).unwrap();
    }

    #[test]
    fn recovers_cells_at_sampled_boundaries() {
        let cluster = ClusterSpec { num_gpus: 4, ..ClusterSpec::two_2080ti() };
        let trace = small_trace(9);
        let cfg = CellsReplayConfig::from_replay(2, &fast_cfg());
        let n = trace.events.len();
        verify_crash_recovery_cells(&cluster, &trace, &cfg, 2, &[0, n / 2, n], &[]).unwrap();
    }

    #[test]
    fn recovery_detects_tampered_wal() {
        let cluster = ClusterSpec::two_2080ti();
        let trace = small_trace(11);
        let cfg = fast_cfg();
        let mut store = MemStore::new();
        replay_durable(&cluster, &trace, &cfg, &mut store, 0, Some(trace.events.len()))
            .unwrap();
        // flip one decision in the log — recovery must refuse to fork
        let tampered = store.wal[1].replace("\"decision\": \"", "\"decision\": \"XX");
        assert_ne!(tampered, store.wal[1], "tamper target present");
        store.wal[1] = tampered;
        let err = recover(&cluster, &trace, &cfg, &mut store, &[]).unwrap_err();
        assert!(err.contains("divergence"), "unexpected error: {err}");
    }

    #[test]
    fn dir_store_round_trips() {
        let dir = std::env::temp_dir().join(format!(
            "camelot-recovery-test-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        let cluster = ClusterSpec::two_2080ti();
        let trace = small_trace(7);
        let cfg = fast_cfg();
        let plain = replay_trace(&cluster, &trace, &cfg).unwrap();
        {
            let mut store = DirStore::open(&dir).unwrap();
            let crashed =
                replay_durable(&cluster, &trace, &cfg, &mut store, 3, Some(4)).unwrap();
            assert!(crashed.is_none());
        }
        let mut store = DirStore::open(&dir).unwrap();
        assert_eq!(store.wal_lines().unwrap().len(), 4);
        assert_eq!(store.latest_snapshot().unwrap().map(|(a, _)| a), Some(3));
        let recovered = recover(&cluster, &trace, &cfg, &mut store, &[]).unwrap();
        assert_eq!(recovered.fingerprint(), plain.fingerprint());
        // recovery extended the WAL to the full trace
        assert_eq!(store.wal_lines().unwrap().len(), trace.events.len());
        let _ = fs::remove_dir_all(&dir);
    }
}
