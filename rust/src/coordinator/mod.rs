//! The online Camelot coordinator (§V-B): query wait queues, dynamic
//! batching with QoS-aware deadlines, per-instance worker threads, and
//! pipelined stage-to-stage handoff.
//!
//! This is the *real* serving loop, running on wall-clock time with a
//! pluggable [`ExecBackend`]: the PJRT backend executes the AOT
//! artifacts (Python never on this path), while the mock backend lets
//! tests and benches drive the control plane deterministically.
//!
//! The event-driven simulator (`sim::engine`) is used for the paper's
//! large parameter sweeps; this module is what a downstream user
//! deploys.
//!
//! All resource planning — the [`autoscale::Autoscaler`]'s Case-2
//! replans, [`admission::AdmissionController`]'s admission / re-pack /
//! shrink decisions — goes through the unified [`crate::planner`] API
//! (one typed `PlanRequest` per decision; no hand-threaded reservation
//! plumbing).

pub mod admission;
pub mod autoscale;
pub mod backend;
pub mod batcher;
pub mod cells;
pub mod recovery;

pub use admission::{
    replay_trace, static_partition_replay, AdmissionConfig, AdmissionController,
    GpuFailReport, QosViolationRecord, RejectReason, RepackPlan, ReplayConfig,
    ReplayReport, ReplayState, ShrinkReport,
};
pub use cells::{
    replay_trace_cells, split_cluster, CellMigration, CellReplayStats, CellRouter,
    CellsConfig, CellsReplayConfig, CellsReplayReport, CellsReplayState, DepartOutcome,
};
pub use recovery::{
    recover, recover_cells, replay_durable, replay_durable_cells, verify_crash_recovery,
    verify_crash_recovery_cells, DirStore, MemStore, WalStore,
};
pub use autoscale::{
    run_closed_loop, AutoscaleConfig, Autoscaler, ClosedLoopReport, EpochLoopConfig,
    EpochRecord,
};
pub use backend::{ExecBackend, MockBackend, PjrtBackend};
pub use batcher::{Batcher, BatchPolicy};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::metrics::LatencyHistogram;

/// A query moving through the pipeline.
#[derive(Debug, Clone)]
pub struct Query {
    pub id: u64,
    pub submitted: Instant,
    /// Activation payload (row of the batched input).
    pub payload: Vec<f32>,
}

/// A completed query.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub latency: Duration,
    pub output: Vec<f32>,
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Stage names, in pipeline order (artifact stage names for PJRT).
    pub stages: Vec<String>,
    /// Instances per stage (N_i from the allocator).
    pub instances: Vec<usize>,
    /// Batch size.
    pub batch: usize,
    /// Batching deadline: a batch is issued when full or when its head
    /// query has waited this long (§V-B step 2).
    pub max_wait: Duration,
}

struct StageChannel {
    tx: Sender<Query>,
}

/// The running coordinator: submit queries, receive completions.
pub struct Coordinator {
    stage_tx: Sender<Query>,
    completions: Receiver<Completion>,
    workers: Vec<JoinHandle<()>>,
    submitted: Arc<AtomicU64>,
    hist: Arc<Mutex<LatencyHistogram>>,
    started: Instant,
}

impl Coordinator {
    /// Launch worker threads for every instance of every stage.
    pub fn launch(config: CoordinatorConfig, backend: Arc<dyn ExecBackend>) -> Coordinator {
        assert_eq!(config.stages.len(), config.instances.len());
        assert!(!config.stages.is_empty());
        let n_stages = config.stages.len();
        let (done_tx, done_rx) = mpsc::channel::<Completion>();
        let hist = Arc::new(Mutex::new(LatencyHistogram::new()));

        // build stage channels back-to-front so each worker knows its
        // successor
        let mut workers = Vec::new();
        let mut next: Option<StageChannel> = None;
        for stage_idx in (0..n_stages).rev() {
            let (tx, rx) = mpsc::channel::<Query>();
            let rx = Arc::new(Mutex::new(rx));
            for _ in 0..config.instances[stage_idx] {
                let rx = Arc::clone(&rx);
                let backend = Arc::clone(&backend);
                let succ = next.as_ref().map(|s| s.tx.clone());
                let done = done_tx.clone();
                let hist = Arc::clone(&hist);
                let batch = config.batch;
                let max_wait = config.max_wait;
                workers.push(std::thread::spawn(move || {
                    instance_loop(stage_idx, rx, backend, succ, done, hist, batch, max_wait);
                }));
            }
            next = Some(StageChannel { tx });
        }
        let stage_tx = next.expect("at least one stage").tx;
        drop(done_tx);

        Coordinator {
            stage_tx,
            completions: done_rx,
            workers,
            submitted: Arc::new(AtomicU64::new(0)),
            hist,
            started: Instant::now(),
        }
    }

    /// Submit one query (non-blocking).
    pub fn submit(&self, payload: Vec<f32>) -> u64 {
        let id = self.submitted.fetch_add(1, Ordering::Relaxed);
        let q = Query { id, submitted: Instant::now(), payload };
        self.stage_tx.send(q).expect("pipeline alive");
        id
    }

    /// Blocking receive of the next completion.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Completion> {
        self.completions.recv_timeout(timeout).ok()
    }

    /// Latency histogram of everything completed so far.
    pub fn histogram(&self) -> LatencyHistogram {
        self.hist.lock().unwrap().clone()
    }

    /// Overall completed-query throughput since launch.
    pub fn qps(&self) -> f64 {
        let n = self.hist.lock().unwrap().count();
        n as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    /// Close the ingress and join all workers.
    pub fn shutdown(self) {
        drop(self.stage_tx);
        drop(self.completions);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Worker body: batch up to `batch` queries (deadline `max_wait`),
/// execute via the backend, hand off to the successor (or complete).
#[allow(clippy::too_many_arguments)]
fn instance_loop(
    stage_idx: usize,
    rx: Arc<Mutex<Receiver<Query>>>,
    backend: Arc<dyn ExecBackend>,
    succ: Option<Sender<Query>>,
    done: Sender<Completion>,
    hist: Arc<Mutex<LatencyHistogram>>,
    batch: usize,
    max_wait: Duration,
) {
    loop {
        // collect one batch, holding the receiver lock only while
        // draining (instances of the same stage share the channel)
        let mut queries: Vec<Query> = Vec::with_capacity(batch);
        {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(q) => queries.push(q),
                Err(_) => return, // ingress closed
            }
            let deadline = Instant::now() + max_wait;
            while queries.len() < batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match guard.recv_timeout(deadline - now) {
                    Ok(q) => queries.push(q),
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        if queries.is_empty() {
                            return;
                        }
                        break;
                    }
                }
            }
        }

        let inputs: Vec<&[f32]> = queries.iter().map(|q| q.payload.as_slice()).collect();
        match backend.execute(stage_idx, &inputs) {
            Ok(outputs) => {
                debug_assert_eq!(outputs.len(), queries.len());
                for (q, out) in queries.into_iter().zip(outputs) {
                    match &succ {
                        Some(tx) => {
                            let _ = tx.send(Query { payload: out, ..q });
                        }
                        None => {
                            let latency = q.submitted.elapsed();
                            hist.lock().unwrap().record(latency.as_secs_f64());
                            let _ = done.send(Completion { id: q.id, latency, output: out });
                        }
                    }
                }
            }
            Err(e) => {
                // failed batch: drop queries, log once (no panic — the
                // coordinator must survive backend hiccups)
                eprintln!("stage {stage_idx} execute failed: {e}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mock_config(stages: usize, instances: usize) -> CoordinatorConfig {
        CoordinatorConfig {
            stages: (0..stages).map(|i| format!("s{i}")).collect(),
            instances: vec![instances; stages],
            batch: 4,
            max_wait: Duration::from_millis(5),
        }
    }

    #[test]
    fn completes_all_queries() {
        let backend = Arc::new(MockBackend::new(2, 8, Duration::from_micros(200)));
        let c = Coordinator::launch(mock_config(2, 1), backend);
        for i in 0..50 {
            c.submit(vec![i as f32; 8]);
        }
        let mut got = 0;
        while got < 50 {
            let comp = c.recv_timeout(Duration::from_secs(5)).expect("completion");
            assert_eq!(comp.output.len(), 8);
            got += 1;
        }
        assert_eq!(c.histogram().count(), 50);
        c.shutdown();
    }

    #[test]
    fn preserves_payload_through_identity_pipeline() {
        let backend = Arc::new(MockBackend::identity(3));
        let c = Coordinator::launch(mock_config(3, 2), backend);
        let id = c.submit(vec![1.0, 2.0, 3.0]);
        let comp = c.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(comp.id, id);
        assert_eq!(comp.output, vec![1.0, 2.0, 3.0]);
        c.shutdown();
    }

    #[test]
    fn batching_deadline_flushes_partial_batches() {
        // a single query must not wait forever for a full batch
        let backend = Arc::new(MockBackend::identity(1));
        let c = Coordinator::launch(mock_config(1, 1), backend);
        c.submit(vec![9.0]);
        let comp = c.recv_timeout(Duration::from_secs(2)).expect("deadline flush");
        assert_eq!(comp.output, vec![9.0]);
        c.shutdown();
    }

    #[test]
    fn multi_instance_parallelism_increases_throughput() {
        let work = Duration::from_millis(4);
        let run = |instances: usize| -> Duration {
            let backend = Arc::new(MockBackend::new(1, 4, work));
            let mut cfg = mock_config(1, instances);
            cfg.batch = 1; // force per-query execution
            let c = Coordinator::launch(cfg, backend);
            let t0 = Instant::now();
            for _ in 0..32 {
                c.submit(vec![0.0; 4]);
            }
            for _ in 0..32 {
                c.recv_timeout(Duration::from_secs(10)).unwrap();
            }
            let dt = t0.elapsed();
            c.shutdown();
            dt
        };
        let one = run(1);
        let four = run(4);
        assert!(
            four < one,
            "4 instances ({four:?}) should beat 1 ({one:?})"
        );
    }
}
