//! Case 2 (§VII-C): minimize GPU resource usage at a given (low) load
//! while ensuring QoS.
//!
//! Two phases, as in the paper:
//!  1. Eq. 2 — lower-bound the number of GPUs `y` from aggregate compute
//!     (Σ C(i,s)·rate / G) and aggregate memory (Σ M(i,s) / F), then
//!  2. Eq. 3 — minimize Σ N_i·p_i on those `y` GPUs subject to the same
//!     constraint families plus a throughput floor at the target load.

use crate::config::ClusterSpec;
use crate::deploy::Allocation;

use super::constraints::AllocContext;
use super::sa::{anneal, SaParams, SaResult};

/// Eq. 2: minimum GPU count for a target load (queries/s).
pub fn min_gpus(ctx: &AllocContext<'_>, load_qps: f64) -> usize {
    let batch = ctx.batch;
    // compute demand: FLOPs per query × load, per stage
    let flops_per_sec: f64 = ctx
        .predictors
        .iter()
        .map(|p| p.flops(batch) / batch as f64 * load_qps)
        .sum();
    let mem_total: f64 = ctx.predictors.iter().map(|p| p.mem_bytes(batch)).sum();
    let by_compute = flops_per_sec / ctx.cluster.gpu.flops_per_sec();
    let by_memory = mem_total / ctx.cluster.gpu.mem_bytes as f64;
    let y = by_compute.max(by_memory).ceil().max(1.0) as usize;
    y.min(ctx.cluster.num_gpus)
}

/// Solve Case 2 for `load_qps`. The returned allocation is feasible on a
/// cluster restricted to `min_gpus` devices and supports the load.
///
/// With shared-cluster reservations (`ctx.reserved` non-empty) the
/// GPU-count restriction is skipped — which devices remain is dictated
/// by the co-located tenant's holds, so the solve runs on the full
/// cluster with the reservations applied and the usage objective alone
/// keeps the plan small.
pub fn solve(ctx: &AllocContext<'_>, load_qps: f64, params: SaParams) -> Option<(SaResult, usize)> {
    let mut y = if ctx.reserved.is_empty() {
        min_gpus(ctx, load_qps)
    } else {
        ctx.cluster.num_gpus
    };
    // Eq. 2 is a lower bound; grow y if the restricted problem is
    // infeasible (e.g. bandwidth or QoS-bound rather than capacity-bound)
    while y <= ctx.cluster.num_gpus {
        let restricted = ClusterSpec { num_gpus: y, ..ctx.cluster.clone() };
        let mut sub = AllocContext::new(ctx.pipeline, &restricted, ctx.predictors, ctx.batch);
        sub.comm = ctx.comm;
        sub.enforce_bw = ctx.enforce_bw;
        sub.qos_headroom = ctx.qos_headroom;
        sub.reserved = ctx.reserved.clone();
        let n = ctx.pipeline.n_stages();
        let init = Allocation {
            instances: vec![1; n],
            quotas: vec![(1.0 / n as f64).min(0.9); n],
        };
        let result = anneal(
            init,
            params,
            // feasible = all constraints + the load's predicted p99
            // stays inside QoS (tail-aware, not just capacity)
            |a| {
                // 35% tail margin: Case 2 sits at the feasibility
                // boundary by construction, so the predicted p99 needs
                // real headroom over the tail-model error
                sub.check(a).is_ok()
                    && sub.predicted_p99(a, load_qps) <= ctx.pipeline.qos_target_s * 0.65
            },
            // maximize the negated usage ⇒ minimize Σ N_i·p_i
            |a| -a.total_quota(),
        );
        if let Some(r) = result {
            return Some((r, y));
        }
        y += 1;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;
    use crate::predictor::{ProfileConfig, StagePredictor};
    use crate::suite::{real, Pipeline};

    fn fixture(p: &Pipeline) -> (ClusterSpec, Vec<StagePredictor>) {
        let cluster = ClusterSpec::two_2080ti();
        let preds = p
            .stages
            .iter()
            .map(|s| StagePredictor::train(s, &GpuSpec::rtx2080ti(), &ProfileConfig::default()))
            .collect();
        (cluster, preds)
    }

    #[test]
    fn min_gpus_grows_with_load() {
        let p = real::img_to_img();
        let (c, preds) = fixture(&p);
        let ctx = AllocContext::new(&p, &c, &preds, 32);
        assert!(min_gpus(&ctx, 10.0) <= min_gpus(&ctx, 10_000.0));
        assert!(min_gpus(&ctx, 1.0) >= 1);
    }

    #[test]
    fn solution_supports_load_and_minimizes() {
        let p = real::text_to_text();
        let (c, preds) = fixture(&p);
        let ctx = AllocContext::new(&p, &c, &preds, 16);
        let load = 50.0;
        let (r, y) = solve(&ctx, load, SaParams::default()).expect("feasible");
        assert!(y >= 1 && y <= c.num_gpus);
        assert!(ctx.predicted_throughput(&r.best) >= load);
        // uses strictly less than the full cluster for a low load
        assert!(
            r.best.total_quota() < c.total_compute(),
            "usage {} should undercut {} GPUs",
            r.best.total_quota(),
            c.num_gpus
        );
    }

    #[test]
    fn lower_load_never_needs_more_quota() {
        let p = real::img_to_text();
        let (c, preds) = fixture(&p);
        let ctx = AllocContext::new(&p, &c, &preds, 16);
        let (lo, _) = solve(&ctx, 20.0, SaParams::default()).unwrap();
        let (hi, _) = solve(&ctx, 200.0, SaParams::default()).unwrap();
        assert!(
            lo.best.total_quota() <= hi.best.total_quota() * 1.05,
            "20 qps uses {} vs 200 qps {}",
            lo.best.total_quota(),
            hi.best.total_quota()
        );
    }

    #[test]
    fn infeasible_load_returns_none() {
        let p = real::img_to_img();
        let (c, preds) = fixture(&p);
        let ctx = AllocContext::new(&p, &c, &preds, 32);
        assert!(solve(&ctx, 1.0e9, SaParams::default()).is_none());
    }
}
