//! Case 2 (§VII-C): minimize GPU resource usage at a given (low) load
//! while ensuring QoS.
//!
//! [`min_gpus`] is the Eq. 2 GPU-count lower bound; [`solve`] is a
//! compatibility shim over the unified planning surface
//! (`planner::engine`, driven by [`crate::planner::Planner::plan`] with
//! [`crate::planner::Objective::MinResource`]). Both paths are
//! golden-tested to agree bit-for-bit (`tests/planner_golden.rs`).

use super::constraints::AllocContext;
use super::sa::{SaParams, SaResult};

/// Eq. 2: minimum GPU count for a target load (queries/s).
pub fn min_gpus(ctx: &AllocContext<'_>, load_qps: f64) -> usize {
    let batch = ctx.batch;
    // compute demand: FLOPs per query × load, per stage
    let flops_per_sec: f64 = ctx
        .predictors
        .iter()
        .map(|p| p.flops(batch) / batch as f64 * load_qps)
        .sum();
    let mem_total: f64 = ctx.predictors.iter().map(|p| p.mem_bytes(batch)).sum();
    let by_compute = flops_per_sec / ctx.cluster().gpu.flops_per_sec();
    let by_memory = mem_total / ctx.cluster().gpu.mem_bytes as f64;
    let y = by_compute.max(by_memory).ceil().max(1.0) as usize;
    y.min(ctx.cluster().num_gpus)
}

/// Solve Case 2 for `load_qps`. The returned allocation is feasible on a
/// cluster restricted to the returned GPU count and supports the load.
/// See `planner::engine::solve_case2` for the reservation semantics
/// (the Eq. 2 restriction survives non-overlapping co-tenant holds).
pub fn solve(ctx: &AllocContext<'_>, load_qps: f64, params: SaParams) -> Option<(SaResult, usize)> {
    crate::planner::engine::solve_case2(ctx, load_qps, params)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, GpuSpec};
    use crate::planner::ClusterState;
    use crate::predictor::{ProfileConfig, StagePredictor};
    use crate::suite::{real, Pipeline};

    fn fixture(p: &Pipeline) -> (ClusterSpec, Vec<StagePredictor>) {
        let cluster = ClusterSpec::two_2080ti();
        let preds = p
            .stages
            .iter()
            .map(|s| StagePredictor::train(s, &GpuSpec::rtx2080ti(), &ProfileConfig::default()))
            .collect();
        (cluster, preds)
    }

    #[test]
    fn min_gpus_grows_with_load() {
        let p = real::img_to_img();
        let (c, preds) = fixture(&p);
        let ctx = AllocContext::new(&p, &c, &preds, 32);
        assert!(min_gpus(&ctx, 10.0) <= min_gpus(&ctx, 10_000.0));
        assert!(min_gpus(&ctx, 1.0) >= 1);
    }

    #[test]
    fn solution_supports_load_and_minimizes() {
        let p = real::text_to_text();
        let (c, preds) = fixture(&p);
        let ctx = AllocContext::new(&p, &c, &preds, 16);
        let load = 50.0;
        let (r, y) = solve(&ctx, load, SaParams::default()).expect("feasible");
        assert!(y >= 1 && y <= c.num_gpus);
        assert!(ctx.predicted_throughput(&r.best) >= load);
        // uses strictly less than the full cluster for a low load
        assert!(
            r.best.total_quota() < c.total_compute(),
            "usage {} should undercut {} GPUs",
            r.best.total_quota(),
            c.num_gpus
        );
    }

    #[test]
    fn lower_load_never_needs_more_quota() {
        let p = real::img_to_text();
        let (c, preds) = fixture(&p);
        let ctx = AllocContext::new(&p, &c, &preds, 16);
        let (lo, _) = solve(&ctx, 20.0, SaParams::default()).unwrap();
        let (hi, _) = solve(&ctx, 200.0, SaParams::default()).unwrap();
        assert!(
            lo.best.total_quota() <= hi.best.total_quota() * 1.05,
            "20 qps uses {} vs 200 qps {}",
            lo.best.total_quota(),
            hi.best.total_quota()
        );
    }

    #[test]
    fn non_overlapping_reservations_keep_gpu_restriction() {
        use crate::deploy::GpuReservation;
        let p = real::text_to_text();
        let (c, preds) = fixture(&p);
        let load = 15.0; // low enough that Eq. 2 bounds y to 1 GPU
        let exclusive = AllocContext::new(&p, &c, &preds, 16);
        let (r0, y0) = solve(&exclusive, load, SaParams::default()).expect("exclusive solves");
        assert_eq!(y0, 1, "low load must restrict to one GPU");

        // a co-tenant holding only GPU 1 does not overlap the candidate
        // set {GPU 0}: the restriction must survive and the solution
        // must match the exclusive solve exactly
        let tail_held = vec![
            GpuReservation::default(),
            GpuReservation { sm_frac: 0.7, contexts: 4, ..Default::default() },
        ];
        let shared = AllocContext::shared(
            &p,
            ClusterState::with_reservations(&c, &tail_held),
            &preds,
            16,
        );
        let (r1, y1) = solve(&shared, load, SaParams::default()).expect("tail-held solves");
        assert_eq!(y1, 1, "non-overlapping holds must not void the Eq. 2 bound");
        assert_eq!(r1.best, r0.best);

        // an all-default reservation vector is equivalent to an
        // exclusive cluster
        let trivial = AllocContext::shared(
            &p,
            ClusterState::with_reservations(&c, &vec![GpuReservation::default(); c.num_gpus]),
            &preds,
            16,
        );
        let (r2, y2) = solve(&trivial, load, SaParams::default()).expect("trivial solves");
        assert_eq!(y2, 1);
        assert_eq!(r2.best, r0.best);

        // a hold on GPU 0 overlaps the candidate set: the restriction is
        // skipped (full cluster) and the solve still succeeds around it
        let head_held = vec![
            GpuReservation { sm_frac: 0.5, contexts: 4, ..Default::default() },
            GpuReservation::default(),
        ];
        let overlapped = AllocContext::shared(
            &p,
            ClusterState::with_reservations(&c, &head_held),
            &preds,
            16,
        );
        let (_, y3) = solve(&overlapped, load, SaParams::default()).expect("overlap solves");
        assert_eq!(y3, c.num_gpus, "overlapping holds must skip the restriction");
    }

    #[test]
    fn infeasible_load_returns_none() {
        let p = real::img_to_img();
        let (c, preds) = fixture(&p);
        let ctx = AllocContext::new(&p, &c, &preds, 32);
        assert!(solve(&ctx, 1.0e9, SaParams::default()).is_none());
    }
}
