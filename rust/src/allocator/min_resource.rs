//! Case 2 (§VII-C): minimize GPU resource usage at a given (low) load
//! while ensuring QoS.
//!
//! Two phases, as in the paper:
//!  1. Eq. 2 — lower-bound the number of GPUs `y` from aggregate compute
//!     (Σ C(i,s)·rate / G) and aggregate memory (Σ M(i,s) / F), then
//!  2. Eq. 3 — minimize Σ N_i·p_i on those `y` GPUs subject to the same
//!     constraint families plus a throughput floor at the target load.

use crate::config::ClusterSpec;
use crate::deploy::Allocation;

use super::constraints::AllocContext;
use super::sa::{anneal, SaParams, SaResult};

/// Eq. 2: minimum GPU count for a target load (queries/s).
pub fn min_gpus(ctx: &AllocContext<'_>, load_qps: f64) -> usize {
    let batch = ctx.batch;
    // compute demand: FLOPs per query × load, per stage
    let flops_per_sec: f64 = ctx
        .predictors
        .iter()
        .map(|p| p.flops(batch) / batch as f64 * load_qps)
        .sum();
    let mem_total: f64 = ctx.predictors.iter().map(|p| p.mem_bytes(batch)).sum();
    let by_compute = flops_per_sec / ctx.cluster.gpu.flops_per_sec();
    let by_memory = mem_total / ctx.cluster.gpu.mem_bytes as f64;
    let y = by_compute.max(by_memory).ceil().max(1.0) as usize;
    y.min(ctx.cluster.num_gpus)
}

/// Whether a reservation actually holds anything on its GPU (an
/// all-default entry is indistinguishable from an unheld device).
fn holds_capacity(r: &crate::deploy::GpuReservation) -> bool {
    r.sm_frac > 0.0 || r.mem_bytes > 0.0 || r.contexts > 0 || r.bw_demand > 0.0
}

/// Solve Case 2 for `load_qps`. The returned allocation is feasible on a
/// cluster restricted to `min_gpus` devices and supports the load.
///
/// With shared-cluster reservations (`ctx.reserved` non-empty) the Eq. 2
/// GPU-count restriction still applies as long as the co-tenants' holds
/// do not overlap the candidate GPUs (the first `y` devices): unheld
/// trailing GPUs are simply dropped, and the restricted sub-problem
/// carries the truncated reservation vector. Only when a hold sits
/// inside the candidate set is the Eq. 2 bound invalid (it assumes
/// empty devices) — then the solve starts from the full cluster with
/// the reservations applied and the usage objective alone keeps the
/// plan small.
pub fn solve(ctx: &AllocContext<'_>, load_qps: f64, params: SaParams) -> Option<(SaResult, usize)> {
    let mut y = {
        let bound = min_gpus(ctx, load_qps);
        if ctx.reserved.iter().take(bound).any(holds_capacity) {
            ctx.cluster.num_gpus
        } else {
            bound
        }
    };
    // Eq. 2 is a lower bound; grow y if the restricted problem is
    // infeasible (e.g. bandwidth or QoS-bound rather than capacity-bound)
    while y <= ctx.cluster.num_gpus {
        let restricted = ClusterSpec { num_gpus: y, ..ctx.cluster.clone() };
        let mut sub = AllocContext::new(ctx.pipeline, &restricted, ctx.predictors, ctx.batch);
        sub.comm = ctx.comm;
        sub.enforce_bw = ctx.enforce_bw;
        sub.qos_headroom = ctx.qos_headroom;
        // the restricted cluster keeps GPUs 0..y, so it keeps exactly
        // their holds (growth past the initial bound can pull held
        // devices into scope — their truncated entries come with them)
        sub.reserved = if ctx.reserved.is_empty() {
            Vec::new()
        } else {
            ctx.reserved[..y].to_vec()
        };
        let n = ctx.pipeline.n_stages();
        let init = Allocation {
            instances: vec![1; n],
            quotas: vec![(1.0 / n as f64).min(0.9); n],
        };
        let result = anneal(
            init,
            params,
            // feasible = all constraints + the load's predicted p99
            // stays inside QoS (tail-aware, not just capacity)
            |a| {
                // 35% tail margin: Case 2 sits at the feasibility
                // boundary by construction, so the predicted p99 needs
                // real headroom over the tail-model error
                sub.check(a).is_ok()
                    && sub.predicted_p99(a, load_qps) <= ctx.pipeline.qos_target_s * 0.65
            },
            // maximize the negated usage ⇒ minimize Σ N_i·p_i
            |a| -a.total_quota(),
        );
        if let Some(r) = result {
            return Some((r, y));
        }
        y += 1;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;
    use crate::predictor::{ProfileConfig, StagePredictor};
    use crate::suite::{real, Pipeline};

    fn fixture(p: &Pipeline) -> (ClusterSpec, Vec<StagePredictor>) {
        let cluster = ClusterSpec::two_2080ti();
        let preds = p
            .stages
            .iter()
            .map(|s| StagePredictor::train(s, &GpuSpec::rtx2080ti(), &ProfileConfig::default()))
            .collect();
        (cluster, preds)
    }

    #[test]
    fn min_gpus_grows_with_load() {
        let p = real::img_to_img();
        let (c, preds) = fixture(&p);
        let ctx = AllocContext::new(&p, &c, &preds, 32);
        assert!(min_gpus(&ctx, 10.0) <= min_gpus(&ctx, 10_000.0));
        assert!(min_gpus(&ctx, 1.0) >= 1);
    }

    #[test]
    fn solution_supports_load_and_minimizes() {
        let p = real::text_to_text();
        let (c, preds) = fixture(&p);
        let ctx = AllocContext::new(&p, &c, &preds, 16);
        let load = 50.0;
        let (r, y) = solve(&ctx, load, SaParams::default()).expect("feasible");
        assert!(y >= 1 && y <= c.num_gpus);
        assert!(ctx.predicted_throughput(&r.best) >= load);
        // uses strictly less than the full cluster for a low load
        assert!(
            r.best.total_quota() < c.total_compute(),
            "usage {} should undercut {} GPUs",
            r.best.total_quota(),
            c.num_gpus
        );
    }

    #[test]
    fn lower_load_never_needs_more_quota() {
        let p = real::img_to_text();
        let (c, preds) = fixture(&p);
        let ctx = AllocContext::new(&p, &c, &preds, 16);
        let (lo, _) = solve(&ctx, 20.0, SaParams::default()).unwrap();
        let (hi, _) = solve(&ctx, 200.0, SaParams::default()).unwrap();
        assert!(
            lo.best.total_quota() <= hi.best.total_quota() * 1.05,
            "20 qps uses {} vs 200 qps {}",
            lo.best.total_quota(),
            hi.best.total_quota()
        );
    }

    #[test]
    fn non_overlapping_reservations_keep_gpu_restriction() {
        use crate::deploy::GpuReservation;
        let p = real::text_to_text();
        let (c, preds) = fixture(&p);
        let load = 15.0; // low enough that Eq. 2 bounds y to 1 GPU
        let exclusive = AllocContext::new(&p, &c, &preds, 16);
        let (r0, y0) = solve(&exclusive, load, SaParams::default()).expect("exclusive solves");
        assert_eq!(y0, 1, "low load must restrict to one GPU");

        // a co-tenant holding only GPU 1 does not overlap the candidate
        // set {GPU 0}: the restriction must survive and the solution
        // must match the exclusive solve exactly
        let tail_held = vec![
            GpuReservation::default(),
            GpuReservation { sm_frac: 0.7, contexts: 4, ..Default::default() },
        ];
        let shared = AllocContext::new(&p, &c, &preds, 16).with_reserved(tail_held);
        let (r1, y1) = solve(&shared, load, SaParams::default()).expect("tail-held solves");
        assert_eq!(y1, 1, "non-overlapping holds must not void the Eq. 2 bound");
        assert_eq!(r1.best, r0.best);

        // an all-default reservation vector is equivalent to an
        // exclusive cluster
        let trivial = AllocContext::new(&p, &c, &preds, 16)
            .with_reserved(vec![GpuReservation::default(); c.num_gpus]);
        let (r2, y2) = solve(&trivial, load, SaParams::default()).expect("trivial solves");
        assert_eq!(y2, 1);
        assert_eq!(r2.best, r0.best);

        // a hold on GPU 0 overlaps the candidate set: the restriction is
        // skipped (full cluster) and the solve still succeeds around it
        let head_held = vec![
            GpuReservation { sm_frac: 0.5, contexts: 4, ..Default::default() },
            GpuReservation::default(),
        ];
        let overlapped = AllocContext::new(&p, &c, &preds, 16).with_reserved(head_held);
        let (_, y3) = solve(&overlapped, load, SaParams::default()).expect("overlap solves");
        assert_eq!(y3, c.num_gpus, "overlapping holds must skip the restriction");
    }

    #[test]
    fn infeasible_load_returns_none() {
        let p = real::img_to_img();
        let (c, preds) = fixture(&p);
        let ctx = AllocContext::new(&p, &c, &preds, 32);
        assert!(solve(&ctx, 1.0e9, SaParams::default()).is_none());
    }
}
