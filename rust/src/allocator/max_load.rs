//! Case 1 (§VII-B): maximize the supported peak load with limited GPUs.
//!
//! This module is a compatibility shim: the solve body lives in the
//! unified planning surface (`planner::engine`, driven by
//! [`crate::planner::Planner::plan`] with
//! [`crate::planner::Objective::MaxLoad`]). [`solve`] remains the
//! stable low-level entry for callers that already hold an
//! [`AllocContext`]; both paths are golden-tested to agree bit-for-bit
//! (`tests/planner_golden.rs`).

use super::constraints::AllocContext;
use super::sa::{SaParams, SaResult};

/// Solve Case 1. Returns the best allocation, its predicted pipeline
/// throughput (queries/s), and search statistics.
pub fn solve(ctx: &AllocContext<'_>, params: SaParams) -> Option<SaResult> {
    crate::planner::engine::solve_case1(ctx, params)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, GpuSpec};
    use crate::deploy::Allocation;
    use crate::predictor::{ProfileConfig, StagePredictor};
    use crate::suite::{real, Pipeline};

    fn fixture(p: &Pipeline) -> (ClusterSpec, Vec<StagePredictor>) {
        let cluster = ClusterSpec::two_2080ti();
        let preds = p
            .stages
            .iter()
            .map(|s| StagePredictor::train(s, &GpuSpec::rtx2080ti(), &ProfileConfig::default()))
            .collect();
        (cluster, preds)
    }

    #[test]
    fn solves_and_is_feasible() {
        let p = real::img_to_text();
        let (c, preds) = fixture(&p);
        let ctx = AllocContext::new(&p, &c, &preds, 16);
        let r = solve(&ctx, SaParams::default()).expect("feasible solution exists");
        ctx.check(&r.best).unwrap();
        assert!(r.best_objective > 0.0);
    }

    #[test]
    fn beats_naive_one_instance_allocation() {
        let p = real::img_to_img();
        let (c, preds) = fixture(&p);
        let ctx = AllocContext::new(&p, &c, &preds, 32);
        let naive = Allocation { instances: vec![1, 1], quotas: vec![0.5, 0.5] };
        let naive_thr = ctx.predicted_throughput(&naive);
        let r = solve(&ctx, SaParams::default()).unwrap();
        assert!(
            r.best_objective > naive_thr,
            "SA {} must beat naive {}",
            r.best_objective,
            naive_thr
        );
    }

    #[test]
    fn bottleneck_stage_gets_more_capacity() {
        // Fig 15: the long-duration stage receives more instances or
        // larger total quota.
        let p = real::img_to_text(); // stage 1 (lstm) scales poorly
        let (c, preds) = fixture(&p);
        let ctx = AllocContext::new(&p, &c, &preds, 16);
        let r = solve(&ctx, SaParams::default()).unwrap();
        let cap0 = r.best.instances[0] as f64 * r.best.quotas[0];
        let cap1 = r.best.instances[1] as f64 * r.best.quotas[1];
        // vgg (stage 0) is heavy but scalable; lstm needs instance
        // parallelism — total capacity should be nontrivial on both
        assert!(cap0 > 0.1 && cap1 > 0.1, "cap0={cap0} cap1={cap1}");
    }
}
