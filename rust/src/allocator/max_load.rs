//! Case 1 (§VII-B): maximize the supported peak load with limited GPUs.
//!
//! Objective: MAX min_i N_i·f(p_i) — the end-to-end peak load is set by
//! the slowest stage, so the optimizer raises the floor — under the
//! Eq. 1 constraint set (checked by [`AllocContext`]).

use crate::deploy::Allocation;

use super::constraints::AllocContext;
use super::sa::{anneal, SaParams, SaResult};

/// Solve Case 1. Returns the best allocation, its predicted pipeline
/// throughput (queries/s), and search statistics.
pub fn solve(ctx: &AllocContext<'_>, params: SaParams) -> Option<SaResult> {
    let n = ctx.pipeline.n_stages();
    let max_inst = (ctx.cluster.num_gpus as u32 * ctx.cluster.gpu.mps_contexts).min(48);
    let c = ctx.cluster.num_gpus as f64;
    // throughput-balanced per-GPU quotas (the Laius shape) — a strong
    // starting corner the optimizer should dominate, never lose to
    let balanced: Vec<f64> = crate::baselines::balanced_quotas(ctx.predictors, ctx.batch)
        .into_iter()
        .map(|q| ((q / 0.05).round() * 0.05).clamp(0.05, 0.95))
        .collect();
    // several starting corners: the annealer keeps the best feasible
    // result across them (the landscape has disconnected feasible
    // islands when the QoS budget is tight)
    let inits = [
        // conservative: one instance per stage, even share of one GPU
        Allocation { instances: vec![1; n], quotas: vec![((1.0 / n as f64).min(0.9) / 0.05).round() * 0.05; n] },
        // fat: one instance per stage at (near-)full quota — the only
        // feasible corner when per-stage durations are QoS-tight
        Allocation {
            instances: vec![1; n],
            quotas: vec![((c / n as f64).min(0.95) / 0.05).round() * 0.05; n],
        },
        // replicated: one instance per stage per GPU, even shares
        Allocation {
            instances: vec![ctx.cluster.num_gpus as u32; n],
            quotas: vec![((1.0 / n as f64).min(0.9) / 0.05).round() * 0.05; n],
        },
        // replicated balanced (the Laius corner)
        Allocation {
            instances: vec![ctx.cluster.num_gpus as u32; n],
            quotas: balanced,
        },
    ];
    let params = SaParams { max_instances: max_inst, ..params };
    let mut inits: Vec<Allocation> = inits.to_vec();
    // If none of the corners is feasible (tight QoS + bandwidth budgets
    // leave a needle-shaped feasible region, e.g. the m3-heavy artifact
    // pipelines), seed from a coarse quota grid search.
    if !inits.iter().any(|a| ctx.check(a).is_ok()) {
        const GRID: [f64; 6] = [0.1, 0.25, 0.4, 0.6, 0.8, 0.95];
        let mut combo = vec![0usize; n];
        'grid: loop {
            let cand = Allocation {
                instances: vec![1; n],
                quotas: combo.iter().map(|&i| GRID[i]).collect(),
            };
            if ctx.check(&cand).is_ok() {
                inits.push(cand);
                break;
            }
            // odometer increment
            for d in 0..n {
                combo[d] += 1;
                if combo[d] < GRID.len() {
                    continue 'grid;
                }
                combo[d] = 0;
            }
            break;
        }
    }
    let mut best: Option<SaResult> = None;
    for (i, init) in inits.into_iter().enumerate() {
        let p = SaParams { seed: params.seed ^ (i as u64) << 32, ..params };
        if let Some(r) = anneal(
            init,
            p,
            |a| ctx.check(a).is_ok(),
            |a| ctx.predicted_peak(a),
        ) {
            if best.as_ref().map_or(true, |b| r.best_objective > b.best_objective) {
                best = Some(r);
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, GpuSpec};
    use crate::predictor::{ProfileConfig, StagePredictor};
    use crate::suite::{real, Pipeline};

    fn fixture(p: &Pipeline) -> (ClusterSpec, Vec<StagePredictor>) {
        let cluster = ClusterSpec::two_2080ti();
        let preds = p
            .stages
            .iter()
            .map(|s| StagePredictor::train(s, &GpuSpec::rtx2080ti(), &ProfileConfig::default()))
            .collect();
        (cluster, preds)
    }

    #[test]
    fn solves_and_is_feasible() {
        let p = real::img_to_text();
        let (c, preds) = fixture(&p);
        let ctx = AllocContext::new(&p, &c, &preds, 16);
        let r = solve(&ctx, SaParams::default()).expect("feasible solution exists");
        ctx.check(&r.best).unwrap();
        assert!(r.best_objective > 0.0);
    }

    #[test]
    fn beats_naive_one_instance_allocation() {
        let p = real::img_to_img();
        let (c, preds) = fixture(&p);
        let ctx = AllocContext::new(&p, &c, &preds, 32);
        let naive = Allocation { instances: vec![1, 1], quotas: vec![0.5, 0.5] };
        let naive_thr = ctx.predicted_throughput(&naive);
        let r = solve(&ctx, SaParams::default()).unwrap();
        assert!(
            r.best_objective > naive_thr,
            "SA {} must beat naive {}",
            r.best_objective,
            naive_thr
        );
    }

    #[test]
    fn bottleneck_stage_gets_more_capacity() {
        // Fig 15: the long-duration stage receives more instances or
        // larger total quota.
        let p = real::img_to_text(); // stage 1 (lstm) scales poorly
        let (c, preds) = fixture(&p);
        let ctx = AllocContext::new(&p, &c, &preds, 16);
        let r = solve(&ctx, SaParams::default()).unwrap();
        let cap0 = r.best.instances[0] as f64 * r.best.quotas[0];
        let cap1 = r.best.instances[1] as f64 * r.best.quotas[1];
        // vgg (stage 0) is heavy but scalable; lstm needs instance
        // parallelism — total capacity should be nontrivial on both
        assert!(cap0 > 0.1 && cap1 > 0.1, "cap0={cap0} cap1={cap1}");
    }
}
