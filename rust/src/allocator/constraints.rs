//! Constraint checking for the Eq. 1 / Eq. 3 optimization problems.
//!
//! A candidate allocation is feasible iff:
//!  * C1 — Σ N_i·p_i ≤ C·R (total SM quota across the cluster),
//!  * C2 — Σ N_i ≤ C·I and the per-GPU context limit holds after
//!    placement (I = 48 Volta MPS clients),
//!  * C3 — per GPU, Σ predicted bandwidth demands b(p_i) ≤ BW
//!    (the constraint Camelot-NC disables, §VIII-D),
//!  * C4 — per GPU, Σ memory footprints M(i,s) ≤ F (checked with model
//!    sharing by the placement pass),
//!  * C5 — predicted end-to-end time (stage durations + estimated
//!    communication + batching wait) ≤ QoS target.
//!
//! C2 and C4 are enforced structurally by running the actual deployment
//! scheme ([`crate::deploy::place`]) on the candidate — if no placement
//! exists the candidate is infeasible, which keeps the optimizer honest
//! about fragmentation.

use std::sync::Arc;

use crate::comm::CommMode;
use crate::config::ClusterSpec;
use crate::deploy::Allocation;
use crate::planner::ClusterState;
use crate::predictor::StagePredictor;
use crate::suite::Pipeline;

/// Per-stage predictor evaluations memoized on the 5% MPS-quota grid
/// (the only quotas the optimizer emits): SA evaluates thousands of
/// candidates per solve and tree traversals would dominate otherwise
/// (§VIII-G budgets the whole solve at ~5 ms).
///
/// The grid depends only on `(predictors, batch)` — not on the cluster
/// state — so one build is shared (via `Arc`) across every
/// [`AllocContext`] evaluating the same tenant: the Case-2 solver's
/// restricted sub-problems, the admission controller's per-resident QoS
/// checks, and repeated planner invocations all reuse it instead of
/// re-querying the predictor trees 60×stages times each.
#[derive(Debug, Clone)]
pub struct StageGrids {
    dur: Vec<[f64; 20]>,
    bw: Vec<[f64; 20]>,
    thr: Vec<[f64; 20]>,
}

impl StageGrids {
    /// Evaluate all three predictor families on the quota grid.
    pub fn build(predictors: &[StagePredictor], batch: u32) -> StageGrids {
        let n = predictors.len();
        let mut dur = vec![[0.0f64; 20]; n];
        let mut bw = vec![[0.0f64; 20]; n];
        let mut thr = vec![[0.0f64; 20]; n];
        for (i, pred) in predictors.iter().enumerate() {
            for k in 0..20 {
                let q = (k + 1) as f64 * 0.05;
                dur[i][k] = pred.duration(batch, q);
                bw[i][k] = pred.bandwidth(batch, q);
                thr[i][k] = pred.throughput(batch, q);
            }
        }
        StageGrids { dur, bw, thr }
    }

    pub fn n_stages(&self) -> usize {
        self.dur.len()
    }
}

/// Everything the checker (and the policies) need to evaluate candidates.
pub struct AllocContext<'a> {
    pub pipeline: &'a Pipeline,
    pub predictors: &'a [StagePredictor],
    pub batch: u32,
    pub comm: CommMode,
    /// Enforce C3 (false reproduces Camelot-NC).
    pub enforce_bw: bool,
    /// Fraction of the QoS budget available to stage processing +
    /// communication (the rest absorbs batching wait and queueing
    /// jitter). Matches the engine's batching deadline policy.
    pub qos_headroom: f64,
    /// Relative service-time multiplier of the GPU class being planned
    /// for (1.0 = the class the predictors were profiled on). Durations
    /// scale by ×s, bandwidth demands and throughputs by ÷s — applied at
    /// grid-*read* time so the `Arc`-shared [`StageGrids`] memo stays
    /// class-agnostic. Exactly 1.0 leaves every lookup bit-identical.
    pub compute_scale: f64,
    /// The cluster plus the merged holds of co-located tenants: every
    /// constraint family (C1/C2/C4 and the placement pass) sees only
    /// the remainder. [`ClusterState::exclusive`] for an unshared
    /// cluster.
    state: ClusterState,
    comm_cache: std::cell::Cell<Option<f64>>,
    grids: Arc<StageGrids>,
}

impl<'a> AllocContext<'a> {
    /// Context over an exclusive (hold-free) cluster.
    pub fn new(
        pipeline: &'a Pipeline,
        cluster: &ClusterSpec,
        predictors: &'a [StagePredictor],
        batch: u32,
    ) -> Self {
        Self::shared(pipeline, ClusterState::exclusive(cluster), predictors, batch)
    }

    /// Context over a shared cluster: plan into the capacity the
    /// state's co-tenant holds leave free.
    pub fn shared(
        pipeline: &'a Pipeline,
        state: ClusterState,
        predictors: &'a [StagePredictor],
        batch: u32,
    ) -> Self {
        let grids = Arc::new(StageGrids::build(predictors, batch));
        Self::shared_with_grids(pipeline, state, predictors, batch, grids)
    }

    /// [`shared`](Self::shared) reusing an already-built predictor grid
    /// (the per-stage predictor-evaluation memo). The grid must have
    /// been built from the same `(predictors, batch)` — it is purely a
    /// recomputation saving, so the context behaves bit-identically to
    /// a fresh [`shared`](Self::shared).
    pub fn shared_with_grids(
        pipeline: &'a Pipeline,
        state: ClusterState,
        predictors: &'a [StagePredictor],
        batch: u32,
        grids: Arc<StageGrids>,
    ) -> Self {
        debug_assert_eq!(grids.n_stages(), pipeline.n_stages(), "grid/pipeline shape mismatch");
        AllocContext {
            pipeline,
            predictors,
            batch,
            comm: CommMode::GlobalIpc,
            enforce_bw: true,
            qos_headroom: 0.80,
            compute_scale: 1.0,
            state,
            comm_cache: std::cell::Cell::new(None),
            grids,
        }
    }

    /// The shared predictor-evaluation memo (hand to
    /// [`shared_with_grids`](Self::shared_with_grids) to avoid
    /// rebuilding it for another context over the same tenant).
    pub fn grids(&self) -> Arc<StageGrids> {
        self.grids.clone()
    }

    /// The static cluster description (spec of [`state`](Self::state)).
    pub fn cluster(&self) -> &ClusterSpec {
        self.state.spec()
    }

    /// The cluster state (spec + merged co-tenant holds).
    pub fn state(&self) -> &ClusterState {
        &self.state
    }

    /// Cluster SM-quota capacity left after co-located tenants' holds
    /// (the C1 right-hand side).
    pub fn available_compute(&self) -> f64 {
        self.state.available_compute()
    }

    /// MPS context capacity left after co-located tenants' holds
    /// (the C2 right-hand side).
    pub fn available_contexts(&self) -> u32 {
        self.state.available_contexts()
    }

    #[inline]
    fn grid_idx(q: f64) -> usize {
        ((q / 0.05).round() as usize).clamp(1, 20) - 1
    }

    /// Grid-memoized duration lookup (falls back to the tree off-grid),
    /// scaled by the context's [`compute_scale`](Self::compute_scale).
    #[inline]
    pub fn duration_at(&self, stage: usize, q: f64) -> f64 {
        let k = Self::grid_idx(q);
        let d = if ((k + 1) as f64 * 0.05 - q).abs() < 1e-9 {
            self.grids.dur[stage][k]
        } else {
            self.predictors[stage].duration(self.batch, q)
        };
        if self.compute_scale == 1.0 { d } else { d * self.compute_scale }
    }

    #[inline]
    pub fn bandwidth_at(&self, stage: usize, q: f64) -> f64 {
        let k = Self::grid_idx(q);
        let b = if ((k + 1) as f64 * 0.05 - q).abs() < 1e-9 {
            self.grids.bw[stage][k]
        } else {
            self.predictors[stage].bandwidth(self.batch, q)
        };
        if self.compute_scale == 1.0 { b } else { b / self.compute_scale }
    }

    #[inline]
    pub fn throughput_at(&self, stage: usize, q: f64) -> f64 {
        let k = Self::grid_idx(q);
        let t = if ((k + 1) as f64 * 0.05 - q).abs() < 1e-9 {
            self.grids.thr[stage][k]
        } else {
            self.predictors[stage].throughput(self.batch, q)
        };
        if self.compute_scale == 1.0 { t } else { t / self.compute_scale }
    }

    /// Predicted communication time per stage hop for this comm mode
    /// (uncontended estimate; contention is the sim's job).
    pub fn comm_estimate(&self) -> f64 {
        if let Some(v) = self.comm_cache.get() {
            return v;
        }
        let bus_rate = self.cluster().pcie.per_stream_bw;
        let setup = self.cluster().pcie.setup_s;
        let n = self.pipeline.n_stages();
        let b = self.batch as f64;
        // ingress upload + egress download always cross the bus
        let mut t = setup
            + self.pipeline.stages[0].in_bytes_per_query * b / bus_rate
            + setup
            + self.pipeline.stages[n - 1].out_bytes_per_query * b / bus_rate;
        for i in 0..n - 1 {
            let bytes = self.pipeline.hop_bytes(i, self.batch);
            t += match self.comm {
                CommMode::GlobalIpc => self.cluster().ipc.per_msg_s,
                CommMode::MainMemory => setup + 2.0 * bytes / bus_rate,
            };
        }
        self.comm_cache.set(Some(t));
        t
    }

    /// Predicted end-to-end service time (C5 left-hand side).
    pub fn predicted_service_time(&self, alloc: &Allocation) -> f64 {
        let mut t = self.comm_estimate();
        for i in 0..self.pipeline.n_stages() {
            t += self.duration_at(i, alloc.quotas[i]);
        }
        t
    }

    /// Predicted pipeline throughput: min_i N_i·f(p_i) (the raw Eq. 1
    /// objective, before the tail-latency correction).
    pub fn predicted_throughput(&self, alloc: &Allocation) -> f64 {
        (0..self.pipeline.n_stages())
            .map(|i| alloc.instances[i] as f64 * self.throughput_at(i, alloc.quotas[i]))
            .fold(f64::INFINITY, f64::min)
    }

    /// Tail multiplier for the per-stage queueing estimate: p99 wait of
    /// an M/D/N-ish stage ≈ TAIL_K · mean wait. Calibrated against the
    /// discrete-event engine.
    const TAIL_K: f64 = 3.0;

    /// Bandwidth utilization margin for C3: Camelot keeps Σ b(p_i) at or
    /// below this fraction of the device peak, because running *at* the
    /// roof already inflates co-runner latencies (sub-saturation
    /// interference) even though the paper states the constraint as
    /// ≤ BW. Camelot-NC has neither the margin nor the constraint.
    const BW_MARGIN: f64 = 0.75;

    /// Expected aggregate memory-traffic congestion (0..1 of device
    /// peak) when serving `load_qps`, averaged over the cluster's GPUs.
    fn expected_congestion(&self, load_qps: f64) -> f64 {
        let req_rate = load_qps / self.batch as f64;
        let traffic: f64 = self
            .pipeline
            .stages
            .iter()
            .map(|st| st.hbm_bytes(self.batch) * req_rate)
            .sum();
        (traffic / (self.cluster().num_gpus as f64 * self.cluster().gpu.mem_bw)).min(1.0)
    }

    /// Duration-inflation factor the offered load's own interference
    /// applies to the solo-trained predictors. Camelot-NC neither
    /// constrains nor models bandwidth contention (§VIII-D), which is
    /// exactly why its plans violate QoS at runtime.
    #[inline]
    fn load_inflation(&self, load_qps: f64) -> f64 {
        if self.enforce_bw {
            1.0 + 0.30 * self.expected_congestion(load_qps)
        } else {
            1.0
        }
    }

    /// Sensitivity of the decode-stall estimate to KV-memory pressure:
    /// the M/M/1-shaped knee `1 + K·ρ/(1-ρ)` calibrated against the
    /// discrete-event engine's issue-stall behavior.
    const KV_STALL_K: f64 = 0.5;

    /// Duration-inflation factor KV-cache memory pressure applies to
    /// stages with a nonzero `mem_bytes_per_query` (LLM prefill/decode):
    /// when resident KV bytes approach [`crate::config::GpuSpec::mem_bytes`],
    /// the engine stalls kernel issue until a co-batch completes and
    /// releases its cache, so the p99 audit must anticipate those decode
    /// stalls. Demand is the static weight/activation footprint plus the
    /// Little's-law in-flight KV bytes (at most `N_i` batches execute
    /// concurrently per stage); capacity is the cluster's free memory
    /// after co-tenant holds. Returns exactly 1.0 for KV-free pipelines
    /// and `INFINITY` at or past saturation.
    fn kv_stall_inflation(&self, alloc: &Allocation, load_qps: f64) -> f64 {
        if !self.pipeline.stages.iter().any(|st| st.mem_bytes_per_query > 0.0) {
            return 1.0;
        }
        let spec = self.cluster();
        let holds = self.state.reservations();
        let capacity: f64 = (0..self.state.num_gpus())
            .map(|g| spec.gpu_at(g).mem_bytes as f64 - holds[g].mem_bytes)
            .sum();
        if capacity <= 0.0 {
            return f64::INFINITY;
        }
        let req_rate = load_qps / self.batch as f64;
        let batch = self.batch as f64;
        let mut demand = 0.0;
        for (i, st) in self.pipeline.stages.iter().enumerate() {
            demand += alloc.instances[i] as f64 * st.mem_footprint(self.batch);
            if st.mem_bytes_per_query > 0.0 {
                let d = self.duration_at(i, alloc.quotas[i]);
                let in_flight = (req_rate * d).min(alloc.instances[i] as f64);
                demand += in_flight * st.mem_bytes_per_query * batch;
            }
        }
        let pressure = demand / capacity;
        if pressure >= 1.0 {
            return f64::INFINITY;
        }
        1.0 + Self::KV_STALL_K * pressure / (1.0 - pressure)
    }

    /// One stage's contribution to the p99 prediction: inflated service
    /// time plus an Allen–Cunneen-style mean wait for an N-server
    /// station with deterministic-ish service, scaled to the 99th
    /// percentile. `INFINITY` when the stage saturates (ρ ≥ 1). The
    /// single source of truth behind both [`predicted_p99`](Self::predicted_p99)
    /// and [`predicted_stage_p99`](Self::predicted_stage_p99).
    #[inline]
    fn stage_p99_term(&self, alloc: &Allocation, stage: usize, req_rate: f64, inflate: f64) -> f64 {
        let d = self.duration_at(stage, alloc.quotas[stage]) * inflate;
        let n = alloc.instances[stage] as f64;
        let rho = req_rate * d / n;
        if rho >= 1.0 {
            return f64::INFINITY;
        }
        let wait = d * rho / (n * (1.0 - rho)) * Self::TAIL_K;
        d + wait
    }

    /// Predicted 99%-ile end-to-end latency at a given offered load
    /// (queries/s): per-stage service + an M/D/N-style queueing tail,
    /// plus communication. This is what "ensuring the required QoS"
    /// means to the allocator — raw capacity without tail headroom does
    /// not serve (§VII-B "still ensuring the end-to-end latency").
    pub fn predicted_p99(&self, alloc: &Allocation, load_qps: f64) -> f64 {
        let req_rate = load_qps / self.batch as f64;
        let mut t = self.comm_estimate();
        let inflate = self.load_inflation(load_qps);
        let kv = self.kv_stall_inflation(alloc, load_qps);
        for i in 0..self.pipeline.n_stages() {
            // KV stalls hit only the stages that hold cache; KV-free
            // pipelines take the plain `inflate` path bit-for-bit
            let inf_i = if self.pipeline.stages[i].mem_bytes_per_query > 0.0 {
                inflate * kv
            } else {
                inflate
            };
            let term = self.stage_p99_term(alloc, i, req_rate, inf_i);
            if term.is_infinite() {
                return f64::INFINITY;
            }
            t += term;
        }
        t
    }

    /// Per-stage decomposition of [`predicted_p99`](Self::predicted_p99)
    /// at `load_qps`: each entry is one stage's inflated service time
    /// plus its queueing tail (`INFINITY` when the stage saturates).
    /// Their sum plus [`comm_estimate`](Self::comm_estimate) equals the
    /// scalar prediction — the planner reports this vector so operators
    /// can see which stage eats the QoS budget.
    pub fn predicted_stage_p99(&self, alloc: &Allocation, load_qps: f64) -> Vec<f64> {
        let req_rate = load_qps / self.batch as f64;
        let inflate = self.load_inflation(load_qps);
        let kv = self.kv_stall_inflation(alloc, load_qps);
        (0..self.pipeline.n_stages())
            .map(|i| {
                let inf_i = if self.pipeline.stages[i].mem_bytes_per_query > 0.0 {
                    inflate * kv
                } else {
                    inflate
                };
                self.stage_p99_term(alloc, i, req_rate, inf_i)
            })
            .collect()
    }

    /// Predicted supported peak load: the largest queries/s whose
    /// predicted p99 stays within the QoS target (the actual Eq. 1
    /// objective once tails are accounted for). Bisection against the
    /// capacity bound.
    pub fn predicted_peak(&self, alloc: &Allocation) -> f64 {
        let qos = self.pipeline.qos_target_s;
        if self.predicted_p99(alloc, 0.0) > qos {
            return 0.0;
        }
        let mut lo = 0.0;
        let mut hi = self.predicted_throughput(alloc).max(1e-9);
        for _ in 0..28 {
            let mid = 0.5 * (lo + hi);
            if self.predicted_p99(alloc, mid) <= qos {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }

    /// Per-stage instance bandwidth demands for the placement pass
    /// (None when C3 is disabled — Camelot-NC).
    pub fn bw_budget_storage(&self, alloc: &Allocation) -> Option<Vec<f64>> {
        if !self.enforce_bw {
            return None;
        }
        Some(
            (0..self.pipeline.n_stages())
                .map(|i| self.bandwidth_at(i, alloc.quotas[i]))
                .collect(),
        )
    }

    /// Full feasibility check. Returns Err(reason) for diagnostics.
    pub fn check(&self, alloc: &Allocation) -> Result<(), String> {
        let n = self.pipeline.n_stages();
        if alloc.instances.len() != n || alloc.quotas.len() != n {
            return Err("shape mismatch".into());
        }
        if alloc.instances.iter().any(|&x| x == 0) {
            return Err("C0: every stage needs ≥1 instance".into());
        }
        if alloc.quotas.iter().any(|&p| !(0.045..=1.0).contains(&p)) {
            return Err("C1: quota outside the profiled range [0.05, 1]".into());
        }
        // C1 cluster-level (net of co-located tenants' holds)
        if alloc.total_quota() > self.available_compute() + 1e-9 {
            return Err(format!(
                "C1: ΣN·p = {:.2} > available C·R = {:.2}",
                alloc.total_quota(),
                self.available_compute()
            ));
        }
        // C2 cluster-level
        let total_inst: u32 = alloc.instances.iter().sum();
        let ctx_cap = self.available_contexts();
        if total_inst > ctx_cap {
            return Err(format!("C2: ΣN = {total_inst} > available C·I = {ctx_cap}"));
        }
        // C5 first (cheap): even an unloaded query must fit the QoS
        // (with headroom for arrival jitter)
        let t = self.predicted_service_time(alloc);
        let budget = self.pipeline.qos_target_s * self.qos_headroom;
        if t > budget {
            return Err(format!("C5: predicted {t:.4}s > budget {budget:.4}s"));
        }
        // C2 + C3 + C4 structurally via bandwidth-aware placement: the
        // deployment scheme spreads bandwidth-hungry instances across
        // GPUs (Fig 13's multi-dimensional ordering) and fails when no
        // assignment satisfies every per-GPU budget.
        let demands = self.bw_budget_storage(alloc);
        let feasible = crate::deploy::feasible_placement(
            self.pipeline,
            &self.state,
            alloc,
            self.batch,
            demands.as_deref().map(|d| crate::deploy::BwBudget {
                demands: d,
                cap: Self::BW_MARGIN * self.cluster().gpu.mem_bw,
            }),
        );
        if !feasible {
            return Err("C2/C3/C4: no valid placement".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, GpuSpec};
    use crate::predictor::{ProfileConfig, StagePredictor};
    use crate::suite::real;

    fn ctx_fixture(pipeline: &Pipeline) -> (ClusterSpec, Vec<StagePredictor>) {
        let cluster = ClusterSpec::two_2080ti();
        let preds = pipeline
            .stages
            .iter()
            .map(|s| StagePredictor::train(s, &GpuSpec::rtx2080ti(), &ProfileConfig::default()))
            .collect();
        (cluster, preds)
    }

    #[test]
    fn reasonable_allocation_is_feasible() {
        let p = real::img_to_text();
        let (c, preds) = ctx_fixture(&p);
        let ctx = AllocContext::new(&p, &c, &preds, 16);
        let a = Allocation { instances: vec![1, 2], quotas: vec![0.5, 0.4] };
        ctx.check(&a).unwrap();
    }

    #[test]
    fn rejects_zero_instances_and_oversubscription() {
        let p = real::img_to_text();
        let (c, preds) = ctx_fixture(&p);
        let ctx = AllocContext::new(&p, &c, &preds, 16);
        assert!(ctx
            .check(&Allocation { instances: vec![0, 1], quotas: vec![0.5, 0.5] })
            .unwrap_err()
            .contains("C0"));
        assert!(ctx
            .check(&Allocation { instances: vec![4, 4], quotas: vec![0.5, 0.5] })
            .is_err());
    }

    #[test]
    fn rejects_starved_quota_via_qos() {
        let p = real::img_to_text();
        let (c, preds) = ctx_fixture(&p);
        let ctx = AllocContext::new(&p, &c, &preds, 16);
        // 5% of a GPU per stage cannot meet the QoS budget for VGG
        let a = Allocation { instances: vec![1, 1], quotas: vec![0.05, 0.05] };
        let err = ctx.check(&a).unwrap_err();
        assert!(err.contains("C5"), "{err}");
        // and quotas below the profiled range are rejected outright
        let b = Allocation { instances: vec![1, 1], quotas: vec![0.02, 0.5] };
        assert!(ctx.check(&b).unwrap_err().contains("C1"));
    }

    #[test]
    fn bw_constraint_toggle() {
        let p = real::text_to_text(); // memory-heavy stages
        let (c, preds) = ctx_fixture(&p);
        let mut ctx = AllocContext::new(&p, &c, &preds, 64);
        // enough instances that Σ b(p) on one GPU can cross the peak
        let a = Allocation { instances: vec![8, 8], quotas: vec![0.12, 0.12] };
        let with = ctx.check(&a);
        ctx.enforce_bw = false;
        let without = ctx.check(&a);
        // disabling C3 can only widen the feasible set
        if with.is_ok() {
            assert!(without.is_ok());
        }
        if let Err(e) = with {
            if e.contains("C3") {
                assert!(without.is_ok() || !without.unwrap_err().contains("C3"));
            }
        }
    }

    #[test]
    fn reservations_tighten_every_family() {
        use crate::deploy::GpuReservation;
        let p = real::img_to_text();
        let (c, preds) = ctx_fixture(&p);
        let a = Allocation { instances: vec![2, 2], quotas: vec![0.45, 0.45] };
        let free = AllocContext::new(&p, &c, &preds, 16);
        free.check(&a).expect("fits an exclusive cluster");
        // a tenant holding 50% SM + 8 contexts per GPU squeezes it out
        let held = vec![
            GpuReservation { sm_frac: 0.5, contexts: 8, ..Default::default() };
            c.num_gpus
        ];
        let shared =
            AllocContext::shared(&p, ClusterState::with_reservations(&c, &held), &preds, 16);
        assert!((shared.available_compute() - 1.0).abs() < 1e-9);
        assert_eq!(shared.available_contexts(), 2 * 48 - 16);
        let err = shared.check(&a).unwrap_err();
        assert!(
            err.contains("C1") || err.contains("placement"),
            "expected a capacity rejection, got: {err}"
        );
        // the known-feasible exclusive-cluster allocation still fits the
        // remainder (QoS is load-independent here; only capacity shrank)
        let small = Allocation { instances: vec![1, 1], quotas: vec![0.5, 0.4] };
        shared.check(&small).expect("remainder admits a small tenant");
    }

    #[test]
    fn shared_grid_reuse_is_bit_identical() {
        // the per-stage predictor-evaluation memo is a pure
        // recomputation saving: a context built on a borrowed grid
        // predicts exactly what a fresh context predicts
        let p = real::img_to_text();
        let (c, preds) = ctx_fixture(&p);
        let fresh = AllocContext::new(&p, &c, &preds, 16);
        let reused = AllocContext::shared_with_grids(
            &p,
            ClusterState::exclusive(&c),
            &preds,
            16,
            fresh.grids(),
        );
        let a = Allocation { instances: vec![1, 2], quotas: vec![0.5, 0.4] };
        assert_eq!(
            fresh.predicted_p99(&a, 50.0).to_bits(),
            reused.predicted_p99(&a, 50.0).to_bits()
        );
        assert_eq!(
            fresh.predicted_peak(&a).to_bits(),
            reused.predicted_peak(&a).to_bits()
        );
        assert_eq!(
            fresh.predicted_service_time(&a).to_bits(),
            reused.predicted_service_time(&a).to_bits()
        );
        assert_eq!(fresh.bw_budget_storage(&a), reused.bw_budget_storage(&a));
        assert_eq!(fresh.check(&a).is_ok(), reused.check(&a).is_ok());
    }

    #[test]
    fn compute_scale_scales_reads_not_grids() {
        let p = real::img_to_text();
        let (c, preds) = ctx_fixture(&p);
        let base = AllocContext::new(&p, &c, &preds, 16);
        let mut slow = AllocContext::shared_with_grids(
            &p,
            ClusterState::exclusive(&c),
            &preds,
            16,
            base.grids(),
        );
        slow.compute_scale = 2.0;
        let a = Allocation { instances: vec![1, 2], quotas: vec![0.5, 0.4] };
        for (st, &q) in a.quotas.iter().enumerate() {
            assert_eq!(
                slow.duration_at(st, q).to_bits(),
                (base.duration_at(st, q) * 2.0).to_bits()
            );
            assert_eq!(
                slow.bandwidth_at(st, q).to_bits(),
                (base.bandwidth_at(st, q) / 2.0).to_bits()
            );
            assert_eq!(
                slow.throughput_at(st, q).to_bits(),
                (base.throughput_at(st, q) / 2.0).to_bits()
            );
        }
        // a slower class supports strictly less peak load
        assert!(slow.predicted_peak(&a) < base.predicted_peak(&a));
        // scale exactly 1.0 is the identity, bit for bit
        slow.compute_scale = 1.0;
        assert_eq!(
            slow.predicted_p99(&a, 50.0).to_bits(),
            base.predicted_p99(&a, 50.0).to_bits()
        );
    }

    #[test]
    fn kv_pressure_inflates_only_kv_stages() {
        let p = real::img_to_text();
        let (c, preds) = ctx_fixture(&p);
        // a KV-free pipeline predicts identically before and after the
        // KV hook existed: the inflation hook must be a strict no-op
        let base = AllocContext::new(&p, &c, &preds, 16);
        let a = Allocation { instances: vec![1, 2], quotas: vec![0.5, 0.4] };
        let clean = base.predicted_p99(&a, 50.0);
        assert!(clean.is_finite());
        // give stage 1 a KV appetite: the same allocation at the same
        // load now predicts a strictly higher p99 (decode stalls), and
        // stage 0's term is untouched
        let mut kv_p = p.clone();
        kv_p.stages[1].mem_bytes_per_query = 50.0e6;
        let (_, kv_preds) = ctx_fixture(&kv_p);
        let kv_ctx = AllocContext::new(&kv_p, &c, &kv_preds, 16);
        let kv_p99 = kv_ctx.predicted_p99(&a, 50.0);
        assert!(kv_p99 > clean, "kv {kv_p99} must exceed clean {clean}");
        let clean_stages = base.predicted_stage_p99(&a, 50.0);
        let kv_stages = kv_ctx.predicted_stage_p99(&a, 50.0);
        assert_eq!(clean_stages[0].to_bits(), kv_stages[0].to_bits());
        assert!(kv_stages[1] > clean_stages[1]);
        // demand beyond the cluster's memory saturates the prediction
        let mut sat_p = p.clone();
        sat_p.stages[1].mem_bytes_per_query = 1.0e15;
        let (_, sat_preds) = ctx_fixture(&sat_p);
        let sat_ctx = AllocContext::new(&sat_p, &c, &sat_preds, 16);
        assert!(sat_ctx.predicted_p99(&a, 50.0).is_infinite());
    }

    #[test]
    fn throughput_and_service_time_consistent() {
        let p = real::img_to_img();
        let (c, preds) = ctx_fixture(&p);
        let ctx = AllocContext::new(&p, &c, &preds, 32);
        let small = Allocation { instances: vec![1, 1], quotas: vec![0.2, 0.2] };
        let big = Allocation { instances: vec![2, 2], quotas: vec![0.5, 0.5] };
        assert!(ctx.predicted_throughput(&big) > ctx.predicted_throughput(&small));
        assert!(ctx.predicted_service_time(&big) < ctx.predicted_service_time(&small));
    }
}
