//! Simulated annealing over the allocation vector
//! `V = [n_1..n_N, p_1..p_N]` (§VII-C): random neighborhood moves,
//! constraint check on every candidate, Metropolis acceptance with a
//! geometric cooling schedule, best-feasible tracking.
//!
//! The same engine solves both optimization problems — it maximizes an
//! arbitrary `objective(Allocation) -> f64` over the feasible set
//! defined by an [`AllocContext`]-style checker.

use crate::deploy::Allocation;
use crate::util::Rng;

/// Annealing hyperparameters. Defaults hit the paper's ≤5 ms solve
/// budget (§VIII-G) on the pipeline sizes it evaluates.
#[derive(Debug, Clone, Copy)]
pub struct SaParams {
    pub iterations: usize,
    pub t_start: f64,
    pub t_end: f64,
    /// Largest SM-quota step of a move.
    pub quota_step: f64,
    /// Largest instance-count step of a move.
    pub inst_step: i64,
    pub max_instances: u32,
    /// Smallest SM quota a move may produce. Keep this at or above the
    /// profiling grid's smallest quota — below it the predictors
    /// extrapolate and the optimizer would exploit model error.
    pub min_quota: f64,
    pub seed: u64,
}

impl Default for SaParams {
    fn default() -> Self {
        SaParams {
            iterations: 2_000,
            t_start: 1.0,
            t_end: 1e-3,
            quota_step: 0.10,
            inst_step: 2,
            max_instances: 16,
            min_quota: 0.05,
            seed: 2024,
        }
    }
}

/// Outcome of a search.
#[derive(Debug, Clone)]
pub struct SaResult {
    pub best: Allocation,
    pub best_objective: f64,
    pub evaluated: usize,
    pub feasible_found: usize,
}

/// Run simulated annealing.
///
/// * `init` — starting candidate (need not be feasible).
/// * `feasible` — constraint predicate (Eq. 1/3 constraint set).
/// * `objective` — score to MAXIMIZE (negate for minimization).
///
/// Returns `None` if no feasible candidate was ever found.
pub fn anneal<F, G>(
    init: Allocation,
    params: SaParams,
    mut feasible: F,
    mut objective: G,
) -> Option<SaResult>
where
    F: FnMut(&Allocation) -> bool,
    G: FnMut(&Allocation) -> f64,
{
    let n = init.instances.len();
    assert!(n > 0 && init.quotas.len() == n);
    let mut rng = Rng::new(params.seed);
    let cooling = (params.t_end / params.t_start).powf(1.0 / params.iterations.max(1) as f64);

    let mut current = init;
    let mut current_score = if feasible(&current) {
        objective(&current)
    } else {
        f64::NEG_INFINITY
    };
    let mut best: Option<(Allocation, f64)> = if current_score.is_finite() {
        Some((current.clone(), current_score))
    } else {
        None
    };
    let mut evaluated = 0;
    let mut feasible_found = usize::from(current_score.is_finite());
    let mut temp = params.t_start;
    // objective scale estimate for the acceptance probability
    let mut scale = current_score.abs().max(1.0);

    for _ in 0..params.iterations {
        // neighborhood move: perturb one stage's n or p
        let mut cand = current.clone();
        let stage = rng.below(n);
        if rng.f64() < 0.5 {
            let delta = rng.range(-params.inst_step, params.inst_step).max(
                1 - cand.instances[stage] as i64,
            );
            cand.instances[stage] =
                ((cand.instances[stage] as i64 + delta).max(1) as u32).min(params.max_instances);
        } else {
            let delta = rng.range_f64(-params.quota_step, params.quota_step);
            // snap to 5% steps: Volta MPS quotas are coarse percentages,
            // and the predictors are exact on the profiling grid
            let q = (cand.quotas[stage] + delta).clamp(params.min_quota, 1.0);
            cand.quotas[stage] = (q / 0.05).round() * 0.05;
        }

        evaluated += 1;
        if !feasible(&cand) {
            // while still searching for the feasible region, random-walk
            // through infeasible space instead of freezing in place
            if current_score == f64::NEG_INFINITY {
                current = cand;
            }
            temp *= cooling;
            continue;
        }
        feasible_found += 1;
        let score = objective(&cand);
        scale = scale.max(score.abs());
        let accept = score > current_score || {
            let delta = (score - current_score) / scale.max(1e-12);
            rng.f64() < (delta / temp.max(1e-12)).exp()
        };
        if accept {
            current = cand;
            current_score = score;
            if best.as_ref().map_or(true, |(_, b)| score > *b) {
                best = Some((current.clone(), score));
            }
        }
        temp *= cooling;
    }

    best.map(|(alloc, score)| SaResult {
        best: alloc,
        best_objective: score,
        evaluated,
        feasible_found,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// toy problem: maximize min(n_i * p_i) under Σ n·p ≤ 2.
    fn toy_feasible(a: &Allocation) -> bool {
        a.total_quota() <= 2.0
            && a.instances.iter().all(|&x| x >= 1)
            && a.quotas.iter().all(|&p| (0.02..=1.0).contains(&p))
    }

    fn toy_objective(a: &Allocation) -> f64 {
        a.instances
            .iter()
            .zip(&a.quotas)
            .map(|(&n, &p)| n as f64 * p)
            .fold(f64::INFINITY, f64::min)
    }

    #[test]
    fn finds_near_optimal_toy_solution() {
        // optimum: both stages get ΣN·p = 1.0 each → objective 1.0
        let init = Allocation { instances: vec![1, 1], quotas: vec![0.1, 0.1] };
        let r = anneal(init, SaParams::default(), toy_feasible, toy_objective).unwrap();
        assert!(r.best_objective > 0.9, "objective {}", r.best_objective);
        assert!(toy_feasible(&r.best));
    }

    #[test]
    fn result_is_always_feasible() {
        crate::util::testkit::forall(3, 10, |r| r.next_u64(), |&seed| {
            let init = Allocation { instances: vec![1, 1, 1], quotas: vec![0.05, 0.05, 0.05] };
            let params = SaParams { seed, iterations: 500, ..Default::default() };
            match anneal(init, params, toy_feasible, toy_objective) {
                Some(r) => toy_feasible(&r.best),
                None => true,
            }
        });
    }

    #[test]
    fn none_when_nothing_feasible() {
        let init = Allocation { instances: vec![1], quotas: vec![0.5] };
        let r = anneal(init, SaParams::default(), |_| false, toy_objective);
        assert!(r.is_none());
    }

    #[test]
    fn deterministic_per_seed() {
        let init = Allocation { instances: vec![1, 1], quotas: vec![0.2, 0.2] };
        let p = SaParams::default();
        let a = anneal(init.clone(), p, toy_feasible, toy_objective).unwrap();
        let b = anneal(init, p, toy_feasible, toy_objective).unwrap();
        assert_eq!(a.best, b.best);
        assert_eq!(a.best_objective, b.best_objective);
    }

    #[test]
    fn infeasible_init_recovers() {
        let init = Allocation { instances: vec![9, 9], quotas: vec![1.0, 1.0] }; // ΣN·p = 18
        let params = SaParams { iterations: 6_000, ..Default::default() };
        let r = anneal(init, params, toy_feasible, toy_objective);
        // moves shrink it back into the feasible region
        assert!(r.is_some());
        assert!(toy_feasible(&r.unwrap().best));
    }
}
