//! Contention-aware GPU resource allocation (§VII) — the paper's core
//! algorithmic contribution.
//!
//! * [`constraints::AllocContext`] — the Eq. 1/3 constraint families,
//!   evaluated against the trained [`crate::predictor::StagePredictor`]s
//!   and the actual multi-GPU placement pass.
//! * [`sa`] — the simulated-annealing engine over
//!   `V = [n_1..n_N, p_1..p_N]`.
//! * [`max_load`] — Case 1: maximize the supported peak load.
//! * [`min_resource`] — Case 2: minimize resource usage at low load
//!   (Eq. 2 GPU-count bound, then Eq. 3).

pub mod constraints;
pub mod max_load;
pub mod min_resource;
pub mod sa;

pub use constraints::AllocContext;
pub use sa::{anneal, SaParams, SaResult};
