//! Contention-aware GPU resource allocation (§VII) — the paper's core
//! algorithmic contribution.
//!
//! **Entry point:** [`crate::planner`] — the unified planning surface.
//! Build a `PlanRequest` (objective + `ClusterState` + pipeline) and
//! call `Planner::plan`; the solve bodies live in `planner::engine`.
//! This module keeps the building blocks and the stable low-level
//! shims:
//!
//! * [`constraints::AllocContext`] — the Eq. 1/3 constraint families,
//!   evaluated against the trained [`crate::predictor::StagePredictor`]s
//!   and the actual multi-GPU placement pass, over a
//!   [`crate::planner::ClusterState`] (reservation-aware throughout).
//! * [`sa`] — the simulated-annealing engine over
//!   `V = [n_1..n_N, p_1..p_N]`.
//! * [`max_load`] — Case 1 shim: maximize the supported peak load.
//! * [`min_resource`] — Case 2 shim: minimize resource usage at low
//!   load (Eq. 2 GPU-count bound, then Eq. 3).

pub mod constraints;
pub mod max_load;
pub mod min_resource;
pub mod sa;

pub use constraints::{AllocContext, StageGrids};
pub use sa::{anneal, SaParams, SaResult};
