//! # Camelot
//!
//! A QoS-aware, resource-efficient runtime for **GPU microservices** on
//! spatial-multitasking GPUs — a full reproduction of Zhang et al.,
//! *"Towards QoS-Aware and Resource-Efficient GPU Microservices Based on
//! Spatial Multitasking GPUs In Datacenters"* (2020).
//!
//! The crate is the L3 (Rust) layer of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): the dense
//!   compute hot-spots, AOT-lowered.
//! * **L2** — JAX stage models (`python/compile/model.py`): microservice
//!   forward graphs, exported once to `artifacts/*.hlo.txt`.
//! * **L3** — this crate: the Camelot runtime (global-memory IPC
//!   communication, contention-aware SM allocation, multi-GPU
//!   deployment, online coordinator) plus the simulation substrate and
//!   the full evaluation harness.
//!
//! Python never runs on the request path: the [`runtime`] module loads
//! the AOT artifacts through PJRT and serves them from Rust.
//!
//! Start with [`suite`] (the benchmarks), [`planner`] (the unified
//! planning surface over the paper's two policies), and [`figures`]
//! (one harness per paper figure).

pub mod allocator;
pub mod baselines;
pub mod comm;
pub mod coordinator;
pub mod deploy;
pub mod figures;
pub mod llm;
pub mod planner;
pub mod predictor;
pub mod runtime;
pub mod config;
pub mod metrics;
pub mod sim;
pub mod suite;
pub mod util;

// The unified planning surface is the crate's primary API: every
// spatial-partitioning decision (Case-1 max-load, Case-2 min-resource,
// re-pack, resident shrink) is one typed request against one trait.
pub use planner::{
    CacheStats, CamelotPlanner, ClusterState, HeteroPlanner, Infeasible, Objective, PlanOutcome,
    PlanRequest, Planner, ScenarioSpec, Solution, SolveCache,
};
