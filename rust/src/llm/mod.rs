//! LLM serving workload model: autoregressive prefill/decode pipelines
//! with KV-cache memory as a second contended resource.
//!
//! "Towards Efficient and Practical GPU Multitasking in the Era of LLM"
//! (arXiv 2508.08448) argues the interesting GPU-multiplexing problems
//! now involve autoregressive serving, where service times are
//! token-count-driven and heavy-tailed and GPU *memory* (the KV cache)
//! — not SM share — is the binding resource. This module maps that
//! workload class onto Camelot's [`StageProfile`] vocabulary:
//!
//! * **prefill** — compute-bound; service time ∝ prompt tokens. One
//!   query's KV footprint while the kernel runs is
//!   `kv_bytes_per_token × prompt_tokens`.
//! * **decode** — memory-bandwidth-bound per-token iteration with a
//!   high Amdahl serial fraction (the autoregressive dependency chain).
//!   Output lengths are heavy-tailed: a seeded bounded-Pareto sample
//!   drawn *at pipeline-construction time* sets the stage's mean work
//!   (empirical mean tokens) and its KV residency (a p95-length
//!   request's cache: `kv_bytes_per_token × (prompt + p95 output)`),
//!   so [`pipeline`] stays a pure function of its parameters and every
//!   downstream golden/determinism contract holds.
//!
//! The per-stage KV footprint lands in
//! [`StageProfile::mem_bytes_per_query`], which the simulator charges
//! against [`crate::config::GpuSpec::mem_bytes`] *dynamically* (held
//! from kernel issue to completion — requests stall in queue when a
//! GPU's resident KV bytes hit capacity) and the planner pre-checks
//! with the typed [`crate::planner::Infeasible::NoMemory`] rejection.
//!
//! Pipelines are addressable anywhere a suite pipeline is, via the name
//! grammar `llm:p<prompt>:o<output>:kv<bytes-per-token>` (see
//! [`LlmParams::parse_name`] / [`crate::suite::pipeline_by_name`]) and
//! declaratively via ScenarioSpec `workload: "llm"` tenants.

use crate::suite::{Pipeline, StageKind, StageProfile};
use crate::util::rng::{self, Rng};

/// Mean dense FLOPs per token (prefill attention + MLP at proxy scale).
pub const FLOPS_PER_TOKEN: f64 = 2.0e7;
/// HBM bytes streamed per generated token during decode (weight +
/// KV-cache reads amortized over a continuous batch).
pub const HBM_BYTES_PER_TOKEN: f64 = 1.5e6;
/// Proxy model weight footprint per stage (shared per GPU by instances
/// of the same stage, like every other suite stage).
pub const MODEL_BYTES: f64 = 2.0e9;
/// Prefill→decode handoff payload (hidden state + sampler state).
pub const HANDOFF_BYTES: f64 = 16_384.0;
/// End-to-end p99 target for the latency-critical serving tier.
pub const QOS_TARGET_S: f64 = 0.400;
/// Default KV-cache bytes per token (fp16 K+V across proxy layers).
pub const DEFAULT_KV_BYTES_PER_TOKEN: u64 = 65_536;
/// Default prompt length (tokens).
pub const DEFAULT_PROMPT_TOKENS: u32 = 512;
/// Default mean output length (tokens).
pub const DEFAULT_OUTPUT_TOKENS: u32 = 128;

/// Draws per construction-time output-length sample.
const LENGTH_SAMPLES: usize = 512;
/// Pareto shape of the output-length distribution (heavy tail: the
/// paper-family observation that a few requests generate far more
/// tokens than the mean).
const PARETO_ALPHA: f64 = 1.8;
/// Bound on the tail: no draw exceeds this multiple of the mean.
const PARETO_CAP_MULT: f64 = 8.0;

/// Parameters of one LLM serving workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LlmParams {
    /// Prompt (context) tokens per query.
    pub prompt_tokens: u32,
    /// Mean of the heavy-tailed output-length distribution (tokens).
    pub output_tokens: u32,
    /// KV-cache bytes appended per token (prompt and generated alike).
    pub kv_bytes_per_token: u64,
}

impl Default for LlmParams {
    fn default() -> Self {
        LlmParams {
            prompt_tokens: DEFAULT_PROMPT_TOKENS,
            output_tokens: DEFAULT_OUTPUT_TOKENS,
            kv_bytes_per_token: DEFAULT_KV_BYTES_PER_TOKEN,
        }
    }
}

impl LlmParams {
    /// The canonical pipeline name: `llm:p<prompt>:o<output>:kv<bytes>`.
    /// Lossless — [`parse_name`](Self::parse_name) round-trips it.
    pub fn pipeline_name(&self) -> String {
        format!(
            "llm:p{}:o{}:kv{}",
            self.prompt_tokens, self.output_tokens, self.kv_bytes_per_token
        )
    }

    /// Parse `llm:p<prompt>:o<output>:kv<bytes>`; `None` when the name
    /// is not in the grammar or any count is zero.
    pub fn parse_name(name: &str) -> Option<LlmParams> {
        let parts: Vec<&str> = name.split(':').collect();
        if parts.len() != 4 || parts[0] != "llm" {
            return None;
        }
        let prompt_tokens: u32 = parts[1].strip_prefix('p')?.parse().ok()?;
        let output_tokens: u32 = parts[2].strip_prefix('o')?.parse().ok()?;
        let kv_bytes_per_token: u64 = parts[3].strip_prefix("kv")?.parse().ok()?;
        if prompt_tokens == 0 || output_tokens == 0 || kv_bytes_per_token == 0 {
            return None;
        }
        Some(LlmParams { prompt_tokens, output_tokens, kv_bytes_per_token })
    }

    /// Seed of the construction-time output-length sample — a pure
    /// function of the parameters, so identical params always build
    /// bit-identical pipelines.
    fn length_seed(&self) -> u64 {
        rng::mix_seed(
            rng::mix_seed(0x4C4C_4D00 ^ self.prompt_tokens as u64, self.output_tokens as u64),
            self.kv_bytes_per_token,
        )
    }
}

/// Empirical statistics of one seeded output-length sample.
#[derive(Debug, Clone, Copy)]
pub struct OutputLengthStats {
    /// Mean generated tokens per query (scales decode work).
    pub mean_tokens: f64,
    /// 95th-percentile generated tokens (sizes decode KV residency —
    /// continuous batching holds cache for the long requests in a
    /// batch, so the tail, not the mean, is what occupies memory).
    pub p95_tokens: f64,
}

/// Draw the seeded bounded-Pareto output-length sample for `params`
/// and summarize it. Deterministic: same params → same stats, bit for
/// bit.
pub fn output_length_stats(params: &LlmParams) -> OutputLengthStats {
    let mean_target = params.output_tokens as f64;
    // bounded Pareto: x = xm / u^(1/α), xm set so the unbounded mean is
    // the requested output_tokens; the cap bounds the tail draw
    let xm = mean_target * (PARETO_ALPHA - 1.0) / PARETO_ALPHA;
    let cap = mean_target * PARETO_CAP_MULT;
    let mut r = Rng::new(params.length_seed());
    let mut draws = Vec::with_capacity(LENGTH_SAMPLES);
    for _ in 0..LENGTH_SAMPLES {
        let u = r.f64().max(1e-12);
        let x = xm / u.powf(1.0 / PARETO_ALPHA);
        draws.push(x.min(cap).max(1.0));
    }
    let mean_tokens = draws.iter().sum::<f64>() / draws.len() as f64;
    let mut sorted = draws;
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("lengths are finite"));
    let idx = ((sorted.len() as f64 * 0.95).ceil() as usize).clamp(1, sorted.len()) - 1;
    OutputLengthStats { mean_tokens, p95_tokens: sorted[idx] }
}

/// Build the two-stage prefill/decode [`Pipeline`] for `params`.
pub fn pipeline(params: &LlmParams) -> Pipeline {
    let kv = params.kv_bytes_per_token as f64;
    let prompt = params.prompt_tokens as f64;
    let lengths = output_length_stats(params);
    let prefill = StageProfile {
        name: "prefill".into(),
        kind: StageKind::Compute,
        flops_per_query: FLOPS_PER_TOKEN * prompt,
        hbm_bytes_per_query: 8.0e6,
        model_bytes: MODEL_BYTES,
        act_bytes_per_query: 2.0e6,
        // token ids in, hidden/sampler state out
        in_bytes_per_query: 4.0 * prompt,
        out_bytes_per_query: HANDOFF_BYTES,
        serial_frac: 0.08,
        batch_half: 16.0,
        mem_bytes_per_query: kv * prompt,
    };
    let decode = StageProfile {
        name: "decode".into(),
        kind: StageKind::Memory,
        flops_per_query: FLOPS_PER_TOKEN * lengths.mean_tokens,
        hbm_bytes_per_query: HBM_BYTES_PER_TOKEN * lengths.mean_tokens,
        model_bytes: MODEL_BYTES,
        act_bytes_per_query: 1.0e6,
        in_bytes_per_query: HANDOFF_BYTES,
        // generated text out
        out_bytes_per_query: 4.0 * params.output_tokens as f64,
        // the autoregressive dependency chain scales poorly with SMs
        serial_frac: 0.45,
        batch_half: 16.0,
        mem_bytes_per_query: kv * (prompt + lengths.p95_tokens),
    };
    Pipeline {
        name: params.pipeline_name(),
        stages: vec![prefill, decode],
        qos_target_s: QOS_TARGET_S,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_grammar_round_trips() {
        let p = LlmParams { prompt_tokens: 384, output_tokens: 96, kv_bytes_per_token: 131_072 };
        assert_eq!(p.pipeline_name(), "llm:p384:o96:kv131072");
        assert_eq!(LlmParams::parse_name(&p.pipeline_name()), Some(p));
        assert_eq!(
            LlmParams::parse_name("llm:p512:o128:kv65536"),
            Some(LlmParams::default())
        );
        for bad in [
            "llm", "llm:p512:o128", "llm:p0:o128:kv65536", "llm:px:o128:kv65536",
            "llm:p512:o128:kv0", "lln:p512:o128:kv65536", "llm:p512:o128:kv65536:x",
        ] {
            assert!(LlmParams::parse_name(bad).is_none(), "{bad} must not parse");
        }
    }

    #[test]
    fn output_lengths_are_deterministic_and_heavy_tailed() {
        let p = LlmParams::default();
        let a = output_length_stats(&p);
        let b = output_length_stats(&p);
        assert_eq!(a.mean_tokens.to_bits(), b.mean_tokens.to_bits());
        assert_eq!(a.p95_tokens.to_bits(), b.p95_tokens.to_bits());
        // the mean lands near the requested mean, and the tail is heavy
        assert!(a.mean_tokens > 0.5 * p.output_tokens as f64);
        assert!(a.mean_tokens < 2.0 * p.output_tokens as f64);
        assert!(a.p95_tokens > 1.5 * a.mean_tokens, "p95 {} vs mean {}", a.p95_tokens, a.mean_tokens);
        assert!(a.p95_tokens <= PARETO_CAP_MULT * p.output_tokens as f64);
        // different params draw a different sample
        let other = output_length_stats(&LlmParams { output_tokens: 256, ..p });
        assert!(other.mean_tokens > a.mean_tokens);
    }

    #[test]
    fn pipeline_validates_and_carries_kv_footprints() {
        let params = LlmParams::default();
        let p = pipeline(&params);
        p.validate().unwrap_or_else(|e| panic!("{e}"));
        assert_eq!(p.name, "llm:p512:o128:kv65536");
        assert_eq!(p.n_stages(), 2);
        let (prefill, decode) = (&p.stages[0], &p.stages[1]);
        assert_eq!(prefill.kind, StageKind::Compute);
        assert_eq!(decode.kind, StageKind::Memory);
        // prefill KV = kv × prompt; decode holds the p95-length cache
        assert_eq!(prefill.mem_bytes_per_query, 65_536.0 * 512.0);
        assert!(decode.mem_bytes_per_query > prefill.mem_bytes_per_query);
        // decode's serial chain dominates prefill's
        assert!(decode.serial_frac > prefill.serial_frac);
        // identical params rebuild the identical pipeline
        let q = pipeline(&params);
        assert_eq!(
            p.stages[1].hbm_bytes_per_query.to_bits(),
            q.stages[1].hbm_bytes_per_query.to_bits()
        );
    }

    #[test]
    fn prompt_scales_prefill_and_kv() {
        let short = pipeline(&LlmParams { prompt_tokens: 128, ..LlmParams::default() });
        let long = pipeline(&LlmParams { prompt_tokens: 1024, ..LlmParams::default() });
        assert!(long.stages[0].flops_per_query > short.stages[0].flops_per_query);
        assert!(long.stages[0].mem_bytes_per_query > short.stages[0].mem_bytes_per_query);
        assert!(long.stages[1].mem_bytes_per_query > short.stages[1].mem_bytes_per_query);
    }
}
