//! `artifacts/manifest.json` reader: the metadata bridge between the L2
//! exporter (`python/compile/aot.py`) and the L3 runtime/coordinator.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::Json;

/// Metadata of one exported (stage, batch) artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub stage: String,
    pub kind: String,
    pub batch: u32,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub flops: f64,
    pub param_bytes: f64,
    pub file: String,
}

/// The parsed manifest, keyed by artifact name.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    entries: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let json = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let arr = json.as_arr().ok_or_else(|| anyhow!("manifest: not an array"))?;
        let mut entries = BTreeMap::new();
        for (i, e) in arr.iter().enumerate() {
            let shape = |key: &str| -> Result<Vec<usize>> {
                e.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("entry {i}: missing {key}"))?
                    .iter()
                    .map(|v| {
                        v.as_f64()
                            .map(|x| x as usize)
                            .ok_or_else(|| anyhow!("entry {i}: bad dim in {key}"))
                    })
                    .collect()
            };
            let meta = ArtifactMeta {
                name: e
                    .get_str("name")
                    .ok_or_else(|| anyhow!("entry {i}: missing name"))?
                    .to_string(),
                stage: e.get_str("stage").unwrap_or_default().to_string(),
                kind: e.get_str("kind").unwrap_or_default().to_string(),
                batch: e.get_f64("batch").unwrap_or(0.0) as u32,
                input_shape: shape("input_shape")?,
                output_shape: shape("output_shape")?,
                flops: e.get_f64("flops").unwrap_or(0.0),
                param_bytes: e.get_f64("param_bytes").unwrap_or(0.0),
                file: e
                    .get_str("file")
                    .ok_or_else(|| anyhow!("entry {i}: missing file"))?
                    .to_string(),
            };
            entries.insert(meta.name.clone(), meta);
        }
        Ok(Manifest { entries })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.entries.get(name)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &ArtifactMeta> {
        self.entries.values()
    }

    /// All batch variants of one stage, sorted by batch size.
    pub fn variants(&self, stage: &str) -> Vec<&ArtifactMeta> {
        let mut v: Vec<&ArtifactMeta> =
            self.entries.values().filter(|m| m.stage == stage).collect();
        v.sort_by_key(|m| m.batch);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"[
        {"name": "s_b8", "stage": "s", "kind": "mlp", "batch": 8,
         "input_shape": [8, 512], "output_shape": [8, 256],
         "flops": 1.5e9, "param_bytes": 4.0e6, "file": "s_b8.hlo.txt"},
        {"name": "s_b16", "stage": "s", "kind": "mlp", "batch": 16,
         "input_shape": [16, 512], "output_shape": [16, 256],
         "flops": 3.0e9, "param_bytes": 4.0e6, "file": "s_b16.hlo.txt"}
    ]"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.len(), 2);
        let e = m.get("s_b8").unwrap();
        assert_eq!(e.input_shape, vec![8, 512]);
        assert_eq!(e.flops, 1.5e9);
    }

    #[test]
    fn variants_sorted_by_batch() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let v = m.variants("s");
        assert_eq!(v.len(), 2);
        assert!(v[0].batch < v[1].batch);
        assert!(m.variants("nope").is_empty());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"[{"name": "x"}]"#).is_err());
    }
}
