//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt` produced
//! by `python/compile/aot.py`) and execute them from Rust.
//!
//! This is the request-path compute engine — Python is never involved
//! after `make artifacts`. HLO *text* is the interchange format (jax ≥
//! 0.5 emits 64-bit instruction ids that xla_extension 0.5.1's proto
//! path rejects; the text parser reassigns ids).

pub mod manifest;

pub use manifest::{ArtifactMeta, Manifest};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

/// A compiled stage executable plus its metadata.
pub struct StageExecutable {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl StageExecutable {
    /// Run the stage on a row-major f32 activation of shape
    /// `meta.input_shape`. Returns the output activation.
    pub fn run(&self, input: &[f32]) -> Result<Vec<f32>> {
        let expected: usize = self.meta.input_shape.iter().product();
        if input.len() != expected {
            return Err(anyhow!(
                "{}: input length {} != expected {} ({:?})",
                self.meta.name,
                input.len(),
                expected,
                self.meta.input_shape
            ));
        }
        let lit = xla::Literal::vec1(input)
            .reshape(&self.meta.input_shape.iter().map(|&d| d as i64).collect::<Vec<_>>())?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True → 1-tuple
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// The engine: a PJRT CPU client plus the compiled stage executables.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: HashMap<String, StageExecutable>,
}

impl Engine {
    /// Open `artifacts/` (reads `manifest.json`, compiles lazily).
    pub fn open(artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine { client, dir, manifest, cache: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) executable for an artifact name,
    /// e.g. `"vgg_features_b16"`.
    pub fn load(&mut self, name: &str) -> Result<&StageExecutable> {
        if !self.cache.contains_key(name) {
            let meta = self
                .manifest
                .get(name)
                .ok_or_else(|| anyhow!("artifact {name} not in manifest"))?
                .clone();
            let path = self.dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(name.to_string(), StageExecutable { meta, exe });
        }
        Ok(&self.cache[name])
    }

    /// Load the artifact for a (stage, batch) pair.
    pub fn load_stage(&mut self, stage: &str, batch: u32) -> Result<&StageExecutable> {
        let name = format!("{stage}_b{batch}");
        self.load(&name)
    }
}

#[cfg(test)]
mod tests {
    //! These tests require `make artifacts` to have run; they are the
    //! integration seam between the L2 exporter and the L3 runtime.

    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn engine() -> Option<Engine> {
        let dir = artifacts_dir();
        if dir.join("manifest.json").exists() {
            Some(Engine::open(dir).expect("engine opens"))
        } else {
            eprintln!("skipping runtime test: run `make artifacts` first");
            None
        }
    }

    #[test]
    fn loads_and_runs_mlp_stage() {
        let Some(mut e) = engine() else { return };
        let exe = e.load("fsrcnn_enhance_b8").unwrap();
        let n_in: usize = exe.meta.input_shape.iter().product();
        let input: Vec<f32> = (0..n_in).map(|i| (i % 13) as f32 * 0.01).collect();
        let out = exe.run(&input).unwrap();
        let n_out: usize = exe.meta.output_shape.iter().product();
        assert_eq!(out.len(), n_out);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn deterministic_across_runs() {
        let Some(mut e) = engine() else { return };
        let exe = e.load("lstm_caption_b8").unwrap();
        let n_in: usize = exe.meta.input_shape.iter().product();
        let input: Vec<f32> = (0..n_in).map(|i| ((i * 31) % 7) as f32 * 0.1).collect();
        let a = exe.run(&input).unwrap();
        let b = exe.run(&input).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn rejects_wrong_input_length() {
        let Some(mut e) = engine() else { return };
        let exe = e.load("fsrcnn_enhance_b8").unwrap();
        assert!(exe.run(&[1.0, 2.0]).is_err());
    }

    #[test]
    fn manifest_covers_pipeline_pairs() {
        let Some(e) = engine() else { return };
        for stage in ["vgg_features", "lstm_caption", "bert_summarize", "nmt_translate"] {
            for batch in [8, 16, 32, 64] {
                assert!(
                    e.manifest().get(&format!("{stage}_b{batch}")).is_some(),
                    "{stage}_b{batch} missing"
                );
            }
        }
    }
}
