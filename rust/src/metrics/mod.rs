//! Measurement: latency histograms with accurate tail percentiles, and
//! throughput meters. Every QoS decision in the paper is a 99%-ile
//! latency check, so the histogram is the ground-truth instrument for
//! the whole evaluation.

/// Log-bucketed latency histogram (HDR-style, base-10 coverage from
/// 1 µs to ~1000 s with ~2% relative resolution).
///
/// Percentile error is bounded by the bucket width (≤ ~2.3%), which is
/// far below the QoS margins the experiments check.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

const BUCKETS_PER_DECADE: usize = 100;
const DECADES: usize = 9; // 1e-6 .. 1e3 seconds
const N_BUCKETS: usize = BUCKETS_PER_DECADE * DECADES + 2; // under/overflow
const MIN_LAT: f64 = 1e-6;

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: vec![0; N_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn index(latency_s: f64) -> usize {
        if latency_s < MIN_LAT {
            return 0;
        }
        let log = (latency_s / MIN_LAT).log10();
        let idx = 1 + (log * BUCKETS_PER_DECADE as f64) as usize;
        idx.min(N_BUCKETS - 1)
    }

    /// Lower edge of a bucket in seconds.
    fn edge(idx: usize) -> f64 {
        if idx == 0 {
            return 0.0;
        }
        MIN_LAT * 10f64.powf((idx - 1) as f64 / BUCKETS_PER_DECADE as f64)
    }

    pub fn record(&mut self, latency_s: f64) {
        debug_assert!(latency_s.is_finite() && latency_s >= 0.0);
        self.buckets[Self::index(latency_s)] += 1;
        self.count += 1;
        self.sum += latency_s;
        self.min = self.min.min(latency_s);
        self.max = self.max.max(latency_s);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.max }
    }

    /// Latency at quantile `q` in [0, 1]; exact at the recorded min/max,
    /// bucket-midpoint (geometric) inside.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = (q * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                let lo = Self::edge(i).max(self.min);
                let hi = if i + 1 < N_BUCKETS {
                    Self::edge(i + 1).min(self.max)
                } else {
                    self.max
                };
                let hi = hi.max(lo);
                return (lo * hi).sqrt().clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// The paper's QoS instrument.
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Counts completed queries over a time window → queries per second.
#[derive(Debug, Clone, Default)]
pub struct ThroughputMeter {
    completed: u64,
    window_start: f64,
    window_end: f64,
}

impl ThroughputMeter {
    pub fn new(start_s: f64) -> Self {
        ThroughputMeter { completed: 0, window_start: start_s, window_end: start_s }
    }

    pub fn record(&mut self, now_s: f64, n: u64) {
        self.completed += n;
        self.window_end = self.window_end.max(now_s);
    }

    pub fn completed(&self) -> u64 {
        self.completed
    }

    /// Queries per second over the observed window.
    pub fn qps(&self) -> f64 {
        let dt = self.window_end - self.window_start;
        if dt <= 0.0 {
            0.0
        } else {
            self.completed as f64 / dt
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{testkit, Rng};

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.p99(), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn single_sample_all_quantiles_equal() {
        let mut h = LatencyHistogram::new();
        h.record(0.123);
        for q in [0.0, 0.5, 0.99, 1.0] {
            testkit::assert_close(h.quantile(q), 0.123, 0.03, 0.0);
        }
    }

    #[test]
    fn uniform_quantiles_within_bucket_error() {
        let mut h = LatencyHistogram::new();
        let mut r = Rng::new(1);
        for _ in 0..100_000 {
            h.record(r.range_f64(0.010, 0.110));
        }
        testkit::assert_close(h.p50(), 0.060, 0.05, 0.0);
        testkit::assert_close(h.quantile(0.99), 0.109, 0.05, 0.0);
    }

    #[test]
    fn quantiles_monotone_property() {
        testkit::forall_res(
            7,
            50,
            |r| {
                let n = 1 + r.below(500);
                (0..n).map(|_| r.range_f64(1e-5, 10.0)).collect::<Vec<f64>>()
            },
            |samples| {
                let mut h = LatencyHistogram::new();
                for &s in samples {
                    h.record(s);
                }
                let mut prev = 0.0;
                for i in 0..=20 {
                    let q = h.quantile(i as f64 / 20.0);
                    if q + 1e-12 < prev {
                        return Err(format!("quantile not monotone: {q} < {prev}"));
                    }
                    prev = q;
                }
                if h.max() < h.quantile(1.0) - 1e-12 {
                    return Err("q(1.0) exceeds max".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn merge_equals_combined() {
        let mut r = Rng::new(3);
        let (mut a, mut b, mut c) = (
            LatencyHistogram::new(),
            LatencyHistogram::new(),
            LatencyHistogram::new(),
        );
        for i in 0..10_000 {
            let x = r.range_f64(1e-4, 1.0);
            if i % 2 == 0 {
                a.record(x);
            } else {
                b.record(x);
            }
            c.record(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), c.count());
        assert_eq!(a.p99(), c.p99());
        assert_eq!(a.max(), c.max());
    }

    #[test]
    fn throughput_meter() {
        let mut m = ThroughputMeter::new(0.0);
        m.record(0.5, 10);
        m.record(2.0, 30);
        assert_eq!(m.completed(), 40);
        testkit::assert_close(m.qps(), 20.0, 1e-9, 0.0);
    }

    #[test]
    fn overflow_bucket_clamps() {
        let mut h = LatencyHistogram::new();
        h.record(1e9); // absurd latency lands in the overflow bucket
        assert_eq!(h.count(), 1);
        assert!(h.p99() > 0.0);
    }
}
