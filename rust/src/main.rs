//! `camelot` — CLI for the Camelot GPU-microservice runtime.
//!
//! Subcommands:
//!   suite list                         Table I of the paper
//!   plan  --pipeline <name> ...        run the allocation policies
//!   plan  --spec <file.json> ...       run a declarative ScenarioSpec
//!   serve --pipeline <name> ...        serve a real workload over PJRT
//!   colocate [--pipelines a,b] ...     co-location + diurnal autoscaling
//!   admit [--tenants N] ...            N-tenant online admission trace
//!   recover --spec f --wal DIR         reconverge a crashed durable replay
//!   reproduce --exp <figN|all> ...     regenerate a paper figure/table
//!
//! Planning always goes through the unified `planner` API
//! (`PlanRequest` -> `Planner::plan` -> `PlanOutcome`); `--spec` files
//! are the declarative form (see EXPERIMENTS.md §ScenarioSpec and
//! `examples/*.json`).
//!
//! (CLI parsing is hand-rolled: the offline build environment has no
//! clap; see DESIGN.md §Environment-Substitutions.)

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use camelot::config::ClusterSpec;
use camelot::coordinator::{Coordinator, CoordinatorConfig, PjrtBackend};
use camelot::figures;
use camelot::planner::{
    ClusterState, HeteroPlanner, Objective, PlanRequest, Planner as _, ScenarioSpec,
};
use camelot::suite::{real, workload::PoissonArrivals, Pipeline};
use camelot::util::fnum;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("suite") => cmd_suite(&args[1..]),
        Some("plan") => cmd_plan(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("colocate") => cmd_colocate(&args[1..]),
        Some("admit") => cmd_admit(&args[1..]),
        Some("recover") => cmd_recover(&args[1..]),
        Some("fuzz") => cmd_fuzz(&args[1..]),
        Some("reproduce") => cmd_reproduce(&args[1..]),
        Some("help") | None => {
            usage();
            0
        }
        Some(other) => {
            eprintln!("unknown command '{other}'");
            usage();
            2
        }
    };
    std::process::exit(code);
}

fn usage() {
    eprintln!(
        "camelot — QoS-aware GPU microservice runtime (Camelot reproduction)

USAGE:
  camelot suite list
  camelot plan --pipeline <name> [--batch N] [--policy max-load|min-resource]
               [--load QPS] [--cluster 2080ti|dgx2] [--no-bw]
  camelot plan --spec <file.json>        (declarative ScenarioSpec:
               Case-1/Case-2 plans per tenant + resident shrink;
               mixed A100/H100/MIG pools via cluster.gpu_classes)
  camelot serve --pipeline <name> [--batch N] [--rate QPS] [--queries N]
                [--artifacts DIR]
  camelot colocate [--pipelines a,b] [--load-a QPS] [--load-b QPS]
                   [--peak QPS] [--epochs N] [--queries N] [--seed S]
                   [--spec <file.json>] [--cache-load FILE] [--cache-save FILE]
  camelot admit [--tenants N] [--gap S] [--life S] [--peak-lo QPS]
                [--peak-hi QPS] [--queries N] [--seed S] [--cells N]
                [--spec <file.json>] [--break-qos]
                [--wal DIR [--snapshot-every N]]     (durable control plane)
                [--cache-load FILE] [--cache-save FILE]  (planner solve cache)
  camelot recover --spec <file.json> --wal DIR [--cells N] [--break-qos]
                (reconverge from DIR's latest snapshot + WAL tail;
                bit-identical to the uninterrupted replay)
  camelot fuzz  [--scenarios N] [--seed S] [--queries N] [--break-qos]
                [--llm] [--degrade] [--crash] [--dump-dir DIR]
                (chaos/burst scenario fuzzer with QoS property checks;
                --llm mixes in LLM/KV-cache tenants, --degrade partial
                GPU slowdowns, --crash runs the crash-recovery invariant;
                failures dump replayable specs)
  camelot reproduce [--exp figN|tab1|all|colocate|admission] [--out DIR]

PIPELINES: img-to-img img-to-text text-to-img text-to-text p<i>+c<j>+m<k>
SPEC: see EXPERIMENTS.md (ScenarioSpec) and examples/*.json"
    );
}

/// Parse `--key value`, `--key=value`, and bare `--flag` arguments
/// (valueless flags store "true"; a following `--token` is never
/// swallowed as a value).
fn opts(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if let Some((k, v)) = key.split_once('=') {
                m.insert(k.to_string(), v.to_string());
            } else {
                let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    i += 1;
                    args[i].clone()
                } else {
                    "true".to_string()
                };
                m.insert(key.to_string(), val);
            }
        }
        i += 1;
    }
    m
}

/// Load a [`ScenarioSpec`] and print the tables a runner produces.
fn run_spec<F>(cmd: &str, path: &str, run: F) -> i32
where
    F: FnOnce(&ScenarioSpec) -> Result<Vec<camelot::util::Table>, String>,
{
    match ScenarioSpec::load(Path::new(path)).and_then(|spec| run(&spec)) {
        Ok(tables) => {
            for t in &tables {
                println!("{}", t.render());
            }
            0
        }
        Err(e) => {
            eprintln!("{cmd} --spec: {e}");
            1
        }
    }
}

fn pipeline_by_name(name: &str) -> Option<Pipeline> {
    camelot::suite::pipeline_by_name(name)
}

/// Read a `--cache-load FILE` solve-cache payload; `Err` carries the
/// exit code (the caller returns it).
fn load_cache_arg(cmd: &str, o: &HashMap<String, String>) -> Result<Option<String>, i32> {
    match o.get("cache-load") {
        Some(path) => match std::fs::read_to_string(path) {
            Ok(text) => Ok(Some(text)),
            Err(e) => {
                eprintln!("{cmd}: --cache-load {path}: {e}");
                Err(1)
            }
        },
        None => Ok(None),
    }
}

/// Print an experiment's tables and persist its `--cache-save` payload
/// (when both a path and a payload exist).
fn finish_tables(
    cmd: &str,
    res: Result<(Vec<camelot::util::Table>, Option<String>), String>,
    save: Option<&str>,
) -> i32 {
    match res {
        Ok((tables, saved)) => {
            for t in &tables {
                println!("{}", t.render());
            }
            if let (Some(path), Some(json)) = (save, saved.as_ref()) {
                if let Err(e) = std::fs::write(path, json) {
                    eprintln!("{cmd}: --cache-save {path}: {e}");
                    return 1;
                }
                eprintln!("(solve cache saved to {path})");
            }
            0
        }
        Err(e) => {
            eprintln!("{cmd}: {e}");
            1
        }
    }
}

fn cluster_by_name(name: &str) -> ClusterSpec {
    match name {
        "dgx2" => ClusterSpec::dgx2(),
        _ => ClusterSpec::two_2080ti(),
    }
}

fn cmd_suite(args: &[String]) -> i32 {
    match args.first().map(String::as_str) {
        Some("list") | None => {
            println!("{}", real::table1().render());
            println!("Artifact benchmarks: c1-c3, m1-m3, p1-p3 -> 27 pipelines p<i>+c<j>+m<k>");
            0
        }
        Some(other) => {
            eprintln!("unknown suite subcommand '{other}'");
            2
        }
    }
}

fn cmd_plan(args: &[String]) -> i32 {
    let o = opts(args);
    // declarative path: one spec file describes cluster + tenants +
    // objectives (Case-1/Case-2 per tenant, then resident shrink)
    if let Some(spec) = o.get("spec") {
        return run_spec("plan", spec, ScenarioSpec::plan_tables);
    }
    let Some(p) = o.get("pipeline").and_then(|n| pipeline_by_name(n)) else {
        eprintln!("--pipeline or --spec required (run `camelot suite list`)");
        return 2;
    };
    let batch: u32 = o.get("batch").and_then(|b| b.parse().ok()).unwrap_or(32);
    let cluster = cluster_by_name(o.get("cluster").map(String::as_str).unwrap_or("2080ti"));
    let policy = o.get("policy").map(String::as_str).unwrap_or("max-load");
    let load: f64 = o.get("load").and_then(|l| l.parse().ok()).unwrap_or(50.0);

    let objective = match policy {
        "max-load" => Objective::MaxLoad,
        "min-resource" => Objective::MinResource { load_qps: load },
        other => {
            eprintln!("unknown policy '{other}' (max-load | min-resource)");
            return 2;
        }
    };

    eprintln!("training predictors for {} (offline phase)...", p.name);
    let preds = figures::common::train_predictors(&p, &cluster);
    let request = PlanRequest::new(objective, ClusterState::exclusive(&cluster), &p, &preds)
        .batch(batch)
        .enforce_bw(!o.contains_key("no-bw"));

    let t0 = Instant::now();
    // HeteroPlanner == CamelotPlanner bit-for-bit on these homogeneous
    // presets; mixed pools come in via --spec (cluster.gpu_classes)
    match HeteroPlanner.plan(&request) {
        Ok(s) => {
            match request.objective {
                Objective::MaxLoad => println!("policy: maximize peak load (Eq. 1)"),
                _ => println!("policy: minimize resource usage at {load} qps (Eq. 2/3)"),
            }
            println!("  GPUs used            : {}", s.gpus);
            println!("  instances per stage : {:?}", s.allocation.instances);
            println!(
                "  SM quota per instance: {:?}",
                s.allocation
                    .quotas
                    .iter()
                    .map(|q| format!("{:.0}%", q * 100.0))
                    .collect::<Vec<_>>()
            );
            if matches!(request.objective, Objective::MaxLoad) {
                println!("  predicted peak load  : {} qps", fnum(s.objective_value));
            }
            println!("  Σ N·p (GPU-equiv)    : {}", fnum(s.usage));
            println!(
                "  predicted p99        : {:.1} ms (QoS {:.1} ms)",
                s.predicted_p99_s * 1e3,
                p.qos_target_s * 1e3
            );
            println!("  solve time           : {:.2} ms", t0.elapsed().as_secs_f64() * 1e3);
            0
        }
        Err(e) => {
            eprintln!("infeasible: {e}");
            1
        }
    }
}

/// Two-pipeline co-location + diurnal closed-loop autoscaling on the
/// shared 2×2080Ti cluster (the cluster-level §VIII-C scenario).
fn cmd_colocate(args: &[String]) -> i32 {
    let o = opts(args);
    let warm = match load_cache_arg("colocate", &o) {
        Ok(w) => w,
        Err(code) => return code,
    };
    let save_path = o.get("cache-save").cloned();
    // declarative path: the spec's first two tenants co-locate
    if let Some(spec) = o.get("spec") {
        let res = ScenarioSpec::load(Path::new(spec)).and_then(|spec| {
            if spec.tenants.len() < 2 {
                return Err("colocate --spec needs at least two tenants".to_string());
            }
            let (ta, tb) = (&spec.tenants[0], &spec.tenants[1]);
            let pa = pipeline_by_name(&ta.pipeline).ok_or("unknown pipeline")?;
            let pb = pipeline_by_name(&tb.pipeline).ok_or("unknown pipeline")?;
            let cfg = figures::macro_evals::ColocateConfig {
                load_a: ta.plan_qps,
                load_b: tb.plan_qps,
                queries: spec.queries,
                batch: spec.batch,
                cluster: spec.cluster.clone(),
                seed: spec.seed,
                warm_cache: warm.clone(),
                ..Default::default()
            };
            figures::macro_evals::colocate_tables_io(&pa, &pb, &cfg, save_path.is_some())
        });
        return finish_tables("colocate --spec", res, save_path.as_deref());
    }
    let names = o
        .get("pipelines")
        .map(String::as_str)
        .unwrap_or("img-to-text,text-to-text");
    let parts: Vec<&str> = names.split(',').collect();
    if parts.len() != 2 {
        eprintln!("--pipelines takes exactly two comma-separated names");
        return 2;
    }
    let (Some(pa), Some(pb)) = (pipeline_by_name(parts[0]), pipeline_by_name(parts[1]))
    else {
        eprintln!("unknown pipeline in '{names}' (run `camelot suite list`)");
        return 2;
    };
    let mut cfg = figures::macro_evals::ColocateConfig::default();
    if let Some(v) = o.get("load-a").and_then(|v| v.parse().ok()) {
        cfg.load_a = v;
    }
    if let Some(v) = o.get("load-b").and_then(|v| v.parse().ok()) {
        cfg.load_b = v;
    }
    if let Some(v) = o.get("peak").and_then(|v| v.parse().ok()) {
        cfg.diurnal_peak = v;
    }
    if let Some(v) = o.get("epochs").and_then(|v| v.parse().ok()) {
        cfg.epochs = v;
    }
    if let Some(v) = o.get("queries").and_then(|v| v.parse().ok()) {
        cfg.queries = v;
    }
    if let Some(v) = o.get("seed").and_then(|v| v.parse().ok()) {
        cfg.seed = v;
    }
    cfg.warm_cache = warm;
    eprintln!(
        "co-locating {} (A, {} qps) + {} (B, {} qps); diurnal peak {} qps over {} epochs...",
        pa.name, cfg.load_a, pb.name, cfg.load_b, cfg.diurnal_peak, cfg.epochs
    );
    let t0 = Instant::now();
    let res = figures::macro_evals::colocate_tables_io(&pa, &pb, &cfg, save_path.is_some());
    let ok = res.is_ok();
    let code = finish_tables("colocate", res, save_path.as_deref());
    if ok {
        eprintln!("(colocate took {:.1} s)", t0.elapsed().as_secs_f64());
    }
    code
}

/// N-tenant online admission with departure re-packing over a
/// seed-reproducible tenant trace, compared against static whole-GPU
/// partitioning (the ROADMAP scale-out scenario).
fn cmd_admit(args: &[String]) -> i32 {
    let o = opts(args);
    let warm = match load_cache_arg("admit", &o) {
        Ok(w) => w,
        Err(code) => return code,
    };
    let save_path = o.get("cache-save").cloned();
    let io = figures::macro_evals::AdmitIo {
        warm_cache: warm,
        save_cache: save_path.is_some(),
        wal_dir: o.get("wal").map(PathBuf::from),
        snapshot_every: o
            .get("snapshot-every")
            .and_then(|v| v.parse().ok())
            .unwrap_or(64),
        recover: false,
    };
    // declarative path: replay the spec's explicit tenant trace
    // (arrive / shrink / depart events) against the spec's cluster
    if let Some(spec) = o.get("spec") {
        let o_cells = o.get("cells").and_then(|v| v.parse().ok());
        let break_qos = o.contains_key("break-qos");
        let res = ScenarioSpec::load(Path::new(spec)).and_then(|spec| {
            let knobs = figures::macro_evals::ReplayKnobs {
                queries: spec.queries,
                batch: spec.batch,
                seed: spec.seed,
                // --cells on the command line overrides the spec's value
                cells: o_cells.unwrap_or(spec.cells),
                break_qos,
            };
            figures::macro_evals::admission_tables_for_trace_io(
                &spec.cluster,
                &spec.trace(),
                knobs,
                &io,
            )
        });
        return finish_tables("admit --spec", res, save_path.as_deref());
    }
    let mut cfg = figures::macro_evals::AdmissionExpConfig::default();
    if let Some(v) = o.get("tenants").and_then(|v| v.parse().ok()) {
        cfg.tenants = v;
    }
    if let Some(v) = o.get("gap").and_then(|v| v.parse().ok()) {
        cfg.mean_interarrival_s = v;
    }
    if let Some(v) = o.get("life").and_then(|v| v.parse().ok()) {
        cfg.mean_lifetime_s = v;
    }
    if let Some(v) = o.get("peak-lo").and_then(|v| v.parse().ok()) {
        cfg.peak_qps_lo = v;
    }
    if let Some(v) = o.get("peak-hi").and_then(|v| v.parse().ok()) {
        cfg.peak_qps_hi = v;
    }
    if let Some(v) = o.get("queries").and_then(|v| v.parse().ok()) {
        cfg.queries = v;
    }
    if let Some(v) = o.get("seed").and_then(|v| v.parse().ok()) {
        cfg.seed = v;
    }
    if let Some(v) = o.get("cells").and_then(|v| v.parse().ok()) {
        cfg.cells = v;
    }
    eprintln!(
        "replaying a {}-tenant trace across {} cell(s) (seed {}, peaks {}-{} qps, mean gap {} s, mean life {} s)...",
        cfg.tenants,
        cfg.cells,
        cfg.seed,
        cfg.peak_qps_lo,
        cfg.peak_qps_hi,
        cfg.mean_interarrival_s,
        cfg.mean_lifetime_s
    );
    let t0 = Instant::now();
    let res = figures::macro_evals::admission_tables_io(&cfg, &io);
    let ok = res.is_ok();
    let code = finish_tables("admit", res, save_path.as_deref());
    if ok {
        eprintln!("(admit took {:.1} s)", t0.elapsed().as_secs_f64());
    }
    code
}

/// Reconverge a crashed durable replay from its WAL directory: restore
/// the latest snapshot, re-apply the trace tail (each re-derived
/// decision verified against its WAL record), and print the same tables
/// `camelot admit` would have — bit-identical to the uninterrupted run.
fn cmd_recover(args: &[String]) -> i32 {
    let o = opts(args);
    let (Some(spec), Some(wal)) = (o.get("spec"), o.get("wal")) else {
        eprintln!("usage: camelot recover --spec <file.json> --wal DIR [--cells N] [--break-qos]");
        return 2;
    };
    let o_cells = o.get("cells").and_then(|v| v.parse().ok());
    let break_qos = o.contains_key("break-qos");
    let io = figures::macro_evals::AdmitIo {
        wal_dir: Some(PathBuf::from(wal)),
        recover: true,
        ..Default::default()
    };
    let res = ScenarioSpec::load(Path::new(spec)).and_then(|spec| {
        let knobs = figures::macro_evals::ReplayKnobs {
            queries: spec.queries,
            batch: spec.batch,
            seed: spec.seed,
            cells: o_cells.unwrap_or(spec.cells),
            break_qos,
        };
        figures::macro_evals::admission_tables_for_trace_io(
            &spec.cluster,
            &spec.trace(),
            knobs,
            &io,
        )
    });
    finish_tables("recover", res, None)
}

/// Chaos & burst scenario fuzzer: generate seed-reproducible
/// ScenarioSpecs (flash crowds, GPU failures, mixed service tiers),
/// replay each through the admission/cells stack, and check the QoS
/// invariants — clean predicted-QoS audit, no re-pack regressions,
/// bit-identical replays across 1/2/8 threads, and (with `--llm`)
/// per-GPU KV-cache residency bounded by physical memory. Violated
/// scenarios are dumped as replayable JSON for `camelot admit --spec`.
fn cmd_fuzz(args: &[String]) -> i32 {
    use camelot::suite::fuzz::{run_fuzz, FuzzConfig};

    let o = opts(args);
    let mut cfg = FuzzConfig::default();
    if let Some(v) = o.get("scenarios").and_then(|v| v.parse().ok()) {
        cfg.scenarios = v;
    }
    if let Some(v) = o.get("seed").and_then(|v| v.parse().ok()) {
        cfg.seed = v;
    }
    if let Some(v) = o.get("queries").and_then(|v| v.parse().ok()) {
        cfg.queries = v;
    }
    cfg.break_qos = o.contains_key("break-qos");
    cfg.llm = o.contains_key("llm");
    cfg.degrade = o.contains_key("degrade");
    cfg.crash = o.contains_key("crash");
    cfg.dump_dir = Some(PathBuf::from(
        o.get("dump-dir").map(String::as_str).unwrap_or("fuzz-failures"),
    ));
    eprintln!(
        "fuzzing {} scenario(s) with seed {} ({} queries/interval{}{}{}{}); the run is \
         seed-reproducible and violated scenarios dump replayable specs",
        cfg.scenarios,
        cfg.seed,
        cfg.queries,
        if cfg.break_qos { ", --break-qos sabotage ON" } else { "" },
        if cfg.llm { ", LLM tenant mix ON" } else { "" },
        if cfg.degrade { ", GPU-degrade mix ON" } else { "" },
        if cfg.crash { ", crash-recovery invariant ON" } else { "" }
    );
    let t0 = Instant::now();
    match run_fuzz(&cfg) {
        Ok(report) => {
            for v in &report.violations {
                println!(
                    "VIOLATION scenario {} [{}]: {}",
                    v.index, v.kind, v.detail
                );
                match &v.dump_path {
                    Some(p) => {
                        println!(
                            "  reproduce: camelot admit --spec {}{}",
                            p.display(),
                            if cfg.break_qos { " --break-qos" } else { "" }
                        );
                        // crash-recovery violations reproduce in two
                        // steps: a durable replay writes the WAL, then
                        // recover reconverges (and reports divergence)
                        if v.kind == "crash-recovery" {
                            println!(
                                "  reproduce: camelot admit --spec {} --wal {}.wal --snapshot-every 2",
                                p.display(),
                                p.display()
                            );
                            println!(
                                "             camelot recover --spec {} --wal {}.wal",
                                p.display(),
                                p.display()
                            );
                        }
                    }
                    None => println!("  (spec dump failed; re-run with --dump-dir)"),
                }
            }
            println!(
                "checked {} scenario(s), {} replay event(s): {} violation(s) (seed {}, {:.1} s)",
                report.scenarios,
                report.events_checked,
                report.violations.len(),
                report.seed,
                t0.elapsed().as_secs_f64()
            );
            if report.ok() {
                0
            } else {
                1
            }
        }
        Err(e) => {
            eprintln!("fuzz: {e}");
            2
        }
    }
}

/// Map a real pipeline to its AOT artifact stage names.
fn artifact_stages(pipeline: &str) -> Option<Vec<String>> {
    let s = match pipeline {
        "img-to-img" => ["face_recognition", "fsrcnn_enhance"],
        "img-to-text" => ["vgg_features", "lstm_caption"],
        "text-to-img" => ["lstm_semantic", "dcgan_generate"],
        "text-to-text" => ["bert_summarize", "nmt_translate"],
        _ => return None,
    };
    Some(s.iter().map(|x| x.to_string()).collect())
}

fn artifact_input_width(stage: &str) -> usize {
    match stage {
        "bert_summarize" => 768,
        "lstm_semantic" => 384,
        "fsrcnn_enhance" => 256,
        _ => 512,
    }
}

fn cmd_serve(args: &[String]) -> i32 {
    let o = opts(args);
    let name = o.get("pipeline").map(String::as_str).unwrap_or("img-to-text");
    let Some(stages) = artifact_stages(name) else {
        eprintln!("--pipeline must be one of the four real benchmarks for serving");
        return 2;
    };
    let batch: usize = o.get("batch").and_then(|b| b.parse().ok()).unwrap_or(8);
    let rate: f64 = o.get("rate").and_then(|r| r.parse().ok()).unwrap_or(30.0);
    let queries: usize = o.get("queries").and_then(|q| q.parse().ok()).unwrap_or(200);
    let artifacts =
        PathBuf::from(o.get("artifacts").map(String::as_str).unwrap_or("artifacts"));

    eprintln!("compiling {} AOT artifacts via PJRT...", stages.len());
    let backend = match PjrtBackend::new(artifacts, &stages, batch) {
        Ok(b) => Arc::new(b),
        Err(e) => {
            eprintln!("backend: {e}\nhint: run `make artifacts` first");
            return 1;
        }
    };
    let d_in = artifact_input_width(&stages[0]);
    let c = Coordinator::launch(
        CoordinatorConfig {
            stages: stages.clone(),
            instances: vec![1; stages.len()],
            batch,
            max_wait: Duration::from_millis(20),
        },
        backend,
    );

    eprintln!("serving {queries} queries at {rate} qps (Poisson, open loop)...");
    let mut arrivals = PoissonArrivals::new(rate, 7).times_until(queries as f64 / rate * 4.0 + 5.0);
    arrivals.truncate(queries);
    let t0 = Instant::now();
    let mut sent = 0;
    let mut received = 0;
    while received < arrivals.len() {
        while sent < arrivals.len() && t0.elapsed().as_secs_f64() >= arrivals[sent] {
            c.submit(vec![0.1; d_in]);
            sent += 1;
        }
        while let Some(_comp) = c.recv_timeout(Duration::from_millis(1)) {
            received += 1;
        }
    }
    let hist = c.histogram();
    println!("== serve report ({name}, batch {batch}, {rate} qps offered) ==");
    println!("  completed : {}", hist.count());
    println!("  throughput: {} qps", fnum(c.qps()));
    println!("  p50       : {:.1} ms", hist.p50() * 1e3);
    println!("  p95       : {:.1} ms", hist.p95() * 1e3);
    println!("  p99       : {:.1} ms", hist.p99() * 1e3);
    println!("  max       : {:.1} ms", hist.max() * 1e3);
    c.shutdown();
    0
}

#[cfg(test)]
mod tests {
    use super::opts;

    fn parse(args: &[&str]) -> std::collections::HashMap<String, String> {
        opts(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn key_value_pairs_parse() {
        let m = parse(&["--pipeline", "img-to-text", "--batch", "16"]);
        assert_eq!(m.get("pipeline").map(String::as_str), Some("img-to-text"));
        assert_eq!(m.get("batch").map(String::as_str), Some("16"));
    }

    #[test]
    fn valueless_flag_before_another_flag_stores_true() {
        // `--no-bw --pipeline x`: the following flag token must never be
        // swallowed as no-bw's value
        let m = parse(&["--no-bw", "--pipeline", "img-to-text"]);
        assert_eq!(m.get("no-bw").map(String::as_str), Some("true"));
        assert_eq!(m.get("pipeline").map(String::as_str), Some("img-to-text"));
        // trailing valueless flag
        let m = parse(&["--load", "50", "--no-bw"]);
        assert_eq!(m.get("no-bw").map(String::as_str), Some("true"));
        assert_eq!(m.get("load").map(String::as_str), Some("50"));
    }

    #[test]
    fn equals_syntax_and_negative_values() {
        let m = parse(&["--batch=64", "--spec=examples/a.json", "--offset", "-5"]);
        assert_eq!(m.get("batch").map(String::as_str), Some("64"));
        assert_eq!(m.get("spec").map(String::as_str), Some("examples/a.json"));
        // single-dash values are values, not flags
        assert_eq!(m.get("offset").map(String::as_str), Some("-5"));
        // `=` in the value survives
        let m = parse(&["--define", "a=b"]);
        assert_eq!(m.get("define").map(String::as_str), Some("a=b"));
    }

    #[test]
    fn non_flag_tokens_are_ignored() {
        let m = parse(&["positional", "--key", "v", "stray"]);
        assert_eq!(m.len(), 1);
        assert_eq!(m.get("key").map(String::as_str), Some("v"));
    }
}

fn cmd_reproduce(args: &[String]) -> i32 {
    let o = opts(args);
    let out = PathBuf::from(o.get("out").map(String::as_str).unwrap_or("results"));
    let exp = o.get("exp").map(String::as_str).unwrap_or("all");
    let list: Vec<&str> = if exp == "all" {
        figures::ALL_EXPERIMENTS.to_vec()
    } else {
        exp.split(',').collect()
    };
    for e in list {
        eprintln!("--- reproducing {e} ---");
        let t0 = Instant::now();
        if let Err(msg) = figures::run_and_save(e, &out) {
            eprintln!("{e}: {msg}");
            return 1;
        }
        eprintln!("    ({e} took {:.1} s)", t0.elapsed().as_secs_f64());
    }
    0
}
