//! `camelot` — CLI for the Camelot GPU-microservice runtime.
//!
//! Subcommands:
//!   suite list                         Table I of the paper
//!   plan  --pipeline <name> ...        run the allocation policies
//!   serve --pipeline <name> ...        serve a real workload over PJRT
//!   colocate [--pipelines a,b] ...     co-location + diurnal autoscaling
//!   admit [--tenants N] ...            N-tenant online admission trace
//!   reproduce --exp <figN|all> ...     regenerate a paper figure/table
//!
//! (CLI parsing is hand-rolled: the offline build environment has no
//! clap; see DESIGN.md §Environment-Substitutions.)

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use camelot::allocator::{max_load, min_resource, AllocContext, SaParams};
use camelot::config::ClusterSpec;
use camelot::coordinator::{Coordinator, CoordinatorConfig, PjrtBackend};
use camelot::figures;
use camelot::suite::{real, workload::PoissonArrivals, Pipeline};
use camelot::util::fnum;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("suite") => cmd_suite(&args[1..]),
        Some("plan") => cmd_plan(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("colocate") => cmd_colocate(&args[1..]),
        Some("admit") => cmd_admit(&args[1..]),
        Some("reproduce") => cmd_reproduce(&args[1..]),
        Some("help") | None => {
            usage();
            0
        }
        Some(other) => {
            eprintln!("unknown command '{other}'");
            usage();
            2
        }
    };
    std::process::exit(code);
}

fn usage() {
    eprintln!(
        "camelot — QoS-aware GPU microservice runtime (Camelot reproduction)

USAGE:
  camelot suite list
  camelot plan --pipeline <name> [--batch N] [--policy max-load|min-resource]
               [--load QPS] [--cluster 2080ti|dgx2] [--no-bw]
  camelot serve --pipeline <name> [--batch N] [--rate QPS] [--queries N]
                [--artifacts DIR]
  camelot colocate [--pipelines a,b] [--load-a QPS] [--load-b QPS]
                   [--peak QPS] [--epochs N] [--queries N] [--seed S]
  camelot admit [--tenants N] [--gap S] [--life S] [--peak-lo QPS]
                [--peak-hi QPS] [--queries N] [--seed S]
  camelot reproduce [--exp figN|tab1|all|colocate|admission] [--out DIR]

PIPELINES: img-to-img img-to-text text-to-img text-to-text p<i>+c<j>+m<k>"
    );
}

/// Parse `--key value` pairs (flags without values get "true").
fn opts(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            m.insert(key.to_string(), val);
        }
        i += 1;
    }
    m
}

fn pipeline_by_name(name: &str) -> Option<Pipeline> {
    camelot::suite::pipeline_by_name(name)
}

fn cluster_by_name(name: &str) -> ClusterSpec {
    match name {
        "dgx2" => ClusterSpec::dgx2(),
        _ => ClusterSpec::two_2080ti(),
    }
}

fn cmd_suite(args: &[String]) -> i32 {
    match args.first().map(String::as_str) {
        Some("list") | None => {
            println!("{}", real::table1().render());
            println!("Artifact benchmarks: c1-c3, m1-m3, p1-p3 -> 27 pipelines p<i>+c<j>+m<k>");
            0
        }
        Some(other) => {
            eprintln!("unknown suite subcommand '{other}'");
            2
        }
    }
}

fn cmd_plan(args: &[String]) -> i32 {
    let o = opts(args);
    let Some(p) = o.get("pipeline").and_then(|n| pipeline_by_name(n)) else {
        eprintln!("--pipeline required (run `camelot suite list`)");
        return 2;
    };
    let batch: u32 = o.get("batch").and_then(|b| b.parse().ok()).unwrap_or(32);
    let cluster = cluster_by_name(o.get("cluster").map(String::as_str).unwrap_or("2080ti"));
    let policy = o.get("policy").map(String::as_str).unwrap_or("max-load");

    eprintln!("training predictors for {} (offline phase)...", p.name);
    let preds = figures::common::train_predictors(&p, &cluster);
    let mut ctx = AllocContext::new(&p, &cluster, &preds, batch);
    ctx.enforce_bw = !o.contains_key("no-bw");

    let t0 = Instant::now();
    match policy {
        "max-load" => match max_load::solve(&ctx, SaParams::default()) {
            Some(r) => {
                println!("policy: maximize peak load (Eq. 1)");
                println!("  instances per stage : {:?}", r.best.instances);
                println!(
                    "  SM quota per instance: {:?}",
                    r.best
                        .quotas
                        .iter()
                        .map(|q| format!("{:.0}%", q * 100.0))
                        .collect::<Vec<_>>()
                );
                println!("  predicted peak load  : {} qps", fnum(r.best_objective));
                println!("  solve time           : {:.2} ms", t0.elapsed().as_secs_f64() * 1e3);
                0
            }
            None => {
                eprintln!("no feasible allocation");
                1
            }
        },
        "min-resource" => {
            let load: f64 = o.get("load").and_then(|l| l.parse().ok()).unwrap_or(50.0);
            match min_resource::solve(&ctx, load, SaParams::default()) {
                Some((r, gpus)) => {
                    println!("policy: minimize resource usage at {load} qps (Eq. 2/3)");
                    println!("  GPUs required        : {gpus}");
                    println!("  instances per stage : {:?}", r.best.instances);
                    println!(
                        "  SM quota per instance: {:?}",
                        r.best
                            .quotas
                            .iter()
                            .map(|q| format!("{:.0}%", q * 100.0))
                            .collect::<Vec<_>>()
                    );
                    println!("  Σ N·p (GPU-equiv)    : {}", fnum(r.best.total_quota()));
                    println!("  solve time           : {:.2} ms", t0.elapsed().as_secs_f64() * 1e3);
                    0
                }
                None => {
                    eprintln!("no feasible allocation for load {load}");
                    1
                }
            }
        }
        other => {
            eprintln!("unknown policy '{other}' (max-load | min-resource)");
            2
        }
    }
}

/// Two-pipeline co-location + diurnal closed-loop autoscaling on the
/// shared 2×2080Ti cluster (the cluster-level §VIII-C scenario).
fn cmd_colocate(args: &[String]) -> i32 {
    let o = opts(args);
    let names = o
        .get("pipelines")
        .map(String::as_str)
        .unwrap_or("img-to-text,text-to-text");
    let parts: Vec<&str> = names.split(',').collect();
    if parts.len() != 2 {
        eprintln!("--pipelines takes exactly two comma-separated names");
        return 2;
    }
    let (Some(pa), Some(pb)) = (pipeline_by_name(parts[0]), pipeline_by_name(parts[1]))
    else {
        eprintln!("unknown pipeline in '{names}' (run `camelot suite list`)");
        return 2;
    };
    let mut cfg = figures::macro_evals::ColocateConfig::default();
    if let Some(v) = o.get("load-a").and_then(|v| v.parse().ok()) {
        cfg.load_a = v;
    }
    if let Some(v) = o.get("load-b").and_then(|v| v.parse().ok()) {
        cfg.load_b = v;
    }
    if let Some(v) = o.get("peak").and_then(|v| v.parse().ok()) {
        cfg.diurnal_peak = v;
    }
    if let Some(v) = o.get("epochs").and_then(|v| v.parse().ok()) {
        cfg.epochs = v;
    }
    if let Some(v) = o.get("queries").and_then(|v| v.parse().ok()) {
        cfg.queries = v;
    }
    if let Some(v) = o.get("seed").and_then(|v| v.parse().ok()) {
        cfg.seed = v;
    }
    eprintln!(
        "co-locating {} (A, {} qps) + {} (B, {} qps); diurnal peak {} qps over {} epochs...",
        pa.name, cfg.load_a, pb.name, cfg.load_b, cfg.diurnal_peak, cfg.epochs
    );
    let t0 = Instant::now();
    match figures::macro_evals::colocate_tables(&pa, &pb, &cfg) {
        Ok(tables) => {
            for t in &tables {
                println!("{}", t.render());
            }
            eprintln!("(colocate took {:.1} s)", t0.elapsed().as_secs_f64());
            0
        }
        Err(e) => {
            eprintln!("colocate: {e}");
            1
        }
    }
}

/// N-tenant online admission with departure re-packing over a
/// seed-reproducible tenant trace, compared against static whole-GPU
/// partitioning (the ROADMAP scale-out scenario).
fn cmd_admit(args: &[String]) -> i32 {
    let o = opts(args);
    let mut cfg = figures::macro_evals::AdmissionExpConfig::default();
    if let Some(v) = o.get("tenants").and_then(|v| v.parse().ok()) {
        cfg.tenants = v;
    }
    if let Some(v) = o.get("gap").and_then(|v| v.parse().ok()) {
        cfg.mean_interarrival_s = v;
    }
    if let Some(v) = o.get("life").and_then(|v| v.parse().ok()) {
        cfg.mean_lifetime_s = v;
    }
    if let Some(v) = o.get("peak-lo").and_then(|v| v.parse().ok()) {
        cfg.peak_qps_lo = v;
    }
    if let Some(v) = o.get("peak-hi").and_then(|v| v.parse().ok()) {
        cfg.peak_qps_hi = v;
    }
    if let Some(v) = o.get("queries").and_then(|v| v.parse().ok()) {
        cfg.queries = v;
    }
    if let Some(v) = o.get("seed").and_then(|v| v.parse().ok()) {
        cfg.seed = v;
    }
    eprintln!(
        "replaying a {}-tenant trace (seed {}, peaks {}-{} qps, mean gap {} s, mean life {} s)...",
        cfg.tenants,
        cfg.seed,
        cfg.peak_qps_lo,
        cfg.peak_qps_hi,
        cfg.mean_interarrival_s,
        cfg.mean_lifetime_s
    );
    let t0 = Instant::now();
    match figures::macro_evals::admission_tables(&cfg) {
        Ok(tables) => {
            for t in &tables {
                println!("{}", t.render());
            }
            eprintln!("(admit took {:.1} s)", t0.elapsed().as_secs_f64());
            0
        }
        Err(e) => {
            eprintln!("admit: {e}");
            1
        }
    }
}

/// Map a real pipeline to its AOT artifact stage names.
fn artifact_stages(pipeline: &str) -> Option<Vec<String>> {
    let s = match pipeline {
        "img-to-img" => ["face_recognition", "fsrcnn_enhance"],
        "img-to-text" => ["vgg_features", "lstm_caption"],
        "text-to-img" => ["lstm_semantic", "dcgan_generate"],
        "text-to-text" => ["bert_summarize", "nmt_translate"],
        _ => return None,
    };
    Some(s.iter().map(|x| x.to_string()).collect())
}

fn artifact_input_width(stage: &str) -> usize {
    match stage {
        "bert_summarize" => 768,
        "lstm_semantic" => 384,
        "fsrcnn_enhance" => 256,
        _ => 512,
    }
}

fn cmd_serve(args: &[String]) -> i32 {
    let o = opts(args);
    let name = o.get("pipeline").map(String::as_str).unwrap_or("img-to-text");
    let Some(stages) = artifact_stages(name) else {
        eprintln!("--pipeline must be one of the four real benchmarks for serving");
        return 2;
    };
    let batch: usize = o.get("batch").and_then(|b| b.parse().ok()).unwrap_or(8);
    let rate: f64 = o.get("rate").and_then(|r| r.parse().ok()).unwrap_or(30.0);
    let queries: usize = o.get("queries").and_then(|q| q.parse().ok()).unwrap_or(200);
    let artifacts =
        PathBuf::from(o.get("artifacts").map(String::as_str).unwrap_or("artifacts"));

    eprintln!("compiling {} AOT artifacts via PJRT...", stages.len());
    let backend = match PjrtBackend::new(artifacts, &stages, batch) {
        Ok(b) => Arc::new(b),
        Err(e) => {
            eprintln!("backend: {e}\nhint: run `make artifacts` first");
            return 1;
        }
    };
    let d_in = artifact_input_width(&stages[0]);
    let c = Coordinator::launch(
        CoordinatorConfig {
            stages: stages.clone(),
            instances: vec![1; stages.len()],
            batch,
            max_wait: Duration::from_millis(20),
        },
        backend,
    );

    eprintln!("serving {queries} queries at {rate} qps (Poisson, open loop)...");
    let mut arrivals = PoissonArrivals::new(rate, 7).times_until(queries as f64 / rate * 4.0 + 5.0);
    arrivals.truncate(queries);
    let t0 = Instant::now();
    let mut sent = 0;
    let mut received = 0;
    while received < arrivals.len() {
        while sent < arrivals.len() && t0.elapsed().as_secs_f64() >= arrivals[sent] {
            c.submit(vec![0.1; d_in]);
            sent += 1;
        }
        while let Some(_comp) = c.recv_timeout(Duration::from_millis(1)) {
            received += 1;
        }
    }
    let hist = c.histogram();
    println!("== serve report ({name}, batch {batch}, {rate} qps offered) ==");
    println!("  completed : {}", hist.count());
    println!("  throughput: {} qps", fnum(c.qps()));
    println!("  p50       : {:.1} ms", hist.p50() * 1e3);
    println!("  p95       : {:.1} ms", hist.p95() * 1e3);
    println!("  p99       : {:.1} ms", hist.p99() * 1e3);
    println!("  max       : {:.1} ms", hist.max() * 1e3);
    c.shutdown();
    0
}

fn cmd_reproduce(args: &[String]) -> i32 {
    let o = opts(args);
    let out = PathBuf::from(o.get("out").map(String::as_str).unwrap_or("results"));
    let exp = o.get("exp").map(String::as_str).unwrap_or("all");
    let list: Vec<&str> = if exp == "all" {
        figures::ALL_EXPERIMENTS.to_vec()
    } else {
        exp.split(',').collect()
    };
    for e in list {
        eprintln!("--- reproducing {e} ---");
        let t0 = Instant::now();
        if let Err(msg) = figures::run_and_save(e, &out) {
            eprintln!("{e}: {msg}");
            return 1;
        }
        eprintln!("    ({e} took {:.1} s)", t0.elapsed().as_secs_f64());
    }
    0
}
