//! Multi-pipeline co-location engine: several tenants — each a
//! (pipeline, deployment, arrival process) triple — share one cluster's
//! GPUs and PCIe bus inside a single merged discrete-event simulation.
//!
//! This is the measurement substrate for the paper's cluster-level
//! claims (Case 1 peak load under co-location, Case 2 diurnal resource
//! savings, §VIII-C): cross-pipeline global-memory-bandwidth contention
//! falls out of the shared per-GPU [`GpuLedger`]s (demand sums
//! accumulate in cluster-global instance-id order, preserving the
//! engine's floating-point determinism contract), and PCIe streams of
//! all tenants contend on one [`PcieBus`].
//!
//! Degenerate-equivalence contract: a [`ClusterSim`] with exactly one
//! tenant whose arrivals are [`ArrivalProcess::Constant`] replays the
//! event trajectory of [`Simulator::run`] operation-for-operation —
//! same arrival stream (tenant 0 seeds from `opts.seed` directly), same
//! event insertion order, same contention sums — so its `SimReport` is
//! bit-identical. `tests/golden_engine.rs` pins this.
//!
//! The event loop deliberately mirrors (rather than calls) the
//! single-tenant engine: the hot path stays free of tenant indirection
//! for the thousands of solo-pipeline sweeps the figures run, and the
//! degenerate golden test is what keeps the two copies in lock-step —
//! any behavioral change to `Simulator::run` that is not mirrored here
//! fails that suite immediately.

use std::collections::{BinaryHeap, VecDeque};

use crate::comm::hop_cost;
use crate::config::ClusterSpec;
use crate::metrics::LatencyHistogram;
use crate::suite::workload::{ArrivalProcess, ArrivalStream};
use crate::suite::Pipeline;

use super::cost::CostModel;
use super::engine::{route_by, Deployment, Event, GpuLedger, SimOptions, SimReport, TimeBreakdown};
use super::gpu::SimGpu;
use super::pcie::PcieBus;

/// One co-located pipeline: its deployment on the shared cluster and
/// its offered-load model.
#[derive(Debug, Clone)]
pub struct TenantSpec<'a> {
    pub pipeline: &'a Pipeline,
    pub deployment: &'a Deployment,
    pub arrivals: ArrivalProcess,
}

/// Mix the base seed with the tenant index so co-located arrival
/// streams decorrelate while tenant 0 keeps the base seed exactly (the
/// degenerate-equivalence contract).
#[inline]
fn tenant_seed(base: u64, tn: usize) -> u64 {
    crate::util::rng::mix_seed(base, tn as u64)
}

/// Multi-tenant event payloads. Request ids are tenant-local handles
/// into that tenant's arrival-time arena; instance ids are
/// cluster-global.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Ev {
    Arrival { tn: u32, rid: u32 },
    ExecDone { inst: usize },
    BusRelease,
    Deliver { target: usize, rid: u32 },
    Complete { tn: u32, rid: u32 },
}

/// Per-instance runtime state (the engine's `Inst` plus tenant wiring).
struct Inst {
    tn: usize,
    stage: usize,
    /// Global stage index (`stage_base[tn] + stage`) into the flat
    /// per-(tenant, stage) arenas — routing lists, round-robin
    /// counters, and exec accumulators all index by this.
    gstage: usize,
    gpu: usize,
    /// Whether `stage` is the tenant pipeline's final stage.
    last_stage: bool,
    queue: VecDeque<(u32, f64)>, // (rid, ready time)
    busy: bool,
    exec_rid: u32,
    cost: super::cost::InstanceCost,
    in_bytes_batch: f64,
    out_bytes_batch: f64,
    /// `mem_bytes_per_query * batch`, frozen — dynamic KV-cache bytes
    /// held on the GPU while a request executes (0 ⇒ no KV gating).
    kv_bytes_batch: f64,
    /// Tenant batch size as f64 (query-weighting of breakdown terms).
    batch_f: f64,
}

/// The co-location engine. Build with [`ClusterSim::new`], run with
/// [`ClusterSim::run`] — one [`SimReport`] per tenant, in input order.
pub struct ClusterSim<'a> {
    cluster: &'a ClusterSpec,
    tenants: Vec<TenantSpec<'a>>,
    opts: SimOptions,
}

impl<'a> ClusterSim<'a> {
    pub fn new(
        cluster: &'a ClusterSpec,
        tenants: Vec<TenantSpec<'a>>,
        opts: SimOptions,
    ) -> Self {
        assert!(!tenants.is_empty(), "cluster sim needs at least one tenant");
        ClusterSim { cluster, tenants, opts }
    }

    /// Statically validate the merged deployment: every tenant's
    /// instances must be admitted on the *shared* GPU states (Σ SM
    /// quotas across tenants ≤ 100% per device, shared MPS context and
    /// memory ledgers). Same-named stages share model weights across
    /// tenants, exactly as same-stage instances do within one (§VII-D).
    pub fn admit(&self) -> Result<Vec<SimGpu>, String> {
        let mut gpus: Vec<SimGpu> = (0..self.cluster.num_gpus)
            .map(|g| SimGpu::new(self.cluster.gpu_at(g).clone()))
            .collect();
        for (tn, t) in self.tenants.iter().enumerate() {
            super::engine::admit_deployment(t.pipeline, t.deployment, &mut gpus)
                .map_err(|e| format!("tenant {tn} ({}): {e}", t.pipeline.name))?;
        }
        Ok(gpus)
    }

    /// Run the merged simulation. Each tenant injects
    /// `opts.queries` queries (requests of its own batch size); the
    /// report order matches the tenant order passed to [`new`](Self::new).
    pub fn run(&self) -> Result<Vec<SimReport>, String> {
        let admitted = self.admit()?;
        // Dynamic KV-cache budget per GPU: whatever static admission
        // (model weights + activations) left free. Mirrors the
        // single-tenant engine so the degenerate case stays
        // bit-identical.
        let kv_cap: Vec<f64> = admitted.iter().map(|g| g.mem_free()).collect();
        let cost = CostModel::new(self.cluster.gpu.clone());
        // per-GPU cost models only when a class departs from the base
        // spec — mirrors the single-tenant engine's heterogeneity hook
        let model_at = |g: usize| -> CostModel {
            let spec = self.cluster.gpu_at(g);
            if *spec == self.cluster.gpu {
                cost.clone()
            } else {
                CostModel::new(spec.clone())
            }
        };
        let mut bus = PcieBus::new(self.cluster.pcie.clone());
        let ipc = &self.cluster.ipc;
        let n_tenants = self.tenants.len();

        // per-tenant request bookkeeping
        let mut batches: Vec<usize> = Vec::with_capacity(n_tenants);
        let mut n_requests: Vec<usize> = Vec::with_capacity(n_tenants);
        for t in &self.tenants {
            let batch = t.deployment.batch.max(1) as usize;
            batches.push(batch);
            n_requests.push(self.opts.queries.div_ceil(batch));
        }

        // flat per-(tenant, stage) arenas: global stage index
        // `gs = stage_base[tn] + stage`, and
        // `stage_insts[insts_off[gs]..insts_off[gs + 1]]` lists that
        // stage's instances in placement order — identical content and
        // order to the former `Vec<Vec<Vec<usize>>>` routing map, with
        // the nested allocations and double pointer-chase removed from
        // the hot path
        let mut stage_base: Vec<usize> = Vec::with_capacity(n_tenants);
        let mut total_stages = 0usize;
        for t in &self.tenants {
            stage_base.push(total_stages);
            total_stages += t.pipeline.n_stages();
        }
        let total_insts: usize = self
            .tenants
            .iter()
            .map(|t| t.deployment.placements.len())
            .sum();
        let mut insts_off = vec![0usize; total_stages + 1];
        for (tn, t) in self.tenants.iter().enumerate() {
            for p in &t.deployment.placements {
                insts_off[stage_base[tn] + p.stage] += 1;
            }
        }
        // exclusive prefix sum: counts -> offsets, sentinel at the end
        let mut acc = 0usize;
        for slot in insts_off.iter_mut() {
            let count = *slot;
            *slot = acc;
            acc += count;
        }
        let mut stage_insts = vec![0usize; total_insts];
        let mut fill_cursor = insts_off.clone();

        // freeze per-instance cost quantities; instance ids are global,
        // assigned in (tenant, placement) order
        let mut instances: Vec<Inst> = Vec::with_capacity(total_insts);
        for (tn, t) in self.tenants.iter().enumerate() {
            let n_stages = t.pipeline.n_stages();
            let batch = batches[tn] as u32;
            for p in &t.deployment.placements {
                let stage = &t.pipeline.stages[p.stage];
                let gs = stage_base[tn] + p.stage;
                stage_insts[fill_cursor[gs]] = instances.len();
                fill_cursor[gs] += 1;
                instances.push(Inst {
                    tn,
                    stage: p.stage,
                    gstage: gs,
                    gpu: p.gpu,
                    last_stage: p.stage + 1 == n_stages,
                    queue: VecDeque::with_capacity(n_requests[tn].clamp(16, 64)),
                    busy: false,
                    exec_rid: 0,
                    cost: model_at(p.gpu).instance_cost_scaled(
                        stage,
                        batch,
                        p.sm_frac,
                        self.cluster.scale_at(p.gpu),
                    ),
                    in_bytes_batch: stage.in_bytes_per_query * batch as f64,
                    out_bytes_batch: stage.out_bytes_per_query * batch as f64,
                    kv_bytes_batch: stage.mem_bytes_per_query * batch as f64,
                    batch_f: batch as f64,
                });
            }
        }
        let mut ledgers: Vec<GpuLedger> = (0..self.cluster.num_gpus)
            .map(|_| GpuLedger::default())
            .collect();
        // dynamic KV-cache residency ledger (bytes) per GPU — shared
        // across tenants, exactly like SM time on the GpuLedger
        let mut kv_used = vec![0.0f64; self.cluster.num_gpus];
        let mut kv_peak = vec![0.0f64; self.cluster.num_gpus];

        // lazy open-loop arrivals: one pending Arrival event per tenant
        let mut streams: Vec<ArrivalStream> = self
            .tenants
            .iter()
            .enumerate()
            .map(|(tn, t)| {
                t.arrivals
                    .request_stream(t.deployment.batch, tenant_seed(self.opts.seed, tn))
            })
            .collect();
        let mut arrivals: Vec<Vec<f64>> = n_requests
            .iter()
            .map(|&n| Vec::with_capacity(n))
            .collect();

        // heap sized from the trace shape: ≤2 in-flight events per
        // instance (exec + hop), one pending arrival per tenant, plus
        // bus releases bounded by concurrent transfers
        let mut heap: BinaryHeap<Event<Ev>> =
            BinaryHeap::with_capacity(instances.len() * 4 + n_tenants * 2 + 16);
        let mut seq = 0u64;
        let push = |heap: &mut BinaryHeap<Event<Ev>>, seq: &mut u64, t: f64, ev: Ev| {
            *seq += 1;
            heap.push(Event { t, seq: *seq, ev });
        };
        for tn in 0..n_tenants {
            if n_requests[tn] > 0 {
                let t = streams[tn].next_time();
                arrivals[tn].push(t);
                push(&mut heap, &mut seq, t, Ev::Arrival { tn: tn as u32, rid: 0 });
            }
        }

        let mut hists: Vec<LatencyHistogram> =
            (0..n_tenants).map(|_| LatencyHistogram::new()).collect();
        let mut breakdowns: Vec<TimeBreakdown> = vec![TimeBreakdown::default(); n_tenants];
        // flat per-(tenant, stage) accumulators, indexed by gstage
        let mut stage_exec_sum: Vec<f64> = vec![0.0f64; total_stages];
        let mut stage_exec_n: Vec<u64> = vec![0u64; total_stages];
        let warmups: Vec<u64> = n_requests
            .iter()
            .map(|&n| (n as f64 * self.opts.warmup_frac) as u64)
            .collect();
        let mut completed = vec![0u64; n_tenants];
        let mut first_counted_t = vec![f64::NAN; n_tenants];
        // per-tenant last completion: a fast tenant's throughput must
        // not be diluted by a slow neighbor's tail. In the degenerate
        // single-tenant case this equals the engine's global last event
        // time (the final pop is always the last Complete), preserving
        // bit-equality.
        let mut last_complete_t = vec![0.0f64; n_tenants];
        let mut rr_counters: Vec<usize> = vec![0usize; total_stages];

        // issue a request on `inst_id` if it is idle with queued work —
        // same float-op sequence as the single-tenant engine's try_issue
        #[allow(clippy::too_many_arguments)]
        fn try_issue(
            inst_id: usize,
            now: f64,
            instances: &mut [Inst],
            ledgers: &mut [GpuLedger],
            bus: &mut PcieBus,
            heap: &mut BinaryHeap<Event<Ev>>,
            seq: &mut u64,
            breakdowns: &mut [TimeBreakdown],
            stage_exec_sum: &mut [f64],
            stage_exec_n: &mut [u64],
            kv_used: &mut [f64],
            kv_peak: &mut [f64],
            kv_cap: &[f64],
        ) {
            let push = |heap: &mut BinaryHeap<Event<Ev>>, seq: &mut u64, t: f64, ev: Ev| {
                *seq += 1;
                heap.push(Event { t, seq: *seq, ev });
            };
            let inst = &mut instances[inst_id];
            if inst.busy || inst.queue.is_empty() {
                return;
            }
            // KV admission gate: a stage with per-query KV footprint
            // only issues when the batch's bytes fit in the GPU's free
            // memory; otherwise the request stays queued (stall accrues
            // as queue_s) until a completion releases bytes.
            if inst.kv_bytes_batch > 0.0
                && kv_used[inst.gpu] + inst.kv_bytes_batch > kv_cap[inst.gpu]
            {
                return;
            }
            let (rid, ready) = inst.queue.pop_front().unwrap();
            let tn = inst.tn;
            let batch_f = inst.batch_f;
            breakdowns[tn].queue_s += (now - ready) * batch_f;
            inst.busy = true;
            inst.exec_rid = rid;

            let gpu = inst.gpu;
            let gstage = inst.gstage;
            let stage_idx = inst.stage;
            let icost = inst.cost;
            let in_bytes = inst.in_bytes_batch;
            if inst.kv_bytes_batch > 0.0 {
                kv_used[gpu] += inst.kv_bytes_batch;
                if kv_used[gpu] > kv_peak[gpu] {
                    kv_peak[gpu] = kv_used[gpu];
                }
            }

            // stage-0 ingress crosses PCIe before the kernel runs
            let mut start = now;
            if stage_idx == 0 {
                let up = bus.begin_transfer(in_bytes);
                push(heap, seq, now + up, Ev::BusRelease);
                breakdowns[tn].upload_s += up * batch_f;
                start += up;
            }
            let others = ledgers[gpu].kernel_start(inst_id, icost.bw_demand);
            let dur = icost.duration_contended(others);
            stage_exec_sum[gstage] += dur;
            stage_exec_n[gstage] += 1;
            breakdowns[tn].exec_s += dur * batch_f;
            push(heap, seq, start + dur, Ev::ExecDone { inst: inst_id });
        }

        while let Some(Event { t: now, ev, .. }) = heap.pop() {
            match ev {
                Ev::Arrival { tn, rid } => {
                    let tn = tn as usize;
                    // keep this tenant's open loop primed
                    let next_rid = rid as usize + 1;
                    if next_rid < n_requests[tn] {
                        let t = streams[tn].next_time();
                        arrivals[tn].push(t);
                        push(
                            &mut heap,
                            &mut seq,
                            t,
                            Ev::Arrival { tn: tn as u32, rid: next_rid as u32 },
                        );
                    }
                    let gs = stage_base[tn];
                    let target = route_by(
                        &stage_insts[insts_off[gs]..insts_off[gs + 1]],
                        None,
                        &mut rr_counters[gs],
                        |i| instances[i].queue.len() + instances[i].busy as usize,
                        |i| instances[i].gpu,
                    );
                    instances[target].queue.push_back((rid, now));
                    try_issue(
                        target, now, &mut instances, &mut ledgers, &mut bus,
                        &mut heap, &mut seq, &mut breakdowns,
                        &mut stage_exec_sum, &mut stage_exec_n,
                        &mut kv_used, &mut kv_peak, &kv_cap,
                    );
                }
                Ev::BusRelease => bus.end_transfer(),
                Ev::ExecDone { inst: inst_id } => {
                    let rid = instances[inst_id].exec_rid;
                    let tn = instances[inst_id].tn;
                    let gpu = instances[inst_id].gpu;
                    let out_bytes = instances[inst_id].out_bytes_batch;
                    let batch_f = instances[inst_id].batch_f;
                    let is_last = instances[inst_id].last_stage;
                    let kv_bytes = instances[inst_id].kv_bytes_batch;
                    ledgers[gpu].kernel_end(inst_id);
                    instances[inst_id].busy = false;
                    if kv_bytes > 0.0 {
                        kv_used[gpu] -= kv_bytes;
                    }
                    if is_last {
                        // egress download crosses PCIe
                        let dl = bus.begin_transfer(out_bytes);
                        push(&mut heap, &mut seq, now + dl, Ev::BusRelease);
                        breakdowns[tn].download_s += dl * batch_f;
                        push(
                            &mut heap,
                            &mut seq,
                            now + dl,
                            Ev::Complete { tn: tn as u32, rid },
                        );
                    } else {
                        // next stage of the same tenant is the next
                        // global stage index
                        let gs = instances[inst_id].gstage + 1;
                        let target = route_by(
                            &stage_insts[insts_off[gs]..insts_off[gs + 1]],
                            Some(gpu),
                            &mut rr_counters[gs],
                            |i| instances[i].queue.len() + instances[i].busy as usize,
                            |i| instances[i].gpu,
                        );
                        let same_gpu = instances[target].gpu == gpu;
                        let hop = hop_cost(
                            self.tenants[tn].deployment.comm,
                            same_gpu,
                            out_bytes,
                            &mut bus,
                            ipc,
                        );
                        if hop.uses_bus {
                            push(&mut heap, &mut seq, now + hop.duration_s, Ev::BusRelease);
                        }
                        breakdowns[tn].hop_s += hop.duration_s * batch_f;
                        push(
                            &mut heap, &mut seq, now + hop.duration_s,
                            Ev::Deliver { target, rid },
                        );
                    }
                    // instance freed: maybe issue the next request
                    try_issue(
                        inst_id, now, &mut instances, &mut ledgers, &mut bus,
                        &mut heap, &mut seq, &mut breakdowns,
                        &mut stage_exec_sum, &mut stage_exec_n,
                        &mut kv_used, &mut kv_peak, &kv_cap,
                    );
                    // KV bytes were released: wake co-resident
                    // instances (any tenant) stalled on this GPU's
                    // memory, in instance-id order — deterministic and
                    // identical to the single-tenant engine's sweep
                    if kv_bytes > 0.0 {
                        for i in 0..instances.len() {
                            if instances[i].gpu == gpu && i != inst_id {
                                try_issue(
                                    i, now, &mut instances, &mut ledgers, &mut bus,
                                    &mut heap, &mut seq, &mut breakdowns,
                                    &mut stage_exec_sum, &mut stage_exec_n,
                                    &mut kv_used, &mut kv_peak, &kv_cap,
                                );
                            }
                        }
                    }
                }
                Ev::Deliver { target, rid } => {
                    instances[target].queue.push_back((rid, now));
                    try_issue(
                        target, now, &mut instances, &mut ledgers, &mut bus,
                        &mut heap, &mut seq, &mut breakdowns,
                        &mut stage_exec_sum, &mut stage_exec_n,
                        &mut kv_used, &mut kv_peak, &kv_cap,
                    );
                }
                Ev::Complete { tn, rid } => {
                    let tn = tn as usize;
                    completed[tn] += 1;
                    last_complete_t[tn] = now;
                    if completed[tn] > warmups[tn] {
                        if first_counted_t[tn].is_nan() {
                            first_counted_t[tn] = now;
                        }
                        hists[tn].record(now - arrivals[tn][rid as usize]);
                    }
                }
            }
        }

        // one report per tenant, each spanning to its own last completion
        let mut reports = Vec::with_capacity(n_tenants);
        for tn in 0..n_tenants {
            let span = (last_complete_t[tn] - first_counted_t[tn]).max(1e-9);
            let counted = completed[tn].saturating_sub(warmups[tn]);
            let base = stage_base[tn];
            let n_stages = self.tenants[tn].pipeline.n_stages();
            reports.push(SimReport {
                achieved_qps: counted as f64 * batches[tn] as f64 / span,
                offered_qps: self.tenants[tn].arrivals.mean_qps(),
                completed: completed[tn],
                hist: std::mem::take(&mut hists[tn]),
                breakdown: breakdowns[tn],
                stage_exec_mean_s: stage_exec_sum[base..base + n_stages]
                    .iter()
                    .zip(&stage_exec_n[base..base + n_stages])
                    .map(|(s, &n)| if n == 0 { 0.0 } else { s / n as f64 })
                    .collect(),
                // KV residency is a shared-GPU phenomenon: every
                // tenant's report carries the same cluster-wide
                // per-GPU peak vector
                kv_peak_bytes: kv_peak.clone(),
            });
        }
        Ok(reports)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommMode;
    use crate::sim::{InstancePlacement, Simulator};
    use crate::suite::real;
    use crate::suite::workload::DiurnalPattern;

    fn colocated(batch: u32) -> Deployment {
        Deployment {
            placements: vec![
                InstancePlacement { stage: 0, gpu: 0, sm_frac: 0.5 },
                InstancePlacement { stage: 1, gpu: 0, sm_frac: 0.5 },
            ],
            batch,
            comm: CommMode::GlobalIpc,
        }
    }

    fn split(batch: u32, g0: usize, g1: usize, q: f64) -> Deployment {
        Deployment {
            placements: vec![
                InstancePlacement { stage: 0, gpu: g0, sm_frac: q },
                InstancePlacement { stage: 1, gpu: g1, sm_frac: q },
            ],
            batch,
            comm: CommMode::GlobalIpc,
        }
    }

    #[test]
    fn degenerate_single_tenant_matches_engine_smoke() {
        // the exhaustive version lives in tests/golden_engine.rs
        let p = real::img_to_text();
        let c = crate::config::ClusterSpec::two_2080ti();
        let d = colocated(16);
        let opts = SimOptions { queries: 600, ..Default::default() };
        let single = Simulator::new(&p, &c, &d, opts.clone()).run(80.0).unwrap();
        let multi = ClusterSim::new(
            &c,
            vec![TenantSpec {
                pipeline: &p,
                deployment: &d,
                arrivals: ArrivalProcess::constant(80.0),
            }],
            opts,
        )
        .run()
        .unwrap();
        assert_eq!(multi.len(), 1);
        assert_eq!(multi[0].completed, single.completed);
        assert_eq!(multi[0].p99().to_bits(), single.p99().to_bits());
        assert_eq!(
            multi[0].breakdown.exec_s.to_bits(),
            single.breakdown.exec_s.to_bits()
        );
        assert_eq!(
            multi[0].achieved_qps.to_bits(),
            single.achieved_qps.to_bits()
        );
    }

    #[test]
    fn degenerate_llm_tenant_matches_engine_with_kv() {
        // KV gating active: the mirrored ledger must keep the
        // degenerate single-tenant case bit-identical, including the
        // per-GPU peak residency vector
        let p = crate::llm::pipeline(&crate::llm::LlmParams::default());
        let c = crate::config::ClusterSpec::two_2080ti();
        let d = colocated(16);
        let opts = SimOptions { queries: 400, ..Default::default() };
        let single = Simulator::new(&p, &c, &d, opts.clone()).run(40.0).unwrap();
        let multi = ClusterSim::new(
            &c,
            vec![TenantSpec {
                pipeline: &p,
                deployment: &d,
                arrivals: ArrivalProcess::constant(40.0),
            }],
            opts,
        )
        .run()
        .unwrap();
        assert_eq!(multi[0].completed, single.completed);
        assert_eq!(multi[0].p99().to_bits(), single.p99().to_bits());
        assert_eq!(
            multi[0].breakdown.queue_s.to_bits(),
            single.breakdown.queue_s.to_bits()
        );
        assert_eq!(multi[0].kv_peak_bytes.len(), single.kv_peak_bytes.len());
        for (m, s) in multi[0].kv_peak_bytes.iter().zip(&single.kv_peak_bytes) {
            assert_eq!(m.to_bits(), s.to_bits());
        }
        assert!(multi[0].kv_peak_bytes[0] > 0.0);
    }

    #[test]
    fn colocated_llm_and_vision_track_shared_kv_peaks() {
        // LLM on gpu 0, vision neighbor on gpu 1: KV peaks are a
        // cluster-wide property, identical in every tenant's report,
        // nonzero only where KV-bearing stages ran, and bounded by
        // the GPU's physical memory
        let llm = crate::llm::pipeline(&crate::llm::LlmParams::default());
        let vis = real::img_to_text();
        let c = crate::config::ClusterSpec::two_2080ti();
        let dl = split(16, 0, 0, 0.45);
        let dv = split(16, 1, 1, 0.45);
        let reps = ClusterSim::new(
            &c,
            vec![
                TenantSpec {
                    pipeline: &llm,
                    deployment: &dl,
                    arrivals: ArrivalProcess::constant(30.0),
                },
                TenantSpec {
                    pipeline: &vis,
                    deployment: &dv,
                    arrivals: ArrivalProcess::constant(60.0),
                },
            ],
            SimOptions { queries: 320, ..Default::default() },
        )
        .run()
        .unwrap();
        assert_eq!(reps.len(), 2);
        for (a, b) in reps[0].kv_peak_bytes.iter().zip(&reps[1].kv_peak_bytes) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let prefill_batch_kv = llm.stages[0].mem_bytes_per_query * 16.0;
        assert!(
            reps[0].kv_peak_bytes[0] >= prefill_batch_kv,
            "gpu0 peak {} below one prefill batch {}",
            reps[0].kv_peak_bytes[0],
            prefill_batch_kv
        );
        assert!(reps[0].kv_peak_bytes[0] <= c.gpu_at(0).mem_bytes as f64);
        assert_eq!(reps[0].kv_peak_bytes[1], 0.0);
        assert_eq!(reps[1].completed, (320 / 16) as u64);
    }

    #[test]
    fn admit_rejects_cross_tenant_oversubscription() {
        let p1 = real::img_to_text();
        let p2 = real::text_to_text();
        let c = crate::config::ClusterSpec::two_2080ti();
        let d1 = split(16, 0, 1, 0.6);
        let d2 = split(16, 0, 1, 0.6); // 0.6 + 0.6 > 1.0 on both GPUs
        let sim = ClusterSim::new(
            &c,
            vec![
                TenantSpec {
                    pipeline: &p1,
                    deployment: &d1,
                    arrivals: ArrivalProcess::constant(50.0),
                },
                TenantSpec {
                    pipeline: &p2,
                    deployment: &d2,
                    arrivals: ArrivalProcess::constant(50.0),
                },
            ],
            SimOptions::default(),
        );
        assert!(sim.admit().is_err());
    }

    #[test]
    fn co_located_tenant_inflates_neighbor_latency() {
        // cross-pipeline contention must be visible: tenant A alone vs
        // tenant A sharing its GPUs with a busy tenant B
        let pa = real::img_to_img();
        let pb = real::text_to_text();
        let c = crate::config::ClusterSpec::two_2080ti();
        let da = split(16, 0, 1, 0.45);
        let db = split(16, 0, 1, 0.45);
        let opts = SimOptions { queries: 1_200, ..Default::default() };
        let alone = ClusterSim::new(
            &c,
            vec![TenantSpec {
                pipeline: &pa,
                deployment: &da,
                arrivals: ArrivalProcess::constant(60.0),
            }],
            opts.clone(),
        )
        .run()
        .unwrap();
        let shared = ClusterSim::new(
            &c,
            vec![
                TenantSpec {
                    pipeline: &pa,
                    deployment: &da,
                    arrivals: ArrivalProcess::constant(60.0),
                },
                TenantSpec {
                    pipeline: &pb,
                    deployment: &db,
                    arrivals: ArrivalProcess::constant(120.0),
                },
            ],
            opts,
        )
        .run()
        .unwrap();
        assert!(
            shared[0].hist.mean() > alone[0].hist.mean(),
            "co-location must cost something: shared {} vs alone {}",
            shared[0].hist.mean(),
            alone[0].hist.mean()
        );
        // and the neighbor's report is independent bookkeeping
        assert_eq!(shared[1].completed, (1_200 / 16) as u64);
    }

    #[test]
    fn diurnal_tenant_runs_and_completes() {
        let p = real::img_to_text();
        let c = crate::config::ClusterSpec::two_2080ti();
        let d = colocated(16);
        // compressed day so the query budget sees the rate actually move
        let pattern = DiurnalPattern {
            peak_qps: 120.0,
            trough_frac: 0.3,
            period_s: 10.0,
        };
        let opts = SimOptions { queries: 1_600, ..Default::default() };
        let reps = ClusterSim::new(
            &c,
            vec![TenantSpec {
                pipeline: &p,
                deployment: &d,
                arrivals: ArrivalProcess::diurnal(pattern.clone()),
            }],
            opts.clone(),
        )
        .run()
        .unwrap();
        assert_eq!(reps[0].completed, (1_600 / 16) as u64);
        assert!(reps[0].p99() > 0.0 && reps[0].p99().is_finite());
        assert!((reps[0].offered_qps - pattern.mean_qps()).abs() < 1e-9);
        // deterministic per seed
        let again = ClusterSim::new(
            &c,
            vec![TenantSpec {
                pipeline: &p,
                deployment: &d,
                arrivals: ArrivalProcess::diurnal(pattern),
            }],
            opts,
        )
        .run()
        .unwrap();
        assert_eq!(reps[0].p99().to_bits(), again[0].p99().to_bits());
    }
}
