//! Spatial-multitasking GPU simulation substrate: cost model, GPU and
//! PCIe resource state, and the discrete-event pipeline engine.
//!
//! This is the hardware substitution for the paper's 2×2080Ti / DGX-2
//! testbeds (see DESIGN.md §2): the allocator and coordinator interact
//! with it through exactly the quantities the paper's runtime sees
//! (durations, bandwidth demands, memory footprints, PCIe transfers).

pub mod cluster;
pub mod cost;
pub mod engine;
pub mod gpu;
pub mod pcie;

pub use cluster::{ClusterSim, TenantSpec};
pub use cost::{CostModel, InstanceCost};
pub use engine::{
    Deployment, InstancePlacement, SimOptions, SimReport, Simulator, TimeBreakdown,
};
pub use gpu::{AdmitError, SimGpu};
pub use pcie::PcieBus;
