//! Per-GPU runtime state for the spatial-multitasking model: SM quota
//! ledger, MPS context count, global-memory capacity ledger (with
//! same-stage model sharing, §VII-D), and the set of running kernels'
//! bandwidth demands (the contention input to `CostModel`).

use std::collections::{BTreeMap, HashMap};

use crate::config::GpuSpec;

/// Static admission error for a deployment.
#[derive(Debug, Clone, PartialEq)]
pub enum AdmitError {
    /// Σ SM quotas would exceed 100% of the device.
    SmOversubscribed { have: f64, want: f64 },
    /// Would exceed the MPS client-context limit (48 on Volta).
    ContextLimit { have: u32, limit: u32 },
    /// Global-memory capacity exceeded (F in Table II).
    MemoryExceeded { have_bytes: f64, cap_bytes: f64 },
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::SmOversubscribed { have, want } => {
                write!(f, "SM oversubscribed: {have:.2} + {want:.2} > 1.0")
            }
            AdmitError::ContextLimit { have, limit } => {
                write!(f, "MPS context limit: {have} >= {limit}")
            }
            AdmitError::MemoryExceeded { have_bytes, cap_bytes } => {
                write!(f, "global memory exceeded: {have_bytes:.3e} > {cap_bytes:.3e} B")
            }
        }
    }
}

/// One simulated GPU.
#[derive(Debug, Clone)]
pub struct SimGpu {
    pub spec: GpuSpec,
    /// Σ SM fractions of admitted instances.
    sm_allocated: f64,
    /// Number of admitted instances (MPS client contexts).
    contexts: u32,
    /// Memory charged per stage name: (model bytes charged once, per-
    /// instance activation bytes × instance count).
    mem_by_stage: HashMap<String, (f64, f64)>,
    /// Bandwidth demand (bytes/s) of each currently-running kernel,
    /// keyed by instance id. A BTreeMap so demand sums accumulate in
    /// instance-id order — floating-point summation order is part of
    /// the engine's determinism contract (the optimized engine must
    /// reproduce these sums bit-for-bit).
    running: BTreeMap<usize, f64>,
}

impl SimGpu {
    pub fn new(spec: GpuSpec) -> Self {
        SimGpu {
            spec,
            sm_allocated: 0.0,
            contexts: 0,
            mem_by_stage: HashMap::new(),
            running: BTreeMap::new(),
        }
    }

    /// Try to admit one instance of `stage_name` with the given SM quota
    /// and memory needs. Same-stage instances on the same GPU share the
    /// model weights (charged once), per §VII-D.
    pub fn admit(
        &mut self,
        stage_name: &str,
        sm_frac: f64,
        model_bytes: f64,
        act_bytes: f64,
    ) -> Result<(), AdmitError> {
        if self.sm_allocated + sm_frac > 1.0 + 1e-9 {
            return Err(AdmitError::SmOversubscribed {
                have: self.sm_allocated,
                want: sm_frac,
            });
        }
        if self.contexts >= self.spec.mps_contexts {
            return Err(AdmitError::ContextLimit {
                have: self.contexts,
                limit: self.spec.mps_contexts,
            });
        }
        let new_model = if self.mem_by_stage.contains_key(stage_name) {
            0.0
        } else {
            model_bytes
        };
        let want = self.mem_used() + new_model + act_bytes;
        if want > self.spec.mem_bytes as f64 {
            return Err(AdmitError::MemoryExceeded {
                have_bytes: want,
                cap_bytes: self.spec.mem_bytes as f64,
            });
        }
        let entry = self
            .mem_by_stage
            .entry(stage_name.to_string())
            .or_insert((model_bytes, 0.0));
        entry.1 += act_bytes;
        self.sm_allocated += sm_frac;
        self.contexts += 1;
        Ok(())
    }

    /// Pre-commit capacity held by a co-located tenant (shared-cluster
    /// planning): shrinks the SM, context, and memory slack the regular
    /// [`admit`](Self::admit) checks see, without tying the charge to a
    /// stage name (no model sharing across the reservation boundary —
    /// conservative).
    pub fn reserve(&mut self, sm_frac: f64, mem_bytes: f64, contexts: u32) {
        self.sm_allocated += sm_frac;
        self.contexts += contexts;
        if mem_bytes > 0.0 {
            let entry = self
                .mem_by_stage
                .entry("__reserved__".to_string())
                .or_insert((0.0, 0.0));
            entry.1 += mem_bytes;
        }
    }

    /// Total global memory currently charged.
    pub fn mem_used(&self) -> f64 {
        self.mem_by_stage.values().map(|(m, a)| m + a).sum()
    }

    pub fn sm_allocated(&self) -> f64 {
        self.sm_allocated
    }

    pub fn contexts(&self) -> u32 {
        self.contexts
    }

    pub fn mem_free(&self) -> f64 {
        self.spec.mem_bytes as f64 - self.mem_used()
    }

    pub fn sm_free(&self) -> f64 {
        (1.0 - self.sm_allocated).max(0.0)
    }

    // ---- runtime kernel tracking (bandwidth contention) ----

    /// Register a kernel starting on instance `inst` with the given
    /// bandwidth demand; returns the Σ demand of the *other* kernels.
    pub fn kernel_start(&mut self, inst: usize, bw_demand: f64) -> f64 {
        let others: f64 = self.running.values().sum();
        self.running.insert(inst, bw_demand);
        others
    }

    pub fn kernel_end(&mut self, inst: usize) {
        self.running.remove(&inst);
    }

    /// Σ bandwidth demand of all running kernels.
    pub fn total_bw_demand(&self) -> f64 {
        self.running.values().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;

    fn gpu() -> SimGpu {
        SimGpu::new(GpuSpec::rtx2080ti())
    }

    #[test]
    fn admits_until_sm_full() {
        let mut g = gpu();
        for _ in 0..4 {
            g.admit("s", 0.25, 1e9, 1e8).unwrap();
        }
        let err = g.admit("s", 0.25, 1e9, 1e8).unwrap_err();
        assert!(matches!(err, AdmitError::SmOversubscribed { .. }));
    }

    #[test]
    fn model_shared_within_stage() {
        let mut g = gpu();
        g.admit("a", 0.1, 2e9, 1e8).unwrap();
        let one = g.mem_used();
        g.admit("a", 0.1, 2e9, 1e8).unwrap();
        // second instance adds only activations, not another model copy
        assert!((g.mem_used() - (one + 1e8)).abs() < 1.0);
        g.admit("b", 0.1, 2e9, 1e8).unwrap();
        assert!((g.mem_used() - (one + 1e8 + 2e9 + 1e8)).abs() < 1.0);
    }

    #[test]
    fn memory_capacity_enforced() {
        let mut g = gpu();
        // 11 GB card: a 9 GB model + 3 GB activations must not fit
        let err = g.admit("big", 0.1, 9.0e9, 3.0e9).unwrap_err();
        assert!(matches!(err, AdmitError::MemoryExceeded { .. }));
        // but 9 GB + 1 GB fits
        g.admit("big", 0.1, 9.0e9, 1.0e9).unwrap();
    }

    #[test]
    fn context_limit_48() {
        let mut g = gpu();
        for i in 0..48 {
            g.admit(&format!("s{i}"), 0.01, 1e6, 1e5).unwrap();
        }
        let err = g.admit("s48", 0.01, 1e6, 1e5).unwrap_err();
        assert!(matches!(err, AdmitError::ContextLimit { .. }));
    }

    #[test]
    fn kernel_tracking_sums_demands() {
        let mut g = gpu();
        assert_eq!(g.kernel_start(0, 100.0), 0.0);
        assert_eq!(g.kernel_start(1, 50.0), 100.0);
        assert_eq!(g.total_bw_demand(), 150.0);
        g.kernel_end(0);
        assert_eq!(g.total_bw_demand(), 50.0);
        g.kernel_end(1);
        assert_eq!(g.total_bw_demand(), 0.0);
    }
}
